#!/usr/bin/env bash
# Build release and record the DSE + simulator performance trajectory.
#
# Writes BENCH_dse.json at the repo root: per-case before/after medians of
# the DSE engines (reference recompute vs incremental), equality of their
# results, plus the warm-start timing column. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release

# DSE hot path: before/after comparison + JSON artifact at the repo root.
# (Absolute path: cargo runs bench binaries with cwd set to the package
# root, so a bare filename would land in rust/.)
cargo bench --bench dse_perf -- --compare --warm --json "$PWD/BENCH_dse.json"

# Simulator hot path (kept in the same report cadence; the full
# compare-mode run with its equivalence/acceptance assertions and the
# BENCH_sim.json artifact lives in scripts/bench_sim.sh).
cargo bench --bench sim_perf

echo
echo "BENCH_dse.json:"
cat BENCH_dse.json
