#!/usr/bin/env bash
# Build release and record the serving-path performance trajectory.
#
# Writes BENCH_serve.json at the repo root (next to BENCH_dse.json), two
# sweeps: (1) one open-loop Poisson load offered to engine pools of 1/2/4/8
# workers on the paced SimOnly engine — offered rate, achieved rps, p50/p99
# latency and queue depth per pool size, plus the workers=4 vs workers=1
# speedup the bench asserts on; (2) the dispatcher-saturation "front" sweep
# (near-zero engine time, 4 concurrent submitters) with the workers=8 vs
# workers=1 front speedup the bench also asserts on.
#
# Regression gate: when the repo has a *committed* BENCH_serve.json
# baseline (git show HEAD:BENCH_serve.json), achieved rps at any matching
# pool size dropping more than 20% below the baseline fails the run — or
# just warns when --advisory is passed (CI uses --advisory so quick-sweep
# jitter cannot hard-fail unrelated changes). Pass --quick for the small
# CI-cadence sweep. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

# (Absolute path: cargo runs bench binaries with cwd set to the package
# root, so a bare filename would land in rust/. The non-empty array also
# keeps `set -u` happy on pre-4.4 bash when no flags are given.)
ARGS=(--json "$PWD/BENCH_serve.json")
ADVISORY=0
for arg in "$@"; do
    case "$arg" in
        --quick) ARGS=(--quick "${ARGS[@]}") ;;
        --advisory) ADVISORY=1 ;;
        *) echo "unknown flag: $arg (known: --quick --advisory)" >&2; exit 2 ;;
    esac
done

cargo build --release

cargo bench --bench e2e_serve_bench -- "${ARGS[@]}"

echo
echo "BENCH_serve.json:"
cat BENCH_serve.json

# ---- telemetry overhead gate ------------------------------------------------
# The bench already hard-asserts span recording costs <2% at front
# saturation; re-check the recorded artifact here so a hand-edited or stale
# BENCH_serve.json cannot slip an overhead regression past review.
if command -v python3 >/dev/null 2>&1; then
    echo
    echo "== telemetry overhead gate (span recording on/off rps ratio >= 0.98) =="
    python3 - <<'PY'
import json, sys

with open("BENCH_serve.json") as f:
    doc = json.load(f)
tele = doc.get("telemetry")
if tele is None:
    print("  FAIL: BENCH_serve.json has no telemetry section")
    sys.exit(1)
ratio = tele["on_over_off_ratio"]
print(f"  off {tele['best_off_rps']:9.0f} rps -> on {tele['best_on_rps']:9.0f} rps "
      f"({ratio:5.3f}x, {tele['spans_recorded']} spans, best of {tele['rounds']} rounds)")
if ratio < 0.98:
    print(f"  FAIL: span recording costs more than 2% ({ratio:5.3f}x)")
    sys.exit(1)
print("  telemetry overhead within budget")
PY
else
    echo "telemetry overhead gate: python3 unavailable; skipped"
fi

# ---- regression gate against the committed baseline ------------------------
# Points are keyed (section, workers, requests, paced_batch_s): a baseline
# recorded with different sweep parameters (quick vs full, resized sweep)
# simply has no matching keys and gates nothing.
if ! command -v python3 >/dev/null 2>&1; then
    echo "regression gate: python3 unavailable; skipped"
    exit 0
fi
BASELINE="$(mktemp)"
trap 'rm -f "$BASELINE"' EXIT
if ! git show HEAD:BENCH_serve.json >"$BASELINE" 2>/dev/null; then
    echo "regression gate: no committed BENCH_serve.json baseline; skipped"
    exit 0
fi
echo
echo "== serving regression gate (>20% achieved-rps drop vs committed baseline) =="
ADVISORY="$ADVISORY" BASELINE="$BASELINE" python3 - <<'PY'
import json, os, sys

def points(doc):
    out = {}
    for p in doc.get("sweep", []):
        key = ("pool", p["workers"], doc.get("requests"), doc.get("paced_batch_s"))
        out[key] = p["achieved_rps"]
    front = doc.get("front", {})
    for p in front.get("sweep", []):
        key = ("front", p["workers"], front.get("requests"), front.get("paced_batch_s"))
        out[key] = p["achieved_rps"]
    return out

with open(os.environ["BASELINE"]) as f:
    base = points(json.load(f))
with open("BENCH_serve.json") as f:
    cur = points(json.load(f))

regressions = []
matched = 0
for key, rps in sorted(base.items()):
    if key not in cur or not rps:
        continue
    matched += 1
    ratio = cur[key] / rps
    tag = "OK " if ratio >= 0.8 else "REG"
    print(f"  {tag} {key[0]:<5} workers={key[1]:<2} "
          f"baseline {rps:9.0f} rps -> current {cur[key]:9.0f} rps ({ratio:5.2f}x)")
    if ratio < 0.8:
        regressions.append(key)

if not matched:
    print("  no comparable points (sweep parameters changed); nothing gated")
elif regressions:
    msg = f"{len(regressions)} pool size(s) regressed >20% vs committed baseline"
    if os.environ.get("ADVISORY") == "1":
        print(f"  WARNING (advisory): {msg}")
    else:
        print(f"  FAIL: {msg}")
        sys.exit(1)
else:
    print(f"  all {matched} comparable points within 20% of baseline")
PY
