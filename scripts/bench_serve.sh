#!/usr/bin/env bash
# Build release and record the serving-path performance trajectory.
#
# Writes BENCH_serve.json at the repo root (next to BENCH_dse.json): one
# open-loop Poisson load offered to engine pools of 1/2/4/8 workers on the
# paced SimOnly engine — offered rate, achieved rps, p50/p99 latency and
# queue depth per pool size, plus the workers=4 vs workers=1 speedup the
# bench asserts on. Pass --quick for the small CI-cadence sweep. Run from
# anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

# (Absolute path: cargo runs bench binaries with cwd set to the package
# root, so a bare filename would land in rust/. The non-empty array also
# keeps `set -u` happy on pre-4.4 bash when no --quick flag is given.)
ARGS=(--json "$PWD/BENCH_serve.json")
if [[ "${1:-}" == "--quick" ]]; then
    ARGS=(--quick "${ARGS[@]}")
fi

cargo build --release

cargo bench --bench e2e_serve_bench -- "${ARGS[@]}"

echo
echo "BENCH_serve.json:"
cat BENCH_serve.json
