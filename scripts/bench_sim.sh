#!/usr/bin/env bash
# Build release and record the simulator performance trajectory.
#
# Writes BENCH_sim.json at the repo root (next to BENCH_dse.json and
# BENCH_serve.json): per (model/device, batch) case, the semantic event
# count, the events the fast-forward engine actually processed, the
# events_ratio between the two, the fast and reference median wall times
# and their speedup. Always runs sim_perf in --compare mode, so the bench
# itself enforces the ≤1e-9 fast-vs-reference equivalence on every case
# and the acceptance gates on resnet50/zcu102 at batch=256 (≥10× fewer
# processed events, ≥5× wall speedup).
#
# Regression gate: when the repo has a *committed* BENCH_sim.json baseline
# (git show HEAD:BENCH_sim.json), a matching case whose fast wall time or
# processed-event count grows more than 20% over the baseline fails the
# run — or just warns when --advisory is passed (CI uses --advisory so
# quick-run jitter cannot hard-fail unrelated changes). Pass --quick for
# the small CI-cadence grid. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

# (Absolute path: cargo runs bench binaries with cwd set to the package
# root, so a bare filename would land in rust/. The non-empty array also
# keeps `set -u` happy on pre-4.4 bash when no flags are given.)
ARGS=(--compare --json "$PWD/BENCH_sim.json")
ADVISORY=0
for arg in "$@"; do
    case "$arg" in
        --quick) ARGS=(--quick "${ARGS[@]}") ;;
        --advisory) ADVISORY=1 ;;
        *) echo "unknown flag: $arg (known: --quick --advisory)" >&2; exit 2 ;;
    esac
done

cargo build --release

cargo bench --bench sim_perf -- "${ARGS[@]}"

echo
echo "BENCH_sim.json:"
cat BENCH_sim.json

# ---- regression gate against the committed baseline ------------------------
# Cases are keyed by name (model/device-bBATCH): a baseline recorded with a
# different grid (quick vs full) simply has no matching keys for the extra
# cases and gates nothing on them.
if ! command -v python3 >/dev/null 2>&1; then
    echo "regression gate: python3 unavailable; skipped"
    exit 0
fi
BASELINE="$(mktemp)"
trap 'rm -f "$BASELINE"' EXIT
if ! git show HEAD:BENCH_sim.json >"$BASELINE" 2>/dev/null; then
    echo "regression gate: no committed BENCH_sim.json baseline; skipped"
    exit 0
fi
echo
echo "== simulator regression gate (>20% wall-time or processed-event growth vs committed baseline) =="
ADVISORY="$ADVISORY" BASELINE="$BASELINE" python3 - <<'PY'
import json, os, sys

def points(doc):
    return {c["name"]: (c.get("fast_median_s"), c.get("events_processed"))
            for c in doc.get("cases", [])}

with open(os.environ["BASELINE"]) as f:
    base = points(json.load(f))
with open("BENCH_sim.json") as f:
    cur = points(json.load(f))

regressions = []
matched = 0
for name, (b_wall, b_ev) in sorted(base.items()):
    if name not in cur:
        continue
    c_wall, c_ev = cur[name]
    matched += 1
    bad = []
    if b_wall and c_wall and c_wall > 1.2 * b_wall:
        bad.append(f"wall {b_wall:.3e}s -> {c_wall:.3e}s")
    if b_ev and c_ev and c_ev > 1.2 * b_ev:
        bad.append(f"processed events {b_ev} -> {c_ev}")
    tag = "REG" if bad else "OK "
    detail = "; ".join(bad) if bad else \
        f"wall {c_wall:.3e}s, {c_ev} processed events"
    print(f"  {tag} {name:<28} {detail}")
    if bad:
        regressions.append(name)

if not matched:
    print("  no comparable cases (grid changed); nothing gated")
elif regressions:
    msg = f"{len(regressions)} case(s) regressed >20% vs committed baseline"
    if os.environ.get("ADVISORY") == "1":
        print(f"  WARNING (advisory): {msg}")
    else:
        print(f"  FAIL: {msg}")
        sys.exit(1)
else:
    print(f"  all {matched} comparable cases within 20% of baseline")
PY
