#!/usr/bin/env bash
# Tier-1 CI gate: build, test, format check, lint, smoke-run the launcher
# (single-device, sharded, co-located, fleet), then record the DSE/simulator performance
# trajectory (BENCH_dse.json via scripts/bench_dse.sh) and the serving-path
# trajectory (BENCH_serve.json via scripts/bench_serve.sh). Run from
# anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== build (release) =="
cargo build --release

echo "== tests =="
cargo test -q

echo "== fmt =="
if cargo fmt --version >/dev/null 2>&1; then
    # Advisory until a toolchain-verified `cargo fmt` pass lands: report
    # drift loudly without failing the gate (the tree predates rustfmt).
    cargo fmt --all -- --check || echo "rustfmt drift detected (advisory, not failing CI)"
else
    echo "rustfmt unavailable in this toolchain; skipped"
fi

echo "== clippy (-D warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy unavailable in this toolchain; skipped"
fi

echo "== smoke: autows run (single device) =="
cargo run --release --bin autows -- run --config configs/resnet18_zcu102.toml

echo "== smoke: autows run (sharded, 2x zcu102) =="
cargo run --release --bin autows -- run --config configs/resnet50_2xzcu102.toml

echo "== smoke: autows run (co-located, resnet18 + squeezenet on one zcu102) =="
cargo run --release --bin autows -- run --config configs/multitenant_zcu102.toml

echo "== smoke: autows run (fleet, resnet18 + squeezenet over zcu102 + zc706) =="
cargo run --release --bin autows -- run --config configs/fleet_mixed.toml

echo "== smoke: simulate --json parses (single + co-located + fleet) =="
SIM_JSON_DIR="$(mktemp -d)"
trap 'rm -rf "$SIM_JSON_DIR"' EXIT
cargo run --release --bin autows -- simulate --model resnet18 --device zcu102 \
    --quant w4a5 --json "$SIM_JSON_DIR/single.json"
cargo run --release --bin autows -- simulate --models resnet18,squeezenet \
    --device zcu102 --quant w4a5 --json "$SIM_JSON_DIR/colocated.json"
cargo run --release --bin autows -- simulate --models resnet18,squeezenet \
    --devices zcu102,zc706 --quant w4a5 --objective agg \
    --json "$SIM_JSON_DIR/fleet.json"
grep -q '"mode": *"fleet"' "$SIM_JSON_DIR/fleet.json" \
    || { echo "fleet JSON missing its mode tag"; exit 1; }
for f in "$SIM_JSON_DIR/single.json" "$SIM_JSON_DIR/colocated.json" "$SIM_JSON_DIR/fleet.json"; do
    if command -v python3 >/dev/null 2>&1; then
        python3 -m json.tool "$f" >/dev/null || { echo "invalid JSON: $f"; exit 1; }
    else
        # no python3: at least require the machine-readable envelope
        grep -q '"mode":' "$f" || { echo "missing mode field: $f"; exit 1; }
    fi
done
echo "simulate --json OK"

echo "== smoke: simulate --trace-out (Perfetto event trace) =="
cargo run --release --bin autows -- simulate --model resnet18 --device zcu102 \
    --quant w4a5 --trace-out "$SIM_JSON_DIR/sim_trace.json"
grep -q '"traceEvents":' "$SIM_JSON_DIR/sim_trace.json" \
    || { echo "sim trace missing traceEvents"; exit 1; }

echo "== smoke: serve telemetry (metrics + span-trace exports) =="
cargo run --release --bin autows -- serve --models resnet18,squeezenet --device zcu102 \
    --requests 48 --metrics-out "$SIM_JSON_DIR/metrics.json" --stats-interval 1
cargo run --release --bin autows -- serve --devices zcu102,zcu102 --requests 48 \
    --metrics-out "$SIM_JSON_DIR/metrics.prom" --trace-out "$SIM_JSON_DIR/spans.json"
grep -q '^autows_requests_total ' "$SIM_JSON_DIR/metrics.prom" \
    || { echo "Prometheus exposition missing autows_requests_total"; exit 1; }
grep -q '^# TYPE autows_spans_total counter$' "$SIM_JSON_DIR/metrics.prom" \
    || { echo "Prometheus exposition missing the span families"; exit 1; }
grep -q '"traceEvents":' "$SIM_JSON_DIR/spans.json" \
    || { echo "span trace missing traceEvents"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    for f in "$SIM_JSON_DIR/metrics.json" "$SIM_JSON_DIR/spans.json" "$SIM_JSON_DIR/sim_trace.json"; do
        python3 -m json.tool "$f" >/dev/null || { echo "invalid JSON: $f"; exit 1; }
    done
else
    grep -q '"requests":' "$SIM_JSON_DIR/metrics.json" \
        || { echo "metrics JSON missing requests field"; exit 1; }
fi
echo "serve telemetry OK"

echo "== perf trajectory (BENCH_dse.json) =="
./scripts/bench_dse.sh

echo "== perf trajectory (BENCH_serve.json, quick sweep) =="
# --advisory: the quick sweep's jitter may not hard-fail unrelated changes;
# the full sweep (no flag) enforces the >20% regression gate strictly
./scripts/bench_serve.sh --quick --advisory

echo "== perf trajectory (BENCH_sim.json, quick grid) =="
# same --advisory reasoning; sim_perf itself still hard-asserts fast-vs-
# reference equivalence and the fast-forward acceptance gates
./scripts/bench_sim.sh --quick --advisory

echo "== bench artifacts parse as JSON =="
for f in BENCH_dse.json BENCH_serve.json BENCH_sim.json; do
    [[ -s "$f" ]] || { echo "missing bench artifact: $f"; exit 1; }
    if command -v python3 >/dev/null 2>&1; then
        python3 -m json.tool "$f" >/dev/null || { echo "invalid JSON: $f"; exit 1; }
    else
        grep -q '"bench":' "$f" || { echo "missing bench field: $f"; exit 1; }
    fi
done
echo "bench artifacts OK"

echo "CI OK"
