#!/usr/bin/env bash
# Tier-1 CI gate: build, test, lint, smoke-run the launcher, then record
# the DSE/simulator performance trajectory (BENCH_dse.json via
# scripts/bench_dse.sh). Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== build (release) =="
cargo build --release

echo "== tests =="
cargo test -q

echo "== clippy =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy unavailable in this toolchain; skipped"
fi

echo "== smoke: autows run =="
cargo run --release --bin autows -- run --config configs/resnet18_zcu102.toml

echo "== perf trajectory (BENCH_dse.json) =="
./scripts/bench_dse.sh

echo "CI OK"
