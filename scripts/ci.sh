#!/usr/bin/env bash
# Tier-1 CI gate: build, test, format check, lint, smoke-run the launcher
# (single-device and sharded), then record the DSE/simulator performance
# trajectory (BENCH_dse.json via scripts/bench_dse.sh). Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== build (release) =="
cargo build --release

echo "== tests =="
cargo test -q

echo "== fmt =="
if cargo fmt --version >/dev/null 2>&1; then
    # Advisory until a toolchain-verified `cargo fmt` pass lands: report
    # drift loudly without failing the gate (the tree predates rustfmt).
    cargo fmt --all -- --check || echo "rustfmt drift detected (advisory, not failing CI)"
else
    echo "rustfmt unavailable in this toolchain; skipped"
fi

echo "== clippy (-D warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy unavailable in this toolchain; skipped"
fi

echo "== smoke: autows run (single device) =="
cargo run --release --bin autows -- run --config configs/resnet18_zcu102.toml

echo "== smoke: autows run (sharded, 2x zcu102) =="
cargo run --release --bin autows -- run --config configs/resnet50_2xzcu102.toml

echo "== perf trajectory (BENCH_dse.json) =="
./scripts/bench_dse.sh

echo "CI OK"
