//! Ablation: the paper's greedy Algorithm 1 vs stochastic DSE baselines
//! (random search, simulated annealing) — solution quality and search cost.

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::dse::{run_with_strategy, DseConfig, Strategy};
use autows::ir::Quant;
use autows::models;

fn main() {
    println!("=== Ablation: DSE strategy comparison ===\n");
    let cfg = DseConfig::default();

    for (model, q, dev) in [
        ("toy", Quant::W8A8, Device::zcu102()),
        ("resnet18", Quant::W4A5, Device::zcu102()),
    ] {
        let net = models::by_name(model, q).unwrap();
        println!("--- {model}-{q} on {} ---", dev.name);
        let mut rows = Vec::new();
        for (label, strat, iters) in [
            ("greedy(Alg.1)", Strategy::Greedy, 5usize),
            ("random-50", Strategy::Random { samples: 50, seed: 7 }, 3),
            ("random-200", Strategy::Random { samples: 200, seed: 7 }, 2),
            ("anneal-500", Strategy::Anneal { iters: 500, t0: 0.5, seed: 7 }, 2),
            ("anneal-2000", Strategy::Anneal { iters: 2000, t0: 0.5, seed: 7 }, 2),
        ] {
            let name = format!("dse_strategies/{model}/{label}");
            let (_, result) =
                harness::bench(&name, iters, || run_with_strategy(&net, &dev, &cfg, strat));
            if let Some(r) = result {
                rows.push((label, r.throughput, r.latency_ms));
            }
        }
        println!("\nstrategy         fps        latency(ms)");
        for (label, fps, lat) in &rows {
            println!("{label:<14} {fps:>9.1} {lat:>12.3}");
        }
        // sanity: every strategy found a feasible design
        assert!(rows.len() >= 4, "all strategies should find feasible designs");
        println!();
    }
    println!("dse_strategies bench OK");
}
