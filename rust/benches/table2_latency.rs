//! Regenerates paper Table II (latency across networks/devices/architectures)
//! and times each cell's full pipeline: DSE + burst schedule + simulation.
//! Cells run through `report::table2_cell`, which is backed by
//! `autows::pipeline` — the repeat timings therefore measure the cached
//! user-facing path (the first pass pays the DSE, later passes hit the
//! design cache).

#[path = "harness.rs"]
mod harness;

use autows::ir::Quant;
use autows::report;

fn main() {
    println!("=== Table II: latency across networks and devices ===\n");
    let mut cells = Vec::new();
    for (net, dev, q) in report::table2_grid() {
        let label = format!("table2/{net}-{dev}-{}", q.label());
        let (_, cell) = harness::bench(&label, 5, || report::table2_cell(net, dev, q));
        cells.push(cell);
    }
    println!("\nnetwork       device    quant   layer-seq   vanilla    AutoWS");
    for c in &cells {
        let fmt = |v: Option<f64>| v.map_or("X".into(), |x| format!("{x:.1}"));
        println!(
            "{:<12} {:<9} {:<7} {:>9.1} {:>9} {:>9}",
            c.network,
            c.device,
            c.quant,
            c.sequential_ms,
            fmt(c.vanilla_ms),
            fmt(c.autows_ms)
        );
    }
    // paper-shape assertions (same checks as the test suite, kept here so a
    // bench run also validates the regenerated table)
    let get = |n: &str, d: &str| cells.iter().find(|c| c.network == n && c.device == d).unwrap();
    assert!(get("resnet18", "zcu102").autows_ms.unwrap() < get("resnet18", "zcu102").sequential_ms);
    assert!(get("resnet50", "u50").autows_ms.unwrap() < get("resnet50", "u50").sequential_ms);
    assert!(get("mobilenetv2", "zedboard").vanilla_ms.is_none());
    let _ = Quant::W4A4;
    println!("\ntable2 bench OK");
}
