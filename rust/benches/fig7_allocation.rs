//! Regenerates paper Fig. 7 (resnet18-ZCU102 per-layer on/off-chip weight
//! allocation with the ΔB criterion) and times the DSE design point.

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::report;

fn main() {
    println!("=== Fig. 7: per-layer weight allocation (design d1) ===\n");
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let (_, result) =
        harness::bench("fig7/dse-design-point", 5, || dse::run(&net, &dev, &DseConfig::default()));
    let r = result.expect("resnet18 fits zcu102 with streaming");

    println!("\n{}", report::fig7());

    let streaming = r.design.streaming_layers();
    println!(
        "{} of {} weight layers partially off-chip (paper: 5 of 21)",
        streaming.len(),
        net.weight_layers().len()
    );
    assert!(!streaming.is_empty());
    println!("fig7 bench OK");
}
