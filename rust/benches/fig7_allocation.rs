//! Regenerates paper Fig. 7 (resnet18-ZCU102 per-layer on/off-chip weight
//! allocation with the ΔB criterion) and times the DSE design point.

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::report;

fn main() {
    println!("=== Fig. 7: per-layer weight allocation (design d1) ===\n");
    let plan = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device(Device::zcu102())
        .expect("resnet18 on zcu102 resolves");
    let net = plan.network().clone();
    let (_, result) = harness::bench("fig7/dse-design-point", 5, || {
        // uncached: this bench times the DSE design point
        plan.clone().explore_uncached(&DseConfig::default()).ok()
    });
    let r = result.expect("resnet18 fits zcu102 with streaming");

    println!("\n{}", report::fig7());

    let streaming = r.design().streaming_layers();
    println!(
        "{} of {} weight layers partially off-chip (paper: 5 of 21)",
        streaming.len(),
        net.weight_layers().len()
    );
    assert!(!streaming.is_empty());
    println!("fig7 bench OK");
}
