//! Ablation: pruning + encoding co-design sweep (the paper's §VI future
//! work) — compression ratio, feasibility and throughput vs sparsity across
//! encodings, on a memory-tight device.

#[path = "harness.rs"]
mod harness;

use autows::compress::{bits_per_weight, compress_network, CompressionSpec, Encoding};
use autows::device::Device;
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::models;
use autows::pipeline::Planned;

fn main() {
    println!("=== Ablation: pruning + encoding co-design ===\n");
    let net = models::resnet18(Quant::W8A8);
    let dev = Device::zc706();
    let cfg = DseConfig::default();

    // encoding cost curves (pure model, no DSE)
    println!("bits/weight at L_W=8:");
    println!("sparsity   dense  bitmap     rle  entropy");
    for s in [0.0, 0.25, 0.5, 0.75, 0.9] {
        println!(
            "{s:>8.2} {:>7.2} {:>7.2} {:>7.2} {:>8.2}",
            bits_per_weight(8, s, Encoding::Dense),
            bits_per_weight(8, s, Encoding::Bitmap),
            bits_per_weight(8, s, Encoding::Rle),
            bits_per_weight(8, s, Encoding::Entropy),
        );
    }

    // co-design sweep: sparsity -> compression -> DSE
    println!("\nsparsity  ratio  acc-proxy  AutoWS fps  latency(ms)");
    let (_, rows) = harness::bench("ablation_compress/sweep-5pts", 3, || {
        let mut rows = Vec::new();
        for s in [0.0, 0.2, 0.4, 0.6, 0.8] {
            let (cnet, rep) = compress_network(&net, &CompressionSpec::pruned(s));
            // cached pipeline explore: repeat rounds hit the design cache
            let r = Planned::from_parts(cnet, dev.clone()).explore(&cfg).ok();
            rows.push((
                s,
                rep.ratio(),
                rep.accuracy_drop_proxy,
                r.as_ref().map(|e| e.result().throughput),
                r.as_ref().map(|e| e.result().latency_ms),
            ));
        }
        rows
    });
    let mut last_fps = 0.0;
    for (s, ratio, drop, fps, lat) in &rows {
        let f = fps.unwrap_or(0.0);
        println!(
            "{s:>8.1} {ratio:>6.2} {drop:>8.1}pp {f:>11.1} {:>12.3}",
            lat.unwrap_or(f64::NAN)
        );
        assert!(f >= last_fps * 0.99, "throughput must not regress with sparsity");
        last_fps = f;
    }
    // the shape the co-design predicts: meaningful speedup by 80% sparsity
    let first = rows.first().unwrap().3.unwrap();
    let last = rows.last().unwrap().3.unwrap();
    assert!(last > first * 1.5, "80% sparsity should speed up >1.5x: {first} -> {last}");
    println!("\nablation_compress bench OK");
}
