//! Regenerates paper Fig. 6 (resnet18-ZCU102 memory/performance trade-off)
//! through the pipeline's cache-aware sweep (`pipeline::sweep::mem_sweep`):
//! points fan across cores via `dse::parallel_cases` and share the global
//! design cache, so repeat timings measure the cached user-facing path
//! (first pass pays the DSE, later passes hit the cache).

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::ir::Quant;
use autows::pipeline::{sweep::mem_sweep, Deployment};

fn main() {
    println!("=== Fig. 6: resnet18-ZCU102 A_mem sweep ===\n");
    let plan = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device(Device::zcu102())
        .expect("resnet18 on zcu102 resolves");

    // time one representative point
    harness::bench("fig6/one-point", 5, || mem_sweep(&plan, &[1.0]));

    // full sweep (printed as the figure's series)
    let scales: Vec<f64> = (2..=20).map(|i| i as f64 * 0.1).collect();
    let (_, pts) = harness::bench("fig6/full-sweep-19pts", 2, || mem_sweep(&plan, &scales));

    println!("\nA_mem   AutoWS fps   vanilla fps   off-chip%");
    for p in &pts {
        let fmt = |v: Option<f64>| v.map_or("     X".into(), |x| format!("{x:>6.1}"));
        println!(
            "{:>5.2}   {:>10}   {:>11}   {:>6.1}",
            p.mem_scale,
            fmt(p.autows_fps),
            fmt(p.vanilla_fps),
            p.autows_offchip_frac * 100.0
        );
    }
    // the figure's regions
    assert!(pts.first().unwrap().vanilla_fps.is_none(), "region 1: vanilla infeasible");
    assert!(pts.iter().any(|p| p.vanilla_fps.is_some()), "region 2/3: vanilla appears");
    println!("\nfig6 bench OK");
}
