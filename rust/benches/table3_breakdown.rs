//! Regenerates paper Table III (resnet18-ZCU102 memory resource breakdown)
//! and times the two design-point DSE runs.

#[path = "harness.rs"]
mod harness;

use autows::report;

fn main() {
    println!("=== Table III: resnet18-ZCU102 memory breakdown ===\n");
    let (_, table) = harness::bench("table3/breakdown", 5, report::table3);
    println!("\n{table}");
    // the headline claim: AutoWS fits in 100% while vanilla needs >100%
    assert!(table.contains("%"));
    println!("table3 bench OK");
}
