//! End-to-end serving benchmark: PJRT numerics + coordinator batching,
//! reporting request throughput and latency percentiles (the e2e driver of
//! DESIGN.md's experiment index). Runs on the `autows::pipeline` chain —
//! model → DSE → schedule → serve — with the PJRT engine spec.
//!
//! Skips gracefully when `make artifacts` has not been run.

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use autows::coordinator::{BatchPolicy, ServerOptions};
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::{drive_synthetic, Deployment, EngineSpec};

fn main() {
    let artifact = format!("{}/artifacts/toy_cnn_b8.hlo.txt", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&artifact).exists() {
        println!("SKIP e2e_serve: {artifact} missing — run `make artifacts`");
        return;
    }

    println!("=== End-to-end serving (toy CNN, PJRT + AutoWS schedule) ===\n");
    let scheduled = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_device("zcu102")
        .expect("zcu102 in the device library")
        .explore(&DseConfig::default())
        .expect("toy CNN fits zcu102")
        .schedule_for_batch(8)
        .with_engine(EngineSpec::Pjrt { artifact, input_shape: (3, 32, 32), artifact_batch: 8 });
    let input_len = scheduled.input_len();
    let server = scheduled
        .serve(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            ServerOptions::default(),
        )
        .expect("engine boot");

    const REQUESTS: usize = 256;
    let (stats, ()) = harness::bench("e2e/serve-256-requests", 5, || {
        drive_synthetic(&server, REQUESTS, input_len).expect("all requests served");
    });

    let m = server.metrics();
    println!(
        "\n{} requests total: throughput {:.0} req/s (wall {:.1} ms/round), \
         p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}, simulated accel {:.1} ms",
        m.requests,
        REQUESTS as f64 / stats.median.as_secs_f64(),
        stats.median.as_secs_f64() * 1e3,
        m.p50_ms,
        m.p99_ms,
        m.mean_batch,
        m.sim_accel_s * 1e3
    );
    assert!(m.mean_batch > 1.5, "batching must engage under load");
    server.shutdown();
    println!("e2e_serve bench OK");
}
