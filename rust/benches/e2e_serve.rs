//! End-to-end serving benchmark: PJRT numerics + coordinator batching,
//! reporting request throughput and latency percentiles (the e2e driver of
//! DESIGN.md's experiment index).
//!
//! Skips gracefully when `make artifacts` has not been run.

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use autows::coordinator::{BatchPolicy, PjrtEngine, Server};
use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::runtime::Runtime;

fn main() {
    let artifact = format!("{}/artifacts/toy_cnn_b8.hlo.txt", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&artifact).exists() {
        println!("SKIP e2e_serve: {artifact} missing — run `make artifacts`");
        return;
    }

    println!("=== End-to-end serving (toy CNN, PJRT + AutoWS schedule) ===\n");
    let net = models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let design = dse::run(&net, &dev, &DseConfig::default()).unwrap().design;

    let server = Server::start_with(
        move || {
            let rt = Runtime::cpu()?;
            let model = rt.load_hlo_text(&artifact)?;
            Ok(Box::new(PjrtEngine::new(model, design, dev, (3, 32, 32), 8)) as _)
        },
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
    )
    .expect("engine boot");

    const REQUESTS: usize = 256;
    let (stats, ()) = harness::bench("e2e/serve-256-requests", 5, || {
        let receivers: Vec<_> = (0..REQUESTS)
            .map(|i| {
                let input: Vec<f32> =
                    (0..3 * 32 * 32).map(|j| ((i * 31 + j) % 255) as f32 / 255.0).collect();
                server.submit(input).unwrap()
            })
            .collect();
        for rx in receivers {
            rx.recv().unwrap().unwrap();
        }
    });

    let m = server.metrics();
    println!(
        "\n{} requests total: throughput {:.0} req/s (wall {:.1} ms/round), \
         p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}, simulated accel {:.1} ms",
        m.requests,
        REQUESTS as f64 / stats.median.as_secs_f64(),
        stats.median.as_secs_f64() * 1e3,
        m.p50_ms,
        m.p99_ms,
        m.mean_batch,
        m.sim_accel_s * 1e3
    );
    assert!(m.mean_batch > 1.5, "batching must engage under load");
    server.shutdown();
    println!("e2e_serve bench OK");
}
