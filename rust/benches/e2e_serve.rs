//! End-to-end serving benchmark, four parts:
//!
//! 1. **Pool sweep** (always runs — SimOnly, self-contained): the same
//!    open-loop Poisson load offered to engine pools of 1/2/4/8 workers.
//!    The engines are paced ([`PacedEngine`]) so each batch occupies its
//!    worker for the simulated accelerator time — without pacing the
//!    checksum engines finish in microseconds and every pool size looks
//!    identical. This is the perf-trajectory artifact: `--json PATH`
//!    writes `BENCH_serve.json` (offered rate, achieved rps, p50/p99 per
//!    pool size) next to `BENCH_dse.json`.
//! 2. **Dispatcher-saturation sweep** (always runs): tiny paced engine
//!    time, tiny inputs, offered load ~1.25× the *8-worker* pool capacity
//!    from 4 concurrent submitters — the configuration where engine time
//!    is near-zero and the old single-dispatcher front flatlined. With the
//!    sharded front, achieved rps must keep scaling with the pool
//!    (`workers = 8` ≥ 3.5× `workers = 1`, asserted here), and the
//!    steady-state lock counter must stay zero.
//! 3. **Router overhead** (always runs): the same two-model mixed Poisson
//!    load offered twice — straight to two per-model paced servers, then
//!    through the fleet [`Router`] fronting the identical pair. The router
//!    adds one hash lookup + one atomic pair per request; achieved rps
//!    through it must stay within 10% of direct (asserted, and written to
//!    the `fleet` section of `BENCH_serve.json`).
//! 4. **PJRT e2e** (skips gracefully when `make artifacts` has not run):
//!    PJRT numerics + coordinator batching through `autows::pipeline`.
//!
//! ```text
//! e2e_serve_bench                  both sweeps + PJRT e2e
//! e2e_serve_bench --quick          smaller sweeps (CI cadence)
//! e2e_serve_bench --json <path>    also write the sweeps as JSON
//! ```

#[path = "harness.rs"]
mod harness;

use std::time::{Duration, Instant};

use autows::coordinator::{
    run_open_loop, run_open_loop_mixed, ArrivalSchedule, BatchPolicy, Engine, LoadResult,
    MixedSpec, PacedEngine, Router, Server, ServerOptions, SimOnlyEngine,
};
use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::pipeline::{drive_synthetic, Deployment, EngineSpec};

const MAX_BATCH: usize = 8;
const INPUT_LEN: usize = 3 * 32 * 32;

struct SweepPoint {
    workers: usize,
    res: LoadResult,
    queue_depth_mean: f64,
    queue_depth_max: usize,
    /// (min, max) batches served by any one worker — pool skew.
    batch_spread: (u64, u64),
}

struct SweepParams {
    paced_batch_s: f64,
    offered_rps: f64,
    requests: usize,
}

/// Offer one fixed Poisson load to pools of 1/2/4/8 paced SimOnly engines.
fn pool_sweep(quick: bool) -> (SweepParams, Vec<SweepPoint>) {
    let net = autows::models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).expect("toy cnn fits zcu102");
    let mut template = SimOnlyEngine {
        design: r.design,
        device: dev,
        input_len: INPUT_LEN,
        output_len: 10,
    };

    // Pace the engines so a full batch occupies its worker for a fixed,
    // machine-independent time; offer ~5x one worker's capacity so the
    // single-worker server saturates and the pool sizes separate.
    let paced_batch_s = if quick { 2e-3 } else { 4e-3 };
    let accel_s = template.accel_batch_time(MAX_BATCH).as_secs_f64().max(1e-9);
    let pace = paced_batch_s / accel_s;
    let capacity_rps = MAX_BATCH as f64 / paced_batch_s;
    let offered_rps = 5.0 * capacity_rps;
    let requests = if quick { 160 } else { 640 };

    let mut points = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let engine = PacedEngine::new(template.clone(), pace);
        let server = Server::start_with_opts(
            move || Ok(Box::new(engine.clone()) as _),
            BatchPolicy { max_batch: MAX_BATCH, max_wait: Duration::from_micros(500) },
            ServerOptions { queue_cap: 0, workers, dispatch_shards: 0, telemetry: true },
        )
        .expect("sim engines boot");
        let schedule = ArrivalSchedule::poisson(requests, offered_rps, 42);
        let res = run_open_loop(&schedule, || server.submit(vec![0.5; INPUT_LEN]));
        let m = server.metrics();
        assert_eq!(res.completed, requests, "open-loop run must lose no responses");
        let batches: Vec<u64> = m.per_worker.iter().map(|w| w.batches).collect();
        let spread = (
            batches.iter().copied().min().unwrap_or(0),
            batches.iter().copied().max().unwrap_or(0),
        );
        points.push(SweepPoint {
            workers,
            res,
            queue_depth_mean: m.queue_depth_mean,
            queue_depth_max: m.queue_depth_max,
            batch_spread: spread,
        });
        server.shutdown();
    }
    (SweepParams { paced_batch_s, offered_rps, requests }, points)
}

/// Bench-local engine for the dispatcher-saturation sweep: occupies its
/// worker for a FIXED, cached batch time (no per-batch simulator call —
/// that would put simulator CPU on the measurement path) and runs the
/// SimOnly checksum numerics. The fixed time is deliberately tiny so the
/// front end, not the engines, is the bottleneck under test.
#[derive(Clone)]
struct FrontEngine {
    inner: SimOnlyEngine,
    batch_time: Duration,
}

impl Engine for FrontEngine {
    fn infer(&mut self, batch: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.batch_time);
        self.inner.infer(batch)
    }

    fn input_len(&self) -> usize {
        self.inner.input_len
    }

    fn accel_batch_time(&mut self, _batch: usize) -> Duration {
        self.batch_time
    }
}

struct FrontPoint {
    workers: usize,
    shards: usize,
    achieved_rps: f64,
    p99_ms: f64,
    completed: usize,
}

struct FrontParams {
    paced_batch_s: f64,
    offered_rps: f64,
    requests: usize,
    submitters: usize,
    input_len: usize,
}

/// Saturate the serving FRONT: near-zero paced engine time, tiny inputs,
/// offered load above the whole 8-worker pool's capacity, submitted from 4
/// concurrent threads. Engine time is negligible by construction, so
/// achieved rps is decided by how fast the dispatch path forms and routes
/// batches — the number this PR exists to scale.
fn front_sweep(quick: bool) -> (FrontParams, Vec<FrontPoint>) {
    let net = autows::models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).expect("toy cnn fits zcu102");
    // tiny inputs: the per-request copy/checksum cost must not mask the front
    let input_len = 16usize;
    let template = FrontEngine {
        inner: SimOnlyEngine { design: r.design, device: dev, input_len, output_len: 4 },
        batch_time: Duration::from_secs_f64(1e-3),
    };
    let paced_batch_s = template.batch_time.as_secs_f64();
    // one worker drains MAX_BATCH per paced tick; offer 1.25x the FULL
    // 8-worker capacity so every pool size saturates
    let offered_rps = 1.25 * 8.0 * MAX_BATCH as f64 / paced_batch_s;
    let submitters = 4usize;
    let requests = if quick { 4000 } else { 12000 };
    let per = requests / submitters;

    let mut points = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let engine = template.clone();
        let server = Server::start_with_opts(
            move || Ok(Box::new(engine.clone()) as _),
            BatchPolicy { max_batch: MAX_BATCH, max_wait: Duration::from_micros(200) },
            ServerOptions { queue_cap: 0, workers, dispatch_shards: 0, telemetry: true },
        )
        .expect("sim engines boot");
        let t0 = Instant::now();
        let results: Vec<LoadResult> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..submitters)
                .map(|k| {
                    let server = &server;
                    s.spawn(move || {
                        let schedule = ArrivalSchedule::poisson(
                            per,
                            offered_rps / submitters as f64,
                            42 + k as u64,
                        );
                        run_open_loop(&schedule, || server.submit(vec![0.5; input_len]))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("submitter thread")).collect()
        });
        let wall = t0.elapsed().as_secs_f64().max(1e-12);
        let completed: usize = results.iter().map(|r| r.completed).sum();
        assert_eq!(completed, per * submitters, "front sweep must lose no responses");
        assert_eq!(
            server.serving_path_locks(),
            0,
            "steady-state dispatch took a lock under saturation"
        );
        let p99_ms = results.iter().map(|r| r.p99_ms).fold(0.0, f64::max);
        points.push(FrontPoint {
            workers,
            shards: server.dispatch_shards(),
            achieved_rps: completed as f64 / wall,
            p99_ms,
            completed,
        });
        server.shutdown();
    }
    (FrontParams { paced_batch_s, offered_rps, requests, submitters, input_len }, points)
}

const FLEET_MODELS: [&str; 2] = ["toy_a", "toy_b"];

struct FleetLeg {
    achieved_rps: f64,
    p99_ms: f64,
    completed: usize,
    rejected: usize,
}

struct FleetParams {
    paced_batch_s: f64,
    offered_rps: f64,
    requests: usize,
}

struct FleetReport {
    params: FleetParams,
    direct: FleetLeg,
    routed: FleetLeg,
    /// `1 - routed/direct` achieved-rps — what the router's hash lookup +
    /// least-outstanding atomics cost under mixed load.
    overhead_frac: f64,
}

/// Two identical paced servers, one per model. The same mixed schedule is
/// offered straight to them (the caller does the routing) and through the
/// [`Router`]; the achieved-rps gap is the router's per-request overhead.
fn fleet_sweep(quick: bool) -> FleetReport {
    let net = autows::models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).expect("toy cnn fits zcu102");
    let mut template = SimOnlyEngine {
        design: r.design,
        device: dev,
        input_len: INPUT_LEN,
        output_len: 10,
    };
    let paced_batch_s = 2e-3;
    let accel_s = template.accel_batch_time(MAX_BATCH).as_secs_f64().max(1e-9);
    let pace = paced_batch_s / accel_s;
    // per-server capacity is MAX_BATCH / paced_batch_s; offer ~75% of the
    // two-server total so neither leg saturates and the gap is pure routing
    let offered_rps = 0.75 * 2.0 * MAX_BATCH as f64 / paced_batch_s;
    let requests = if quick { 400 } else { 1200 };
    let specs: Vec<MixedSpec> = FLEET_MODELS
        .iter()
        .map(|m| MixedSpec { model: m.to_string(), rate_rps: offered_rps / 2.0 })
        .collect();

    let boot = |engine: PacedEngine<SimOnlyEngine>| {
        Server::start_with_opts(
            move || Ok(Box::new(engine.clone()) as _),
            BatchPolicy { max_batch: MAX_BATCH, max_wait: Duration::from_micros(500) },
            ServerOptions { queue_cap: 0, workers: 1, dispatch_shards: 0, telemetry: true },
        )
        .expect("sim engines boot")
    };

    // leg 1: direct — the submit closure is the router (a match statement)
    let schedule = ArrivalSchedule::mixed(requests, &specs, 42);
    let servers: Vec<Server> =
        FLEET_MODELS.iter().map(|_| boot(PacedEngine::new(template.clone(), pace))).collect();
    let res = run_open_loop_mixed(&schedule, |model| {
        let i = FLEET_MODELS.iter().position(|m| *m == model).expect("model from the mix");
        servers[i].submit(vec![0.5; INPUT_LEN])
    });
    let direct = FleetLeg {
        achieved_rps: res.achieved_rps,
        p99_ms: res.p99_ms,
        completed: res.completed,
        rejected: res.rejected,
    };
    for s in servers {
        s.shutdown();
    }

    // leg 2: the identical pair behind one Router, same mixed schedule
    let mut router = Router::new();
    for model in FLEET_MODELS {
        router.add_server("zcu102", model, INPUT_LEN, boot(PacedEngine::new(template.clone(), pace)));
    }
    let res = run_open_loop_mixed(&schedule, |model| router.submit(model, vec![0.5; INPUT_LEN]));
    let routed = FleetLeg {
        achieved_rps: res.achieved_rps,
        p99_ms: res.p99_ms,
        completed: res.completed,
        rejected: res.rejected,
    };
    router.shutdown();

    let overhead_frac = 1.0 - routed.achieved_rps / direct.achieved_rps.max(1e-9);
    FleetReport {
        params: FleetParams { paced_batch_s, offered_rps, requests },
        direct,
        routed,
        overhead_frac,
    }
}

struct TelemetryReport {
    rounds: usize,
    best_off_rps: f64,
    best_on_rps: f64,
    /// `best_on / best_off` achieved-rps at front saturation — the span
    /// rings' hot-path cost. Gated ≥ 0.98 in `main`.
    ratio: f64,
    spans_recorded: u64,
}

/// The telemetry overhead gate: the front-saturation configuration at
/// `workers = 8`, run paired with span recording off and on. The seqlock
/// span rings ride the hottest path this bench has (three records per
/// batch on the worker, one per dispatch on the shard), so the on-leg's
/// achieved rps must stay within 2% of the off-leg. Paired best-of-N sheds
/// scheduler noise: both legs get the same seeds, and only the best round
/// of each is compared.
fn telemetry_overhead(quick: bool) -> TelemetryReport {
    let net = autows::models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).expect("toy cnn fits zcu102");
    let input_len = 16usize;
    let template = FrontEngine {
        inner: SimOnlyEngine { design: r.design, device: dev, input_len, output_len: 4 },
        batch_time: Duration::from_secs_f64(1e-3),
    };
    let paced_batch_s = template.batch_time.as_secs_f64();
    let offered_rps = 1.25 * 8.0 * MAX_BATCH as f64 / paced_batch_s;
    let submitters = 4usize;
    let requests = if quick { 4000 } else { 8000 };
    let per = requests / submitters;
    let rounds = if quick { 2 } else { 3 };

    let run_leg = |telemetry: bool, seed: u64| -> (f64, u64) {
        let engine = template.clone();
        let server = Server::start_with_opts(
            move || Ok(Box::new(engine.clone()) as _),
            BatchPolicy { max_batch: MAX_BATCH, max_wait: Duration::from_micros(200) },
            ServerOptions { queue_cap: 0, workers: 8, dispatch_shards: 0, telemetry },
        )
        .expect("sim engines boot");
        let t0 = Instant::now();
        let results: Vec<LoadResult> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..submitters)
                .map(|k| {
                    let server = &server;
                    s.spawn(move || {
                        let schedule = ArrivalSchedule::poisson(
                            per,
                            offered_rps / submitters as f64,
                            seed + k as u64,
                        );
                        run_open_loop(&schedule, || server.submit(vec![0.5; input_len]))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("submitter thread")).collect()
        });
        let wall = t0.elapsed().as_secs_f64().max(1e-12);
        let completed: usize = results.iter().map(|r| r.completed).sum();
        assert_eq!(completed, per * submitters, "telemetry leg must lose no responses");
        assert_eq!(
            server.serving_path_locks(),
            0,
            "telemetry must not put a lock on the serving path"
        );
        let spans = server.spans_recorded();
        if telemetry {
            assert!(spans > 0, "the telemetry-on leg must record spans");
        } else {
            assert_eq!(spans, 0, "the telemetry-off leg must record nothing");
        }
        server.shutdown();
        (completed as f64 / wall, spans)
    };

    let mut best_off = 0.0_f64;
    let mut best_on = 0.0_f64;
    let mut spans_recorded = 0_u64;
    for round in 0..rounds {
        let seed = 1000 + 10 * round as u64;
        let (off, _) = run_leg(false, seed);
        let (on, spans) = run_leg(true, seed);
        best_off = best_off.max(off);
        best_on = best_on.max(on);
        spans_recorded = spans_recorded.max(spans);
    }
    let ratio = best_on / best_off.max(1e-9);
    TelemetryReport {
        rounds,
        best_off_rps: best_off,
        best_on_rps: best_on,
        ratio,
        spans_recorded,
    }
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

struct FrontReport<'a> {
    params: &'a FrontParams,
    points: &'a [FrontPoint],
    speedup_w8_over_w1: f64,
}

fn write_json(
    path: &str,
    params: &SweepParams,
    points: &[SweepPoint],
    speedup: f64,
    front: &FrontReport,
    fleet: &FleetReport,
    tele: &TelemetryReport,
) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"serve_pool\",\n");
    out.push_str("  \"engine\": \"sim_only_paced\",\n");
    out.push_str("  \"model\": \"toy_cnn\",\n");
    out.push_str("  \"device\": \"zcu102\",\n");
    out.push_str(&format!("  \"max_batch\": {MAX_BATCH},\n"));
    out.push_str(&format!("  \"paced_batch_s\": {},\n", json_f64(params.paced_batch_s)));
    out.push_str(&format!("  \"offered_rps\": {},\n", json_f64(params.offered_rps)));
    out.push_str(&format!("  \"requests\": {},\n", params.requests));
    out.push_str(&format!("  \"speedup_w4_over_w1\": {},\n", json_f64(speedup)));
    out.push_str("  \"sweep\": [\n");
    for (i, p) in points.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!("      \"workers\": {},\n", p.workers));
        out.push_str(&format!("      \"offered_rps\": {},\n", json_f64(p.res.offered_rps)));
        out.push_str(&format!("      \"achieved_rps\": {},\n", json_f64(p.res.achieved_rps)));
        out.push_str(&format!("      \"p50_ms\": {},\n", json_f64(p.res.p50_ms)));
        out.push_str(&format!("      \"p99_ms\": {},\n", json_f64(p.res.p99_ms)));
        out.push_str(&format!("      \"mean_ms\": {},\n", json_f64(p.res.mean_ms)));
        out.push_str(&format!("      \"completed\": {},\n", p.res.completed));
        out.push_str(&format!("      \"rejected\": {},\n", p.res.rejected));
        out.push_str(&format!(
            "      \"queue_depth_mean\": {},\n",
            json_f64(p.queue_depth_mean)
        ));
        out.push_str(&format!("      \"queue_depth_max\": {},\n", p.queue_depth_max));
        out.push_str(&format!(
            "      \"worker_batches_min\": {},\n      \"worker_batches_max\": {}\n",
            p.batch_spread.0, p.batch_spread.1
        ));
        out.push_str(if i + 1 == points.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ],\n");
    out.push_str("  \"front\": {\n");
    out.push_str(&format!(
        "    \"paced_batch_s\": {},\n",
        json_f64(front.params.paced_batch_s)
    ));
    out.push_str(&format!("    \"offered_rps\": {},\n", json_f64(front.params.offered_rps)));
    out.push_str(&format!("    \"requests\": {},\n", front.params.requests));
    out.push_str(&format!("    \"submitters\": {},\n", front.params.submitters));
    out.push_str(&format!("    \"input_len\": {},\n", front.params.input_len));
    out.push_str(&format!(
        "    \"speedup_w8_over_w1\": {},\n",
        json_f64(front.speedup_w8_over_w1)
    ));
    out.push_str("    \"sweep\": [\n");
    for (i, p) in front.points.iter().enumerate() {
        out.push_str("      {\n");
        out.push_str(&format!("        \"workers\": {},\n", p.workers));
        out.push_str(&format!("        \"dispatch_shards\": {},\n", p.shards));
        out.push_str(&format!("        \"achieved_rps\": {},\n", json_f64(p.achieved_rps)));
        out.push_str(&format!("        \"p99_ms\": {},\n", json_f64(p.p99_ms)));
        out.push_str(&format!("        \"completed\": {}\n", p.completed));
        out.push_str(if i + 1 == front.points.len() { "      }\n" } else { "      },\n" });
    }
    out.push_str("    ]\n  },\n");
    out.push_str("  \"fleet\": {\n");
    out.push_str(&format!(
        "    \"models\": [\"{}\", \"{}\"],\n",
        FLEET_MODELS[0], FLEET_MODELS[1]
    ));
    out.push_str(&format!(
        "    \"paced_batch_s\": {},\n",
        json_f64(fleet.params.paced_batch_s)
    ));
    out.push_str(&format!("    \"offered_rps\": {},\n", json_f64(fleet.params.offered_rps)));
    out.push_str(&format!("    \"requests\": {},\n", fleet.params.requests));
    for (key, leg) in [("direct", &fleet.direct), ("routed", &fleet.routed)] {
        out.push_str(&format!("    \"{key}\": {{\n"));
        out.push_str(&format!(
            "      \"achieved_rps\": {},\n",
            json_f64(leg.achieved_rps)
        ));
        out.push_str(&format!("      \"p99_ms\": {},\n", json_f64(leg.p99_ms)));
        out.push_str(&format!("      \"completed\": {},\n", leg.completed));
        out.push_str(&format!("      \"rejected\": {}\n", leg.rejected));
        out.push_str("    },\n");
    }
    out.push_str(&format!(
        "    \"router_overhead_frac\": {}\n",
        json_f64(fleet.overhead_frac)
    ));
    out.push_str("  },\n");
    out.push_str("  \"telemetry\": {\n");
    out.push_str(&format!("    \"rounds\": {},\n", tele.rounds));
    out.push_str(&format!(
        "    \"best_off_rps\": {},\n",
        json_f64(tele.best_off_rps)
    ));
    out.push_str(&format!("    \"best_on_rps\": {},\n", json_f64(tele.best_on_rps)));
    out.push_str(&format!("    \"on_over_off_ratio\": {},\n", json_f64(tele.ratio)));
    out.push_str(&format!("    \"spans_recorded\": {}\n", tele.spans_recorded));
    out.push_str("  }\n}\n");
    std::fs::write(path, out).expect("write BENCH_serve.json");
    println!("wrote {path}");
}

/// PJRT numerics + coordinator batching through the pipeline chain.
fn pjrt_e2e() {
    let artifact = format!("{}/artifacts/toy_cnn_b8.hlo.txt", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&artifact).exists() {
        println!("SKIP e2e pjrt: {artifact} missing — run `make artifacts`");
        return;
    }

    println!("\n=== End-to-end serving (toy CNN, PJRT + AutoWS schedule) ===\n");
    let scheduled = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_device("zcu102")
        .expect("zcu102 in the device library")
        .explore(&DseConfig::default())
        .expect("toy CNN fits zcu102")
        .schedule_for_batch(8)
        .with_engine(EngineSpec::Pjrt { artifact, input_shape: (3, 32, 32), artifact_batch: 8 });
    let input_len = scheduled.input_len();
    let server = scheduled
        .serve(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            ServerOptions::default(),
        )
        .expect("engine boot");

    const REQUESTS: usize = 256;
    let (stats, ()) = harness::bench("e2e/serve-256-requests", 5, || {
        drive_synthetic(&server, REQUESTS, input_len).expect("all requests served");
    });

    let m = server.metrics();
    println!(
        "\n{} requests total: throughput {:.0} req/s (wall {:.1} ms/round), \
         p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}, simulated accel {:.1} ms",
        m.requests,
        REQUESTS as f64 / stats.median.as_secs_f64(),
        stats.median.as_secs_f64() * 1e3,
        m.p50_ms,
        m.p99_ms,
        m.mean_batch,
        m.sim_accel_s * 1e3
    );
    assert!(m.mean_batch > 1.5, "batching must engage under load");
    server.shutdown();
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = match args.iter().position(|a| a == "--json") {
        None => None,
        Some(i) => match args.get(i + 1) {
            Some(p) => Some(p.clone()),
            None => {
                eprintln!("error: --json requires an output path");
                std::process::exit(2);
            }
        },
    };

    println!("=== Engine-pool sweep (paced SimOnly, open-loop Poisson) ===\n");
    let (params, points) = pool_sweep(quick);
    println!(
        "offered {:.0} rps ({} requests, paced batch {:.1} ms):",
        params.offered_rps,
        params.requests,
        params.paced_batch_s * 1e3
    );
    println!("workers  achieved(rps)  p50(ms)  p99(ms)  qdepth(max)  batches/worker");
    for p in &points {
        println!(
            "{:>7} {:>14.0} {:>8.2} {:>8.2} {:>12} {:>9}..{}",
            p.workers,
            p.res.achieved_rps,
            p.res.p50_ms,
            p.res.p99_ms,
            p.queue_depth_max,
            p.batch_spread.0,
            p.batch_spread.1
        );
    }

    let w1 = points.iter().find(|p| p.workers == 1).expect("sweep includes workers=1");
    let w4 = points.iter().find(|p| p.workers == 4).expect("sweep includes workers=4");
    let speedup = w4.res.achieved_rps / w1.res.achieved_rps.max(1e-9);
    println!("\nworkers=4 vs workers=1 achieved-rps: {speedup:.2}x");

    println!("\n=== Dispatcher-saturation sweep (sharded front, near-zero engine time) ===\n");
    let (fparams, fpoints) = front_sweep(quick);
    println!(
        "offered {:.0} rps from {} submitters ({} requests, paced batch {:.1} ms):",
        fparams.offered_rps,
        fparams.submitters,
        fparams.requests,
        fparams.paced_batch_s * 1e3
    );
    println!("workers  shards  achieved(rps)  p99(ms)  completed");
    for p in &fpoints {
        println!(
            "{:>7} {:>7} {:>14.0} {:>8.2} {:>10}",
            p.workers, p.shards, p.achieved_rps, p.p99_ms, p.completed
        );
    }
    let f1 = fpoints.iter().find(|p| p.workers == 1).expect("front sweep includes workers=1");
    let f8 = fpoints.iter().find(|p| p.workers == 8).expect("front sweep includes workers=8");
    let front_speedup = f8.achieved_rps / f1.achieved_rps.max(1e-9);
    println!("\nfront: workers=8 vs workers=1 achieved-rps: {front_speedup:.2}x");

    println!("\n=== Router overhead (two-model mixed load, direct vs routed) ===\n");
    let fleet = fleet_sweep(quick);
    println!(
        "offered {:.0} rps over {:?} ({} requests, paced batch {:.1} ms):",
        fleet.params.offered_rps,
        FLEET_MODELS,
        fleet.params.requests,
        fleet.params.paced_batch_s * 1e3
    );
    println!("leg      achieved(rps)  p99(ms)  completed  rejected");
    for (name, leg) in [("direct", &fleet.direct), ("routed", &fleet.routed)] {
        println!(
            "{name:<8} {:>13.0} {:>8.2} {:>10} {:>9}",
            leg.achieved_rps, leg.p99_ms, leg.completed, leg.rejected
        );
    }
    println!("\nrouter overhead: {:.1}% of direct achieved-rps", fleet.overhead_frac * 100.0);

    println!("\n=== Telemetry overhead (span recording off vs on at front saturation) ===\n");
    let tele = telemetry_overhead(quick);
    println!("leg      best(rps)   (best of {} paired rounds, workers=8)", tele.rounds);
    println!("off      {:>9.0}", tele.best_off_rps);
    println!("on       {:>9.0}   ({} spans recorded)", tele.best_on_rps, tele.spans_recorded);
    println!("\ntelemetry on/off achieved-rps ratio: {:.3}", tele.ratio);

    if let Some(path) = json_path {
        let front =
            FrontReport { params: &fparams, points: &fpoints, speedup_w8_over_w1: front_speedup };
        write_json(&path, &params, &points, speedup, &front, &fleet, &tele);
    }
    assert!(
        speedup >= 2.0,
        "the pool must scale: workers=4 achieved only {speedup:.2}x of workers=1"
    );
    assert!(
        front_speedup >= 3.5,
        "the sharded front must scale with the pool at saturating load: \
         workers=8 achieved only {front_speedup:.2}x of workers=1"
    );
    assert!(
        fleet.routed.achieved_rps >= 0.9 * fleet.direct.achieved_rps,
        "the router must cost under 10%: routed {:.0} rps vs direct {:.0} rps",
        fleet.routed.achieved_rps,
        fleet.direct.achieved_rps
    );
    assert!(
        tele.ratio >= 0.98,
        "span recording must cost under 2% at front saturation: \
         on {:.0} rps vs off {:.0} rps (ratio {:.3})",
        tele.best_on_rps,
        tele.best_off_rps,
        tele.ratio
    );

    pjrt_e2e();
    println!("\ne2e_serve bench OK");
}
