//! Regenerates paper §V-D (YOLOv5n-COCO on ZCU102: AutoWS vs Vitis AI vs
//! vanilla layer-pipelined) and times the full evaluation.

#[path = "harness.rs"]
mod harness;

use autows::baseline::{self, sequential_latency_ms};
use autows::device::Device;
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::sim::{simulate, SimConfig};

fn main() {
    println!("=== §V-D: YOLOv5n object detection on ZCU102 ===\n");
    let dev = Device::zcu102();
    let plan = Deployment::for_model("yolov5n")
        .quant(Quant::W8A8)
        .on_device(dev.clone())
        .expect("yolov5n on zcu102 resolves");
    let net = plan.network().clone();

    let (_, seq) = harness::bench("yolo/sequential", 20, || sequential_latency_ms(&net, &dev));
    let (_, autows) = harness::bench("yolo/autows-dse+sim", 5, || {
        // uncached: this bench times the DSE itself
        plan.clone()
            .explore_uncached(&DseConfig::default())
            .ok()
            .map(|e| e.schedule().simulate(&SimConfig::default()).latency_ms)
    });
    let (_, vanilla) = harness::bench("yolo/vanilla-dse+sim", 5, || {
        baseline::vanilla(&net, &dev)
            .map(|r| simulate(&r.design, &dev, &SimConfig::default()).latency_ms)
    });

    let a = autows.expect("autows feasible");
    println!("\nlayer-sequential (Vitis-AI-like): {seq:.1} ms   (paper: 13.7)");
    match vanilla {
        Some(v) => println!("vanilla layer-pipelined:          {v:.1} ms   (paper: 9.5)"),
        None => println!("vanilla layer-pipelined:          X"),
    }
    println!("AutoWS (this work):               {a:.1} ms   (paper: 8.7)");
    assert!(a < seq, "AutoWS must beat the sequential baseline");
    println!("\nyolo bench OK");
}
