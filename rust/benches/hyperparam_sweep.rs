//! Ablation: DSE hyperparameters φ (unroll step) and μ (eviction block
//! depth) — the §IV-A exploration-time vs solution-quality trade-off.
//! Runs through the pipeline's cache-aware grid
//! (`pipeline::sweep::phi_mu_sweep`): cells fan across cores via
//! `dse::parallel_cases` and share the global design cache.

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::ir::Quant;
use autows::pipeline::{sweep::phi_mu_sweep, Deployment};

fn main() {
    println!("=== Ablation: φ/μ hyperparameter sweep (resnet18-ZCU102) ===\n");
    let plan = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device(Device::zcu102())
        .expect("resnet18 on zcu102 resolves");

    let phis = [1u32, 2, 4, 8];
    let mus = [128u64, 512, 2048];
    let (_, pts) = harness::bench("hyperparam/phi-mu-grid-12pts", 2, || {
        phi_mu_sweep(&plan, &phis, &mus)
    });

    println!("\n  φ     μ   iterations      fps   latency(ms)");
    for p in &pts {
        println!(
            "{:>3} {:>5} {:>12} {:>8.1} {:>12.3}",
            p.phi, p.mu, p.iterations, p.throughput, p.latency_ms
        );
    }

    // the paper's claim: larger step sizes explore faster (fewer
    // iterations) at equal or lower solution quality
    let fine = pts.iter().find(|p| p.phi == 1 && p.mu == 512).unwrap();
    let coarse = pts.iter().find(|p| p.phi == 8 && p.mu == 512).unwrap();
    assert!(
        coarse.iterations <= fine.iterations,
        "coarse φ must explore fewer iterations: {} vs {}",
        coarse.iterations,
        fine.iterations
    );
    assert!(
        fine.throughput >= coarse.throughput * 0.95,
        "fine φ must not lose quality: {} vs {}",
        fine.throughput,
        coarse.throughput
    );
    println!("\nhyperparam_sweep bench OK");
}
