//! Performance benchmark of the event simulator (the §Perf L3 target:
//! >= 10M fragment-iteration events per second).

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::sim::{simulate, SimConfig};

fn main() {
    println!("=== Simulator performance (L3 hot path #2) ===\n");
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let design = dse::run(&net, &dev, &DseConfig::default()).unwrap().design;

    let mut rate = 0.0;
    for batch in [1u64, 8, 64] {
        let cfg = SimConfig { batch, ..Default::default() };
        let (stats, events) =
            harness::bench(&format!("sim/resnet18-zcu102-b{batch}"), 30, || {
                simulate(&design, &dev, &cfg).events
            });
        rate = events as f64 / stats.median.as_secs_f64();
        println!("        -> {events} events, {:.2} M events/s", rate / 1e6);
    }
    println!("\nlast rate: {:.2} M events/s (target: >= 10 M/s)", rate / 1e6);
    println!("sim_perf bench OK");
}
