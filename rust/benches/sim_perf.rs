//! Performance benchmark of the event simulator (the §Perf L3 target:
//! >= 10M fragment-iteration events per second).

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::sim::{simulate, SimConfig};

fn main() {
    println!("=== Simulator performance (L3 hot path #2) ===\n");
    let dev = Device::zcu102();
    let design = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device(dev.clone())
        .unwrap()
        .explore(&DseConfig::default())
        .expect("resnet18 fits zcu102")
        .design()
        .clone();

    let mut rate = 0.0;
    for batch in [1u64, 8, 64] {
        let cfg = SimConfig { batch, ..Default::default() };
        let (stats, events) =
            harness::bench(&format!("sim/resnet18-zcu102-b{batch}"), 30, || {
                simulate(&design, &dev, &cfg).events
            });
        rate = events as f64 / stats.median.as_secs_f64();
        println!("        -> {events} events, {:.2} M events/s", rate / 1e6);
    }
    println!("\nlast rate: {:.2} M events/s (target: >= 10 M/s)", rate / 1e6);
    println!("sim_perf bench OK");
}
