//! Performance benchmark of the event simulator (the §Perf L3 target:
//! >= 10M fragment-iteration events per second, now met by *skipping* the
//! steady-state bulk of the event train rather than grinding through it).
//!
//! Model/device resolution goes through `autows::pipeline`; the timed
//! region is the bare engine call `sim::simulate` — symmetric with the
//! `sim::reference::simulate` baseline (the pre-fast-forward heap engine,
//! preserved verbatim as the oracle).
//!
//! Modes:
//!
//! ```text
//! sim_perf                         time the fast-forward engine per case
//! sim_perf --compare               also time the reference engine
//!                                  ("before"), check ≤1e-9 equivalence on
//!                                  every result field, and enforce the
//!                                  acceptance gates on resnet50/zcu102
//!                                  at batch=256 (≥10× fewer processed
//!                                  events, ≥5× wall speedup)
//! sim_perf --quick                 trim the grid for CI (acceptance case
//!                                  kept, fewer timing repetitions)
//! sim_perf --json <path>           write the results as JSON (BENCH_sim.json)
//! ```

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;
use autows::sim::{self, simulate, SimConfig, SimResult};

struct CaseReport {
    name: String,
    batch: u64,
    events: u64,
    events_processed: u64,
    events_ratio: f64,
    fast_median_s: f64,
    ref_median_s: Option<f64>,
    speedup: Option<f64>,
    equivalent: Option<bool>,
    iterations: usize,
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn write_json(path: &str, reports: &[CaseReport]) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"sim_perf\",\n");
    out.push_str("  \"unit\": \"seconds\",\n");
    out.push_str("  \"cases\": [\n");
    for (i, r) in reports.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!("      \"name\": \"{}\",\n", r.name));
        out.push_str(&format!("      \"batch\": {},\n", r.batch));
        out.push_str(&format!("      \"events\": {},\n", r.events));
        out.push_str(&format!("      \"events_processed\": {},\n", r.events_processed));
        out.push_str(&format!("      \"events_ratio\": {},\n", json_f64(r.events_ratio)));
        out.push_str(&format!("      \"fast_median_s\": {},\n", json_f64(r.fast_median_s)));
        out.push_str(&format!(
            "      \"ref_median_s\": {},\n",
            r.ref_median_s.map_or("null".into(), json_f64)
        ));
        out.push_str(&format!(
            "      \"speedup\": {},\n",
            r.speedup.map_or("null".into(), json_f64)
        ));
        out.push_str(&format!(
            "      \"equivalent\": {},\n",
            r.equivalent.map_or("null".into(), |e| e.to_string())
        ));
        out.push_str(&format!("      \"iterations\": {}\n", r.iterations));
        out.push_str(if i + 1 == reports.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(path, out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("cannot write {path}: {e}"),
    }
}

/// ≤1e-9 relative equivalence with a makespan-scaled absolute floor for
/// accumulators that sit near zero (a stall of 1e-18 s against an exact 0
/// is equal for every purpose of this tool).
fn close(a: f64, b: f64, span: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()) + 1e-12 * span
}

fn equivalent(fast: &SimResult, oracle: &SimResult) -> bool {
    let span = oracle.makespan_s.max(1e-30);
    fast.events == oracle.events
        && close(fast.makespan_s, oracle.makespan_s, span)
        && close(fast.latency_ms, oracle.latency_ms, span * 1e3)
        && close(fast.total_stall_s, oracle.total_stall_s, span)
        && close(fast.dma_busy_frac, oracle.dma_busy_frac, 1.0)
        && fast.per_layer_stall_s.len() == oracle.per_layer_stall_s.len()
        && fast
            .per_layer_stall_s
            .iter()
            .zip(&oracle.per_layer_stall_s)
            .all(|(&a, &b)| close(a, b, span))
        && fast
            .per_layer_contention_s
            .iter()
            .zip(&oracle.per_layer_contention_s)
            .all(|(&a, &b)| close(a, b, span))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let compare = args.iter().any(|a| a == "--compare");
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = match args.iter().position(|a| a == "--json") {
        None => None,
        Some(i) => match args.get(i + 1) {
            Some(p) if !p.starts_with("--") => Some(p.clone()),
            _ => {
                eprintln!("error: --json requires an output path");
                std::process::exit(2);
            }
        },
    };

    println!("=== Simulator performance (L3 hot path #2) ===\n");
    // (name, model, quant, device, batches) — resnet50/zcu102 at batch=256
    // is the acceptance case the compare-mode gates are pinned to.
    let full: &[(&str, &str, Quant, Device, &[u64])] = &[
        ("toy/zcu102", "toy", Quant::W8A8, Device::zcu102(), &[8]),
        ("resnet18/zcu102", "resnet18", Quant::W4A5, Device::zcu102(), &[1, 8, 64]),
        ("resnet50/zcu102", "resnet50", Quant::W4A5, Device::zcu102(), &[8, 256]),
        ("resnet50/u250", "resnet50", Quant::W8A8, Device::u250(), &[8]),
        ("mobilenetv2/zc706", "mobilenetv2", Quant::W4A4, Device::zc706(), &[8]),
        ("yolov5n/zcu102", "yolov5n", Quant::W8A8, Device::zcu102(), &[8]),
    ];
    let trimmed: &[(&str, &str, Quant, Device, &[u64])] = &[
        ("resnet18/zcu102", "resnet18", Quant::W4A5, Device::zcu102(), &[8]),
        ("resnet50/zcu102", "resnet50", Quant::W4A5, Device::zcu102(), &[256]),
    ];
    let cases = if quick { trimmed } else { full };

    let mut reports = Vec::new();
    for (name, model, quant, dev, batches) in cases {
        let planned = Deployment::for_model(model)
            .quant(*quant)
            .on_device(dev.clone())
            .expect("zoo model on library device");
        let design = match planned.explore(&DseConfig::default()) {
            Some(e) => e.design().clone(),
            None => {
                println!("  (skip {name}: infeasible on this device)");
                continue;
            }
        };
        for &batch in *batches {
            let case = format!("{name}-b{batch}");
            let cfg = SimConfig { batch, ..Default::default() };
            // the fast engine finishes in O(warm-up); keep repetitions low on
            // the huge batches anyway so compare mode's reference runs fit
            let iters = match (quick, batch >= 64) {
                (true, _) => 3,
                (false, true) => 3,
                (false, false) => 20,
            };
            let (stats, fast) = harness::bench(&format!("sim/{case}"), iters, || {
                simulate(&design, dev, &cfg)
            });
            let ratio = fast.events as f64 / (fast.events_processed.max(1)) as f64;
            println!(
                "        -> {} events, {} processed ({:.1}x skipped past)",
                fast.events, fast.events_processed, ratio
            );

            let mut report = CaseReport {
                name: case.clone(),
                batch,
                events: fast.events,
                events_processed: fast.events_processed,
                events_ratio: ratio,
                fast_median_s: stats.median.as_secs_f64(),
                ref_median_s: None,
                speedup: None,
                equivalent: None,
                iterations: stats.iters,
            };

            if compare {
                let ref_iters = if batch >= 64 { 1 } else { iters };
                let (ref_stats, oracle) =
                    harness::bench(&format!("sim-ref/{case}"), ref_iters, || {
                        sim::reference::simulate(&design, dev, &cfg)
                    });
                let equal = equivalent(&fast, &oracle);
                let speedup =
                    ref_stats.median.as_secs_f64() / stats.median.as_secs_f64().max(1e-12);
                report.ref_median_s = Some(ref_stats.median.as_secs_f64());
                report.speedup = Some(speedup);
                report.equivalent = Some(equal);
                println!(
                    "        -> before {:?} / after {:?} = {:.1}x speedup, equivalent: {}",
                    ref_stats.median, stats.median, speedup, equal
                );
                assert!(equal, "{case}: fast-forward and reference engines must agree");
                if *name == "resnet50/zcu102" && batch == 256 {
                    assert!(
                        ratio >= 10.0,
                        "acceptance gate: {case} must skip >=10x of its events \
                         (processed {} of {})",
                        fast.events_processed,
                        fast.events
                    );
                    assert!(
                        speedup >= 5.0,
                        "acceptance gate: {case} must run >=5x faster than the \
                         reference engine (got {speedup:.1}x)"
                    );
                }
            }
            reports.push(report);
        }
    }

    if let Some(path) = json_path {
        write_json(&path, &reports);
    }
    println!("sim_perf bench OK");
}
