//! Minimal self-contained benchmark harness (criterion is unavailable in
//! this offline build; the statistics mirror its headline output).
//!
//! Used by every bench target via `#[path = "harness.rs"] mod harness;`.

use std::time::{Duration, Instant};

/// Result of one benchmark: per-iteration timing statistics.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "bench {:<44} iters={:<4} mean={:>12?} median={:>12?} min={:>12?} max={:>12?}",
            self.name, self.iters, self.mean, self.median, self.min, self.max
        );
    }
}

/// Run `f` repeatedly: a warm-up pass, then up to `max_iters` timed passes
/// or ~2 s of wall-clock, whichever comes first. Returns the value of the
/// last call so the caller can print/verify the regenerated table.
pub fn bench<T>(name: &str, max_iters: usize, mut f: impl FnMut() -> T) -> (BenchStats, T) {
    let mut out = f(); // warm-up
    let mut samples = Vec::with_capacity(max_iters);
    let budget = Duration::from_secs(2);
    let start = Instant::now();
    for _ in 0..max_iters.max(1) {
        let t0 = Instant::now();
        out = f();
        samples.push(t0.elapsed());
        if start.elapsed() > budget && samples.len() >= 3 {
            break;
        }
    }
    samples.sort();
    let sum: Duration = samples.iter().sum();
    let stats = BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean: sum / samples.len() as u32,
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
    };
    stats.print();
    (stats, out)
}
