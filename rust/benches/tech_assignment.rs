//! Ablation: memory technology assignment (URAM / LUTRAM / FINN-style
//! overclocking) vs the all-BRAM baseline, across the paper's device grid.

#[path = "harness.rs"]
mod harness;

use autows::ce::{assign_memory_tech, TechOptions};
use autows::device::Device;
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::Deployment;

fn main() {
    println!("=== Ablation: memory technology assignment ===\n");

    println!("network      device   baseBRAM  BRAM  URAM  +LUTs");
    for (model, q, dev) in [
        ("resnet18", Quant::W4A5, Device::zcu102()),
        ("resnet50", Quant::W8A8, Device::u50()),
        ("resnet50", Quant::W8A8, Device::u250()),
        ("mobilenetv2", Quant::W4A4, Device::zc706()),
    ] {
        let Ok(r) = Deployment::for_model(model)
            .quant(q)
            .on_device(dev.clone())
            .expect("zoo model on library device")
            .explore(&DseConfig::default())
        else {
            println!("{model:<12} {:<8} INFEASIBLE", dev.name);
            continue;
        };
        let name = format!("tech_assignment/{model}-{}", dev.name);
        let (_, plan) = harness::bench(&name, 10, || {
            assign_memory_tech(r.design(), &dev, &TechOptions::for_device(&dev))
        });
        println!(
            "{model:<12} {:<8} {:>8} {:>5} {:>5} {:>6}",
            dev.name, plan.baseline_bram, plan.bram, plan.uram, plan.extra_luts
        );
        // invariants: never exceed pools, never cost extra BRAM
        assert!(plan.bram <= plan.baseline_bram);
        assert!(plan.uram <= dev.uram);
        if dev.uram == 0 {
            assert_eq!(plan.uram, 0);
        }
    }

    // ablation: each option disabled in turn, on the U50 (URAM-rich) case
    let dev = Device::u50();
    let r = Deployment::for_model("resnet50")
        .quant(Quant::W8A8)
        .on_device(dev.clone())
        .unwrap()
        .explore(&DseConfig::default())
        .expect("resnet50 fits u50");
    println!("\nU50 option ablation (resnet50-W8A8):");
    for (label, opts) in [
        ("all options", TechOptions::for_device(&dev)),
        ("no URAM", TechOptions { use_uram: false, ..TechOptions::for_device(&dev) }),
        ("no LUTRAM", TechOptions { use_lutram: false, ..TechOptions::for_device(&dev) }),
        (
            "no overclock",
            TechOptions { max_overclock: 1, ..TechOptions::for_device(&dev) },
        ),
        (
            "BRAM only",
            TechOptions { use_uram: false, use_lutram: false, max_overclock: 1, ..Default::default() },
        ),
    ] {
        let plan = assign_memory_tech(r.design(), &dev, &opts);
        println!(
            "  {label:<14} BRAM {:>5}  URAM {:>4}  +LUTs {:>6}",
            plan.bram, plan.uram, plan.extra_luts
        );
        if label == "BRAM only" {
            assert_eq!(plan.bram, plan.baseline_bram);
        }
    }
    println!("\ntech_assignment bench OK");
}
