//! Regenerates paper Fig. 5 (two-layer DMA write/read scheduling,
//! imbalanced vs balanced burst numbers) and times the simulations.

#[path = "harness.rs"]
mod harness;

use autows::sim::{fig5_scenario, simulate, SimConfig};

fn main() {
    println!("=== Fig. 5: DMA scheduling, imbalanced vs balanced ===\n");
    let cfg = SimConfig { batch: 8, ..Default::default() };

    let (_, stall_imb) = harness::bench("fig5/imbalanced", 50, || {
        let (d, dev) = fig5_scenario(false);
        simulate(&d, &dev, &cfg).total_stall_s
    });
    let (_, stall_bal) = harness::bench("fig5/balanced", 50, || {
        let (d, dev) = fig5_scenario(true);
        simulate(&d, &dev, &cfg).total_stall_s
    });

    println!("\nimbalanced (a): total stalls {:.2} us", stall_imb * 1e6);
    println!("balanced   (b): total stalls {:.2} us", stall_bal * 1e6);
    println!("\n{}", autows::report::fig5());
    assert!(stall_bal < stall_imb, "write burst balancing must remove stalls");
    println!("fig5 bench OK");
}
