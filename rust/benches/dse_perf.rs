//! Performance benchmark of the DSE itself (the §Perf L3 target: a full
//! ResNet50/U250 exploration in under one second).

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::models;

fn main() {
    println!("=== DSE performance (L3 hot path #1) ===\n");
    let cases = [
        ("toy/zcu102", models::toy_cnn(Quant::W8A8), Device::zcu102()),
        ("resnet18/zcu102", models::resnet18(Quant::W4A5), Device::zcu102()),
        ("resnet18/zedboard", models::resnet18(Quant::W4A5), Device::zedboard()),
        ("resnet50/u250", models::resnet50(Quant::W8A8), Device::u250()),
        ("resnet50/zcu102", models::resnet50(Quant::W4A5), Device::zcu102()),
        ("mobilenetv2/zc706", models::mobilenet_v2(Quant::W4A4), Device::zc706()),
        ("yolov5n/zcu102", models::yolov5n(Quant::W8A8), Device::zcu102()),
    ];
    let mut worst = std::time::Duration::ZERO;
    for (name, net, dev) in cases {
        let (stats, r) = harness::bench(&format!("dse/{name}"), 10, || {
            dse::run(&net, &dev, &DseConfig::default())
        });
        if let Some(r) = &r {
            println!("        -> θ={:.1} fps in {} iterations", r.throughput, r.iterations);
        }
        worst = worst.max(stats.median);
    }
    println!("\nworst-case median DSE time: {worst:?} (target: < 1 s)");
    println!("dse_perf bench OK");
}
