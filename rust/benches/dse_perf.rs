//! Performance benchmark of the DSE itself (the §Perf L3 target: a full
//! ResNet50/U250 exploration in under one second).
//!
//! Model/device resolution goes through `autows::pipeline`
//! (`Deployment` → `Planned`); the timed region is the bare engine call
//! `dse::run` — symmetric with the `dse::reference::run` baseline and free
//! of cache effects or per-iteration clones (`tests/pipeline_api.rs` pins
//! that the pipeline's `.explore()` is bit-identical to this path).
//!
//! Modes:
//!
//! ```text
//! dse_perf                         time the incremental engine per case
//! dse_perf --compare               also time the pre-refactor reference
//!                                  engine ("before") and check that both
//!                                  return identical design metrics
//! dse_perf --warm                  additionally time the warm-start mode
//! dse_perf --json <path>           write the results as JSON (BENCH_dse.json)
//! ```

#[path = "harness.rs"]
mod harness;

use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::pipeline::Deployment;

struct CaseReport {
    name: String,
    after_median_s: f64,
    before_median_s: Option<f64>,
    warm_median_s: Option<f64>,
    equal_metrics: Option<bool>,
    throughput_fps: f64,
    bandwidth_bps: f64,
    bram_blocks: u32,
    iterations: usize,
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn write_json(path: &str, reports: &[CaseReport], worst_after_s: f64) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"dse_perf\",\n");
    out.push_str("  \"unit\": \"seconds\",\n");
    out.push_str(&format!("  \"worst_after_median_s\": {},\n", json_f64(worst_after_s)));
    out.push_str("  \"cases\": [\n");
    for (i, r) in reports.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!("      \"name\": \"{}\",\n", r.name));
        out.push_str(&format!("      \"after_median_s\": {},\n", json_f64(r.after_median_s)));
        out.push_str(&format!(
            "      \"before_median_s\": {},\n",
            r.before_median_s.map_or("null".into(), json_f64)
        ));
        out.push_str(&format!(
            "      \"speedup\": {},\n",
            r.before_median_s
                .map_or("null".into(), |b| json_f64(b / r.after_median_s.max(1e-12)))
        ));
        out.push_str(&format!(
            "      \"warm_median_s\": {},\n",
            r.warm_median_s.map_or("null".into(), json_f64)
        ));
        out.push_str(&format!(
            "      \"equal_metrics\": {},\n",
            r.equal_metrics.map_or("null".into(), |e| e.to_string())
        ));
        out.push_str(&format!("      \"throughput_fps\": {},\n", json_f64(r.throughput_fps)));
        out.push_str(&format!("      \"bandwidth_bps\": {},\n", json_f64(r.bandwidth_bps)));
        out.push_str(&format!("      \"bram_blocks\": {},\n", r.bram_blocks));
        out.push_str(&format!("      \"iterations\": {}\n", r.iterations));
        out.push_str(if i + 1 == reports.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(path, out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("cannot write {path}: {e}"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let compare = args.iter().any(|a| a == "--compare");
    let warm = args.iter().any(|a| a == "--warm");
    let json_path = match args.iter().position(|a| a == "--json") {
        None => None,
        Some(i) => match args.get(i + 1) {
            Some(p) if !p.starts_with("--") => Some(p.clone()),
            _ => {
                eprintln!("error: --json requires an output path");
                std::process::exit(2);
            }
        },
    };

    println!("=== DSE performance (L3 hot path #1) ===\n");
    let cases = [
        ("toy/zcu102", "toy", Quant::W8A8, Device::zcu102()),
        ("resnet18/zcu102", "resnet18", Quant::W4A5, Device::zcu102()),
        ("resnet18/zedboard", "resnet18", Quant::W4A5, Device::zedboard()),
        ("resnet50/u250", "resnet50", Quant::W8A8, Device::u250()),
        ("resnet50/zcu102", "resnet50", Quant::W4A5, Device::zcu102()),
        ("mobilenetv2/zc706", "mobilenetv2", Quant::W4A4, Device::zc706()),
        ("yolov5n/zcu102", "yolov5n", Quant::W8A8, Device::zcu102()),
    ];
    let cfg = DseConfig::default();

    let mut worst = std::time::Duration::ZERO;
    let mut reports = Vec::new();
    for (name, model, quant, dev) in cases {
        let net = Deployment::for_model(model)
            .quant(quant)
            .on_device(dev.clone())
            .expect("zoo model on library device")
            .network()
            .clone();
        let (stats, r) = harness::bench(&format!("dse/{name}"), 10, || {
            dse::run(&net, &dev, &cfg)
        });
        if let Some(r) = &r {
            println!("        -> θ={:.1} fps in {} iterations", r.throughput, r.iterations);
        }
        worst = worst.max(stats.median);

        let mut report = CaseReport {
            name: name.to_string(),
            after_median_s: stats.median.as_secs_f64(),
            before_median_s: None,
            warm_median_s: None,
            equal_metrics: None,
            throughput_fps: r.as_ref().map_or(0.0, |r| r.throughput),
            bandwidth_bps: r.as_ref().map_or(0.0, |r| r.bandwidth_bps),
            bram_blocks: r.as_ref().map_or(0, |r| r.area.bram.total()),
            iterations: r.as_ref().map_or(0, |r| r.iterations),
        };

        if compare {
            let (ref_stats, ref_r) = harness::bench(&format!("dse-ref/{name}"), 10, || {
                dse::reference::run(&net, &dev, &cfg)
            });
            report.before_median_s = Some(ref_stats.median.as_secs_f64());
            let equal = match (&r, &ref_r) {
                (Some(a), Some(b)) => {
                    a.design.cfgs == b.design.cfgs
                        && a.design.off_bits == b.design.off_bits
                        && a.throughput == b.throughput
                        && a.area == b.area
                        && a.bandwidth_bps == b.bandwidth_bps
                }
                (None, None) => true,
                _ => false,
            };
            report.equal_metrics = Some(equal);
            let speedup = ref_stats.median.as_secs_f64() / stats.median.as_secs_f64().max(1e-12);
            println!(
                "        -> before {:?} / after {:?} = {:.1}x speedup, identical results: {}",
                ref_stats.median, stats.median, speedup, equal
            );
            assert!(equal, "{name}: incremental and reference engines must agree");
        }

        if warm {
            let warm_cfg = DseConfig::warm();
            let (warm_stats, _) = harness::bench(&format!("dse-warm/{name}"), 10, || {
                dse::run(&net, &dev, &warm_cfg)
            });
            report.warm_median_s = Some(warm_stats.median.as_secs_f64());
        }
        reports.push(report);
    }

    println!("\nworst-case median DSE time: {worst:?} (target: < 1 s)");
    if let Some(path) = json_path {
        let worst_s = worst.as_secs_f64();
        write_json(&path, &reports, worst_s);
    }
    println!("dse_perf bench OK");
}
