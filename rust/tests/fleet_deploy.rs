//! Integration tests of the fleet deployment path: the degenerate goldens
//! (1×1 ≡ `on_device`, 1×M ≡ `on_devices`, N×1 ≡ `colocate` — bit-identical
//! designs, schedules and simulations), the four-schema cache-separation
//! contract, the acceptance placement (resnet50 shards while the small pair
//! co-locates on a mixed pool), typed errors for bad pools, and the
//! router-fronted serving terminal.

use autows::device::Device;
use autows::dse::{self, colocate, partition, slo_metric, DseConfig, FleetObjective,
    FleetPlacement};
use autows::ir::Quant;
use autows::pipeline::{Deployment, DesignCache, PlacementSchedule, PlacementSim};
use autows::sim::SimConfig;
use autows::Error;

fn resnet18() -> Deployment {
    Deployment::for_model("resnet18").quant(Quant::W4A5)
}

fn squeezenet() -> Deployment {
    Deployment::for_model("squeezenet").quant(Quant::W8A8)
}

/// Golden (satellite): a 1×1 fleet is the single-device deployment —
/// design, burst schedule and simulation are bit-identical, mirroring the
/// 1-partition and 1-tenant goldens of PR 4/5.
#[test]
fn one_by_one_equals_on_device_bit_for_bit() {
    let cfg = DseConfig::default();
    let single = resnet18()
        .on_device("zcu102")
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();
    let fleet = Deployment::fleet([resnet18()], &["zcu102"])
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();

    assert_eq!(fleet.placements().len(), 1);
    assert_eq!(fleet.result().devices_used, 1);
    match &fleet.placements()[0] {
        FleetPlacement::Solo { model: 0, device: 0, result } => {
            assert_eq!(result.design.cfgs, single.design().cfgs, "identical per-layer configs");
            assert_eq!(result.design.off_bits, single.design().off_bits, "identical evictions");
            assert_eq!(result.throughput, single.result().throughput);
            assert_eq!(result.latency_ms, single.result().latency_ms);
            assert_eq!(result.area, single.result().area);
        }
        other => panic!("expected a Solo placement, got {other:?}"),
    }
    // the placement's schedule is the single-device burst schedule, verbatim
    match &fleet.schedules()[0] {
        PlacementSchedule::Solo(b) => assert_eq!(b, single.burst_schedule()),
        other => panic!("expected a Solo schedule, got {other:?}"),
    }
    assert_eq!(fleet.input_len("resnet18"), Some(single.input_len()));

    // and the simulation is the single-device simulation, verbatim
    let sim_cfg = SimConfig::default();
    let sim_single = single.simulate(&sim_cfg);
    let sim_fleet = fleet.simulate(&sim_cfg);
    assert_eq!(sim_fleet.per_placement.len(), 1);
    match &sim_fleet.per_placement[0] {
        PlacementSim::Solo(r) => {
            assert_eq!(r.makespan_s, sim_single.makespan_s, "bit-identical makespan");
            assert_eq!(r.total_stall_s, sim_single.total_stall_s);
            assert_eq!(r.events, sim_single.events);
        }
        other => panic!("expected a Solo sim, got {other:?}"),
    }
    assert_eq!(sim_fleet.makespan_s, sim_single.makespan_s);
}

/// Golden (satellite): a 1×M fleet under the default objective is the
/// sharded deployment of the full chain — same cuts, schedules, simulation.
#[test]
fn one_by_m_equals_on_devices_bit_for_bit() {
    let cfg = DseConfig::default();
    let chain = ["zcu102", "zcu102"];
    let sharded = resnet18()
        .on_devices(&chain)
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();
    let fleet = Deployment::fleet([resnet18()], &chain)
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();

    assert_eq!(fleet.placements().len(), 1);
    match &fleet.placements()[0] {
        FleetPlacement::Sharded { model: 0, devices, result } => {
            assert_eq!(devices, &[0, 1], "the whole pool, in chain order");
            assert_eq!(result.cuts, sharded.result().cuts, "identical cut points");
            assert_eq!(result.throughput, sharded.result().throughput);
            assert_eq!(result.parts.len(), sharded.partitions().len());
            for (a, b) in result.parts.iter().zip(sharded.partitions()) {
                assert_eq!(a.lo, b.lo);
                assert_eq!(a.hi, b.hi);
                assert_eq!(a.result.design.cfgs, b.result.design.cfgs);
                assert_eq!(a.result.design.off_bits, b.result.design.off_bits);
            }
        }
        other => panic!("expected a Sharded placement, got {other:?}"),
    }
    match &fleet.schedules()[0] {
        PlacementSchedule::Sharded(schedules) => {
            assert_eq!(schedules.as_slice(), sharded.burst_schedules());
        }
        other => panic!("expected a Sharded schedule, got {other:?}"),
    }
    assert_eq!(fleet.input_len("resnet18"), Some(sharded.input_len()));

    let sim_cfg = SimConfig::default();
    let sim_sharded = sharded.simulate(&sim_cfg);
    let sim_fleet = fleet.simulate(&sim_cfg);
    match &sim_fleet.per_placement[0] {
        PlacementSim::Sharded(r) => {
            assert_eq!(r.makespan_s, sim_sharded.makespan_s, "bit-identical makespan");
            assert_eq!(r.total_stall_s, sim_sharded.total_stall_s);
            assert_eq!(r.steady_period_s, sim_sharded.steady_period_s);
        }
        other => panic!("expected a Sharded sim, got {other:?}"),
    }
}

/// Golden (satellite): an N×1 fleet is the co-located deployment — same
/// shares, per-tenant designs, shared-port schedule and simulation.
#[test]
fn n_by_one_equals_colocate_bit_for_bit() {
    let cfg = DseConfig::default();
    let joint = Deployment::colocate([resnet18(), squeezenet()])
        .on_device("zcu102")
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();
    let fleet = Deployment::fleet([resnet18(), squeezenet()], &["zcu102"])
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();

    assert_eq!(fleet.placements().len(), 1);
    assert_eq!(fleet.result().devices_used, 1);
    match &fleet.placements()[0] {
        FleetPlacement::Colocated { models, device: 0, result } => {
            assert_eq!(models, &[0, 1], "both models, in input order");
            assert_eq!(result.tenants.len(), joint.tenants().len());
            for (a, b) in result.tenants.iter().zip(joint.tenants()) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.share, b.share, "identical budget shares");
                assert_eq!(a.result.design.cfgs, b.result.design.cfgs);
                assert_eq!(a.result.throughput, b.result.throughput);
            }
            assert_eq!(result.min_norm_throughput, joint.result().min_norm_throughput);
        }
        other => panic!("expected a Colocated placement, got {other:?}"),
    }
    match &fleet.schedules()[0] {
        PlacementSchedule::Colocated(port) => assert_eq!(port, joint.port_schedule()),
        other => panic!("expected a Colocated schedule, got {other:?}"),
    }
    for name in ["resnet18", "squeezenet"] {
        assert_eq!(fleet.input_len(name), joint.input_len(name));
    }

    let sim_cfg = SimConfig::default();
    let sim_joint = joint.simulate(&sim_cfg);
    let sim_fleet = fleet.simulate(&sim_cfg);
    match &sim_fleet.per_placement[0] {
        PlacementSim::Colocated(r) => {
            assert_eq!(r.makespan_s, sim_joint.makespan_s, "bit-identical makespan");
            assert_eq!(r.total_stall_s, sim_joint.total_stall_s);
            assert_eq!(r.events, sim_joint.events);
        }
        other => panic!("expected a Colocated sim, got {other:?}"),
    }
}

/// Satellite: the FOURTH cache schema never cross-answers the other three —
/// while the fleet search's solo sub-evaluations deliberately land in the
/// shared single-device map.
#[test]
fn four_cache_schemas_never_cross_answer() {
    let cache = DesignCache::new();
    let cfg = DseConfig::default();
    let toy = || Deployment::for_model("toy").quant(Quant::W8A8);

    // fleet 1×1 first: fills the fleet map AND (via its solo evaluation)
    // the single-device map
    let f = Deployment::fleet([toy()], &["zcu102"])
        .unwrap()
        .explore_in(&cache, &cfg)
        .unwrap();
    assert!(!f.was_cached());

    // the same content through the 1-chain and 1-tenant schemas MISSES —
    // their maps were never touched by the fleet lookup
    let p = toy().on_devices(&["zcu102"]).unwrap().explore_in(&cache, &cfg).unwrap();
    assert!(!p.was_cached(), "the 1-chain schema must not be answered by the fleet map");
    let c = Deployment::colocate([toy()])
        .on_device("zcu102")
        .unwrap()
        .explore_in(&cache, &cfg)
        .unwrap();
    assert!(!c.was_cached(), "the 1-tenant schema must not be answered by the fleet map");

    // ...but the single-device schema HITS: fleet sub-evaluations share the
    // first three maps by design (whole placements stay in the fourth)
    let s = toy().on_device("zcu102").unwrap().explore_in(&cache, &cfg).unwrap();
    assert!(s.was_cached(), "fleet solo sub-evaluations land in the single-device map");

    // a second identical fleet plan hits the fourth map...
    let f2 = Deployment::fleet([toy()], &["zcu102"])
        .unwrap()
        .explore_in(&cache, &cfg)
        .unwrap();
    assert!(f2.was_cached());
    // ...and the objective is part of the key, so changing it re-searches
    let f3 = Deployment::fleet([toy()], &["zcu102"])
        .unwrap()
        .with_objective(FleetObjective::MinDevicesAtSlo { p99_ms: 1e9 })
        .explore_in(&cache, &cfg)
        .unwrap();
    assert!(!f3.was_cached(), "the objective must be part of the fleet key");
}

/// Acceptance: on a mixed [zc706, zcu102, zcu102] pool under a p99 SLO that
/// no single board can meet for resnet50, the search shards resnet50 across
/// two boards and co-locates the small resnet18+squeezenet pair on the
/// remaining one. The SLO threshold is derived from the physics so the test
/// tracks the model, not magic numbers.
#[test]
fn resnet50_shards_while_the_small_pair_colocates() {
    let cfg = DseConfig::default();
    let pool = [Device::zc706(), Device::zcu102(), Device::zcu102()];
    let r50 = autows::models::resnet50(Quant::W8A8);
    let r18 = autows::models::resnet18(Quant::W4A5);
    let sqz = autows::models::squeezenet(Quant::W8A8);

    // best solo tail-latency proxy for resnet50 anywhere in the pool
    let m_solo_min = pool
        .iter()
        .map(|d| {
            dse::run(&r50, d, &cfg)
                .map_or(f64::INFINITY, |r| slo_metric(r.latency_ms, r.throughput))
        })
        .fold(f64::INFINITY, f64::min);
    // sharding across the two zcu102s beats every solo option...
    let shard = partition::partition(&r50, &pool[1..], &cfg).expect("2x zcu102 must shard");
    let m_shard = slo_metric(shard.latency_ms(), shard.throughput);
    assert!(m_shard < m_solo_min, "precondition: sharding helps ({m_shard} vs {m_solo_min})");
    // ...and the small pair co-locates acceptably on either board flavour
    let m_colo = [&pool[0], &pool[1]]
        .into_iter()
        .map(|d| {
            let joint = colocate::colocate(&[r18.clone(), sqz.clone()], d, &cfg)
                .expect("the small pair must co-locate");
            joint
                .tenants
                .iter()
                .map(|t| slo_metric(t.result.latency_ms, t.result.throughput))
                .fold(0.0, f64::max)
        })
        .fold(0.0, f64::max);
    assert!(m_colo < m_solo_min, "precondition: co-location beats solo resnet50");

    // an SLO between "what sharding/co-location achieve" and "what any solo
    // resnet50 achieves": only the mixed placement can satisfy it
    let p99_ms = 0.5 * (m_shard.max(m_colo) + m_solo_min);

    let fleet = Deployment::fleet(
        [
            Deployment::for_model("resnet50").quant(Quant::W8A8),
            resnet18(),
            squeezenet(),
        ],
        &["zc706", "zcu102", "zcu102"],
    )
    .unwrap()
    .with_objective(FleetObjective::MinDevicesAtSlo { p99_ms })
    .explore_uncached(&cfg)
    .expect("the fleet must place")
    .schedule();

    assert_eq!(fleet.placements().len(), 2, "one shard + one co-located pair");
    assert_eq!(fleet.result().devices_used, 3);
    let sharded = fleet
        .placements()
        .iter()
        .find_map(|p| match p {
            FleetPlacement::Sharded { model: 0, devices, result } => Some((devices, result)),
            _ => None,
        })
        .expect("resnet50 must shard");
    assert_eq!(sharded.0.len(), 2, "across two boards");
    assert!(
        slo_metric(sharded.1.latency_ms(), sharded.1.throughput) <= p99_ms,
        "the shard meets the SLO"
    );
    let colocated = fleet
        .placements()
        .iter()
        .find_map(|p| match p {
            FleetPlacement::Colocated { models, device, result } => {
                Some((models, device, result))
            }
            _ => None,
        })
        .expect("the small pair must co-locate");
    assert_eq!(colocated.0, &[1, 2], "resnet18 + squeezenet, in input order");
    assert!(!sharded.0.contains(colocated.1), "on the remaining board");
    for t in &colocated.2.tenants {
        assert!(
            slo_metric(t.result.latency_ms, t.result.throughput) <= p99_ms,
            "{} meets the SLO",
            t.name
        );
    }

    // the placement table names every mode
    let report = fleet.report();
    assert!(report.contains("sharded"), "{report}");
    assert!(report.contains("colocated"), "{report}");
    assert!(report.contains("min-devices-at-slo"), "{report}");
}

/// Satellite: a typo'd pool name is the typed [`Error::UnknownDevice`]
/// carrying the known board list (the CLI `--devices` path resolves through
/// the same entry point).
#[test]
fn unknown_pool_device_is_typed_with_known_boards() {
    let e = Deployment::fleet([resnet18()], &["zcu9000"]).unwrap_err();
    match e {
        Error::UnknownDevice { ref name, ref known } => {
            assert_eq!(name, "zcu9000");
            assert!(known.iter().any(|k| k == "zcu102"), "known list: {known:?}");
        }
        other => panic!("expected UnknownDevice, got {other:?}"),
    }
    // empty lists and duplicate names are typed too
    let e = Deployment::fleet(Vec::new(), &["zcu102"]).unwrap_err();
    assert!(matches!(e, Error::Usage(_)), "{e}");
    let e = Deployment::fleet([resnet18(), resnet18()], &["zcu102", "zc706"]).unwrap_err();
    assert!(matches!(e, Error::DuplicateModel(_)), "{e}");
}

/// The serving terminal: a two-model fleet behind one router answers
/// requests for both models and rolls metrics up per model.
#[test]
fn fleet_serves_both_models_through_one_router() {
    use autows::coordinator::{BatchPolicy, ServerOptions};

    let fleet = Deployment::fleet([resnet18(), squeezenet()], &["zcu102", "zc706"])
        .unwrap()
        .explore_uncached(&DseConfig::default())
        .unwrap()
        .schedule();
    let router = fleet
        .serve(
            BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
            ServerOptions::default(),
        )
        .unwrap();
    assert_eq!(router.models(), vec!["resnet18".to_string(), "squeezenet".to_string()]);

    for name in ["resnet18", "squeezenet"] {
        let input_len = fleet.input_len(name).expect("planned above");
        let mut pending = Vec::new();
        for _ in 0..6 {
            pending.push(router.submit(name, vec![0.5; input_len]).unwrap());
        }
        for rx in pending {
            rx.recv().expect("reply channel alive").expect("no typed error");
        }
        let m = router.model_metrics(name).expect("routed above");
        assert_eq!(m.requests, 6, "{name}");
        assert!(m.throughput_rps > 0.0);
    }
    // an unknown model is a typed error, not a hang
    let e = router.submit("vgg16", vec![0.0; 8]).unwrap_err();
    assert!(matches!(e, Error::UnknownModel(_)), "{e}");
    router.shutdown();
}
