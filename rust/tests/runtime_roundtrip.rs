//! Integration: python-AOT HLO-text artifacts -> PJRT load -> execute, with
//! numerics checked against values computed independently in Rust.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use autows::runtime::{Runtime, Tensor};

fn artifact(name: &str) -> Option<String> {
    let path = format!("{}/artifacts/{}", env!("CARGO_MANIFEST_DIR"), name);
    if std::path::Path::new(&path).exists() {
        Some(path)
    } else {
        eprintln!("SKIP: {path} missing — run `make artifacts`");
        None
    }
}

/// Tiny deterministic PRNG (xorshift*) so the test needs no rand crate.
struct Rng(u64);
impl Rng {
    fn next_f32(&mut self) -> f32 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        ((self.0 >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
    }
}

#[test]
fn stream_matmul_artifact_matches_rust_reference() {
    let Some(path) = artifact("stream_matmul.hlo.txt") else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let model = rt.load_hlo_text(&path).expect("load artifact");

    // deterministic inputs
    let mut rng = Rng(0x12345678);
    let x: Vec<f32> = (0..8 * 64).map(|_| (rng.next_f32() * 4.0).round()).collect();
    let w: Vec<f32> = (0..64 * 32).map(|_| (rng.next_f32() * 4.0).round()).collect();

    let out = model
        .run(&[
            Tensor::new(x.clone(), vec![8, 64]).unwrap(),
            Tensor::new(w.clone(), vec![64, 32]).unwrap(),
        ])
        .expect("execute");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].dims, vec![8, 32]);

    // rust-side reference matmul — integer values, must be exact
    for i in 0..8 {
        for j in 0..32 {
            let want: f32 = (0..64).map(|l| x[i * 64 + l] * w[l * 32 + j]).sum();
            let got = out[0].data[i * 32 + j];
            assert_eq!(got, want, "mismatch at ({i},{j})");
        }
    }
}

#[test]
fn toy_cnn_artifacts_load_and_execute() {
    let Some(p1) = artifact("toy_cnn_b1.hlo.txt") else { return };
    let Some(p8) = artifact("toy_cnn_b8.hlo.txt") else { return };
    let rt = Runtime::cpu().unwrap();
    let m1 = rt.load_hlo_text(&p1).unwrap();
    let m8 = rt.load_hlo_text(&p8).unwrap();

    let mut rng = Rng(42);
    let img: Vec<f32> = (0..3 * 32 * 32).map(|_| rng.next_f32()).collect();

    let o1 = m1.run(&[Tensor::new(img.clone(), vec![1, 3, 32, 32]).unwrap()]).unwrap();
    assert_eq!(o1[0].dims, vec![1, 10]);
    assert!(o1[0].data.iter().all(|v| v.is_finite()));

    // batch-8 artifact with the same image in slot 0 must agree on slot 0
    let mut batch = img.clone();
    batch.resize(8 * 3 * 32 * 32, 0.0);
    let o8 = m8.run(&[Tensor::new(batch, vec![8, 3, 32, 32]).unwrap()]).unwrap();
    assert_eq!(o8[0].dims, vec![8, 10]);
    for j in 0..10 {
        let d = (o8[0].data[j] - o1[0].data[j]).abs();
        assert!(d < 1e-4, "slot-0 logit {j}: b8 {} vs b1 {}", o8[0].data[j], o1[0].data[j]);
    }
}

#[test]
fn toy_cnn_is_deterministic_across_runs() {
    let Some(p1) = artifact("toy_cnn_b1.hlo.txt") else { return };
    let rt = Runtime::cpu().unwrap();
    let m = rt.load_hlo_text(&p1).unwrap();
    let img: Vec<f32> = (0..3 * 32 * 32).map(|i| (i % 17) as f32 / 17.0).collect();
    let t = Tensor::new(img, vec![1, 3, 32, 32]).unwrap();
    let a = m.run(std::slice::from_ref(&t)).unwrap();
    let b = m.run(std::slice::from_ref(&t)).unwrap();
    assert_eq!(a[0].data, b[0].data);
}

#[test]
fn mobile_block_artifact_loads_and_preserves_residual() {
    let Some(p) = artifact("mobile_block_b4.hlo.txt") else { return };
    let rt = Runtime::cpu().unwrap();
    let m = rt.load_hlo_text(&p).unwrap();

    let mut rng = Rng(0xBEEF);
    let x: Vec<f32> = (0..4 * 16 * 14 * 14).map(|_| rng.next_f32()).collect();
    let out = m.run(&[Tensor::new(x.clone(), vec![4, 16, 14, 14]).unwrap()]).unwrap();
    assert_eq!(out[0].dims, vec![4, 16, 14, 14]);
    assert!(out[0].data.iter().all(|v| v.is_finite()));

    // The block is quantized-input + residual branch: its output must be
    // correlated with (close in scale to) the input, not a runaway value.
    let in_rms = (x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32).sqrt();
    let out_rms =
        (out[0].data.iter().map(|v| v * v).sum::<f32>() / out[0].data.len() as f32).sqrt();
    assert!(
        out_rms > 0.1 * in_rms && out_rms < 10.0 * in_rms,
        "residual block output scale off: in {in_rms} out {out_rms}"
    );
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let rt = Runtime::cpu().unwrap();
    let Err(err) = rt.load_hlo_text("/nonexistent/foo.hlo.txt") else {
        panic!("loading a missing artifact must fail");
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("artifacts"), "helpful message expected, got: {msg}");
}
