//! Equivalence gate for the steady-state fast-forward simulator (PR 9).
//!
//! The fast engines (`sim::simulate`, `sim::simulate_colocated`,
//! `sim::simulate_partitioned`) must agree with the preserved
//! pre-fast-forward engines (`sim::reference`) on every result field —
//! exactly for the integer event counts, to ≤1e-9 relative for every
//! float — across the model zoo × device grid × batch sizes, and with
//! `fast_forward: false` they must be **bit-identical** (same loop, new
//! queue). `events_processed` is the one deliberate difference: it is the
//! diagnostic count of events the engine stepped rather than skipped.
//!
//! Debug builds cap the grid at batch 8 (the reference engine grinds
//! through every event, and a 256-batch resnet50 train is ~1e8 of them);
//! release builds — `scripts/bench_sim.sh`, `cargo test --release` — run
//! the full batch-256 comparison.

use autows::device::Device;
use autows::dse::{self, colocate, partition, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::pipeline::{Deployment, PlacementSim};
use autows::sim::{self, reference, simulate, SimConfig, SimResult};

/// ≤1e-9 relative, with a span-scaled absolute floor for accumulators that
/// sit at (or within rounding of) zero.
fn close(a: f64, b: f64, span: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()) + 1e-12 * span
}

fn assert_sim_close(name: &str, fast: &SimResult, oracle: &SimResult) {
    let span = oracle.makespan_s.max(1e-30);
    assert_eq!(fast.events, oracle.events, "{name}: semantic event count");
    assert!(
        close(fast.makespan_s, oracle.makespan_s, span),
        "{name}: makespan {} vs {}",
        fast.makespan_s,
        oracle.makespan_s
    );
    assert!(
        close(fast.latency_ms, oracle.latency_ms, span * 1e3),
        "{name}: latency {} vs {}",
        fast.latency_ms,
        oracle.latency_ms
    );
    assert!(
        close(fast.total_stall_s, oracle.total_stall_s, span),
        "{name}: stall {} vs {}",
        fast.total_stall_s,
        oracle.total_stall_s
    );
    assert!(
        close(fast.dma_busy_frac, oracle.dma_busy_frac, 1.0),
        "{name}: busy {} vs {}",
        fast.dma_busy_frac,
        oracle.dma_busy_frac
    );
    assert_eq!(fast.per_layer_stall_s.len(), oracle.per_layer_stall_s.len(), "{name}");
    for (i, (&a, &b)) in
        fast.per_layer_stall_s.iter().zip(&oracle.per_layer_stall_s).enumerate()
    {
        assert!(close(a, b, span), "{name}: layer {i} stall {a} vs {b}");
    }
    for (i, (&a, &b)) in
        fast.per_layer_contention_s.iter().zip(&oracle.per_layer_contention_s).enumerate()
    {
        assert!(close(a, b, span), "{name}: layer {i} contention {a} vs {b}");
    }
}

/// The zoo grid: every feasible (model, device) pair at several batch
/// sizes. Known-feasible anchor cases must actually run — a silently empty
/// grid would gate nothing.
#[test]
fn fast_forward_matches_reference_across_the_zoo() {
    let zoo: &[(&str, Quant)] = &[
        ("toy", Quant::W8A8),
        ("resnet18", Quant::W4A5),
        ("resnet50", Quant::W4A5),
        ("squeezenet", Quant::W8A8),
        ("vgg16", Quant::W4A4),
        ("yolov5n", Quant::W8A8),
    ];
    let devices = [Device::zcu102(), Device::u250()];
    let batches: &[u64] = if cfg!(debug_assertions) { &[1, 8] } else { &[1, 8, 256] };
    let cfg = DseConfig::default();

    let mut compared = Vec::new();
    for (model, quant) in zoo {
        let net = models::by_name(model, *quant).unwrap();
        for dev in &devices {
            let Some(r) = dse::run(&net, dev, &cfg) else { continue };
            for &batch in batches {
                let sim_cfg = SimConfig { batch, ..Default::default() };
                let fast = simulate(&r.design, dev, &sim_cfg);
                // debug builds: skip reference runs that would grind through
                // tens of millions of events at unoptimized speed
                if cfg!(debug_assertions) && fast.events > 5_000_000 {
                    continue;
                }
                let oracle = reference::simulate(&r.design, dev, &sim_cfg);
                let name = format!("{model}/{}-b{batch}", dev.name);
                assert_sim_close(&name, &fast, &oracle);
                assert!(
                    fast.events_processed <= fast.events,
                    "{name}: processed is a subset of the semantic count"
                );
                compared.push(name);
            }
        }
    }
    for anchor in ["resnet18/zcu102-b8", "resnet50/zcu102-b8", "resnet18/u250-b1"] {
        assert!(
            compared.iter().any(|n| n == anchor),
            "anchor case {anchor} must be feasible and compared (got {compared:?})"
        );
    }
}

/// Batch 256 (the acceptance batch): the fast engine must actually skip —
/// processing at least 10× fewer events than the semantic count — while
/// its results stay self-consistent with the batch-8 run of the same
/// design. (The full batch-256 reference comparison runs in release via
/// the zoo grid above and `scripts/bench_sim.sh`.)
#[test]
fn big_batch_fast_forward_skips_and_scales() {
    let net = models::resnet50(Quant::W4A5);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).expect("resnet50 streams on zcu102");

    let small = simulate(&r.design, &dev, &SimConfig { batch: 8, ..Default::default() });
    let big = simulate(&r.design, &dev, &SimConfig { batch: 256, ..Default::default() });
    assert!(big.events > small.events, "more iterations, more semantic events");
    assert!(
        big.events_processed * 10 <= big.events,
        "fast-forward must skip ≥10× of a 256-batch train (processed {} of {})",
        big.events_processed,
        big.events
    );
    // throughput is batch-linear once the pipeline is warm: 32× the batch
    // takes ~32× the makespan, within a generous pipeline-fill allowance
    let scale = big.makespan_s / small.makespan_s;
    assert!(
        (16.0..=64.0).contains(&scale),
        "batch 8 -> 256 must scale the makespan ~32x, got {scale:.2}x"
    );
}

/// With fast-forward disabled the engine is the reference loop over a
/// different queue: results must be bit-identical, not just close.
#[test]
fn disabled_fast_forward_is_bit_identical_to_reference() {
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
    for batch in [1, 4] {
        let cfg = SimConfig { batch, fast_forward: false, ..Default::default() };
        let full = simulate(&r.design, &dev, &cfg);
        let oracle = reference::simulate(&r.design, &dev, &cfg);
        assert_eq!(full, oracle, "batch {batch}: full loop must be bit-identical");
        assert_eq!(full.events, full.events_processed, "nothing skipped");
    }
    // the imbalanced fig5 scenario stalls: the stall path must match too
    let (design, fig_dev) = sim::fig5_scenario(false);
    let cfg = SimConfig { batch: 4, fast_forward: false, ..Default::default() };
    assert_eq!(
        simulate(&design, &fig_dev, &cfg),
        reference::simulate(&design, &fig_dev, &cfg),
        "stalling schedule must be bit-identical with fast-forward off"
    );
}

/// Co-located (multi-tenant) joint simulation: fast vs reference heap.
#[test]
fn colocated_fast_forward_matches_reference() {
    let nets = [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
    let dev = Device::zcu102();
    let joint = colocate::colocate(&nets, &dev, &DseConfig::default()).expect("pair fits");
    let tenants: Vec<(&str, &dse::Design, &Device)> = joint
        .tenants
        .iter()
        .map(|t| (t.name.as_str(), &t.result.design, &t.view))
        .collect();
    let cfg = SimConfig { batch: 4, ..Default::default() };
    let fast = sim::simulate_colocated(&tenants, &dev, &cfg);
    let oracle = reference::simulate_colocated(&tenants, &dev, &cfg);

    let span = oracle.makespan_s.max(1e-30);
    assert_eq!(fast.events, oracle.events);
    assert!(close(fast.makespan_s, oracle.makespan_s, span));
    assert!(close(fast.latency_ms, oracle.latency_ms, span * 1e3));
    assert!(close(fast.total_stall_s, oracle.total_stall_s, span));
    assert!(close(fast.port_busy_frac, oracle.port_busy_frac, 1.0));
    assert_eq!(fast.per_tenant.len(), oracle.per_tenant.len());
    for (a, b) in fast.per_tenant.iter().zip(&oracle.per_tenant) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.events, b.events, "{}", a.name);
        assert!(close(a.makespan_s, b.makespan_s, span), "{}", a.name);
        assert!(close(a.total_stall_s, b.total_stall_s, span), "{}", a.name);
        assert!(close(a.contention_s, b.contention_s, span), "{}", a.name);
    }
}

/// Sharded (multi-device) chain: per-partition fast engines composed with
/// the link model vs the same composition over the reference engine.
#[test]
fn partitioned_fast_forward_matches_reference() {
    let net = models::resnet50(Quant::W4A5);
    let devs = [Device::zcu102(), Device::zcu102()];
    let sharded =
        partition::partition(&net, &devs, &DseConfig::default()).expect("2x zcu102 chain");
    let stages: Vec<(&dse::Design, &Device)> =
        sharded.parts.iter().map(|p| (&p.result.design, &p.device)).collect();
    let cfg = SimConfig { batch: 8, ..Default::default() };
    let fast = sim::simulate_partitioned(&stages, &cfg);
    let oracle = reference::simulate_partitioned(&stages, &cfg);

    let span = oracle.makespan_s.max(1e-30);
    assert!(close(fast.makespan_s, oracle.makespan_s, span));
    assert!(close(fast.latency_ms, oracle.latency_ms, span * 1e3));
    assert!(close(fast.steady_period_s, oracle.steady_period_s, span));
    assert!(close(fast.total_stall_s, oracle.total_stall_s, span));
    assert_eq!(fast.per_partition.len(), oracle.per_partition.len());
    for (i, (a, b)) in fast.per_partition.iter().zip(&oracle.per_partition).enumerate() {
        assert_sim_close(&format!("partition {i}"), a, b);
    }
    assert_eq!(fast.links.len(), oracle.links.len());
}

/// Fleet rollup: the (now parallel) per-placement fan-out must agree with
/// reference simulations of each placement, in placement order.
#[test]
fn fleet_simulation_matches_per_placement_reference() {
    use autows::dse::FleetPlacement;
    let fleet = Deployment::fleet(
        [
            Deployment::for_model("resnet18").quant(Quant::W4A5),
            Deployment::for_model("squeezenet").quant(Quant::W8A8),
        ],
        &["zcu102", "zc706"],
    )
    .unwrap()
    .explore_uncached(&DseConfig::default())
    .expect("pair places on the pool")
    .schedule();
    let pool = [Device::zcu102(), Device::zc706()];

    let cfg = SimConfig { batch: 4, ..Default::default() };
    let report = fleet.simulate(&cfg);
    assert_eq!(report.per_placement.len(), fleet.placements().len());

    for (sim, placement) in report.per_placement.iter().zip(fleet.placements()) {
        match (sim, placement) {
            (PlacementSim::Solo(fast), FleetPlacement::Solo { device, result, .. }) => {
                let oracle = reference::simulate(&result.design, &pool[*device], &cfg);
                assert_sim_close("fleet solo", fast, &oracle);
            }
            (PlacementSim::Sharded(fast), FleetPlacement::Sharded { result, .. }) => {
                let stages: Vec<(&dse::Design, &Device)> =
                    result.parts.iter().map(|p| (&p.result.design, &p.device)).collect();
                let oracle = reference::simulate_partitioned(&stages, &cfg);
                let span = oracle.makespan_s.max(1e-30);
                assert!(close(fast.makespan_s, oracle.makespan_s, span), "fleet shard");
                assert!(close(fast.total_stall_s, oracle.total_stall_s, span));
            }
            (PlacementSim::Colocated(fast), FleetPlacement::Colocated { device, result, .. }) => {
                let tenants: Vec<(&str, &dse::Design, &Device)> = result
                    .tenants
                    .iter()
                    .map(|t| (t.name.as_str(), &t.result.design, &t.view))
                    .collect();
                let oracle = reference::simulate_colocated(&tenants, &pool[*device], &cfg);
                let span = oracle.makespan_s.max(1e-30);
                assert_eq!(fast.events, oracle.events);
                assert!(close(fast.makespan_s, oracle.makespan_s, span), "fleet colo");
                assert!(close(fast.total_stall_s, oracle.total_stall_s, span));
            }
            (sim, placement) => {
                panic!("placement/simulation shape mismatch: {placement:?} vs {sim:?}")
            }
        }
    }
}
