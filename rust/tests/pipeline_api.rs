//! Integration tests of `autows::pipeline`: the golden equivalence against
//! the direct `dse::run` path, the design-cache hit semantics, the staged
//! error surface, and the terminal stages. (The stage-*ordering* guarantees
//! are compile-time and covered by the `compile_fail` doc-tests on
//! `autows::pipeline`.)

use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::pipeline::{Deployment, DesignCache};
use autows::sim::SimConfig;
use autows::{models, Error};

/// Golden: the pipeline's resnet18/zcu102/w4a5 design is bit-identical to
/// the direct `dse::run` result — the builder adds no semantic drift.
#[test]
fn golden_resnet18_zcu102_matches_direct_dse() {
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let cfg = DseConfig::default();
    let direct = dse::run(&net, &dev, &cfg).expect("direct path feasible");

    let explored = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device("zcu102")
        .expect("model and device resolve")
        .explore_uncached(&cfg)
        .expect("pipeline path feasible");
    let r = explored.result();

    assert_eq!(r.design.cfgs, direct.design.cfgs, "per-layer configs must be identical");
    assert_eq!(r.design.off_bits, direct.design.off_bits, "evicted bits must be identical");
    assert_eq!(r.throughput, direct.throughput, "bit-identical throughput");
    assert_eq!(r.latency_ms, direct.latency_ms, "bit-identical latency");
    assert_eq!(r.area, direct.area, "identical area");
    assert_eq!(r.bandwidth_bps, direct.bandwidth_bps, "bit-identical bandwidth");
    assert_eq!(r.iterations, direct.iterations, "same greedy iteration count");
}

/// The cached explore path returns the same design as the uncached one.
#[test]
fn cached_explore_equals_uncached() {
    let cfg = DseConfig::default().with_phi(2);
    let plan = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_device("zcu102")
        .unwrap();
    let cached = plan.clone().explore(&cfg).unwrap();
    let uncached = plan.explore_uncached(&cfg).unwrap();
    assert_eq!(cached.design().cfgs, uncached.design().cfgs);
    assert_eq!(cached.result().throughput, uncached.result().throughput);
}

/// Cache-hit semantics: a second `.explore()` with an identical key does no
/// DSE work — asserted via the cache's eval counters.
#[test]
fn second_explore_hits_cache_without_dse_work() {
    let cache = DesignCache::new();
    let cfg = DseConfig::default();
    let plan = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_device("zcu102")
        .unwrap();

    let first = plan.clone().explore_in(&cache, &cfg).unwrap();
    assert!(!first.was_cached(), "first explore must run the DSE");
    let s = cache.stats();
    assert_eq!((s.hits, s.misses, s.entries), (0, 1, 1));

    let second = plan.clone().explore_in(&cache, &cfg).unwrap();
    assert!(second.was_cached(), "identical key must hit");
    let s = cache.stats();
    assert_eq!((s.hits, s.misses), (1, 1), "no second DSE run");
    assert_eq!(s.entries, 1, "no duplicate entry");
    assert_eq!(second.design().cfgs, first.design().cfgs, "hit returns the same design");
    assert_eq!(second.result().throughput, first.result().throughput);

    // any key ingredient change misses: φ, µ, batch, device budget, quant
    let third = plan.clone().explore_in(&cache, &cfg.with_mu(256)).unwrap();
    assert!(!third.was_cached(), "different µ is a different design point");
    assert_eq!(cache.stats().misses, 2);
}

/// Infeasible design points are routine errors, matchable and cached.
#[test]
fn infeasible_is_typed_and_cached() {
    let cache = DesignCache::new();
    let plan = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device("zedboard")
        .unwrap();
    let e = plan.clone().explore_in(&cache, &DseConfig::vanilla()).unwrap_err();
    assert!(e.is_infeasible(), "{e}");
    assert!(e.to_string().contains("resnet18") && e.to_string().contains("zedboard"), "{e}");
    // the infeasible outcome is memoized too
    let _ = plan.explore_in(&cache, &DseConfig::vanilla()).unwrap_err();
    let s = cache.stats();
    assert_eq!((s.hits, s.misses), (1, 1));
}

/// Stage-0 lookup failures surface as typed errors at `.on_device()`.
#[test]
fn unknown_names_are_typed_errors() {
    let e = Deployment::for_model("resnet18").on_device("zcu9000").unwrap_err();
    assert!(matches!(e, Error::UnknownDevice { .. }), "{e}");

    let e = Deployment::for_model("resnet9000").on_device("zcu102").unwrap_err();
    assert!(matches!(e, Error::UnknownModel(_)), "{e}");

    let e = Deployment::for_model("toy").quant_label("w3b7").unwrap_err();
    assert!(matches!(e, Error::UnknownQuant(_)), "{e}");

    let e = Deployment::for_net_file("nets/does_not_exist.net")
        .on_device("zcu102")
        .unwrap_err();
    assert!(matches!(e, Error::Io { .. }), "{e}");
}

/// The terminal stages work end to end on a small design: schedule metrics
/// are consistent and the simulator validates the schedule.
#[test]
fn schedule_and_simulate_terminals() {
    let scheduled = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device("zedboard")
        .unwrap()
        .explore(&DseConfig::default())
        .unwrap()
        .schedule();
    assert!(
        !scheduled.burst_schedule().entries.is_empty(),
        "resnet18 on zedboard must stream"
    );
    assert!(scheduled.burst_schedule().schedulable(), "burst schedule must be stall-free");

    let report = scheduled.report();
    assert!(report.contains("resnet18"), "{report}");
    assert!(report.contains("streaming layers"), "{report}");

    let sim = scheduled.simulate(&SimConfig::default());
    assert!(sim.makespan_s > 0.0);
    let analytic = scheduled.design().latency_ms(1);
    assert!(
        sim.latency_ms >= analytic * 0.999,
        "the simulator must not beat the analytic stall-free bound: \
         sim {} vs analytic {analytic}",
        sim.latency_ms
    );
}

/// A checkpoint round-trip through `adopt_design` preserves the design.
#[test]
fn adopt_design_roundtrip() {
    let plan = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_device("zcu102")
        .unwrap();
    let explored = plan.clone().explore(&DseConfig::default()).unwrap();
    let text = dse::serialize_design(explored.design(), plan.device());
    let design = dse::parse_design(&text, plan.network(), plan.device()).unwrap();
    let adopted = plan.adopt_design(design);
    assert_eq!(adopted.design().cfgs, explored.design().cfgs);
    assert_eq!(adopted.result().throughput, explored.result().throughput);
}

/// Serving terminal: the SimOnly engine serves real requests from a
/// pipeline-built design.
#[test]
fn serve_terminal_sim_only() {
    use autows::coordinator::{BatchPolicy, ServerOptions};
    let scheduled = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_device("zcu102")
        .unwrap()
        .explore(&DseConfig::default())
        .unwrap()
        .schedule();
    let server = scheduled.serve(BatchPolicy::default(), ServerOptions::default()).unwrap();
    let resp = server.infer(vec![0.5; scheduled.input_len()]).unwrap();
    assert_eq!(resp.output.len(), 10);
    assert!(resp.accel > std::time::Duration::ZERO);
    server.shutdown();
}
