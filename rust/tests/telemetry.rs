//! Telemetry integration tests: golden exposition formats and a live
//! served session.
//!
//! The format tests pin the **exact** Prometheus text, JSON snapshot, and
//! Chrome trace-event documents for a hand-built [`TelemetrySnapshot`] —
//! the exporters are pure functions of the snapshot, so these are true
//! goldens (no load-dependent noise). The live test boots a real sharded
//! server, drives it, and checks the properties that matter across any
//! load: snapshots fold idempotently, spans account for every request,
//! and the serving-path lock tripwire stays at zero with recording on.

use std::time::Duration;

use autows::coordinator::{
    BatchPolicy, MetricsSnapshot, Server, ServerOptions, SimOnlyEngine, WorkerStats,
};
use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::pipeline::drive_synthetic;
use autows::telemetry::{
    chrome_trace_spans, json_snapshot, prometheus_text, span_stats, Span, SpanKind,
    TelemetrySnapshot, SHARD_LANE_BASE,
};

/// A fully determined snapshot: every float chosen to render exactly
/// (`4.0` → `4`, `1.5` → `1.5`) so the goldens are byte-stable.
fn fixture() -> TelemetrySnapshot {
    TelemetrySnapshot {
        metrics: MetricsSnapshot {
            requests: 12,
            batches: 3,
            mean_batch: 4.0,
            p50_ms: 1.5,
            p95_ms: 2.5,
            p99_ms: 3.5,
            mean_ms: 1.75,
            throughput_rps: 256.0,
            sim_accel_s: 0.125,
            per_worker: vec![
                WorkerStats { batches: 2, requests: 8, busy_s: 0.25 },
                WorkerStats { batches: 1, requests: 4, busy_s: 0.125 },
            ],
            queue_depth_mean: 1.5,
            queue_depth_max: 4,
        },
        counters: vec![("cache_hits".to_string(), 7), ("sim_runs".to_string(), 2)],
        spans: vec![
            Span { kind: SpanKind::Wait, lane: 0, items: 4, start_us: 0, dur_us: 10 },
            Span { kind: SpanKind::Engine, lane: 0, items: 4, start_us: 10, dur_us: 30 },
            Span { kind: SpanKind::Engine, lane: 1, items: 2, start_us: 15, dur_us: 20 },
            Span { kind: SpanKind::Batch, lane: SHARD_LANE_BASE, items: 4, start_us: 2, dur_us: 3 },
        ],
    }
}

const PROM_GOLDEN: &str = "\
# HELP autows_requests_total Requests completed by the serving session.
# TYPE autows_requests_total counter
autows_requests_total 12
# HELP autows_batches_total Engine batches executed.
# TYPE autows_batches_total counter
autows_batches_total 3
# HELP autows_mean_batch Mean requests per engine batch.
# TYPE autows_mean_batch gauge
autows_mean_batch 4
# HELP autows_throughput_rps Achieved request throughput over the session.
# TYPE autows_throughput_rps gauge
autows_throughput_rps 256
# HELP autows_latency_ms Request latency distribution, milliseconds.
# TYPE autows_latency_ms gauge
autows_latency_ms{quantile=\"0.5\"} 1.5
autows_latency_ms{quantile=\"0.95\"} 2.5
autows_latency_ms{quantile=\"0.99\"} 3.5
autows_latency_ms{quantile=\"mean\"} 1.75
# HELP autows_queue_depth Dispatch-point queue depth (requests admitted, not yet on an engine).
# TYPE autows_queue_depth gauge
autows_queue_depth{stat=\"mean\"} 1.5
autows_queue_depth{stat=\"max\"} 4
# HELP autows_sim_accel_seconds_total Simulated accelerator busy time, seconds.
# TYPE autows_sim_accel_seconds_total counter
autows_sim_accel_seconds_total 0.125
# HELP autows_worker_batches_total Batches served per pool worker.
# TYPE autows_worker_batches_total counter
autows_worker_batches_total{worker=\"0\"} 2
autows_worker_batches_total{worker=\"1\"} 1
# HELP autows_worker_requests_total Requests served per pool worker.
# TYPE autows_worker_requests_total counter
autows_worker_requests_total{worker=\"0\"} 8
autows_worker_requests_total{worker=\"1\"} 4
# HELP autows_worker_busy_seconds_total Engine busy time per pool worker, seconds.
# TYPE autows_worker_busy_seconds_total counter
autows_worker_busy_seconds_total{worker=\"0\"} 0.25
autows_worker_busy_seconds_total{worker=\"1\"} 0.125
# HELP autows_spans_total Serving-path spans recorded per kind (ring-resident).
# TYPE autows_spans_total counter
autows_spans_total{kind=\"wait\"} 1
autows_spans_total{kind=\"engine\"} 2
autows_spans_total{kind=\"reply\"} 0
autows_spans_total{kind=\"batch\"} 1
autows_spans_total{kind=\"steal\"} 0
# HELP autows_span_items_total Requests covered by the recorded spans, per kind.
# TYPE autows_span_items_total counter
autows_span_items_total{kind=\"wait\"} 4
autows_span_items_total{kind=\"engine\"} 6
autows_span_items_total{kind=\"reply\"} 0
autows_span_items_total{kind=\"batch\"} 4
autows_span_items_total{kind=\"steal\"} 0
# HELP autows_span_duration_us_sum Summed span duration per kind, microseconds.
# TYPE autows_span_duration_us_sum counter
autows_span_duration_us_sum{kind=\"wait\"} 10
autows_span_duration_us_sum{kind=\"engine\"} 50
autows_span_duration_us_sum{kind=\"reply\"} 0
autows_span_duration_us_sum{kind=\"batch\"} 3
autows_span_duration_us_sum{kind=\"steal\"} 0
# HELP autows_span_duration_us_max Longest single span per kind, microseconds.
# TYPE autows_span_duration_us_max gauge
autows_span_duration_us_max{kind=\"wait\"} 10
autows_span_duration_us_max{kind=\"engine\"} 30
autows_span_duration_us_max{kind=\"reply\"} 0
autows_span_duration_us_max{kind=\"batch\"} 3
autows_span_duration_us_max{kind=\"steal\"} 0
# HELP autows_pipeline_counter Process-wide DSE/simulator/design-cache counters.
# TYPE autows_pipeline_counter counter
autows_pipeline_counter{name=\"cache_hits\"} 7
autows_pipeline_counter{name=\"sim_runs\"} 2
";

#[test]
fn prometheus_text_matches_golden() {
    assert_eq!(prometheus_text(&fixture()), PROM_GOLDEN);
}

#[test]
fn json_snapshot_matches_golden() {
    let golden = concat!(
        "{\"requests\":12,\"batches\":3,\"mean_batch\":4,",
        "\"p50_ms\":1.5,\"p95_ms\":2.5,\"p99_ms\":3.5,\"mean_ms\":1.75,",
        "\"throughput_rps\":256,\"sim_accel_s\":0.125,",
        "\"queue_depth_mean\":1.5,\"queue_depth_max\":4,",
        "\"per_worker\":[",
        "{\"worker\":0,\"batches\":2,\"requests\":8,\"busy_s\":0.25},",
        "{\"worker\":1,\"batches\":1,\"requests\":4,\"busy_s\":0.125}],",
        "\"spans\":[",
        "{\"kind\":\"wait\",\"count\":1,\"items\":4,\"dur_us_sum\":10,\"dur_us_max\":10},",
        "{\"kind\":\"engine\",\"count\":2,\"items\":6,\"dur_us_sum\":50,\"dur_us_max\":30},",
        "{\"kind\":\"reply\",\"count\":0,\"items\":0,\"dur_us_sum\":0,\"dur_us_max\":0},",
        "{\"kind\":\"batch\",\"count\":1,\"items\":4,\"dur_us_sum\":3,\"dur_us_max\":3},",
        "{\"kind\":\"steal\",\"count\":0,\"items\":0,\"dur_us_sum\":0,\"dur_us_max\":0}],",
        "\"counters\":{\"cache_hits\":7,\"sim_runs\":2}}\n",
    );
    assert_eq!(json_snapshot(&fixture()), golden);
}

#[test]
fn chrome_trace_spans_matches_golden() {
    let spans = vec![
        Span { kind: SpanKind::Engine, lane: 0, items: 4, start_us: 10, dur_us: 30 },
        Span { kind: SpanKind::Batch, lane: SHARD_LANE_BASE, items: 4, start_us: 2, dur_us: 3 },
    ];
    let golden = concat!(
        "{\"traceEvents\":[",
        "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":0,",
        "\"args\":{\"name\":\"worker 0\"}},",
        "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":10000,",
        "\"args\":{\"name\":\"shard 0\"}},",
        "{\"name\":\"engine\",\"cat\":\"serve\",\"ph\":\"X\",\"ts\":10,\"dur\":30,",
        "\"pid\":0,\"tid\":0,\"args\":{\"items\":4}},",
        "{\"name\":\"batch\",\"cat\":\"serve\",\"ph\":\"X\",\"ts\":2,\"dur\":3,",
        "\"pid\":0,\"tid\":10000,\"args\":{\"items\":4}}",
        "],\"displayTimeUnit\":\"ms\"}\n",
    );
    assert_eq!(chrome_trace_spans(&spans), golden);
}

/// An empty session still renders both formats in full shape — every
/// family and key appears, zeroed, so scrapers never see a varying schema.
#[test]
fn empty_snapshot_keeps_the_exposition_shape() {
    let empty = TelemetrySnapshot {
        metrics: MetricsSnapshot {
            requests: 0,
            batches: 0,
            mean_batch: 0.0,
            p50_ms: 0.0,
            p95_ms: 0.0,
            p99_ms: 0.0,
            mean_ms: 0.0,
            throughput_rps: 0.0,
            sim_accel_s: 0.0,
            per_worker: Vec::new(),
            queue_depth_mean: 0.0,
            queue_depth_max: 0,
        },
        counters: Vec::new(),
        spans: Vec::new(),
    };
    let prom = prometheus_text(&empty);
    assert!(prom.contains("autows_requests_total 0\n"));
    // every span kind still gets a zero sample
    for kind in SpanKind::ALL {
        assert!(prom.contains(&format!("autows_spans_total{{kind=\"{}\"}} 0\n", kind.label())));
    }
    // per-series families keep their HELP/TYPE headers even with no series
    assert!(prom.contains("# TYPE autows_worker_batches_total counter\n"));
    assert!(prom.contains("# TYPE autows_pipeline_counter counter\n"));
    let js = json_snapshot(&empty);
    assert!(js.starts_with('{') && js.ends_with("}\n"));
    assert!(js.contains("\"per_worker\":[]"));
    assert!(js.contains("\"counters\":{}"));
    assert_eq!(js.matches('{').count(), js.matches('}').count());
}

/// Non-finite metric values (possible only from a corrupted snapshot)
/// must not leak `NaN`/`inf` tokens into either format.
#[test]
fn non_finite_metrics_render_parseable() {
    let mut t = fixture();
    t.metrics.mean_batch = f64::NAN;
    t.metrics.throughput_rps = f64::INFINITY;
    let prom = prometheus_text(&t);
    assert!(prom.contains("autows_mean_batch 0\n"));
    assert!(prom.contains("autows_throughput_rps 0\n"));
    assert!(!prom.contains("NaN") && !prom.contains("inf"));
    let js = json_snapshot(&t);
    assert!(js.contains("\"mean_batch\":0,"));
    assert!(!js.contains("NaN") && !js.contains("inf"));
}

fn boot_server(telemetry: bool) -> Server {
    let net = autows::models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).expect("toy cnn fits zcu102");
    let engine =
        SimOnlyEngine { design: r.design, device: dev, input_len: 16, output_len: 4 };
    Server::start_with_opts(
        move || Ok(Box::new(engine.clone()) as _),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
        ServerOptions { queue_cap: 0, workers: 2, dispatch_shards: 0, telemetry },
    )
    .expect("sim engines boot")
}

/// Live session: snapshots fold idempotently, engine spans account for
/// every request, and recording never takes a serving-path lock.
#[test]
fn live_server_telemetry_accounts_for_every_request() {
    const REQUESTS: usize = 64;
    let server = boot_server(true);
    drive_synthetic(&server, REQUESTS, 16).expect("all requests served");

    // Folding is idempotent: a second snapshot (which re-takes the fold
    // lock and drains an empty event queue) reports identical totals.
    let t1 = server.telemetry();
    let t2 = server.telemetry();
    assert_eq!(t1.metrics.requests, REQUESTS as u64);
    assert_eq!(t2.metrics.requests, t1.metrics.requests);
    assert_eq!(t2.metrics.batches, t1.metrics.batches);
    assert_eq!(t2.metrics.queue_depth_max, t1.metrics.queue_depth_max);

    // 64 requests fit in the rings (1024 slots/lane) — engine spans must
    // cover each request exactly once.
    let stats = span_stats(&t2.spans);
    let engine = stats.iter().find(|s| s.kind == SpanKind::Engine).expect("ALL covers Engine");
    assert_eq!(engine.items, REQUESTS as u64, "engine spans must cover every request");
    assert!(engine.count >= t2.metrics.batches, "one engine span per batch at least");
    assert!(server.spans_recorded() > 0);

    // The tripwire: span recording rides the dispatch path lock-free.
    assert_eq!(server.serving_path_locks(), 0);

    // The live snapshot renders in both formats without structural damage.
    let prom = prometheus_text(&t2);
    assert!(prom.contains(&format!("autows_requests_total {REQUESTS}\n")));
    let js = json_snapshot(&t2);
    assert_eq!(js.matches('{').count(), js.matches('}').count());

    // Process-wide counters arrive sorted by name (the exposition order).
    let names: Vec<&str> = t2.counters.iter().map(|(n, _)| n.as_str()).collect();
    let mut sorted = names.clone();
    sorted.sort_unstable();
    assert_eq!(names, sorted, "counters must expose in sorted order");
    for key in ["dse_greedy_steps", "sim_runs", "cache_hits", "sim_events_processed"] {
        assert!(names.contains(&key), "counter {key} missing from the snapshot");
    }
    server.shutdown();
}

/// `telemetry: false` disables the span rings entirely — zero spans after
/// real load — while metrics keep working.
#[test]
fn telemetry_off_records_no_spans() {
    let server = boot_server(false);
    drive_synthetic(&server, 32, 16).expect("all requests served");
    assert_eq!(server.spans_recorded(), 0);
    let t = server.telemetry();
    assert_eq!(t.metrics.requests, 32);
    assert!(t.spans.is_empty());
    assert_eq!(server.serving_path_locks(), 0);
    server.shutdown();
}

/// A cloneable [`MetricsHandle`] reads the same hub as the server.
#[test]
fn metrics_handle_tracks_the_server() {
    let server = boot_server(true);
    let handle = server.metrics_handle();
    drive_synthetic(&server, 16, 16).expect("all requests served");
    let via_handle = handle.snapshot();
    let via_server = server.telemetry().metrics;
    assert_eq!(via_handle.requests, 16);
    assert_eq!(via_handle.requests, via_server.requests);
    assert_eq!(via_handle.batches, via_server.batches);
    assert_eq!(handle.serving_path_locks(), 0);
    server.shutdown();
}
