//! Property-based tests over randomly generated layers, configurations and
//! designs (self-contained xorshift generator — this build is offline).
//!
//! Each property encodes an invariant of the paper's model:
//!   P1  Eq. 1: depth x width always conserves the layer's weight bits.
//!   P2  Eq. 2: fragmentation covers the memory and never loses words.
//!   P3  Eq. 5: β is monotone in the evicted share and bounded by the
//!       full word rate.
//!   P4  Eq. 10: after any eviction sequence, repeat counts stay balanced.
//!   P5  throughput never decreases when an unroll factor grows.
//!   P6  the DSE result always satisfies both Eq. 6 constraints.
//!   P7  the simulator never beats the analytic stall-free bound.

use autows::ce::{divisors, next_unroll, CeConfig, CeModel, Fragmentation};
use autows::device::Device;
use autows::dse::{self, increment_offchip, Design, DseConfig};
use autows::ir::{Layer, Quant};
use autows::sim::{simulate, SimConfig};

/// xorshift64* PRNG, deterministic per test.
struct Rng(u64);
impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
    fn pick<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[(self.next() % xs.len() as u64) as usize]
    }
}

fn random_conv(rng: &mut Rng) -> Layer {
    let quant = rng.pick(&[Quant::W4A4, Quant::W4A5, Quant::W8A8]);
    let c_in = rng.range(1, 64) as u32;
    let c_out = rng.range(1, 128) as u32;
    let hw = rng.pick(&[8u32, 14, 16, 28, 32, 56]);
    let k = rng.pick(&[1u32, 3, 5, 7]);
    let stride = rng.pick(&[1u32, 2]);
    let pad = k / 2;
    Layer::conv("c", c_in, c_out, hw, hw, k, stride, pad, quant)
}

fn random_cfg(rng: &mut Rng, layer: &Layer) -> CeConfig {
    let k2 = layer.kernel() * layer.kernel();
    let kp = rng.pick(&divisors(k2));
    let cp = rng.pick(&divisors(layer.c_per_group()));
    let fp = rng.pick(&divisors(layer.c_out));
    let mut cfg = CeConfig { kp, cp, fp, frag: Fragmentation::all_on_chip(0) };
    let m_dep = CeModel::new(layer, cfg, 200.0).m_dep();
    let off = rng.range(0, m_dep);
    let n = rng.range(1, m_dep.min(64)) as u32;
    cfg.frag =
        if off == 0 { Fragmentation::all_on_chip(m_dep) } else { Fragmentation::new(m_dep, off, n) };
    cfg
}

#[test]
fn p1_eq1_bit_conservation() {
    let mut rng = Rng::new(101);
    for _ in 0..500 {
        let l = random_conv(&mut rng);
        let cfg = random_cfg(&mut rng, &l);
        let m = CeModel::new(&l, cfg, 200.0);
        let bits = m.m_dep() * m.m_wid_bits();
        assert!(bits >= l.weight_bits(), "{l:?} {cfg:?}");
        // exact whenever the unrolls divide their dimensions (they do, by
        // construction from divisors())
        assert_eq!(bits, l.weight_bits(), "{l:?} {cfg:?}");
    }
}

#[test]
fn p2_fragmentation_covers_memory() {
    let mut rng = Rng::new(202);
    for _ in 0..2000 {
        let m_dep = rng.range(1, 1 << 20);
        let off = rng.range(0, m_dep);
        let n = rng.range(1, 256) as u32;
        let f = Fragmentation::new(m_dep, off, n);
        assert!(f.m_dep() >= m_dep, "covers all words");
        assert!(f.m_off_dep() >= off.min(m_dep) || f.u_on == 0, "covers evicted words");
        assert!(f.m_dep() - m_dep < 2 * n as u64, "padding bounded by fragments");
        assert!((0.0..=1.0).contains(&f.off_chip_ratio()));
    }
}

#[test]
fn p3_beta_monotone_and_bounded() {
    let mut rng = Rng::new(303);
    for _ in 0..300 {
        let l = random_conv(&mut rng);
        let cfg0 = random_cfg(&mut rng, &l);
        let m_dep = CeModel::new(&l, cfg0, 200.0).m_dep();
        let full_rate = CeModel::new(&l, cfg0, 200.0).m_wid_bits() as f64 * 200e6;
        let mut last = -1.0;
        for step in 0..=4 {
            let off = m_dep * step / 4;
            let mut cfg = cfg0;
            cfg.frag = if off == 0 {
                Fragmentation::all_on_chip(m_dep)
            } else {
                Fragmentation::new(m_dep, off, (m_dep.min(4)).max(1) as u32)
            };
            let beta = CeModel::new(&l, cfg, 200.0).beta_bps();
            assert!(beta >= last - 1e-6, "β must grow with eviction");
            assert!(beta <= full_rate * 1.0001, "β bounded by word rate");
            last = beta;
        }
    }
}

#[test]
fn p4_burst_balance_after_random_evictions() {
    let mut rng = Rng::new(404);
    for trial in 0..15 {
        let net = autows::models::by_name(
            ["resnet18", "mobilenetv2", "toy"][trial % 3],
            Quant::W4A5,
        )
        .unwrap();
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let mut d = Design::initialize(&net, &dev);
        let weight_layers = net.weight_layers();
        for _ in 0..rng.range(2, 12) {
            let l = rng.pick(&weight_layers);
            increment_offchip(&mut d, l, &cfg);
        }
        // every streaming layer hits the common repeat target unless its
        // fragment count is physically capped at the memory depth (a layer
        // cannot have more fragments than words)
        let target = autows::dse::r_target(&d, 1);
        for i in d.streaming_layers() {
            let r = d.repeats(i, 1);
            let m_dep = autows::ce::CeModel::new(
                &d.network.layers[i],
                d.cfgs[i],
                d.clk_comp_mhz,
            )
            .m_dep();
            let capped = d.cfgs[i].frag.n as u64 >= m_dep;
            assert!(
                r >= target || capped,
                "layer {i}: r={r} < target {target} without depth cap"
            );
        }
    }
}

#[test]
fn p5_throughput_monotone_in_unroll() {
    let mut rng = Rng::new(505);
    for _ in 0..300 {
        let l = random_conv(&mut rng);
        let cfg = random_cfg(&mut rng, &l);
        let base = CeModel::new(&l, cfg, 200.0).throughput();
        let k2 = l.kernel() * l.kernel();
        let grow: [Option<CeConfig>; 3] = [
            next_unroll(k2, cfg.kp, 1).map(|v| CeConfig { kp: v, ..cfg }),
            next_unroll(l.c_out, cfg.fp, 1).map(|v| CeConfig { fp: v, ..cfg }),
            next_unroll(l.c_per_group(), cfg.cp, 1).map(|v| CeConfig { cp: v, ..cfg }),
        ];
        for c2 in grow.into_iter().flatten() {
            let t = CeModel::new(&l, c2, 200.0).throughput();
            assert!(t >= base * 0.999, "unroll slowed CE: {l:?} {cfg:?} -> {c2:?}");
        }
    }
}

#[test]
fn p6_dse_respects_constraints_everywhere() {
    for model in ["toy", "resnet18", "mobilenetv2"] {
        for dev in Device::all() {
            let net = autows::models::by_name(model, Quant::W4A5).unwrap();
            if let Some(r) = dse::run(&net, &dev, &DseConfig::default()) {
                assert!(r.area.fits(&dev), "{model} on {}", dev.name);
                assert!(
                    r.bandwidth_bps <= dev.bandwidth_bps * 1.0001,
                    "{model} on {} uses {} of {}",
                    dev.name,
                    r.bandwidth_bps,
                    dev.bandwidth_bps
                );
                assert!(r.throughput > 0.0);
            }
        }
    }
}

#[test]
fn p7_sim_never_beats_analytic_bound() {
    for (model, devf) in [
        ("toy", Device::zcu102 as fn() -> Device),
        ("resnet18", Device::zcu102),
        ("mobilenetv2", Device::zc706),
        ("resnet18", Device::u50),
    ] {
        let net = autows::models::by_name(model, Quant::W4A5).unwrap();
        let dev = devf();
        let Some(r) = dse::run(&net, &dev, &DseConfig::default()) else { continue };
        let sim = simulate(&r.design, &dev, &SimConfig::default());
        assert!(
            sim.latency_ms >= r.latency_ms * 0.999,
            "{model}/{}: sim {} < analytic {}",
            dev.name,
            sim.latency_ms,
            r.latency_ms
        );
    }
}

//   P8  compression: effective bits bounded, ratio monotone in sparsity.
//   P9  tech assignment never over-commits any resource pool.
//   P10 .net serializer/parser round-trip preserves network stats.
//   P11 FIFO sizing: positive depths, rate-matched links need only slack.
//   P12 config parser: arbitrary byte soup never panics, only errors.

#[test]
fn p8_compression_bounded_and_monotone() {
    use autows::compress::{compress_network, CompressionSpec};
    for model in ["toy", "resnet18", "mobilenetv2", "vgg16"] {
        for q in [Quant::W4A4, Quant::W8A8] {
            let net = autows::models::by_name(model, q).unwrap();
            let mut last_ratio = f64::INFINITY;
            for step in 0..8 {
                let s = step as f64 / 8.0;
                let (cnet, rep) = compress_network(&net, &CompressionSpec::pruned(s));
                assert!(rep.ratio() <= last_ratio + 1e-9, "{model}-{q} s={s}");
                last_ratio = rep.ratio();
                for (l, cl) in net.layers.iter().zip(&cnet.layers) {
                    if l.has_weights() {
                        assert!(cl.quant.w_bits >= 1 && cl.quant.w_bits <= l.quant.w_bits);
                    } else {
                        assert_eq!(cl.quant.w_bits, l.quant.w_bits);
                    }
                }
            }
        }
    }
}

#[test]
fn p9_tech_plan_never_overcommits() {
    use autows::ce::{assign_memory_tech, TechOptions};
    for model in ["toy", "resnet18", "resnet50", "mobilenetv2"] {
        for dev in Device::all() {
            let net = autows::models::by_name(model, Quant::W8A8).unwrap();
            let Some(r) = dse::run(&net, &dev, &DseConfig::default()) else { continue };
            let plan = assign_memory_tech(&r.design, &dev, &TechOptions::for_device(&dev));
            assert!(plan.uram <= dev.uram, "{model}/{}", dev.name);
            assert!(plan.bram <= plan.baseline_bram, "{model}/{}", dev.name);
            assert!(
                r.design.total_area().lut + plan.extra_luts <= dev.lut,
                "{model}/{}: LUT overflow",
                dev.name
            );
            // plan covers exactly the weight layers with a static region
            for c in &plan.choices {
                assert!(r.design.network.layers[c.layer].has_weights());
            }
        }
    }
}

#[test]
fn p10_textfmt_roundtrip_random_chains() {
    use autows::ir::{parse_network, serialize_network, Network};
    let mut rng = Rng::new(606);
    for trial in 0..40 {
        // random chain: convs/pools/relu/depthwise, valid by construction
        let mut net = Network::new(format!("rand{trial}"), (3, 32, 32), Quant::W8A8);
        let (mut c, mut hw) = (3u32, 32u32);
        let n_layers = rng.range(1, 8);
        for i in 0..n_layers {
            match rng.range(0, 3) {
                0 => {
                    let out = rng.pick(&[4u32, 8, 16, 24]);
                    let k = rng.pick(&[1u32, 3]);
                    net.push(Layer::conv(
                        format!("c{i}"),
                        c,
                        out,
                        hw,
                        hw,
                        k,
                        1,
                        k / 2,
                        Quant::W8A8,
                    ));
                    c = out;
                }
                1 if hw >= 4 => {
                    net.push(Layer {
                        name: format!("p{i}"),
                        op: autows::ir::OpKind::Pool {
                            kernel: 2,
                            stride: 2,
                            pad: 0,
                            kind: autows::ir::PoolKind::Max,
                        },
                        c_in: c,
                        c_out: c,
                        h_in: hw,
                        w_in: hw,
                        quant: Quant::W8A8,
                        skip_from: None,
                    });
                    hw /= 2;
                }
                _ => {
                    net.push(Layer::depthwise(format!("d{i}"), c, hw, hw, 3, 1, 1, Quant::W8A8));
                }
            }
        }
        let text = serialize_network(&net);
        let back = parse_network(&text, Quant::W8A8)
            .unwrap_or_else(|e| panic!("trial {trial}: {e}\n{text}"));
        assert_eq!(net.stats(), back.stats(), "trial {trial}\n{text}");
    }
}

#[test]
fn p11_fifo_sizing_sane_everywhere() {
    use autows::sim::fifo_depths;
    for model in ["toy", "resnet18", "mobilenetv2"] {
        for dev in [Device::zcu102(), Device::u250()] {
            let net = autows::models::by_name(model, Quant::W8A8).unwrap();
            let Some(r) = dse::run(&net, &dev, &DseConfig::default()) else { continue };
            for s in fifo_depths(&r.design) {
                assert!(s.required_depth >= 8, "{model}/{}: {s:?}", dev.name);
                assert!(s.fill_rate.is_finite() && s.drain_rate.is_finite());
                if s.drain_rate >= s.fill_rate {
                    assert_eq!(s.required_depth, 8, "{model}/{}: {s:?}", dev.name);
                }
            }
        }
    }
}

#[test]
fn p12_config_parser_never_panics() {
    use autows::config::RunSpec;
    let mut rng = Rng::new(707);
    let tokens = [
        "[model]", "[dse]", "[junk]", "name", "=", "\"toy\"", "phi", "0", "1",
        "2.5", "true", "[", "]", "#x", "\"unterminated", "mu", "\n", "quant",
    ];
    for _ in 0..300 {
        let n = rng.range(1, 20);
        let mut text = String::new();
        for _ in 0..n {
            text.push_str(tokens[(rng.next() % tokens.len() as u64) as usize]);
            text.push(if rng.next() % 3 == 0 { '\n' } else { ' ' });
        }
        // must never panic — Ok or Err are both acceptable
        let _ = RunSpec::from_str(&text);
    }
}
