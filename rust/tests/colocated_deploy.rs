//! Integration tests of the co-located (multi-tenant) deployment path: the
//! golden 1-tenant equivalence against the single-device pipeline, the
//! acceptance case (resnet18 + squeezenet jointly feasible on one zcu102
//! within every physical cap), cache-key separation across the three
//! deployment schemas, typed infeasibility for over-budget tenant sets, and
//! the registry serving terminal.

use autows::device::Device;
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::{Deployment, DesignCache};
use autows::sim::SimConfig;
use autows::Error;

/// Golden (satellite): `colocate([one tenant])` is the single-device
/// deployment — design, burst schedule and simulation are bit-identical on
/// resnet18/zcu102/W4A5, mirroring the 1-partition golden from PR 4.
#[test]
fn one_tenant_equals_single_device_bit_for_bit() {
    let cfg = DseConfig::default();
    let single = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device("zcu102")
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();
    let joint = Deployment::colocate([Deployment::for_model("resnet18").quant(Quant::W4A5)])
        .on_device("zcu102")
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();

    assert_eq!(joint.tenants().len(), 1);
    let t = &joint.tenants()[0];
    assert_eq!(t.share, 1.0, "a lone tenant owns the whole device");
    assert_eq!(t.view, *single.device(), "its view is the untouched device");
    assert_eq!(t.result.design.cfgs, single.design().cfgs, "identical per-layer configs");
    assert_eq!(t.result.design.off_bits, single.design().off_bits, "identical evicted bits");
    assert_eq!(t.result.throughput, single.result().throughput, "bit-identical throughput");
    assert_eq!(t.result.latency_ms, single.result().latency_ms, "bit-identical latency");
    assert_eq!(t.result.area, single.result().area);
    assert_eq!(t.result.bandwidth_bps, single.result().bandwidth_bps);

    // the tenant's DMA burst schedule is the single-device schedule
    assert_eq!(joint.port_schedule().slices.len(), 1);
    assert_eq!(joint.burst_schedule("resnet18").unwrap(), single.burst_schedule());
    assert_eq!(joint.input_len("resnet18"), Some(single.input_len()));

    // and the simulation is the single-device simulation, verbatim
    let sim_cfg = SimConfig::default();
    let sim_single = single.simulate(&sim_cfg);
    let sim_joint = joint.simulate(&sim_cfg);
    assert_eq!(sim_joint.per_tenant.len(), 1);
    assert_eq!(sim_joint.makespan_s, sim_single.makespan_s, "bit-identical makespan");
    assert_eq!(sim_joint.latency_ms, sim_single.latency_ms);
    assert_eq!(sim_joint.total_stall_s, sim_single.total_stall_s);
    assert_eq!(sim_joint.port_busy_frac, sim_single.dma_busy_frac);
    assert_eq!(sim_joint.events, sim_single.events);
}

/// Acceptance: resnet18 + squeezenet on zcu102 yield a feasible joint plan
/// whose summed per-tenant area/BRAM/bandwidth respect the device caps, and
/// the report carries per-tenant shares plus the port utilization.
#[test]
fn resnet18_plus_squeezenet_fit_one_zcu102_within_every_cap() {
    let cfg = DseConfig::default();
    let dev = Device::zcu102();
    let scheduled = Deployment::colocate([
        Deployment::for_model("resnet18").quant(Quant::W4A5),
        Deployment::for_model("squeezenet").quant(Quant::W8A8),
    ])
    .on_device("zcu102")
    .unwrap()
    .explore(&cfg)
    .expect("resnet18+squeezenet must co-locate on zcu102")
    .schedule();

    assert_eq!(scheduled.tenants().len(), 2);
    let r = scheduled.result();
    // shares partition the budget
    let share_sum: f64 = r.tenants.iter().map(|t| t.share).sum();
    assert!((share_sum - 1.0).abs() < 1e-9, "{share_sum}");
    // summed area/BRAM fit the physical device
    let area = r.joint_area();
    assert!(area.fits(&dev), "joint area {area:?} exceeds zcu102");
    assert!(area.bram.total() <= dev.mem_bram_equiv());
    // summed bandwidth fits the physical DMA port
    assert!(r.joint_bandwidth_bps() <= dev.bandwidth_bps * (1.0 + 1e-9));
    // every tenant fits its own slice and actually runs
    for t in r.tenants.iter() {
        assert!(t.result.area.fits(&t.view), "{}", t.name);
        assert!(t.result.throughput > 0.0, "{}", t.name);
    }
    // the composed port schedule upholds the Eq. 8-10 argument per tenant
    let port = scheduled.port_schedule();
    assert!(port.schedulable(), "composed shared-port schedule must be feasible");
    assert!(port.port_utilization() <= 1.0 + 1e-9);

    // report surfaces the joint accounting
    let report = scheduled.report();
    assert!(report.contains("co-located on zcu102"), "{report}");
    assert!(report.contains("tenant 0 resnet18"), "{report}");
    assert!(report.contains("tenant 1 squeezenet"), "{report}");
    assert!(report.contains("share="), "per-tenant share: {report}");
    assert!(report.contains("port util"), "port utilization: {report}");
    assert!(report.contains("joint area"), "{report}");

    // and the joint simulation stays within the shared port
    let sim = scheduled.simulate(&SimConfig::default());
    assert!(sim.makespan_s > 0.0);
    assert!((0.0..=1.0 + 1e-9).contains(&sim.port_busy_frac), "{}", sim.port_busy_frac);
    assert_eq!(sim.per_tenant.len(), 2);
}

/// An over-budget tenant set fails with typed `Error::Infeasible` naming
/// the whole tenant list — not a panic.
#[test]
fn over_budget_tenant_set_is_typed_infeasible() {
    let e = Deployment::colocate([
        Deployment::for_model("resnet50").quant(Quant::W8A8),
        Deployment::for_model("vgg16").quant(Quant::W8A8),
    ])
    .on_device("zedboard")
    .unwrap()
    .explore(&DseConfig::vanilla())
    .unwrap_err();
    assert!(e.is_infeasible(), "{e}");
    assert!(matches!(e, Error::Infeasible { .. }), "{e}");
    assert!(e.to_string().contains("resnet50+vgg16"), "{e}");
    assert!(e.to_string().contains("zedboard"), "{e}");
}

/// Cache separation (satellite): co-located keys never collide with
/// single-device or partitioned keys of the same content, and tenant-list
/// changes miss.
#[test]
fn cache_separates_colocated_from_single_and_partitioned() {
    let cfg = DseConfig::default();
    let cache = DesignCache::new();

    // the same content through all three schemas: three entries, zero hits
    let single = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_device("zcu102")
        .unwrap()
        .explore_in(&cache, &cfg);
    assert!(single.is_ok());
    let sharded = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_devices(&["zcu102"])
        .unwrap()
        .explore_in(&cache, &cfg);
    assert!(sharded.is_ok());
    let colocated = Deployment::colocate([Deployment::for_model("toy").quant(Quant::W8A8)])
        .on_device("zcu102")
        .unwrap()
        .explore_in(&cache, &cfg);
    assert!(colocated.is_ok());
    let s = cache.stats();
    assert_eq!(s.hits, 0, "the three schemas must never answer each other");
    assert_eq!((s.misses, s.entries), (3, 3));

    // revisiting the co-located point hits its own entry
    let again = Deployment::colocate([Deployment::for_model("toy").quant(Quant::W8A8)])
        .on_device("zcu102")
        .unwrap()
        .explore_in(&cache, &cfg)
        .unwrap();
    assert!(again.was_cached());
    let s = cache.stats();
    assert_eq!((s.hits, s.misses, s.entries), (1, 3, 3));

    // a different tenant list is a different entry, not a hit
    let two = Deployment::colocate([
        Deployment::for_model("toy").quant(Quant::W8A8),
        Deployment::for_model("squeezenet").quant(Quant::W8A8),
    ])
    .on_device("zcu102")
    .unwrap()
    .explore_in(&cache, &cfg);
    assert!(two.is_ok());
    let s = cache.stats();
    assert_eq!((s.hits, s.misses, s.entries), (1, 4, 4));
}

/// The serving terminal: every tenant answers inference behind the one
/// registry, with independent metrics, and unknown routes stay typed.
#[test]
fn every_tenant_serves_behind_one_registry() {
    use autows::coordinator::{BatchPolicy, ServerOptions};
    let scheduled = Deployment::colocate([
        Deployment::for_model("toy").quant(Quant::W8A8),
        Deployment::for_model("squeezenet").quant(Quant::W8A8),
    ])
    .on_device("zcu102")
    .unwrap()
    .explore(&DseConfig::default())
    .unwrap()
    .schedule();
    let registry = scheduled
        .serve(BatchPolicy::default(), ServerOptions::default())
        .unwrap();
    assert_eq!(registry.models(), vec!["squeezenet", "toy_cnn"]);
    for name in scheduled.tenant_names() {
        let input_len = scheduled.input_len(name).unwrap();
        let resp = registry.infer(name, vec![0.5; input_len]).unwrap();
        assert_eq!(resp.output.len(), 10, "{name}");
        assert_eq!(registry.metrics(name).unwrap().requests, 1, "{name}");
    }
    let e = registry.infer("nonexistent", vec![0.0; 4]).unwrap_err();
    assert!(matches!(e, Error::UnknownModel(_)), "{e}");
    registry.shutdown();
}

/// Stage-0 failures of the multi-tenant path are typed errors.
#[test]
fn colocate_error_surface() {
    // empty tenant list
    let e = Deployment::colocate(Vec::new()).on_device("zcu102").unwrap_err();
    assert!(matches!(e, Error::Usage(_)), "{e}");

    // duplicate tenant names collide in the serving registry, so they are
    // rejected at planning time
    let e = Deployment::colocate([
        Deployment::for_model("toy").quant(Quant::W8A8),
        Deployment::for_model("toy").quant(Quant::W4A4),
    ])
    .on_device("zcu102")
    .unwrap_err();
    assert!(matches!(e, Error::DuplicateModel(ref m) if m == "toy_cnn"), "{e}");

    // unknown device / model stay typed
    let e = Deployment::colocate([Deployment::for_model("toy")])
        .on_device("zcu9000")
        .unwrap_err();
    assert!(matches!(e, Error::UnknownDevice { .. }), "{e}");
    let e = Deployment::colocate([Deployment::for_model("resnet9000")])
        .on_device("zcu102")
        .unwrap_err();
    assert!(matches!(e, Error::UnknownModel(_)), "{e}");
}

/// The `[[tenant]]` config path drives the same joint plan end to end.
#[test]
fn multitenant_runspec_plans_and_reports() {
    use autows::config::RunSpec;
    let spec = RunSpec::from_str(
        "[device]\nname = \"zcu102\"\n\
         [[tenant]]\nname = \"toy\"\n\
         [[tenant]]\nname = \"squeezenet\"\n",
    )
    .unwrap();
    assert!(spec.is_colocated());
    let scheduled = spec
        .plan_colocated()
        .unwrap()
        .explore(&DseConfig::default())
        .unwrap()
        .schedule();
    assert_eq!(scheduled.tenants().len(), 2);
    let report = scheduled.report();
    assert!(report.contains("toy_cnn"), "{report}");
    assert!(report.contains("squeezenet"), "{report}");
}
