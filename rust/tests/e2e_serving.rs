//! End-to-end integration: DSE schedule + PJRT numerics + coordinator
//! batching, exercised together the way `autows serve` wires them.

use std::time::Duration;

use autows::coordinator::{BatchPolicy, PjrtEngine, Server};
use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::runtime::Runtime;

fn artifact(name: &str) -> Option<String> {
    let path = format!("{}/artifacts/{}", env!("CARGO_MANIFEST_DIR"), name);
    if std::path::Path::new(&path).exists() {
        Some(path)
    } else {
        eprintln!("SKIP: {path} missing — run `make artifacts`");
        None
    }
}

#[test]
fn serve_batched_requests_through_pjrt() {
    let Some(path) = artifact("toy_cnn_b8.hlo.txt") else { return };

    let net = models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let plan = dse::run(&net, &dev, &DseConfig::default()).expect("toy cnn fits zcu102");
    let design = plan.design;

    let server = Server::start_with(
        move || {
            let rt = Runtime::cpu()?;
            let model = rt.load_hlo_text(&path)?;
            Ok(Box::new(PjrtEngine::new(model, design, dev, (3, 32, 32), 8)) as _)
        },
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
    )
    .expect("engine boot");

    // 32 concurrent requests with distinct deterministic inputs
    let receivers: Vec<_> = (0..32)
        .map(|i| {
            let input: Vec<f32> =
                (0..3 * 32 * 32).map(|j| ((i * 131 + j * 7) % 255) as f32 / 255.0 - 0.5).collect();
            server.submit(input).unwrap()
        })
        .collect();

    let mut batched = 0;
    for rx in receivers {
        let resp = rx.recv().unwrap().expect("inference ok");
        assert_eq!(resp.output.len(), 10);
        assert!(resp.output.iter().all(|v| v.is_finite()));
        assert!(resp.accel > Duration::ZERO, "simulated accelerator time present");
        if resp.batch > 1 {
            batched += 1;
        }
    }
    assert!(batched > 0, "at least some requests must ride shared batches");

    let m = server.metrics();
    assert_eq!(m.requests, 32);
    assert!(m.batches < 32, "batching reduced executable invocations");
    assert!(m.sim_accel_s > 0.0);
    server.shutdown();
}

#[test]
fn identical_inputs_get_identical_outputs_across_batches() {
    let Some(path) = artifact("toy_cnn_b8.hlo.txt") else { return };
    let net = models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let design = dse::run(&net, &dev, &DseConfig::default()).unwrap().design;

    let server = Server::start_with(
        move || {
            let rt = Runtime::cpu()?;
            let model = rt.load_hlo_text(&path)?;
            Ok(Box::new(PjrtEngine::new(model, design, dev, (3, 32, 32), 8)) as _)
        },
        // max_batch 1: every request runs alone
        BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
    )
    .unwrap();

    let input: Vec<f32> = (0..3 * 32 * 32).map(|j| (j % 29) as f32 / 29.0).collect();
    let a = server.infer(input.clone()).unwrap();
    let b = server.infer(input).unwrap();
    assert_eq!(a.output, b.output, "padding/batching must not perturb numerics");
    server.shutdown();
}
