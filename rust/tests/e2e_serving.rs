//! End-to-end integration: DSE schedule + PJRT numerics + coordinator
//! batching, exercised together the way `autows serve` wires them — plus
//! the engine-pool serving path on the SimOnly engine (always runs, no
//! artifacts needed).

use std::time::Duration;

use autows::coordinator::{
    BatchPolicy, Engine, PacedEngine, PjrtEngine, Priority, Server, ServerOptions, SimOnlyEngine,
};
use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::runtime::Runtime;
use autows::Error;

/// Deterministic checksum engine for the toy CNN on zcu102.
fn sim_engine() -> SimOnlyEngine {
    let net = models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).expect("toy cnn fits zcu102");
    SimOnlyEngine { design: r.design, device: dev, input_len: 3 * 32 * 32, output_len: 10 }
}

fn artifact(name: &str) -> Option<String> {
    let path = format!("{}/artifacts/{}", env!("CARGO_MANIFEST_DIR"), name);
    if std::path::Path::new(&path).exists() {
        Some(path)
    } else {
        eprintln!("SKIP: {path} missing — run `make artifacts`");
        None
    }
}

#[test]
fn serve_batched_requests_through_pjrt() {
    let Some(path) = artifact("toy_cnn_b8.hlo.txt") else { return };

    let net = models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let plan = dse::run(&net, &dev, &DseConfig::default()).expect("toy cnn fits zcu102");
    let design = plan.design;

    let server = Server::start_with(
        move || {
            let rt = Runtime::cpu()?;
            let model = rt.load_hlo_text(&path)?;
            Ok(Box::new(PjrtEngine::new(model, design, dev, (3, 32, 32), 8)) as _)
        },
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
    )
    .expect("engine boot");

    // 32 concurrent requests with distinct deterministic inputs
    let receivers: Vec<_> = (0..32)
        .map(|i| {
            let input: Vec<f32> =
                (0..3 * 32 * 32).map(|j| ((i * 131 + j * 7) % 255) as f32 / 255.0 - 0.5).collect();
            server.submit(input).unwrap()
        })
        .collect();

    let mut batched = 0;
    for rx in receivers {
        let resp = rx.recv().unwrap().expect("inference ok");
        assert_eq!(resp.output.len(), 10);
        assert!(resp.output.iter().all(|v| v.is_finite()));
        assert!(resp.accel > Duration::ZERO, "simulated accelerator time present");
        if resp.batch > 1 {
            batched += 1;
        }
    }
    assert!(batched > 0, "at least some requests must ride shared batches");

    let m = server.metrics();
    assert_eq!(m.requests, 32);
    assert!(m.batches < 32, "batching reduced executable invocations");
    assert!(m.sim_accel_s > 0.0);
    server.shutdown();
}

#[test]
fn identical_inputs_get_identical_outputs_across_batches() {
    let Some(path) = artifact("toy_cnn_b8.hlo.txt") else { return };
    let net = models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let design = dse::run(&net, &dev, &DseConfig::default()).unwrap().design;

    let server = Server::start_with(
        move || {
            let rt = Runtime::cpu()?;
            let model = rt.load_hlo_text(&path)?;
            Ok(Box::new(PjrtEngine::new(model, design, dev, (3, 32, 32), 8)) as _)
        },
        // max_batch 1: every request runs alone
        BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
    )
    .unwrap();

    let input: Vec<f32> = (0..3 * 32 * 32).map(|j| (j % 29) as f32 / 29.0).collect();
    let a = server.infer(input.clone()).unwrap();
    let b = server.infer(input).unwrap();
    assert_eq!(a.output, b.output, "padding/batching must not perturb numerics");
    server.shutdown();
}

/// workers = 1 must behave exactly like the pre-pool server: same outputs
/// for the same fixed trace through both the legacy `start` entry point and
/// `start_with_opts { workers: 1 }`, and the same serving metrics.
#[test]
fn pool_of_one_matches_legacy_server_on_fixed_trace() {
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
    let legacy = Server::start(sim_engine(), policy);
    let engine = sim_engine();
    let pooled = Server::start_with_opts(
        move || Ok(Box::new(engine.clone()) as _),
        policy,
        ServerOptions { queue_cap: 0, workers: 1, dispatch_shards: 1, telemetry: true },
    )
    .unwrap();

    let trace: Vec<Vec<f32>> = (0..16)
        .map(|i| (0..3 * 32 * 32).map(|j| ((i * 37 + j) % 101) as f32 / 101.0).collect())
        .collect();
    let mut outputs = Vec::new();
    for server in [&legacy, &pooled] {
        let rxs: Vec<_> = trace.iter().map(|t| server.submit(t.clone()).unwrap()).collect();
        let outs: Vec<Vec<f32>> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().output).collect();
        outputs.push(outs);
    }
    assert_eq!(outputs[0], outputs[1], "pool of one must be bit-identical to legacy path");

    let (lm, pm) = (legacy.metrics(), pooled.metrics());
    assert_eq!(lm.requests, 16);
    assert_eq!(pm.requests, 16);
    assert_eq!(pm.per_worker.len(), 1, "single worker, id 0");
    assert_eq!(pm.per_worker[0].requests, 16);
    legacy.shutdown();
    pooled.shutdown();
}

/// K > 1 loses no responses and keeps per-request integrity: every request
/// carries a distinct input whose checksum output must come back on *its*
/// receiver, no matter which worker served it.
#[test]
fn pool_preserves_per_request_integrity_under_load() {
    let engine = sim_engine();
    let input_len = engine.input_len;
    let server = Server::start_with_opts(
        move || Ok(Box::new(engine.clone()) as _),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
        ServerOptions { queue_cap: 0, workers: 4, dispatch_shards: 0, telemetry: true },
    )
    .unwrap();

    const N: usize = 96;
    let rxs: Vec<_> = (0..N).map(|i| server.submit(vec![i as f32; input_len]).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("no response lost").expect("inference ok");
        let want = i as f32 * input_len as f32;
        assert_eq!(resp.output.len(), 10);
        for v in &resp.output {
            assert!(
                (v - want).abs() <= 1e-1 * want.max(1.0),
                "request {i} got checksum {v}, want {want} — cross-request mixup"
            );
        }
    }
    let m = server.metrics();
    assert_eq!(m.requests, N as u64);
    let served: u64 = m.per_worker.iter().map(|w| w.requests).sum();
    assert_eq!(served, N as u64, "per-worker accounting covers every request");
    server.shutdown();
}

/// A full queue surfaces `Error::Overloaded` at submit time instead of
/// blocking or deadlocking; every admitted request still completes.
#[test]
fn pool_overload_rejects_instead_of_deadlocking() {
    // Paced engine so workers stay busy ~5ms per batch: submissions landing
    // while the queue is at cap must bounce synchronously.
    let mut engine = sim_engine();
    let input_len = engine.input_len;
    let accel_s = engine.accel_batch_time(8).as_secs_f64().max(1e-9);
    let paced = PacedEngine::new(engine, 5e-3 / accel_s);
    let server = Server::start_with_opts(
        move || Ok(Box::new(paced.clone()) as _),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
        ServerOptions { queue_cap: 4, workers: 2, dispatch_shards: 0, telemetry: true },
    )
    .unwrap();

    let mut admitted = Vec::new();
    let mut rejected = 0usize;
    for i in 0..64 {
        match server.submit(vec![i as f32; input_len]) {
            Ok(rx) => admitted.push(rx),
            Err(e) => {
                assert!(
                    matches!(e, Error::Overloaded { cap: 4, .. }),
                    "expected typed overload, got: {e}"
                );
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "64 instant submissions must overflow a cap of 4");
    assert!(!admitted.is_empty(), "admission control must not reject everything");
    for rx in admitted {
        rx.recv().expect("admitted request must complete").expect("inference ok");
    }
    server.shutdown();
}

/// Starvation bound through the sharded front: a high-priority request
/// arriving behind a backlog of normals must ride the boosted deadline
/// (`high_wait_frac` of `max_wait`), not wait out the normals' full window.
#[test]
fn sharded_front_high_priority_beats_backlog() {
    let engine = sim_engine();
    let input_len = engine.input_len;
    let max_wait = Duration::from_millis(400);
    let server = Server::start_with_opts(
        move || Ok(Box::new(engine.clone()) as _),
        // max_batch far above the backlog: only a deadline can flush
        BatchPolicy { max_batch: 100, max_wait },
        // one shard so the backlog and the high request share a batcher
        ServerOptions { queue_cap: 0, workers: 2, dispatch_shards: 1, telemetry: true },
    )
    .unwrap();

    let normals: Vec<_> =
        (0..10).map(|i| server.submit(vec![i as f32; input_len]).unwrap()).collect();
    // let the shard pull the normals into its batcher, arming their 400ms window
    std::thread::sleep(Duration::from_millis(30));
    let t0 = std::time::Instant::now();
    let high = server.submit_with(vec![99.0; input_len], Priority::High).unwrap();
    let resp = high.recv().unwrap().unwrap();
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_millis(250),
        "high priority must flush at ~25% of max_wait (100ms), waited {waited:?}"
    );
    assert!(
        resp.batch >= 11,
        "the boosted flush must carry the queued normals along, batch {}",
        resp.batch
    );
    for rx in normals {
        rx.recv().unwrap().unwrap();
    }
    server.shutdown();
}

/// Degenerate batching policies through the sharded front: `max_wait == 0`
/// (every poll flushes immediately) and `max_batch == 1` (no batch ever
/// carries two requests) must both serve every request.
#[test]
fn sharded_front_zero_wait_and_unit_batch_edges() {
    let engine = sim_engine();
    let input_len = engine.input_len;

    let e = engine.clone();
    let zero_wait = Server::start_with_opts(
        move || Ok(Box::new(e.clone()) as _),
        BatchPolicy { max_batch: 8, max_wait: Duration::ZERO },
        ServerOptions { queue_cap: 0, workers: 4, dispatch_shards: 2, telemetry: true },
    )
    .unwrap();
    let rxs: Vec<_> =
        (0..64).map(|i| zero_wait.submit(vec![i as f32; input_len]).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("no response lost").expect("inference ok");
        let want = i as f32 * input_len as f32;
        assert!((resp.output[0] - want).abs() <= 1e-1 * want.max(1.0), "request {i}");
    }
    assert_eq!(zero_wait.metrics().requests, 64);
    zero_wait.shutdown();

    let e = engine.clone();
    let unit_batch = Server::start_with_opts(
        move || Ok(Box::new(e.clone()) as _),
        BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
        ServerOptions { queue_cap: 0, workers: 4, dispatch_shards: 4, telemetry: true },
    )
    .unwrap();
    let rxs: Vec<_> =
        (0..32).map(|i| unit_batch.submit(vec![i as f32; input_len]).unwrap()).collect();
    for rx in rxs {
        let resp = rx.recv().expect("no response lost").expect("inference ok");
        assert_eq!(resp.batch, 1, "max_batch = 1 must never co-batch requests");
    }
    let m = unit_batch.metrics();
    assert_eq!(m.requests, 32);
    assert_eq!(m.batches, 32, "unit batches: one executable invocation per request");
    unit_batch.shutdown();
}

/// Per-request checksum integrity at K = 8 with genuinely concurrent
/// submitters: every reply must land on the handle of the request that
/// produced it, whichever shard batched it and whichever worker served it.
#[test]
fn sharded_front_checksum_integrity_k8() {
    let engine = sim_engine();
    let input_len = engine.input_len;
    let server = Server::start_with_opts(
        move || Ok(Box::new(engine.clone()) as _),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
        ServerOptions { queue_cap: 0, workers: 8, dispatch_shards: 0, telemetry: true },
    )
    .unwrap();
    assert_eq!(server.dispatch_shards(), 4, "workers=8 auto-sizes to 4 shards");

    const SUBMITTERS: usize = 4;
    const PER: usize = 64;
    std::thread::scope(|s| {
        for t in 0..SUBMITTERS {
            let server = &server;
            s.spawn(move || {
                let rxs: Vec<_> = (0..PER)
                    .map(|i| {
                        let tag = (t * PER + i) as f32;
                        (tag, server.submit(vec![tag; input_len]).unwrap())
                    })
                    .collect();
                for (tag, rx) in rxs {
                    let resp = rx.recv().expect("no response lost").expect("inference ok");
                    let want = tag * input_len as f32;
                    assert!(
                        (resp.output[0] - want).abs() <= 1e-1 * want.max(1.0),
                        "request {tag} got checksum {} — cross-request mixup",
                        resp.output[0]
                    );
                }
            });
        }
    });
    let m = server.metrics();
    assert_eq!(m.requests, (SUBMITTERS * PER) as u64, "no responses lost at K=8");
    let served: u64 = m.per_worker.iter().map(|w| w.requests).sum();
    assert_eq!(served, (SUBMITTERS * PER) as u64);
    assert_eq!(server.serving_path_locks(), 0, "K=8 serving path stayed lock-free");
    server.shutdown();
}

/// Satellite (b): hammering `Server::metrics()` from a reader thread while
/// requests stream through must neither stall dispatch nor charge a lock
/// to the serving path — snapshots fold on the reader's clock only.
#[test]
fn metrics_snapshots_under_load_do_not_stall_dispatch() {
    let engine = sim_engine();
    let input_len = engine.input_len;
    let server = Server::start_with_opts(
        move || Ok(Box::new(engine.clone()) as _),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
        ServerOptions { queue_cap: 0, workers: 4, dispatch_shards: 2, telemetry: true },
    )
    .unwrap();

    const N: usize = 192;
    let done = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|s| {
        let server_ref = &server;
        let done_ref = &done;
        // reader: tight snapshot loop for the whole serving window
        let reader = s.spawn(move || {
            let mut snaps = 0u64;
            while !done_ref.load(std::sync::atomic::Ordering::Acquire) {
                let m = server_ref.metrics();
                assert!(m.requests <= N as u64);
                snaps += 1;
            }
            snaps
        });
        let rxs: Vec<_> =
            (0..N).map(|i| server_ref.submit(vec![i as f32; input_len]).unwrap()).collect();
        for rx in rxs {
            rx.recv().expect("snapshot reader must not stall serving").unwrap();
        }
        done.store(true, std::sync::atomic::Ordering::Release);
        let snaps = reader.join().unwrap();
        assert!(snaps > 0, "the reader actually snapshotted under load");
    });
    assert_eq!(server.metrics().requests, N as u64);
    assert_eq!(
        server.serving_path_locks(),
        0,
        "snapshots under load must never charge the serving path"
    );
    server.shutdown();
}
