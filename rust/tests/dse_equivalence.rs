//! Equivalence guard for the incremental DSE evaluation engine.
//!
//! Three layers of protection:
//!
//! 1. **Engine equivalence** — the incremental engine (O(1) aggregates,
//!    min-ΔB heap, undo-log trials) must produce *identical* designs to the
//!    preserved pre-refactor recompute engine (`dse::reference`) on every
//!    `dse_perf` case: same per-layer configs and evicted bits, hence the
//!    same throughput, area and bandwidth.
//! 2. **Aggregate replay** — randomized `increment_unroll` /
//!    `increment_offchip` / rollback sequences leave the cached aggregates
//!    bit-identical to a fresh `Design::initialize` replaying only the
//!    committed operations, and consistent with an O(L) recomputation.
//! 3. **Warm-start safety** — the opt-in warm-start path matches the cold
//!    path exactly on workloads that never stream, and preserves all Eq. 6
//!    feasibility guarantees where eviction states may legitimately differ.

use autows::device::Device;
use autows::dse::{self, increment_offchip, increment_unroll, Design, DseConfig};
use autows::ir::Quant;
use autows::models;

/// xorshift64* PRNG, deterministic per test (no rand crate in this build).
struct Rng(u64);
impl Rng {
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

fn assert_designs_identical(a: &Design, b: &Design, label: &str) {
    assert_eq!(a.cfgs, b.cfgs, "{label}: per-layer configs diverged");
    assert_eq!(a.off_bits, b.off_bits, "{label}: evicted bits diverged");
    assert!(
        a.min_throughput() == b.min_throughput(),
        "{label}: throughput {} vs {}",
        a.min_throughput(),
        b.min_throughput()
    );
    assert_eq!(a.total_area(), b.total_area(), "{label}: area diverged");
    assert!(
        a.total_bandwidth() == b.total_bandwidth(),
        "{label}: bandwidth {} vs {}",
        a.total_bandwidth(),
        b.total_bandwidth()
    );
}

/// The `benches/dse_perf.rs` case list (the acceptance grid).
fn perf_cases() -> Vec<(&'static str, autows::ir::Network, Device)> {
    vec![
        ("toy/zcu102", models::toy_cnn(Quant::W8A8), Device::zcu102()),
        ("resnet18/zcu102", models::resnet18(Quant::W4A5), Device::zcu102()),
        ("resnet18/zedboard", models::resnet18(Quant::W4A5), Device::zedboard()),
        ("resnet50/u250", models::resnet50(Quant::W8A8), Device::u250()),
        ("resnet50/zcu102", models::resnet50(Quant::W4A5), Device::zcu102()),
        ("mobilenetv2/zc706", models::mobilenet_v2(Quant::W4A4), Device::zc706()),
        ("yolov5n/zcu102", models::yolov5n(Quant::W8A8), Device::zcu102()),
    ]
}

#[test]
fn incremental_engine_matches_reference_on_perf_grid() {
    let cfg = DseConfig::default();
    // fan the (slow) reference runs across cores; each case is independent
    let cases = perf_cases();
    let pairs = dse::parallel_cases(&cases, |_, (name, net, dev)| {
        let fast = dse::run(net, dev, &cfg);
        let slow = dse::reference::run(net, dev, &cfg);
        (*name, fast, slow)
    });
    for (name, fast, slow) in pairs {
        match (fast, slow) {
            (Some(f), Some(s)) => {
                assert_designs_identical(&f.design, &s.design, name);
                assert_eq!(f.iterations, s.iterations, "{name}: iteration counts diverged");
                f.design.assert_aggregates_consistent();
            }
            (None, None) => {}
            (f, s) => panic!(
                "{name}: feasibility diverged (incremental {:?} vs reference {:?})",
                f.map(|r| r.throughput),
                s.map(|r| r.throughput)
            ),
        }
    }
}

#[test]
fn incremental_engine_matches_reference_for_vanilla_and_coarse_hyperparams() {
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    for cfg in [
        DseConfig::vanilla(),
        DseConfig::default().with_phi(4).with_mu(2048),
        DseConfig::default().with_batch(8),
    ] {
        let fast = dse::run(&net, &dev, &cfg);
        let slow = dse::reference::run(&net, &dev, &cfg);
        match (fast, slow) {
            (Some(f), Some(s)) => assert_designs_identical(&f.design, &s.design, "resnet18"),
            (None, None) => {}
            _ => panic!("feasibility diverged for {cfg:?}"),
        }
    }
}

/// Apply a random committed mutation through the sanctioned entry points.
fn random_op(design: &mut Design, rng: &mut Rng, cfg: &DseConfig) {
    let weight_layers = design.network.weight_layers();
    match rng.below(3) {
        0 => {
            let l = rng.below(design.len());
            let phi = [1u32, 2, 4][rng.below(3)];
            increment_unroll(design, l, phi);
        }
        1 => {
            let l = weight_layers[rng.below(weight_layers.len())];
            increment_offchip(design, l, cfg);
        }
        _ => {
            let l = design.slowest();
            increment_unroll(design, l, 1);
        }
    }
}

#[test]
fn aggregates_bit_match_fresh_replay_under_random_trials_and_rollbacks() {
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let cfg = DseConfig::default();

    for seed in 1..=5u64 {
        let mut rng = Rng(seed);
        let mut live = Design::initialize(&net, &dev);
        // record of committed op seeds so the replay draws the same ops
        let mut committed: Vec<u64> = Vec::new();

        for step in 0..60 {
            let op_seed = rng.next();
            if step % 3 == 2 {
                // speculative trial: mutate a few times, then roll back
                live.begin_trial();
                let mut trial_rng = Rng(op_seed);
                for _ in 0..1 + trial_rng.below(3) {
                    random_op(&mut live, &mut trial_rng, &cfg);
                }
                live.rollback_trial();
            } else {
                let mut op_rng = Rng(op_seed);
                random_op(&mut live, &mut op_rng, &cfg);
                committed.push(op_seed);
            }
            live.assert_aggregates_consistent();
        }

        // fresh design replaying only the committed operations
        let mut replay = Design::initialize(&net, &dev);
        for &op_seed in &committed {
            let mut op_rng = Rng(op_seed);
            random_op(&mut replay, &mut op_rng, &cfg);
        }

        assert_eq!(live.cfgs, replay.cfgs, "seed {seed}: configs diverged");
        assert_eq!(live.off_bits, replay.off_bits, "seed {seed}: off_bits diverged");
        // cached aggregates must be bit-identical to the replay's — rolled
        // back trials may leave no trace, not even floating-point residue
        assert!(live.total_bandwidth() == replay.total_bandwidth(), "seed {seed}: bandwidth");
        assert!(live.min_throughput() == replay.min_throughput(), "seed {seed}: throughput");
        assert_eq!(live.total_area(), replay.total_area(), "seed {seed}: area");
        assert_eq!(live.mem_blocks(), replay.mem_blocks(), "seed {seed}: mem blocks");
        assert_eq!(live.latency_ms(1), replay.latency_ms(1), "seed {seed}: latency");
    }
}

#[test]
fn warm_start_matches_cold_on_non_streaming_grid() {
    // Cases whose cold-path result keeps every weight on-chip: the warm
    // memory path is then step-for-step identical to the cold path.
    for (name, net, dev) in
        [("toy/u250", models::toy_cnn(Quant::W8A8), Device::u250())]
    {
        let cold = dse::run(&net, &dev, &DseConfig::default()).expect("feasible");
        assert!(
            !cold.design.any_streaming(),
            "{name}: precondition — cold result must be all on-chip"
        );
        let warm = dse::run(&net, &dev, &DseConfig::warm()).expect("feasible");
        assert_designs_identical(&cold.design, &warm.design, name);
        assert_eq!(cold.iterations, warm.iterations, "{name}");
    }
}

#[test]
fn warm_start_respects_constraints_on_streaming_grid() {
    // Where eviction states may legitimately differ from the cold path, the
    // warm-started DSE must still satisfy every Eq. 6 constraint and stay
    // within the device budget.
    for (name, net, dev) in [
        ("resnet18/zcu102", models::resnet18(Quant::W4A5), Device::zcu102()),
        ("resnet18/zedboard", models::resnet18(Quant::W4A5), Device::zedboard()),
        ("mobilenetv2/zc706", models::mobilenet_v2(Quant::W4A4), Device::zc706()),
    ] {
        let Some(r) = dse::run(&net, &dev, &DseConfig::warm()) else {
            panic!("{name}: warm-start run must be feasible");
        };
        assert!(r.area.fits(&dev), "{name}: area");
        assert!(
            r.bandwidth_bps <= dev.bandwidth_bps * 1.0001,
            "{name}: bandwidth {} over {}",
            r.bandwidth_bps,
            dev.bandwidth_bps
        );
        assert!(r.design.mem_blocks() <= dev.mem_bram_equiv(), "{name}: memory budget");
        assert!(r.throughput > 0.0, "{name}");
        r.design.assert_aggregates_consistent();
    }
}
