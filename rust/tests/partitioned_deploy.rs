//! Integration tests of the partitioned (sharded) deployment path: the
//! golden 1-partition equivalence against the single-device pipeline, the
//! scale-out acceptance case (infeasible on one device, feasible on two),
//! cache-key separation between layouts, and the chained serving terminal.

use autows::device::Device;
use autows::dse::DseConfig;
use autows::ir::Quant;
use autows::pipeline::{Deployment, DesignCache};
use autows::sim::SimConfig;
use autows::Error;

/// Golden: `on_devices(&["zcu102"])` is the single-device deployment —
/// design, burst schedule and simulation are bit-identical on
/// resnet18/zcu102/W4A5.
#[test]
fn one_partition_equals_single_device_bit_for_bit() {
    let cfg = DseConfig::default();
    let single = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_device("zcu102")
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();
    let sharded = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_devices(&["zcu102"])
        .unwrap()
        .explore_uncached(&cfg)
        .unwrap()
        .schedule();

    assert_eq!(sharded.partitions().len(), 1);
    assert!(sharded.result().cuts.is_empty());
    let pd = &sharded.partitions()[0].result;
    assert_eq!(pd.design.cfgs, single.design().cfgs, "identical per-layer configs");
    assert_eq!(pd.design.off_bits, single.design().off_bits, "identical evicted bits");
    assert_eq!(pd.throughput, single.result().throughput, "bit-identical throughput");
    assert_eq!(pd.latency_ms, single.result().latency_ms, "bit-identical latency");
    assert_eq!(pd.area, single.result().area);
    assert_eq!(pd.bandwidth_bps, single.result().bandwidth_bps);

    // the partition's DMA burst schedule is the single-device schedule
    assert_eq!(sharded.burst_schedules().len(), 1);
    assert_eq!(sharded.burst_schedules()[0], *single.burst_schedule());
    assert!(sharded.links().is_empty());

    // and the simulation is the single-device simulation, verbatim
    let sim_cfg = SimConfig::default();
    let sim_single = single.simulate(&sim_cfg);
    let sim_sharded = sharded.simulate(&sim_cfg);
    assert_eq!(sim_sharded.per_partition.len(), 1);
    assert_eq!(sim_sharded.per_partition[0], sim_single, "bit-identical SimResult");
    assert_eq!(sim_sharded.makespan_s, sim_single.makespan_s);
    assert_eq!(sim_sharded.latency_ms, sim_single.latency_ms);
    assert_eq!(sim_sharded.total_stall_s, sim_single.total_stall_s);
}

/// Acceptance: a model that stops fitting one tightened zcu102 deploys
/// feasibly across two, and the report carries per-partition area/bandwidth
/// plus inter-device link utilization.
#[test]
fn infeasible_on_one_device_deploys_on_two() {
    let cfg = DseConfig::default();
    let single = Deployment::for_model("resnet50")
        .quant(Quant::W4A5)
        .on_device("zcu102")
        .unwrap();
    let sharded = Deployment::for_model("resnet50")
        .quant(Quant::W4A5)
        .on_devices(&["zcu102", "zcu102"])
        .unwrap();

    // walk the memory budget down until one device gives up; two devices of
    // the same budget must still deploy (each hosts only its partition)
    let mut witnessed = None;
    for scale in [0.45, 0.4, 0.35, 0.3, 0.25, 0.2, 0.15, 0.1, 0.07, 0.05] {
        let alone = single.with_mem_scale(scale).explore(&cfg);
        if alone.is_ok() {
            continue;
        }
        let err = alone.unwrap_err();
        assert!(err.is_infeasible(), "{err}");
        if let Ok(explored) = sharded.with_mem_scale(scale).explore(&cfg) {
            witnessed = Some((scale, explored));
            break;
        }
    }
    let (scale, explored) = witnessed.expect(
        "some tightened zcu102 budget must reject resnet50 alone yet accept it sharded",
    );
    assert_eq!(explored.partitions().len(), 2);
    for p in explored.partitions() {
        assert!(p.result.area.fits(&p.device), "partition must fit its device at {scale}x");
    }

    let scheduled = explored.schedule();
    let report = scheduled.report();
    assert!(report.contains("sharded across 2 devices"), "{report}");
    assert!(report.contains("partition 0"), "{report}");
    assert!(report.contains("partition 1"), "{report}");
    assert!(report.contains("bandwidth="), "per-partition bandwidth: {report}");
    assert!(report.contains("% mem"), "per-partition area/memory: {report}");
    assert!(report.contains("link 0→1"), "inter-device link line: {report}");
    assert!(report.contains("utilization"), "link utilization: {report}");

    // the chain also survives the partitioned simulator
    let sim = scheduled.simulate(&SimConfig::default());
    assert!(sim.makespan_s > 0.0);
    assert_eq!(sim.links.len(), 1);
    assert!((0.0..=1.0 + 1e-9).contains(&sim.links[0].utilization));
}

/// Cache separation (satellite): layouts differing only in device *count*
/// miss each other, and a cached infeasible on one layout does not leak to
/// another.
#[test]
fn cache_separates_layouts_and_does_not_leak_infeasibles() {
    let cfg = DseConfig::default();
    let cache = DesignCache::new();
    // a budget tight enough that resnet18 W4A5 cannot fit one zedboard-like
    // sliver of a zcu102 but can fit two
    let dev = Device::zcu102().with_mem_scale(0.12);

    let one = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_devices(std::slice::from_ref(&dev))
        .unwrap()
        .explore_in(&cache, &cfg);
    let two = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_devices(&[dev.clone(), dev.clone()])
        .unwrap()
        .explore_in(&cache, &cfg);

    // both were computed, neither was answered from the other's entry
    let s = cache.stats();
    assert_eq!(s.hits, 0, "device-count change must never hit");
    assert_eq!(s.misses, 2);
    assert_eq!(s.entries, 2);

    // whatever the outcomes, they are independent entries; revisiting each
    // layout hits its own entry and reproduces its own outcome
    let one_again = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_devices(std::slice::from_ref(&dev))
        .unwrap()
        .explore_in(&cache, &cfg);
    let two_again = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_devices(&[dev.clone(), dev.clone()])
        .unwrap()
        .explore_in(&cache, &cfg);
    let s = cache.stats();
    assert_eq!((s.hits, s.misses, s.entries), (2, 2, 2));
    assert_eq!(one.is_ok(), one_again.is_ok(), "cached outcome must replay identically");
    assert_eq!(two.is_ok(), two_again.is_ok());
    if let (Ok(a), Ok(b)) = (&two, &two_again) {
        assert_eq!(a.result().cuts, b.result().cuts);
        assert_eq!(a.result().throughput, b.result().throughput);
        assert!(b.was_cached());
    }
}

/// The chained serving terminal: one server, batching and metrics
/// unchanged, requests flow through the whole chain.
#[test]
fn sharded_serve_behind_one_server() {
    use autows::coordinator::{BatchPolicy, ServerOptions};
    let scheduled = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_devices(&["zcu102", "zcu102"])
        .unwrap()
        .explore(&DseConfig::default())
        .unwrap()
        .schedule();
    assert_eq!(scheduled.partitions().len(), 2);
    let server = scheduled.serve(BatchPolicy::default(), ServerOptions::default()).unwrap();
    let resp = server.infer(vec![0.5; scheduled.input_len()]).unwrap();
    assert_eq!(resp.output.len(), 10);
    assert!(resp.accel > std::time::Duration::ZERO);
    assert_eq!(server.metrics().requests, 1);
    server.shutdown();
}

/// Stage-0 failures of the multi-device path are typed errors.
#[test]
fn on_devices_error_surface() {
    let none: [&str; 0] = [];
    let e = Deployment::for_model("toy").on_devices(&none).unwrap_err();
    assert!(matches!(e, Error::Usage(_)), "{e}");

    let e = Deployment::for_model("toy").on_devices(&["zcu102", "zcu9000"]).unwrap_err();
    assert!(matches!(e, Error::UnknownDevice { ref name, .. } if name == "zcu9000"), "{e}");

    let e = Deployment::for_model("resnet9000").on_devices(&["zcu102"]).unwrap_err();
    assert!(matches!(e, Error::UnknownModel(_)), "{e}");

    // the infeasible error names the whole chain
    let e = Deployment::for_model("resnet50")
        .quant(Quant::W8A8)
        .on_devices(&["zedboard", "zedboard"])
        .unwrap()
        .explore(&DseConfig::vanilla())
        .unwrap_err();
    assert!(e.is_infeasible(), "{e}");
    assert!(e.to_string().contains("zedboard+zedboard"), "{e}");
}

/// A malformed pinned cut vector is a usage error, surfaced before any DSE
/// runs — never reported (or cached) as an infeasible design point.
#[test]
fn malformed_pinned_cuts_are_usage_errors_not_infeasible() {
    let cache = DesignCache::new();
    for bad in [vec![1, 2, 3], vec![5, 5], vec![0], vec![9999], vec![3]] {
        let e = Deployment::for_model("resnet18")
            .quant(Quant::W4A5)
            .on_devices(&["zcu102", "zcu102"])
            .unwrap()
            .with_cuts(bad.clone())
            .explore_in(&cache, &DseConfig::default())
            .unwrap_err();
        assert!(matches!(e, Error::Usage(_)), "cuts {bad:?}: {e}");
        assert!(!e.is_infeasible(), "cuts {bad:?} must not read as infeasibility");
    }
    let s = cache.stats();
    assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0), "nothing may be cached");
}

/// A pinned cut vector is honored and keys separately from the searched one.
#[test]
fn pinned_cuts_are_honored() {
    let cfg = DseConfig::default();
    let net = autows::models::resnet18(Quant::W4A5);
    let legal = autows::dse::partition::valid_cuts(&net);
    let pin = legal[legal.len() / 2];
    let explored = Deployment::for_model("resnet18")
        .quant(Quant::W4A5)
        .on_devices(&["zcu102", "zcu102"])
        .unwrap()
        .with_cuts(vec![pin])
        .explore_uncached(&cfg)
        .unwrap();
    assert_eq!(explored.result().cuts, vec![pin]);
    assert_eq!(explored.partitions()[0].hi, pin);
    assert_eq!(explored.partitions()[1].lo, pin);
}
