//! Integration tests over the extension subsystems: the config launcher
//! path, `.net` model files, the multi-model registry, compression
//! co-design end-to-end, and failure injection on each input surface.

use std::time::Duration;

use autows::compress::{compress_network, CompressionSpec};
use autows::config::{ModelSource, RunSpec};
use autows::coordinator::{
    BatchPolicy, ModelEntry, ModelRegistry, Priority, ServerOptions, SimOnlyEngine,
};
use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::{parse_network, Quant};
use autows::sim::{simulate, SimConfig};

/// Full launcher path: config text -> spec -> network -> DSE -> simulator.
#[test]
fn config_to_simulation_pipeline() {
    let spec = RunSpec::from_str(
        r#"
title = "integration"
[model]
name  = "resnet18"
quant = "w4a5"
[device]
name = "zcu102"
[dse]
phi = 2
mu  = 1024
[sim]
batch = 4
"#,
    )
    .unwrap();
    let net = spec.build_network().unwrap();
    let r = dse::run(&net, &spec.device, &spec.dse).expect("feasible");
    let sim = simulate(&r.design, &spec.device, &SimConfig { batch: spec.sim_batch, ..Default::default() });
    assert!(sim.makespan_s > 0.0);
    assert!(sim.total_stall_s <= 0.1 * sim.makespan_s, "balanced schedule");
}

/// The shipped example `.net` file must parse and deploy on the smallest
/// device (that is its documented purpose).
#[test]
fn shipped_net_file_deploys_on_zedboard() {
    let path = format!("{}/nets/residual_tiny.net", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path).expect("nets/residual_tiny.net shipped");
    let net = parse_network(&text, Quant::W8A8).unwrap();
    assert_eq!(net.name, "residual_tiny");
    let r = dse::run(&net, &Device::zedboard(), &DseConfig::default()).expect("fits zedboard");
    assert!(r.throughput > 100.0, "tiny net should be fast: {}", r.throughput);
}

/// Config `model.file` resolves through the same path.
#[test]
fn config_with_net_file_source() {
    let path = format!("{}/nets/residual_tiny.net", env!("CARGO_MANIFEST_DIR"));
    let cfg = format!("[model]\nfile = \"{path}\"\nquant = \"w8a8\"");
    let spec = RunSpec::from_str(&cfg).unwrap();
    assert_eq!(spec.model, ModelSource::File(path));
    let net = spec.build_network().unwrap();
    assert_eq!(net.stats().weight_layers, 8);
}

/// Missing model file is an error, not a panic.
#[test]
fn config_with_missing_net_file_errors() {
    let spec =
        RunSpec::from_str("[model]\nfile = \"/nonexistent/x.net\"").unwrap();
    let err = spec.build_network().unwrap_err();
    assert!(err.to_string().contains("cannot read"), "{err}");
}

/// Registry serving two models concurrently with priorities and admission
/// control — the multi-tenant coordinator scenario.
#[test]
fn registry_multi_model_serving() {
    let mut reg = ModelRegistry::new();
    for (alias, model) in [("small", "toy"), ("big", "resnet18")] {
        let net = autows::models::by_name(model, Quant::W8A8).unwrap();
        let dev = Device::u250();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let (c, h, w) = net.input_shape;
        let input_len = (c * h * w) as usize;
        let engine =
            SimOnlyEngine { design: r.design, device: dev, input_len, output_len: 10 };
        reg.register(
            ModelEntry {
                name: alias.into(),
                input_len,
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                options: ServerOptions { queue_cap: 64, workers: 1, dispatch_shards: 0, telemetry: true },
            },
            move || Ok(Box::new(engine.clone()) as _),
        )
        .unwrap();
    }
    assert_eq!(reg.models(), vec!["big", "small"]);

    let small_len = reg.entry("small").unwrap().input_len;
    let big_len = reg.entry("big").unwrap().input_len;
    assert_ne!(small_len, big_len);

    // interleave traffic across both models and priorities
    let mut rxs = Vec::new();
    for i in 0..12 {
        let (model, len) = if i % 3 == 0 { ("big", big_len) } else { ("small", small_len) };
        let prio = if i % 4 == 0 { Priority::High } else { Priority::Normal };
        rxs.push(reg.submit(model, vec![0.25; len], prio).unwrap());
    }
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
    assert_eq!(reg.metrics("big").unwrap().requests, 4);
    assert_eq!(reg.metrics("small").unwrap().requests, 8);
    reg.shutdown();
}

/// Compression co-design end-to-end: a model/device pair where the vanilla
/// pipeline cannot fit gains feasibility (or throughput) from pruning.
#[test]
fn compression_extends_device_reach() {
    let net = autows::models::resnet50(Quant::W8A8);
    let dev = Device::zcu102();
    // dense W8A8 resnet50 on zcu102: vanilla cannot fit (paper Table II: X
    // territory — 25.6 MB of weights vs ~5 MB on-chip)
    assert!(dse::run(&net, &dev, &DseConfig::vanilla()).is_none());

    let dense = dse::run(&net, &dev, &DseConfig::default());
    let (pruned, rep) = compress_network(&net, &CompressionSpec::pruned(0.7));
    assert!(rep.ratio() < 0.5);
    let compressed = dse::run(&pruned, &dev, &DseConfig::default())
        .expect("pruned resnet50 must fit zcu102 with streaming");
    if let Some(d) = dense {
        assert!(
            compressed.throughput >= d.throughput,
            "pruning must help the bandwidth-bound case: {} vs {}",
            compressed.throughput,
            d.throughput
        );
    }
}

/// Failure injection: zero-bandwidth device makes streaming designs
/// infeasible but leaves all-on-chip designs alone.
#[test]
fn bandwidth_starved_device_fails_cleanly() {
    let mut dev = Device::zcu102();
    dev.bandwidth_bps = 1e3; // effectively none
    // toy fits on-chip: still feasible (needs no weight streaming, and β_io
    // is the only bandwidth user — which the paper charges against B too,
    // so even this can fail; accept either, but no panic)
    let toy = autows::models::toy_cnn(Quant::W8A8);
    let _ = dse::run(&toy, &dev, &DseConfig::default());
    // resnet18-W4A5 needs streaming on zcu102: must be infeasible
    let net = autows::models::resnet18(Quant::W4A5);
    assert!(dse::run(&net, &dev, &DseConfig::default()).is_none());
}

/// Gantt + CSV trace exports hold together on a real streamed design.
#[test]
fn trace_exports_on_real_design() {
    use autows::sim::{render_gantt, to_csv};
    let net = autows::models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
    let sim = simulate(
        &r.design,
        &dev,
        &SimConfig { batch: 1, trace: true, max_trace_events: 256, ..Default::default() },
    );
    assert!(!sim.traces.is_empty(), "streamed design must trace");
    let csv = to_csv(&sim.traces);
    assert!(csv.lines().count() > 10);
    let gantt = render_gantt(&sim.traces, 80);
    assert!(gantt.contains("dma wr"));
}
