//! Cross-module integration: DSE -> burst schedule -> simulator, across the
//! paper's full (model, device, quant) grid, checking the qualitative claims
//! of the evaluation section hold end-to-end.

use autows::baseline::{self, sequential_latency_ms};
use autows::device::Device;
use autows::dse::{self, mem_sweep, DseConfig};
use autows::ir::Quant;
use autows::models;
use autows::schedule::{demux_sequence, BurstSchedule};
use autows::sim::{simulate, SimConfig};

/// Table II, row resnet18: the three-architecture ordering per device class.
#[test]
fn table2_resnet18_orderings() {
    let q = Quant::W4A5;
    let net = models::resnet18(q);

    // small device (zc706-class): vanilla infeasible, AutoWS feasible
    let zc706 = Device::zc706();
    assert!(baseline::vanilla(&net, &zc706).is_none());
    let autows = dse::run(&net, &zc706, &DseConfig::default()).unwrap();
    assert!(autows.throughput > 0.0);

    // mid device (zcu102): vanilla infeasible, AutoWS beats sequential
    let zcu102 = Device::zcu102();
    assert!(baseline::vanilla(&net, &zcu102).is_none());
    let a = dse::run(&net, &zcu102, &DseConfig::default()).unwrap();
    let a_ms = simulate(&a.design, &zcu102, &SimConfig::default()).latency_ms;
    let s_ms = sequential_latency_ms(&net, &zcu102);
    assert!(a_ms < s_ms, "AutoWS {a_ms} must beat sequential {s_ms} on zcu102");

    // large device (u50, W8A8): vanilla ~= AutoWS, both beat sequential
    let u50 = Device::u50();
    let net8 = models::resnet18(Quant::W8A8);
    let v = baseline::vanilla(&net8, &u50).expect("vanilla fits u50");
    let a = dse::run(&net8, &u50, &DseConfig::default()).unwrap();
    let ratio = a.throughput / v.throughput;
    assert!((0.8..1.3).contains(&ratio), "large device: AutoWS ≈ vanilla ({ratio})");
    let s = sequential_latency_ms(&net8, &u50);
    assert!(1e3 / a.throughput < s, "pipelined must beat sequential on u50");
}

/// Table II, resnet50-U50: the paper's flagship result — AutoWS turns a
/// 15 ms-class vanilla design into one beating the sequential baseline.
#[test]
fn table2_resnet50_u50_headline() {
    let net = models::resnet50(Quant::W8A8);
    let dev = Device::u50();
    let v = baseline::vanilla(&net, &dev).expect("vanilla fits (memory-starved)");
    let a = dse::run(&net, &dev, &DseConfig::default()).unwrap();
    let s = sequential_latency_ms(&net, &dev);
    let v_ms = 1e3 / v.throughput;
    let a_ms = simulate(&a.design, &dev, &SimConfig::default()).latency_ms;
    assert!(a_ms < v_ms, "AutoWS {a_ms} must beat memory-starved vanilla {v_ms}");
    assert!(a_ms < s, "AutoWS {a_ms} must beat sequential {s}");
    assert!(v_ms > s, "vanilla should lose to sequential when memory-starved");
}

/// Fig. 6's three regions on the real sweep axis.
#[test]
fn fig6_three_regions() {
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let pts = mem_sweep(&net, &dev, &[0.5, 1.0, 1.5, 2.0]);

    // region 1: vanilla infeasible, AutoWS delivers
    assert!(pts[0].vanilla_fps.is_none());
    assert!(pts[0].autows_fps.is_some());
    // region 2/3 boundary: vanilla appears once memory suffices
    let vanilla_appears = pts.iter().filter(|p| p.vanilla_fps.is_some()).count();
    assert!(vanilla_appears >= 1, "vanilla must become feasible at 2x memory");
    // region 3: convergence
    let last = &pts[3];
    if let (Some(a), Some(v)) = (last.autows_fps, last.vanilla_fps) {
        assert!((a / v - 1.0).abs() < 0.35, "converged region: {a} vs {v}");
    }
    // AutoWS monotone non-decreasing with memory (tolerance for greedy noise)
    for w in pts.windows(2) {
        let (a, b) = (w[0].autows_fps.unwrap(), w[1].autows_fps.unwrap());
        assert!(b >= a * 0.9, "{a} -> {b}");
    }
}

/// Fig. 7: the eviction set prefers layers with small output maps (late
/// layers) — minimal ΔB.
#[test]
fn fig7_eviction_prefers_small_output_maps() {
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
    let streaming = r.design.streaming_layers();
    assert!(!streaming.is_empty());
    let avg_pixels_streaming: f64 = streaming
        .iter()
        .map(|&i| {
            let l = &net.layers[i];
            (l.h_out() * l.w_out()) as f64
        })
        .sum::<f64>()
        / streaming.len() as f64;
    let weight_layers = net.weight_layers();
    let avg_pixels_all: f64 = weight_layers
        .iter()
        .map(|&i| (net.layers[i].h_out() * net.layers[i].w_out()) as f64)
        .sum::<f64>()
        / weight_layers.len() as f64;
    assert!(
        avg_pixels_streaming < avg_pixels_all,
        "streamed layers should have smaller maps: {avg_pixels_streaming} vs {avg_pixels_all}"
    );
}

/// The DMA demux sequence of a DSE design is deterministic, contiguous and
/// schedulable (paper §IV-B).
#[test]
fn dma_demux_sequence_is_deterministic_and_schedulable() {
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
    let s1 = BurstSchedule::from_design(&r.design, &dev, 1);
    let s2 = BurstSchedule::from_design(&r.design, &dev, 1);
    let d1 = demux_sequence(&s1);
    let d2 = demux_sequence(&s2);
    assert_eq!(d1.len(), d2.len());
    for (a, b) in d1.iter().zip(&d2) {
        assert_eq!(a.layer, b.layer);
        assert!((a.offset - b.offset).abs() < 1e-15);
    }
    assert!(s1.schedulable());
    assert!(s1.balanced());
}

/// Simulated latency of DSE designs tracks the analytic model within 25%
/// on every feasible Table II cell (validates the models the DSE trusts).
#[test]
fn sim_validates_analytic_model_across_grid() {
    for (model, device, q) in [
        ("mobilenetv2", "zcu102", Quant::W4A5),
        ("resnet18", "zcu102", Quant::W4A5),
        ("resnet18", "u50", Quant::W8A8),
        ("resnet50", "u250", Quant::W8A8),
    ] {
        let net = models::by_name(model, q).unwrap();
        let dev = Device::by_name(device).unwrap();
        let Some(r) = dse::run(&net, &dev, &DseConfig::default()) else { continue };
        let sim = simulate(&r.design, &dev, &SimConfig::default());
        let rel = (sim.latency_ms - r.latency_ms) / r.latency_ms;
        assert!(
            (-0.001..0.25).contains(&rel),
            "{model}/{device}: sim {} vs analytic {} ({:+.1}%)",
            sim.latency_ms,
            r.latency_ms,
            rel * 100.0
        );
    }
}

/// Hyperparameters φ and μ trade exploration time for quality (paper §IV-A):
/// coarser steps must not crash and should stay within 2x of the fine result.
#[test]
fn hyperparameter_coarseness_tradeoff() {
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    let fine = dse::run(&net, &dev, &DseConfig::default()).unwrap();
    let coarse = dse::run(
        &net,
        &dev,
        &DseConfig::default().with_phi(8).with_mu(4096),
    )
    .unwrap();
    assert!(coarse.iterations <= fine.iterations);
    assert!(
        coarse.throughput >= fine.throughput * 0.4,
        "coarse {} vs fine {}",
        coarse.throughput,
        fine.throughput
    );
}

/// YOLOv5n §V-D: pipelined beats the sequential (Vitis-AI-class) baseline.
#[test]
fn yolo_pipelined_beats_sequential() {
    let net = models::yolov5n(Quant::W8A8);
    let dev = Device::zcu102();
    let s = sequential_latency_ms(&net, &dev);
    let a = dse::run(&net, &dev, &DseConfig::default()).unwrap();
    let a_ms = simulate(&a.design, &dev, &SimConfig::default()).latency_ms;
    assert!(a_ms < s, "AutoWS {a_ms} must beat sequential {s} (paper: 8.7 vs 13.7)");
}
