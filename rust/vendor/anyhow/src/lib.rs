//! Offline shim for the subset of the `anyhow` 1.x API used by this
//! workspace: [`Error`], [`Result`], the [`Context`] extension trait and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build is fully offline (no registry access), so the real crate cannot
//! be fetched; this shim keeps the call sites source-compatible. Errors are
//! stored as a chain of display strings (context outermost), which is all
//! the callers rely on: `Display`, alternate `{:#}` chains, `Debug` with a
//! "Caused by" list, and `?`-conversion from any `std::error::Error`.

use std::fmt;

/// `Result` with the shim's [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A chain of error messages, outermost context first.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.cause.as_deref();
            Some(cur.msg.as_str())
        })
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cause = self.cause.as_deref();
            while let Some(e) = cause {
                write!(f, ": {}", e.msg)?;
                cause = e.cause.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.cause.as_deref();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cause {
            write!(f, "\n    {}", e.msg)?;
            cause = e.cause.as_deref();
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion legal and
// lets `?` lift any std error into the chain.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error { msg: it.next().unwrap(), cause: None };
        for msg in it {
            err = Error { msg, cause: Some(Box::new(err)) };
        }
        err
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to results
/// and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::msg("root").context("mid").context("top");
        assert_eq!(e.to_string(), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("top") && dbg.contains("Caused by") && dbg.contains("root"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io_fail() -> Result<()> {
            std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(())
        }
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Err(anyhow!("always fails with {}", x))
        }
        assert_eq!(f(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(f(0).unwrap_err().to_string(), "zero is not allowed");
        assert_eq!(f(3).unwrap_err().to_string(), "always fails with 3");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert_eq!(e.to_string(), "while formatting");
        let o: Option<i32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }
}
