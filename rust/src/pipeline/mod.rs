//! One typed, staged pipeline from model name to served requests — the
//! crate's front door.
//!
//! AutoWS's promise is that the whole flow is automated: model ingest, the
//! greedy DSE (paper Algorithm 1), deterministic burst scheduling
//! (Eq. 8–10), simulation and serving. This module packages that flow as a
//! staged builder where **each stage is a distinct type**, so the compiler
//! enforces the ordering:
//!
//! ```text
//! Deployment::for_model("resnet18")   // stage 0: pick the model
//!     .quant(Quant::W4A5)             //          quantization
//!     .on_device("zcu102")?           // stage 1: Planned (model+device resolved)
//!     .explore(&DseConfig::default())? // stage 2: Explored (DSE ran / cache hit)
//!     .schedule()                     // stage 3: Scheduled (burst schedule derived)
//!     // terminals: .simulate(..) / .report() / .serve(policy, opts)
//! ```
//!
//! Exploration goes through a process-wide **content-keyed design cache**
//! ([`design_cache`], see [`cache`] for the key semantics): sweeps and
//! repeated serve runs on the same (network, device, config) content skip
//! the redundant DSE and get bit-identical results.
//!
//! **Sharded deployments** take the same staircase with `on_devices`: the
//! network is split across a chain of devices (contiguous layer ranges, cut
//! points searched to balance the pipeline — see
//! [`crate::dse::partition`]), each partition gets its own DMA burst
//! schedule, and the terminals simulate/report/serve the whole chain. A
//! one-element device list is bit-identical to `on_device`:
//!
//! ```no_run
//! use autows::dse::DseConfig;
//! use autows::ir::Quant;
//! use autows::pipeline::Deployment;
//!
//! fn main() -> Result<(), autows::Error> {
//!     let sharded = Deployment::for_model("resnet50")
//!         .quant(Quant::W4A5)
//!         .on_devices(&["zcu102", "zcu102"])?   // -> PartitionedPlanned
//!         .explore(&DseConfig::default())?      // -> PartitionedExplored (cut search)
//!         .schedule();                          // -> PartitionedScheduled
//!     print!("{}", sharded.report());           // per-partition table + link utilization
//!     Ok(())
//! }
//! ```
//!
//! **Co-located deployments** are the dual: several models share ONE device
//! via [`Deployment::colocate`]. The joint `.explore()` splits the device's
//! area and DMA bandwidth into per-tenant budgets (seeded by weight
//! footprint, rebalanced toward the worst bottleneck — see
//! [`crate::dse::colocate`]), `.schedule()` composes one burst schedule per
//! tenant on the shared port, and `.serve` registers every tenant behind a
//! [`crate::coordinator::ModelRegistry`]. A one-element tenant list is
//! bit-identical to `on_device`:
//!
//! ```no_run
//! use autows::dse::DseConfig;
//! use autows::ir::Quant;
//! use autows::pipeline::Deployment;
//!
//! fn main() -> Result<(), autows::Error> {
//!     let joint = Deployment::colocate([
//!         Deployment::for_model("resnet18").quant(Quant::W4A5),
//!         Deployment::for_model("squeezenet").quant(Quant::W8A8),
//!     ])
//!     .on_device("zcu102")?                     // -> ColocatedPlanned
//!     .explore(&DseConfig::default())?          // -> ColocatedExplored (joint search)
//!     .schedule();                              // -> ColocatedScheduled
//!     print!("{}", joint.report());             // per-tenant shares + port utilization
//!     Ok(())
//! }
//! ```
//!
//! **Fleet deployments** generalize all three: N models placed onto an
//! M-device pool via [`Deployment::fleet`]. The placement search at
//! `.explore()` decides per model between solo, sharded and co-located
//! placement under a [`crate::dse::FleetObjective`] (maximize aggregate
//! throughput, or meet a p99 SLO on the fewest devices — see
//! [`crate::dse::fleet`]), `.schedule()` derives the placement-appropriate
//! schedule per decision, and `.serve` fronts every per-device stack behind
//! one [`crate::coordinator::Router`]. The degenerate shapes (1×1, 1×M,
//! N×1) stay bit-identical to the narrower builders:
//!
//! ```no_run
//! use autows::dse::{DseConfig, FleetObjective};
//! use autows::ir::Quant;
//! use autows::pipeline::Deployment;
//!
//! fn main() -> Result<(), autows::Error> {
//!     let fleet = Deployment::fleet(
//!         [
//!             Deployment::for_model("resnet50").quant(Quant::W8A8),
//!             Deployment::for_model("resnet18").quant(Quant::W4A5),
//!             Deployment::for_model("squeezenet").quant(Quant::W8A8),
//!         ],
//!         &["zc706", "zcu102", "zcu102"],
//!     )?                                        // -> FleetPlanned
//!     .with_objective(FleetObjective::MinDevicesAtSlo { p99_ms: 50.0 })
//!     .explore(&DseConfig::default())?          // -> FleetExplored (placement search)
//!     .schedule();                              // -> FleetScheduled
//!     print!("{}", fleet.report());             // placement table
//!     Ok(())
//! }
//! ```
//!
//! Skipping a stage is a *compile* error — `Planned` simply has no
//! `schedule` method:
//!
//! ```compile_fail
//! use autows::pipeline::Deployment;
//! // ERROR: cannot schedule before exploring (no `schedule` on `Planned`)
//! let s = autows::pipeline::Deployment::for_model("resnet18")
//!     .on_device("zcu102")
//!     .unwrap()
//!     .schedule();
//! ```
//!
//! and so is simulating before scheduling:
//!
//! ```compile_fail
//! use autows::dse::DseConfig;
//! use autows::pipeline::Deployment;
//! use autows::sim::SimConfig;
//! // ERROR: no `simulate` on `Explored` — derive the schedule first
//! let sim = Deployment::for_model("toy")
//!     .on_device("zcu102")
//!     .unwrap()
//!     .explore(&DseConfig::default())
//!     .unwrap()
//!     .simulate(&SimConfig::default());
//! ```
//!
//! The full chain, end to end:
//!
//! ```no_run
//! use autows::coordinator::{BatchPolicy, ServerOptions};
//! use autows::dse::DseConfig;
//! use autows::ir::Quant;
//! use autows::pipeline::Deployment;
//!
//! fn main() -> Result<(), autows::Error> {
//!     let scheduled = Deployment::for_model("resnet18")
//!         .quant(Quant::W4A5)
//!         .on_device("zcu102")?
//!         .explore(&DseConfig::default())?
//!         .schedule();
//!     print!("{}", scheduled.report());
//!     let server = scheduled.serve(BatchPolicy::default(), ServerOptions::default())?;
//!     let reply = server.infer(vec![0.5; scheduled.input_len()]);
//!     server.shutdown();
//!     reply.map(|_| ()).map_err(|e| autows::Error::Serve(e.to_string()))
//! }
//! ```

pub mod cache;
mod colocated;
mod fleet;
mod partitioned;
mod serve;
mod stages;
pub mod sweep;

pub use cache::{design_cache, CacheStats, DesignCache};
pub use colocated::{
    ColocatedDeployment, ColocatedExplored, ColocatedPlanned, ColocatedScheduled,
};
pub use fleet::{
    FleetExplored, FleetPlanned, FleetScheduled, FleetSimReport, PlacementSchedule,
    PlacementSim,
};
pub use partitioned::{PartitionedExplored, PartitionedPlanned, PartitionedScheduled};
pub use serve::{drive_synthetic, drive_synthetic_tenant, EngineSpec};
pub use stages::{Deployment, Explored, IntoDevice, Planned, Scheduled};
