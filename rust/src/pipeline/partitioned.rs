//! The partitioned (sharded) deployment stages: `PartitionedPlanned` →
//! `PartitionedExplored` → `PartitionedScheduled`.
//!
//! Mirrors the single-device staged builder one-to-one —
//! [`Deployment::on_devices`](super::Deployment::on_devices) instead of
//! `on_device`, then `explore` (cut-point search + per-partition DSE,
//! through the design cache), then `schedule` (one burst schedule per
//! partition's DMA port), then the terminals `simulate` / `report` /
//! `serve` (a chain of per-partition engines behind one [`Server`]).
//!
//! The 1-partition case is the trivial degenerate chain and is bit-identical
//! to the single-device path (enforced by `tests/partitioned_deploy.rs`).

use crate::coordinator::{BatchPolicy, ChainedEngine, Server, ServerOptions};
use crate::device::Device;
use crate::dse::{partition, DseConfig, PartitionPlan, PartitionedResult};
use crate::error::Error;
use crate::ir::Network;
use crate::schedule::{BurstSchedule, LinkSpec};
use crate::sim::{simulate_partitioned, PartitionedSimResult, SimConfig};

use super::cache::{design_cache, DesignCache};

/// Stage 1 (multi-device) — a model resolved against a device chain, ready
/// for the cut-point search.
#[derive(Debug, Clone)]
pub struct PartitionedPlanned {
    network: Network,
    devices: Vec<Device>,
    /// Pinned interior cut points; `None` lets `.explore()` search.
    cuts: Option<Vec<usize>>,
}

impl PartitionedPlanned {
    /// Build a partitioned plan directly from parts.
    pub fn from_parts(network: Network, devices: Vec<Device>) -> PartitionedPlanned {
        assert!(!devices.is_empty(), "a deployment needs at least one device");
        PartitionedPlanned { network, devices, cuts: None }
    }

    pub fn network(&self) -> &Network {
        &self.network
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// Pin the cut vector instead of searching (`cuts.len()` must be
    /// `devices.len() - 1`; every cut must be legal per
    /// [`partition::valid_cuts`], or exploration reports infeasible).
    pub fn with_cuts(mut self, cuts: Vec<usize>) -> PartitionedPlanned {
        self.cuts = Some(cuts);
        self
    }

    /// The same plan with every device's memory budget scaled (the sharded
    /// analogue of [`super::Planned::with_mem_scale`]).
    pub fn with_mem_scale(&self, scale: f64) -> PartitionedPlanned {
        PartitionedPlanned {
            network: self.network.clone(),
            devices: self.devices.iter().map(|d| d.with_mem_scale(scale)).collect(),
            cuts: self.cuts.clone(),
        }
    }

    fn infeasible(&self, cfg: &DseConfig) -> Error {
        let chain: Vec<&str> = self.devices.iter().map(|d| d.name).collect();
        Error::Infeasible {
            model: self.network.name.clone(),
            device: chain.join("+"),
            vanilla: !cfg.allow_streaming,
        }
    }

    /// A malformed pinned cut vector is an argument bug, reported as
    /// [`Error::Usage`] *before* any DSE runs or cache writes — it must not
    /// masquerade as (and be cached as) an infeasible design point.
    fn check_pinned_cuts(&self) -> Result<(), Error> {
        if let Some(cuts) = &self.cuts {
            partition::validate_cuts(&self.network, self.devices.len(), cuts)
                .map_err(|why| Error::Usage(format!("with_cuts: {why}")))?;
        }
        Ok(())
    }

    /// Run the cut-point search and per-partition DSE through the
    /// process-wide [design cache](design_cache).
    pub fn explore(self, cfg: &DseConfig) -> Result<PartitionedExplored, Error> {
        self.explore_in(design_cache(), cfg)
    }

    /// [`PartitionedPlanned::explore`] with [`DseConfig::default`].
    pub fn explore_default(self) -> Result<PartitionedExplored, Error> {
        self.explore(&DseConfig::default())
    }

    /// [`PartitionedPlanned::explore`] against a caller-owned cache.
    pub fn explore_in(
        self,
        cache: &DesignCache,
        cfg: &DseConfig,
    ) -> Result<PartitionedExplored, Error> {
        self.check_pinned_cuts()?;
        let (outcome, cached) =
            cache.explore_partitioned(&self.network, &self.devices, self.cuts.as_deref(), cfg);
        match outcome {
            Some(outcome) => Ok(PartitionedExplored {
                outcome,
                devices: self.devices,
                cfg: *cfg,
                cached,
            }),
            None => Err(self.infeasible(cfg)),
        }
    }

    /// Run the search bypassing the cache (benchmarks, equivalence oracles).
    pub fn explore_uncached(self, cfg: &DseConfig) -> Result<PartitionedExplored, Error> {
        self.check_pinned_cuts()?;
        let outcome = match &self.cuts {
            None => partition::partition(&self.network, &self.devices, cfg),
            Some(cuts) => {
                partition::partition_with_cuts(&self.network, &self.devices, cuts, cfg)
            }
        };
        match outcome {
            Some(outcome) => Ok(PartitionedExplored {
                outcome,
                devices: self.devices,
                cfg: *cfg,
                cached: false,
            }),
            None => Err(self.infeasible(cfg)),
        }
    }
}

/// Stage 2 (multi-device) — a feasible sharding with per-partition designs.
#[derive(Debug, Clone)]
pub struct PartitionedExplored {
    outcome: PartitionedResult,
    devices: Vec<Device>,
    cfg: DseConfig,
    cached: bool,
}

impl PartitionedExplored {
    pub fn result(&self) -> &PartitionedResult {
        &self.outcome
    }

    pub fn partitions(&self) -> &[PartitionPlan] {
        &self.outcome.parts
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    pub fn config(&self) -> &DseConfig {
        &self.cfg
    }

    /// `true` when the sharding came from the design cache (no search ran).
    pub fn was_cached(&self) -> bool {
        self.cached
    }

    /// Derive each partition's DMA burst schedule for the batch size the
    /// DSE planned for.
    pub fn schedule(self) -> PartitionedScheduled {
        let batch = self.cfg.batch;
        self.schedule_for_batch(batch)
    }

    /// [`PartitionedExplored::schedule`] for an explicit serving batch size.
    pub fn schedule_for_batch(self, batch: u64) -> PartitionedScheduled {
        let schedules = self
            .outcome
            .parts
            .iter()
            .map(|p| BurstSchedule::from_design(&p.result.design, &p.device, batch))
            .collect();
        PartitionedScheduled {
            outcome: self.outcome,
            devices: self.devices,
            schedules,
            output_len: 10,
        }
    }
}

/// Stage 3 (multi-device) — per-partition designs + burst schedules: the
/// terminal sharded artifact. Simulate it, render a report, or serve it as
/// a chain behind one [`Server`].
#[derive(Debug, Clone)]
pub struct PartitionedScheduled {
    outcome: PartitionedResult,
    devices: Vec<Device>,
    schedules: Vec<BurstSchedule>,
    output_len: usize,
}

impl PartitionedScheduled {
    pub fn result(&self) -> &PartitionedResult {
        &self.outcome
    }

    pub fn partitions(&self) -> &[PartitionPlan] {
        &self.outcome.parts
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// One burst schedule per partition's DMA port, in chain order.
    pub fn burst_schedules(&self) -> &[BurstSchedule] {
        &self.schedules
    }

    /// `(design, device)` per partition, in chain order — the simulator's
    /// and link model's view of this deployment.
    fn stage_refs(&self) -> Vec<(&crate::dse::Design, &Device)> {
        self.outcome.parts.iter().map(|p| (&p.result.design, &p.device)).collect()
    }

    /// The inter-device links, in chain order (empty for one partition).
    pub fn links(&self) -> Vec<LinkSpec> {
        LinkSpec::chain(&self.stage_refs())
    }

    /// Output vector length of the served checksum engine (default 10).
    pub fn with_output_len(mut self, output_len: usize) -> PartitionedScheduled {
        self.output_len = output_len;
        self
    }

    /// Flattened per-sample input length of the deployed network
    /// (partition 0's input).
    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.outcome.parts[0].result.design.network.input_shape;
        (c as usize) * (h as usize) * (w as usize)
    }

    /// Validate the chain in the partitioned simulator: per-partition event
    /// simulation plus the link model.
    pub fn simulate(&self, cfg: &SimConfig) -> PartitionedSimResult {
        simulate_partitioned(&self.stage_refs(), cfg)
    }

    /// Human-readable sharded deployment report: chain metrics, then per
    /// partition the area/bandwidth/DMA figures, with each inter-device
    /// link's demand and utilization in between.
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let net0 = &self.outcome.parts[0].result.design.network;
        let chain: Vec<&str> = self.devices.iter().map(|d| d.name).collect();
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}-{} sharded across {} devices [{}]: θ={:.1} fps, latency={:.2} ms, cuts={:?}",
            net0.name.split('.').next().unwrap_or(&net0.name),
            net0.quant,
            self.devices.len(),
            chain.join(", "),
            self.outcome.throughput,
            self.outcome.latency_ms(),
            self.outcome.cuts
        );
        let links = self.links();
        for (i, p) in self.outcome.parts.iter().enumerate() {
            let r = &p.result;
            let sched = &self.schedules[i];
            let _ = writeln!(
                out,
                "  partition {i}: layers {}..{} ({} CEs) on {}: θ={:.1} fps, \
                 area dsp={} lut={} bram={} ({:.0}% mem), bandwidth={:.2}/{:.2} Gbps, \
                 {} streaming (DMA util {:.0}%)",
                p.lo,
                p.hi,
                p.len(),
                p.device.name,
                r.throughput,
                r.area.dsp,
                r.area.lut,
                r.area.bram.total(),
                r.area.mem_utilization(&p.device) * 100.0,
                r.bandwidth_bps / 1e9,
                p.device.bandwidth_gbps(),
                sched.entries.len(),
                sched.dma_utilization() * 100.0
            );
            if i < links.len() {
                let link = &links[i];
                let _ = writeln!(
                    out,
                    "  link {i}→{}: {:.1} Kbit/sample over {:.0} Gbps: utilization {:.1}%, \
                     latency {:.1} us",
                    i + 1,
                    link.boundary_bits as f64 / 1e3,
                    link.bandwidth_bps / 1e9,
                    link.utilization(self.outcome.throughput) * 100.0,
                    link.latency_s * 1e6
                );
            }
        }
        out
    }

    /// Boot the serving loop for this sharded design: one [`Server`] (queue,
    /// batcher, metrics unchanged) dispatching to the chain of per-partition
    /// engines via [`ChainedEngine`] — or, with `opts.workers > 1`, to a
    /// pool of identical chains.
    pub fn serve(&self, policy: BatchPolicy, opts: ServerOptions) -> Result<Server, Error> {
        let stages: Vec<(crate::dse::Design, Device)> = self
            .outcome
            .parts
            .iter()
            .map(|p| (p.result.design.clone(), p.device.clone()))
            .collect();
        let engine = ChainedEngine::new(stages, self.input_len(), self.output_len);
        Server::start_with_opts(move || Ok(Box::new(engine.clone()) as _), policy, opts)
            .map_err(|e| Error::Serve(e.to_string()))
    }
}
