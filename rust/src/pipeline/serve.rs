//! Terminal serving stage: turn a [`Scheduled`](super::Scheduled) design
//! into a running [`Server`] and drive it.

use crate::coordinator::{
    BatchPolicy, ModelRegistry, PjrtEngine, Priority, Server, ServerOptions, SimOnlyEngine,
};
use crate::error::Error;
use crate::runtime::Runtime;

use super::stages::Scheduled;

/// Which inference engine backs the server.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// Timing-only engine: checksum numerics + simulated accelerator clock.
    /// The input length is derived from the network's input shape.
    SimOnly {
        /// Output vector length per request.
        output_len: usize,
    },
    /// PJRT numerics from an AOT-compiled HLO-text artifact + simulated
    /// accelerator clock (requires the `pjrt` feature to actually execute).
    Pjrt {
        /// Path to the HLO-text artifact.
        artifact: String,
        /// (channels, height, width) of one sample.
        input_shape: (usize, usize, usize),
        /// Batch size the artifact was lowered with (smaller batches pad).
        artifact_batch: usize,
    },
}

impl Default for EngineSpec {
    fn default() -> Self {
        EngineSpec::SimOnly { output_len: 10 }
    }
}

impl Scheduled {
    /// Replace the engine the terminal [`Scheduled::serve`] stage boots
    /// (default: [`EngineSpec::SimOnly`]).
    pub fn with_engine(mut self, engine: EngineSpec) -> Scheduled {
        self.engine = engine;
        self
    }

    /// Flattened per-sample input length of the deployed network.
    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.result.design.network.input_shape;
        (c as usize) * (h as usize) * (w as usize)
    }

    /// Boot the serving loop for this design: the engine (per
    /// [`Scheduled::with_engine`]) is constructed on each pool worker's own
    /// thread (`opts.workers` of them), the batcher runs `policy`, and
    /// admission control follows `opts`.
    pub fn serve(&self, policy: BatchPolicy, opts: ServerOptions) -> Result<Server, Error> {
        let design = self.result.design.clone();
        let device = self.device.clone();
        match &self.engine {
            EngineSpec::SimOnly { output_len } => {
                let engine = SimOnlyEngine {
                    design,
                    device,
                    input_len: self.input_len(),
                    output_len: *output_len,
                };
                Server::start_with_opts(move || Ok(Box::new(engine.clone()) as _), policy, opts)
                    .map_err(|e| Error::Serve(e.to_string()))
            }
            EngineSpec::Pjrt { artifact, input_shape, artifact_batch } => {
                let artifact = artifact.clone();
                let input_shape = *input_shape;
                let artifact_batch = *artifact_batch;
                // PJRT handles are thread-affine: each worker loads its own
                // copy of the artifact on its own thread.
                Server::start_with_opts(
                    move || {
                        let rt = Runtime::cpu()?;
                        let model = rt.load_hlo_text(&artifact)?;
                        Ok(Box::new(PjrtEngine::new(
                            model,
                            design.clone(),
                            device.clone(),
                            input_shape,
                            artifact_batch,
                        )) as _)
                    },
                    policy,
                    opts,
                )
                .map_err(|e| Error::Serve(e.to_string()))
            }
        }
    }
}

/// The deterministic synthetic input of request `i` — ONE definition shared
/// by every drive path, so the CLI, launcher and benches always offer the
/// same load.
fn synthetic_input(i: usize, input_len: usize) -> Vec<f32> {
    (0..input_len).map(|j| ((i * 31 + j) % 255) as f32 / 255.0).collect()
}

/// Wait for every submitted response; per-request failures arrive typed
/// from the coordinator, a dropped coordinator maps to [`Error::Serve`].
fn await_all(receivers: Vec<crate::coordinator::ReplyHandle>) -> Result<(), Error> {
    for rx in receivers {
        rx.recv().map_err(|_| Error::Serve("coordinator dropped request".to_string()))??;
    }
    Ok(())
}

/// Submit `requests` deterministic synthetic inputs and wait for every
/// response — the shared driver of the CLI serve command, `RunSpec`
/// serving sections and the e2e bench.
pub fn drive_synthetic(server: &Server, requests: usize, input_len: usize) -> Result<(), Error> {
    let receivers: Result<Vec<_>, Error> =
        (0..requests).map(|i| server.submit(synthetic_input(i, input_len))).collect();
    await_all(receivers?)
}

/// [`drive_synthetic`] against one tenant of a co-located
/// [`ModelRegistry`]: same deterministic inputs and error mapping, routed
/// by tenant name — the shared driver of the colocated CLI serve path and
/// `RunSpec` tenant serving sections.
pub fn drive_synthetic_tenant(
    registry: &ModelRegistry,
    tenant: &str,
    requests: usize,
    input_len: usize,
) -> Result<(), Error> {
    let receivers: Result<Vec<_>, Error> = (0..requests)
        .map(|i| registry.submit(tenant, synthetic_input(i, input_len), Priority::Normal))
        .collect();
    await_all(receivers?)
}
