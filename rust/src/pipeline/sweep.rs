//! Cache-aware parameter sweeps: the pipeline-level entry points to
//! [`crate::dse::parallel_cases`]. Every point is explored through the
//! process-wide [design cache](super::design_cache), so re-running a sweep
//! (or overlapping sweeps — a Fig. 6 grid and a report regenerating the
//! same points) skips the redundant DSE work while returning results
//! identical to the uncached path.

use crate::dse::{parallel_cases, DseConfig, HyperPoint, SweepPoint};

use super::stages::Planned;

/// Fan `f` over plans across the machine's cores, in input order — the
/// pipeline-aware twin of [`parallel_cases`]. Closures that call
/// [`Planned::explore`] share the global design cache across workers (the
/// cache never serializes them: the DSE runs outside its lock).
pub fn parallel_plans<R, F>(plans: &[Planned], f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &Planned) -> R + Sync,
{
    parallel_cases(plans, f)
}

/// The Fig. 6 memory sweep for `plan`'s (network, device) pair: each scale
/// probes AutoWS and the vanilla baseline at that on-chip budget.
pub fn mem_sweep(plan: &Planned, scales: &[f64]) -> Vec<SweepPoint> {
    let autows_cfg = DseConfig::default();
    let vanilla_cfg = DseConfig::vanilla();
    parallel_cases(scales, |_, &s| {
        let scaled = plan.with_mem_scale(s);
        let autows = scaled.clone().explore(&autows_cfg).ok();
        let vanilla = scaled.explore(&vanilla_cfg).ok();
        SweepPoint {
            mem_scale: s,
            autows_offchip_frac: autows
                .as_ref()
                .map_or(0.0, |e| e.design().offchip_weight_frac()),
            autows_fps: autows.map(|e| e.result().throughput),
            vanilla_fps: vanilla.map(|e| e.result().throughput),
        }
    })
}

/// Memory sweep of a single configuration (no vanilla baseline): per scale,
/// the achieved throughput or `None` when infeasible. The launcher's
/// `device.mem_sweep` config option runs on this.
pub fn mem_sweep_points(plan: &Planned, scales: &[f64], cfg: &DseConfig) -> Vec<(f64, Option<f64>)> {
    parallel_cases(scales, |_, &s| {
        let fps = plan.with_mem_scale(s).explore(cfg).ok().map(|e| e.result().throughput);
        (s, fps)
    })
}

/// The φ/µ hyperparameter grid (§IV-A exploration-cost vs quality trade-off)
/// for `plan`'s (network, device) pair; infeasible cells are dropped.
pub fn phi_mu_sweep(plan: &Planned, phis: &[u32], mus: &[u64]) -> Vec<HyperPoint> {
    let grid: Vec<(u32, u64)> =
        phis.iter().flat_map(|&phi| mus.iter().map(move |&mu| (phi, mu))).collect();
    parallel_cases(&grid, |_, &(phi, mu)| {
        let cfg = DseConfig::default().with_phi(phi).with_mu(mu);
        plan.clone().explore(&cfg).ok().map(|e| {
            let r = e.result();
            HyperPoint {
                phi,
                mu,
                iterations: r.iterations,
                throughput: r.throughput,
                latency_ms: r.latency_ms,
            }
        })
    })
    .into_iter()
    .flatten()
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::dse;
    use crate::ir::Quant;
    use crate::models;
    use crate::pipeline::Deployment;

    fn resnet18_plan() -> Planned {
        Deployment::for_model("resnet18")
            .quant(Quant::W4A5)
            .on_device(Device::zcu102())
            .unwrap()
    }

    /// The cached pipeline sweep returns exactly what the direct per-point
    /// DSE returns.
    #[test]
    fn cached_sweep_matches_direct_runs() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let plan = Planned::from_parts(net.clone(), dev.clone());
        let scales = [0.6, 1.0, 1.4];
        let pts = mem_sweep(&plan, &scales);
        for (p, &s) in pts.iter().zip(&scales) {
            let direct = dse::run(&net, &dev.with_mem_scale(s), &DseConfig::default())
                .map(|r| r.throughput);
            assert_eq!(p.autows_fps, direct, "scale {s}");
        }
        // second pass: identical results straight from the cache
        let again = mem_sweep(&plan, &scales);
        for (a, b) in pts.iter().zip(&again) {
            assert_eq!(a.autows_fps, b.autows_fps);
            assert_eq!(a.vanilla_fps, b.vanilla_fps);
        }
    }

    /// The three regions of Fig. 6 on a coarse grid (pipeline path).
    #[test]
    fn fig6_regions_exist() {
        let pts = mem_sweep(&resnet18_plan(), &[0.4, 0.8, 1.6]);
        assert!(pts[0].vanilla_fps.is_none(), "vanilla should not fit at 0.4x");
        assert!(pts[0].autows_fps.is_some(), "AutoWS must fit at 0.4x");
        let fps: Vec<f64> = pts.iter().map(|p| p.autows_fps.unwrap()).collect();
        assert!(fps[0] <= fps[2] * 1.05, "{fps:?}");
        assert!(pts[0].autows_offchip_frac >= pts[2].autows_offchip_frac);
    }

    #[test]
    fn phi_mu_grid_covers_feasible_cells() {
        let pts = phi_mu_sweep(&resnet18_plan(), &[1, 8], &[512]);
        assert_eq!(pts.len(), 2);
        let fine = pts.iter().find(|p| p.phi == 1).unwrap();
        let coarse = pts.iter().find(|p| p.phi == 8).unwrap();
        assert!(coarse.iterations <= fine.iterations);
    }

    #[test]
    fn mem_sweep_points_respects_config() {
        let plan = Planned::from_parts(models::toy_cnn(Quant::W8A8), Device::zcu102());
        let pts = mem_sweep_points(&plan, &[1.0], &DseConfig::default().with_phi(2));
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].0, 1.0);
        assert!(pts[0].1.is_some());
    }
}
