//! The co-located (multi-tenant) deployment stages: `ColocatedPlanned` →
//! `ColocatedExplored` → `ColocatedScheduled`.
//!
//! Mirrors the single-device staged builder one-to-one —
//! [`Deployment::colocate`](super::Deployment::colocate) instead of a single
//! model, then `on_device` (ONE device shared by every tenant), then
//! `explore` (joint budget search + per-tenant DSE, through the design
//! cache), then `schedule` (one [`BurstSchedule`] per tenant composed on the
//! shared DMA port), then the terminals `simulate` / `report` / `serve` (a
//! [`ModelRegistry`] answering every tenant).
//!
//! The 1-tenant case is the trivial degenerate co-location and is
//! bit-identical to the single-device path (enforced by
//! `tests/colocated_deploy.rs`), mirroring PR 4's 1-partition golden.

use crate::coordinator::{BatchPolicy, ModelEntry, ModelRegistry, ServerOptions, SimOnlyEngine};
use crate::device::Device;
use crate::dse::{colocate, ColocatedResult, DseConfig, TenantPlan};
use crate::error::Error;
use crate::ir::Network;
use crate::schedule::{BurstSchedule, SharedDmaSchedule};
use crate::sim::{simulate_colocated, ColocatedSimResult, SimConfig};

use super::cache::{design_cache, DesignCache};
use super::stages::{Deployment, IntoDevice};

/// Stage 0 (multi-tenant) — a set of tenant deployments waiting for their
/// shared device. Created by [`Deployment::colocate`]; advanced by
/// [`ColocatedDeployment::on_device`].
#[derive(Debug, Clone)]
pub struct ColocatedDeployment {
    pub(super) tenants: Vec<Deployment>,
}

impl ColocatedDeployment {
    /// Resolve every tenant's model and the one shared device into a
    /// [`ColocatedPlanned`] deployment. Tenant names must be unique — the
    /// serving registry routes by name, so a duplicate is a typed
    /// [`Error::DuplicateModel`] here, not a surprise at `.serve`.
    pub fn on_device(self, device: impl IntoDevice) -> Result<ColocatedPlanned, Error> {
        if self.tenants.is_empty() {
            return Err(Error::Usage("colocate: the tenant list is empty".to_string()));
        }
        let device = device.resolve()?;
        let networks: Vec<Network> = self
            .tenants
            .into_iter()
            .map(Deployment::into_network)
            .collect::<Result<_, _>>()?;
        for (i, net) in networks.iter().enumerate() {
            if networks[..i].iter().any(|n| n.name == net.name) {
                return Err(Error::DuplicateModel(net.name.clone()));
            }
        }
        Ok(ColocatedPlanned { networks, device })
    }
}

/// Stage 1 (multi-tenant) — N models resolved against one shared device,
/// ready for the joint budget search.
#[derive(Debug, Clone)]
pub struct ColocatedPlanned {
    networks: Vec<Network>,
    device: Device,
}

impl ColocatedPlanned {
    /// Build a co-located plan directly from parts.
    pub fn from_parts(networks: Vec<Network>, device: Device) -> ColocatedPlanned {
        assert!(!networks.is_empty(), "a co-location needs at least one tenant");
        ColocatedPlanned { networks, device }
    }

    pub fn networks(&self) -> &[Network] {
        &self.networks
    }

    pub fn device(&self) -> &Device {
        &self.device
    }

    /// The same tenant set against a memory-scaled variant of the shared
    /// device (the co-located analogue of
    /// [`super::Planned::with_mem_scale`]).
    pub fn with_mem_scale(&self, scale: f64) -> ColocatedPlanned {
        ColocatedPlanned {
            networks: self.networks.clone(),
            device: self.device.with_mem_scale(scale),
        }
    }

    fn infeasible(&self, cfg: &DseConfig) -> Error {
        let tenants: Vec<&str> = self.networks.iter().map(|n| n.name.as_str()).collect();
        Error::Infeasible {
            model: tenants.join("+"),
            device: self.device.name.to_string(),
            vanilla: !cfg.allow_streaming,
        }
    }

    /// Run the joint budget search and per-tenant DSE through the
    /// process-wide [design cache](design_cache).
    pub fn explore(self, cfg: &DseConfig) -> Result<ColocatedExplored, Error> {
        self.explore_in(design_cache(), cfg)
    }

    /// [`ColocatedPlanned::explore`] with [`DseConfig::default`].
    pub fn explore_default(self) -> Result<ColocatedExplored, Error> {
        self.explore(&DseConfig::default())
    }

    /// [`ColocatedPlanned::explore`] against a caller-owned cache.
    pub fn explore_in(
        self,
        cache: &DesignCache,
        cfg: &DseConfig,
    ) -> Result<ColocatedExplored, Error> {
        let (outcome, cached) = cache.explore_colocated(&self.networks, &self.device, cfg);
        match outcome {
            Some(outcome) => {
                Ok(ColocatedExplored { outcome, device: self.device, cfg: *cfg, cached })
            }
            None => Err(self.infeasible(cfg)),
        }
    }

    /// Run the search bypassing the cache (benchmarks, equivalence oracles).
    pub fn explore_uncached(self, cfg: &DseConfig) -> Result<ColocatedExplored, Error> {
        match colocate::colocate(&self.networks, &self.device, cfg) {
            Some(outcome) => Ok(ColocatedExplored {
                outcome,
                device: self.device,
                cfg: *cfg,
                cached: false,
            }),
            None => Err(self.infeasible(cfg)),
        }
    }
}

/// Stage 2 (multi-tenant) — a feasible joint plan with per-tenant designs
/// and budget shares.
#[derive(Debug, Clone)]
pub struct ColocatedExplored {
    outcome: ColocatedResult,
    device: Device,
    cfg: DseConfig,
    cached: bool,
}

impl ColocatedExplored {
    pub fn result(&self) -> &ColocatedResult {
        &self.outcome
    }

    pub fn tenants(&self) -> &[TenantPlan] {
        &self.outcome.tenants
    }

    pub fn device(&self) -> &Device {
        &self.device
    }

    pub fn config(&self) -> &DseConfig {
        &self.cfg
    }

    /// `true` when the joint plan came from the design cache (no search
    /// ran).
    pub fn was_cached(&self) -> bool {
        self.cached
    }

    /// Derive every tenant's DMA burst schedule (against its bandwidth
    /// slice) composed on the shared port, for the batch size the DSE
    /// planned for.
    pub fn schedule(self) -> ColocatedScheduled {
        let batch = self.cfg.batch;
        self.schedule_for_batch(batch)
    }

    /// [`ColocatedExplored::schedule`] for an explicit serving batch size.
    pub fn schedule_for_batch(self, batch: u64) -> ColocatedScheduled {
        let port = {
            let tenants: Vec<(&str, f64, &crate::dse::Design, &Device)> = self
                .outcome
                .tenants
                .iter()
                .map(|t| (t.name.as_str(), t.share, &t.result.design, &t.view))
                .collect();
            SharedDmaSchedule::compose(&tenants, &self.device, batch)
        };
        ColocatedScheduled { outcome: self.outcome, device: self.device, port, output_len: 10 }
    }
}

/// Stage 3 (multi-tenant) — per-tenant designs + the composed shared-port
/// schedule: the terminal co-located artifact. Simulate it, render a
/// report, or serve every tenant from one registry.
#[derive(Debug, Clone)]
pub struct ColocatedScheduled {
    outcome: ColocatedResult,
    device: Device,
    port: SharedDmaSchedule,
    output_len: usize,
}

impl ColocatedScheduled {
    pub fn result(&self) -> &ColocatedResult {
        &self.outcome
    }

    pub fn tenants(&self) -> &[TenantPlan] {
        &self.outcome.tenants
    }

    pub fn device(&self) -> &Device {
        &self.device
    }

    /// The composed shared-DMA-port schedule (one [`BurstSchedule`] per
    /// tenant under the port-level cap).
    pub fn port_schedule(&self) -> &SharedDmaSchedule {
        &self.port
    }

    /// A tenant's own burst schedule, by name.
    pub fn burst_schedule(&self, tenant: &str) -> Option<&BurstSchedule> {
        self.port.slice(tenant).map(|s| &s.schedule)
    }

    /// Output vector length of the served checksum engines (default 10).
    pub fn with_output_len(mut self, output_len: usize) -> ColocatedScheduled {
        self.output_len = output_len;
        self
    }

    /// Flattened per-sample input length of a tenant's network.
    pub fn input_len(&self, tenant: &str) -> Option<usize> {
        self.outcome.tenants.iter().find(|t| t.name == tenant).map(|t| {
            let (c, h, w) = t.result.design.network.input_shape;
            (c as usize) * (h as usize) * (w as usize)
        })
    }

    /// Tenant names in plan order.
    pub fn tenant_names(&self) -> Vec<&str> {
        self.outcome.tenants.iter().map(|t| t.name.as_str()).collect()
    }

    /// Validate the joint plan in the co-located simulator: every tenant's
    /// burst train interleaved on the one shared DMA port.
    pub fn simulate(&self, cfg: &SimConfig) -> ColocatedSimResult {
        let stages: Vec<(&str, &crate::dse::Design, &Device)> = self
            .outcome
            .tenants
            .iter()
            .map(|t| (t.name.as_str(), &t.result.design, &t.view))
            .collect();
        simulate_colocated(&stages, &self.device, cfg)
    }

    /// Human-readable co-located deployment report: joint metrics, then per
    /// tenant the budget share, throughput (absolute and normalized to its
    /// solo run), area/bandwidth figures and streaming count, closing with
    /// the shared-port composition.
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let names = self.tenant_names().join(" + ");
        let area = self.outcome.joint_area();
        let _ = writeln!(
            out,
            "{} co-located on {}: min norm θ={:.2}, aggregate θ={:.1} fps, \
             budget from rebalance round {}",
            names,
            self.device.name,
            self.outcome.min_norm_throughput,
            self.outcome.aggregate_throughput(),
            self.outcome.rounds
        );
        let _ = writeln!(
            out,
            "joint area: dsp={}/{} lut={}/{} bram={}/{} ({:.0}% mem)  \
             bandwidth={:.2}/{:.2} Gbps (port util {:.0}%)",
            area.dsp,
            self.device.dsp,
            area.lut,
            self.device.lut,
            area.bram.total(),
            self.device.mem_bram_equiv(),
            area.mem_utilization(&self.device) * 100.0,
            self.outcome.joint_bandwidth_bps() / 1e9,
            self.device.bandwidth_gbps(),
            self.port.port_utilization() * 100.0
        );
        for (i, t) in self.outcome.tenants.iter().enumerate() {
            let r = &t.result;
            let sched = &self.port.slices[i].schedule;
            let _ = writeln!(
                out,
                "  tenant {i} {:<16} share={:.0}%: θ={:.1} fps ({:.0}% of solo), \
                 area dsp={} lut={} bram={} ({:.0}% of its slice), \
                 bandwidth={:.2} Gbps, {} streaming (DMA util {:.0}%)",
                t.name,
                t.share * 100.0,
                r.throughput,
                t.norm_throughput() * 100.0,
                r.area.dsp,
                r.area.lut,
                r.area.bram.total(),
                r.area.mem_utilization(&t.view) * 100.0,
                r.bandwidth_bps / 1e9,
                sched.entries.len(),
                sched.dma_utilization() * 100.0
            );
        }
        let _ = writeln!(
            out,
            "shared DMA port: {} burst entries across {} tenants, schedulable={}",
            self.port.total_entries(),
            self.outcome.tenants.len(),
            self.port.schedulable()
        );
        out
    }

    /// Boot the serving side of this joint plan: every tenant registered
    /// behind one [`ModelRegistry`] (its own engine on its budget view;
    /// queue, batcher and metrics per tenant), routed by tenant name.
    pub fn serve(
        &self,
        policy: BatchPolicy,
        opts: ServerOptions,
    ) -> Result<ModelRegistry, Error> {
        let mut registry = ModelRegistry::new();
        for t in &self.outcome.tenants {
            let input_len = self
                .input_len(&t.name)
                .expect("tenant names come from the plan itself");
            let engine = SimOnlyEngine {
                design: t.result.design.clone(),
                device: t.view.clone(),
                input_len,
                output_len: self.output_len,
            };
            registry.register(
                ModelEntry { name: t.name.clone(), input_len, policy, options: opts },
                move || Ok(Box::new(engine.clone()) as _),
            )?;
        }
        Ok(registry)
    }
}
