//! The staged builder: `Deployment` → `Planned` → `Explored` → `Scheduled`.
//!
//! Each stage is a distinct type exposing only the operations that are valid
//! at that point, so an out-of-order pipeline (scheduling before the DSE,
//! simulating before scheduling) is a *compile* error, not a runtime panic.

use crate::device::Device;
use crate::dse::{self, Design, DseConfig, DseResult};
use crate::error::Error;
use crate::ir::{Network, Quant};
use crate::models;
use crate::schedule::BurstSchedule;
use crate::sim::{simulate, SimConfig, SimResult};

use super::cache::{design_cache, DesignCache};
use super::serve::EngineSpec;

/// Where the model comes from.
#[derive(Debug, Clone)]
enum ModelSpec {
    /// Zoo builder by name ([`models::by_name`]).
    Zoo(String),
    /// `.net` description file ([`crate::ir::parse_network`]).
    File(String),
    /// A network built by the caller (its own quantization is kept;
    /// [`Deployment::quant`] has no effect on this variant).
    Network(Network),
}

/// Stage 0 — model selection. Created by [`Deployment::for_model`] /
/// [`Deployment::for_net_file`] / [`Deployment::for_network`]; advanced by
/// [`Deployment::on_device`], which resolves model and device eagerly so
/// lookup failures surface at one defined point.
#[derive(Debug, Clone)]
pub struct Deployment {
    source: ModelSpec,
    quant: Quant,
}

/// Accepted by [`Deployment::on_device`]: a device library name or an
/// already-built (possibly budget-scaled) [`Device`].
pub trait IntoDevice {
    fn resolve(self) -> Result<Device, Error>;
}

impl IntoDevice for Device {
    fn resolve(self) -> Result<Device, Error> {
        Ok(self)
    }
}

impl IntoDevice for &Device {
    fn resolve(self) -> Result<Device, Error> {
        Ok(self.clone())
    }
}

impl IntoDevice for &str {
    fn resolve(self) -> Result<Device, Error> {
        Device::by_name(self).ok_or_else(|| Error::UnknownDevice {
            name: self.to_string(),
            known: Device::known_names(),
        })
    }
}

impl IntoDevice for &String {
    fn resolve(self) -> Result<Device, Error> {
        self.as_str().resolve()
    }
}

impl IntoDevice for String {
    fn resolve(self) -> Result<Device, Error> {
        self.as_str().resolve()
    }
}

impl Deployment {
    /// Deploy a zoo model by name (resolved at [`Deployment::on_device`]).
    pub fn for_model(name: impl Into<String>) -> Deployment {
        Deployment { source: ModelSpec::Zoo(name.into()), quant: Quant::W8A8 }
    }

    /// Deploy a custom network from a `.net` description file.
    pub fn for_net_file(path: impl Into<String>) -> Deployment {
        Deployment { source: ModelSpec::File(path.into()), quant: Quant::W8A8 }
    }

    /// Deploy an already-built network (keeps the network's own
    /// quantization).
    pub fn for_network(network: Network) -> Deployment {
        let quant = network.quant;
        Deployment { source: ModelSpec::Network(network), quant }
    }

    /// Quantization to build the model with (default `w8a8`). Ignored for
    /// [`Deployment::for_network`] — a built network carries its own.
    pub fn quant(mut self, quant: Quant) -> Deployment {
        self.quant = quant;
        self
    }

    /// Parse-and-set quantization from a label (`"w4a5"`, `"w8a8"`, …).
    pub fn quant_label(self, label: &str) -> Result<Deployment, Error> {
        let q = Quant::parse(label).ok_or_else(|| Error::UnknownQuant(label.to_string()))?;
        Ok(self.quant(q))
    }

    /// Resolve the model source into a network (shared by the single- and
    /// multi-device planning paths).
    fn build_network(source: ModelSpec, quant: Quant) -> Result<Network, Error> {
        match source {
            ModelSpec::Zoo(name) => {
                models::by_name(&name, quant).ok_or_else(|| Error::UnknownModel(name))
            }
            ModelSpec::File(path) => {
                let text = std::fs::read_to_string(&path)
                    .map_err(|source| Error::Io { path: path.clone(), source })?;
                crate::ir::parse_network(&text, quant)
                    .map_err(|source| Error::NetParse { path, source })
            }
            ModelSpec::Network(net) => Ok(net),
        }
    }

    /// Resolve this deployment's model into its network (the multi-tenant
    /// planning path consumes tenants one by one).
    pub(super) fn into_network(self) -> Result<Network, Error> {
        Self::build_network(self.source, self.quant)
    }

    /// Resolve model and device into a [`Planned`] deployment.
    pub fn on_device(self, device: impl IntoDevice) -> Result<Planned, Error> {
        let device = device.resolve()?;
        let network = Self::build_network(self.source, self.quant)?;
        Ok(Planned { network, device })
    }

    /// Co-locate several tenant deployments on ONE shared device: the dual
    /// of [`Deployment::on_devices`] (N networks, one device instead of one
    /// network, N devices). Returns the multi-tenant stage-0 builder;
    /// advance with
    /// [`ColocatedDeployment::on_device`](super::ColocatedDeployment::on_device),
    /// after which `.explore()` runs the joint budget search. A one-element
    /// tenant list is the trivial co-location, bit-identical to
    /// [`Deployment::on_device`].
    pub fn colocate(
        tenants: impl IntoIterator<Item = Deployment>,
    ) -> super::ColocatedDeployment {
        super::ColocatedDeployment { tenants: tenants.into_iter().collect() }
    }

    /// Place N models onto an M-device pool: the fleet generalization of
    /// every narrower builder. The placement search at `.explore()` decides
    /// per model between running **solo** on one device, **sharding** across
    /// several (via the cut-point search), or **co-locating** with other
    /// small models on a shared device — under the plan's
    /// [`FleetObjective`](crate::dse::FleetObjective). Returns the
    /// [`FleetPlanned`](super::FleetPlanned) stage; the degenerate shapes
    /// (1×1, 1×M, N×1) stay bit-identical to
    /// [`Deployment::on_device`]/[`Deployment::on_devices`]/
    /// [`Deployment::colocate`].
    pub fn fleet<D: IntoDevice + Clone>(
        models: impl IntoIterator<Item = Deployment>,
        devices: &[D],
    ) -> Result<super::FleetPlanned, Error> {
        super::FleetPlanned::plan(models.into_iter().collect(), devices)
    }

    /// Resolve model and a **device chain** into a
    /// [`PartitionedPlanned`](super::PartitionedPlanned) deployment: the
    /// network will be sharded across the listed devices (in chain order) by
    /// the cut-point search at `.explore()`. A one-element list is the
    /// trivial 1-partition case, bit-identical to [`Deployment::on_device`].
    pub fn on_devices<D: IntoDevice + Clone>(
        self,
        devices: &[D],
    ) -> Result<super::PartitionedPlanned, Error> {
        if devices.is_empty() {
            return Err(Error::Usage("on_devices: the device list is empty".to_string()));
        }
        let devices: Vec<Device> = devices
            .iter()
            .cloned()
            .map(IntoDevice::resolve)
            .collect::<Result<_, _>>()?;
        let network = Self::build_network(self.source, self.quant)?;
        Ok(super::PartitionedPlanned::from_parts(network, devices))
    }
}

/// Stage 1 — a model resolved against a device, ready to explore.
#[derive(Debug, Clone)]
pub struct Planned {
    network: Network,
    device: Device,
}

impl Planned {
    /// Build a plan directly from parts (the entry point library code uses
    /// when it already holds a [`Network`] and [`Device`]).
    pub fn from_parts(network: Network, device: Device) -> Planned {
        Planned { network, device }
    }

    pub fn network(&self) -> &Network {
        &self.network
    }

    pub fn device(&self) -> &Device {
        &self.device
    }

    /// The same plan against a memory-scaled variant of the device
    /// (Fig. 6-style budget sweeps).
    pub fn with_mem_scale(&self, scale: f64) -> Planned {
        Planned { network: self.network.clone(), device: self.device.with_mem_scale(scale) }
    }

    /// Run the greedy DSE (paper Algorithm 1) through the process-wide
    /// [design cache](design_cache): a revisited design point returns the
    /// memoized result without re-running the search.
    pub fn explore(self, cfg: &DseConfig) -> Result<Explored, Error> {
        self.explore_in(design_cache(), cfg)
    }

    /// [`Planned::explore`] with [`DseConfig::default`].
    pub fn explore_default(self) -> Result<Explored, Error> {
        self.explore(&DseConfig::default())
    }

    /// [`Planned::explore`] against a caller-owned cache (tests, isolated
    /// sweeps).
    pub fn explore_in(self, cache: &DesignCache, cfg: &DseConfig) -> Result<Explored, Error> {
        let (result, cached) = cache.explore(&self.network, &self.device, cfg);
        match result {
            Some(result) => {
                Ok(Explored { result, device: self.device, cfg: *cfg, cached })
            }
            None => Err(Error::Infeasible {
                model: self.network.name.clone(),
                device: self.device.name.to_string(),
                vanilla: !cfg.allow_streaming,
            }),
        }
    }

    /// Run the DSE bypassing the cache (benchmarks timing the search
    /// itself, equivalence oracles).
    pub fn explore_uncached(self, cfg: &DseConfig) -> Result<Explored, Error> {
        match dse::run(&self.network, &self.device, cfg) {
            Some(result) => {
                Ok(Explored { result, device: self.device, cfg: *cfg, cached: false })
            }
            None => Err(Error::Infeasible {
                model: self.network.name.clone(),
                device: self.device.name.to_string(),
                vanilla: !cfg.allow_streaming,
            }),
        }
    }

    /// Adopt a design produced elsewhere (a deserialized checkpoint from
    /// [`dse::parse_design`]) as this plan's exploration outcome, deriving
    /// the summary metrics from the analytic models.
    pub fn adopt_design(self, design: Design) -> Explored {
        let result = DseResult {
            throughput: design.min_throughput(),
            latency_ms: design.latency_ms(1),
            area: design.total_area(),
            bandwidth_bps: design.total_bandwidth(),
            iterations: 0,
            design,
        };
        Explored { result, device: self.device, cfg: DseConfig::default(), cached: false }
    }
}

/// Stage 2 — a feasible design point with its DSE metrics.
#[derive(Debug, Clone)]
pub struct Explored {
    result: DseResult,
    device: Device,
    cfg: DseConfig,
    cached: bool,
}

impl Explored {
    pub fn result(&self) -> &DseResult {
        &self.result
    }

    pub fn design(&self) -> &Design {
        &self.result.design
    }

    pub fn device(&self) -> &Device {
        &self.device
    }

    pub fn config(&self) -> &DseConfig {
        &self.cfg
    }

    /// `true` when the design came from the design cache (no DSE ran).
    pub fn was_cached(&self) -> bool {
        self.cached
    }

    /// Derive the deterministic DMA burst schedule (paper Eq. 8–10) for the
    /// batch size the DSE planned for, producing the terminal stage.
    pub fn schedule(self) -> Scheduled {
        let batch = self.cfg.batch;
        self.schedule_for_batch(batch)
    }

    /// [`Explored::schedule`] for an explicit serving batch size.
    pub fn schedule_for_batch(self, batch: u64) -> Scheduled {
        let schedule = BurstSchedule::from_design(&self.result.design, &self.device, batch);
        let engine = EngineSpec::default();
        Scheduled { result: self.result, device: self.device, schedule, engine }
    }
}

/// Stage 3 — design + burst schedule: the terminal artifact. Simulate it,
/// render a report, or serve requests on it.
#[derive(Debug, Clone)]
pub struct Scheduled {
    pub(super) result: DseResult,
    pub(super) device: Device,
    pub(super) schedule: BurstSchedule,
    pub(super) engine: EngineSpec,
}

impl Scheduled {
    pub fn result(&self) -> &DseResult {
        &self.result
    }

    pub fn design(&self) -> &Design {
        &self.result.design
    }

    pub fn device(&self) -> &Device {
        &self.device
    }

    pub fn burst_schedule(&self) -> &BurstSchedule {
        &self.schedule
    }

    /// Validate the design in the cycle-accurate event simulator.
    pub fn simulate(&self, cfg: &SimConfig) -> SimResult {
        simulate(&self.result.design, &self.device, cfg)
    }

    /// Human-readable deployment report: DSE metrics, schedule health and
    /// the per-layer configuration table (what `autows dse` prints).
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let r = &self.result;
        let net = &r.design.network;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}-{} on {}: θ={:.1} fps, latency={:.2} ms, iterations={}",
            net.name, net.quant, self.device.name, r.throughput, r.latency_ms, r.iterations
        );
        let _ = writeln!(
            out,
            "area: dsp={} lut={} bram={} ({:.0}% mem)  bandwidth={:.2}/{:.2} Gbps",
            r.area.dsp,
            r.area.lut,
            r.area.bram.total(),
            r.area.mem_utilization(&self.device) * 100.0,
            r.bandwidth_bps / 1e9,
            self.device.bandwidth_gbps()
        );
        let _ = writeln!(
            out,
            "streaming layers: {} (balanced={}, DMA util {:.0}%)",
            self.schedule.entries.len(),
            self.schedule.balanced(),
            self.schedule.dma_utilization() * 100.0
        );
        for (i, l) in net.layers.iter().enumerate() {
            if !l.has_weights() {
                continue;
            }
            let c = &r.design.cfgs[i];
            let _ = writeln!(
                out,
                "  {:<24} kp={:<2} cp={:<3} fp={:<3} n={:<3} u_on={:<6} u_off={:<6} off={:.0}%",
                l.name,
                c.kp,
                c.cp,
                c.fp,
                c.frag.n,
                c.frag.u_on,
                c.frag.u_off,
                c.frag.off_chip_ratio() * 100.0
            );
        }
        out
    }
}
