//! The fleet deployment stages: `FleetPlanned` → `FleetExplored` →
//! `FleetScheduled`.
//!
//! Mirrors the single-device staged builder one-to-one —
//! [`Deployment::fleet`](super::Deployment::fleet) takes N models AND M
//! devices at once, then `explore` (the placement search of
//! [`crate::dse::fleet`], through the design cache), then `schedule` (the
//! placement-appropriate schedule per decision: one burst schedule for a
//! solo model, one per partition for a shard, a shared-port composition for
//! a co-located group), then the terminals `simulate` (per-device sims +
//! fleet rollup) / `report` (the placement table) / `serve` (every
//! per-device stack behind one [`Router`]).
//!
//! The degenerate shapes stay bit-identical to the narrower builders
//! (1×1 ≡ `on_device`, 1×M ≡ `on_devices`, N×1 ≡ `colocate` — enforced by
//! `tests/fleet_deploy.rs`), so `fleet` is a strict superset: the unit of
//! deployment stops being a device and becomes a cluster.

use crate::coordinator::{
    BatchPolicy, ChainedEngine, ModelEntry, ModelRegistry, Router, Server, ServerOptions,
    SimOnlyEngine,
};
use crate::device::Device;
use crate::dse::{fleet, Design, DseConfig, FleetObjective, FleetPlacement, FleetResult};
use crate::error::Error;
use crate::ir::Network;
use crate::schedule::{BurstSchedule, SharedDmaSchedule};
use crate::sim::{
    simulate, simulate_colocated, simulate_partitioned, ColocatedSimResult,
    PartitionedSimResult, SimConfig, SimResult,
};

use super::cache::{design_cache, DesignCache};
use super::stages::{Deployment, IntoDevice};

/// Flattened per-sample input length of a design's network.
fn input_len_of(design: &Design) -> usize {
    let (c, h, w) = design.network.input_shape;
    (c as usize) * (h as usize) * (w as usize)
}

/// Stage 1 (fleet) — N models resolved against an M-device pool, ready for
/// the placement search. Created by [`Deployment::fleet`]; the objective
/// defaults to [`FleetObjective::MaxAggregateThroughput`] and is swapped
/// with [`FleetPlanned::with_objective`].
#[derive(Debug, Clone)]
pub struct FleetPlanned {
    networks: Vec<Network>,
    devices: Vec<Device>,
    objective: FleetObjective,
}

impl FleetPlanned {
    /// Resolve the model list and device pool eagerly (the
    /// [`Deployment::fleet`] entry point). Model names must be unique — the
    /// router routes by name, so a duplicate is a typed
    /// [`Error::DuplicateModel`] here, not a surprise at `.serve`.
    pub(super) fn plan<D: IntoDevice + Clone>(
        models: Vec<Deployment>,
        devices: &[D],
    ) -> Result<FleetPlanned, Error> {
        if models.is_empty() {
            return Err(Error::Usage("fleet: the model list is empty".to_string()));
        }
        if devices.is_empty() {
            return Err(Error::Usage("fleet: the device pool is empty".to_string()));
        }
        let devices: Vec<Device> = devices
            .iter()
            .cloned()
            .map(IntoDevice::resolve)
            .collect::<Result<_, _>>()?;
        let networks: Vec<Network> = models
            .into_iter()
            .map(Deployment::into_network)
            .collect::<Result<_, _>>()?;
        for (i, net) in networks.iter().enumerate() {
            if networks[..i].iter().any(|n| n.name == net.name) {
                return Err(Error::DuplicateModel(net.name.clone()));
            }
        }
        Ok(FleetPlanned {
            networks,
            devices,
            objective: FleetObjective::MaxAggregateThroughput,
        })
    }

    /// Build a fleet plan directly from parts.
    pub fn from_parts(networks: Vec<Network>, devices: Vec<Device>) -> FleetPlanned {
        assert!(!networks.is_empty(), "a fleet needs at least one model");
        assert!(!devices.is_empty(), "a fleet needs at least one device");
        FleetPlanned { networks, devices, objective: FleetObjective::MaxAggregateThroughput }
    }

    /// Swap the placement objective (default
    /// [`FleetObjective::MaxAggregateThroughput`]).
    pub fn with_objective(mut self, objective: FleetObjective) -> FleetPlanned {
        self.objective = objective;
        self
    }

    pub fn networks(&self) -> &[Network] {
        &self.networks
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    pub fn objective(&self) -> FleetObjective {
        self.objective
    }

    fn infeasible(&self, cfg: &DseConfig) -> Error {
        let models: Vec<&str> = self.networks.iter().map(|n| n.name.as_str()).collect();
        let pool: Vec<&str> = self.devices.iter().map(|d| d.name).collect();
        Error::Infeasible {
            model: models.join("+"),
            device: pool.join("+"),
            vanilla: !cfg.allow_streaming,
        }
    }

    /// Run the placement search through the process-wide
    /// [design cache](design_cache).
    pub fn explore(self, cfg: &DseConfig) -> Result<FleetExplored, Error> {
        self.explore_in(design_cache(), cfg)
    }

    /// [`FleetPlanned::explore`] with [`DseConfig::default`].
    pub fn explore_default(self) -> Result<FleetExplored, Error> {
        self.explore(&DseConfig::default())
    }

    /// [`FleetPlanned::explore`] against a caller-owned cache.
    pub fn explore_in(self, cache: &DesignCache, cfg: &DseConfig) -> Result<FleetExplored, Error> {
        let (outcome, cached) =
            cache.explore_fleet(&self.networks, &self.devices, self.objective, cfg);
        match outcome {
            Some(outcome) => Ok(FleetExplored {
                names: self.networks.iter().map(|n| n.name.clone()).collect(),
                outcome,
                devices: self.devices,
                cfg: *cfg,
                cached,
            }),
            None => Err(self.infeasible(cfg)),
        }
    }

    /// Run the search bypassing the cache maps (benchmarks, isolation
    /// tests). Sub-evaluations still share a fresh private cache so the
    /// search's internal re-probes stay memoized.
    pub fn explore_uncached(self, cfg: &DseConfig) -> Result<FleetExplored, Error> {
        let scratch = DesignCache::new();
        match fleet::fleet_in(&scratch, &self.networks, &self.devices, self.objective, cfg) {
            Some(outcome) => Ok(FleetExplored {
                names: self.networks.iter().map(|n| n.name.clone()).collect(),
                outcome,
                devices: self.devices,
                cfg: *cfg,
                cached: false,
            }),
            None => Err(self.infeasible(cfg)),
        }
    }
}

/// Stage 2 (fleet) — a feasible placement of every model with its
/// solo/sharded/co-located design outcomes.
#[derive(Debug, Clone)]
pub struct FleetExplored {
    outcome: FleetResult,
    /// Model names by input index (placements refer to models by index; a
    /// shard's subnetwork names mangle the original, so the plan keeps it).
    names: Vec<String>,
    devices: Vec<Device>,
    cfg: DseConfig,
    cached: bool,
}

impl FleetExplored {
    pub fn result(&self) -> &FleetResult {
        &self.outcome
    }

    pub fn placements(&self) -> &[FleetPlacement] {
        &self.outcome.placements
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// Model names in input order (placements index into this).
    pub fn model_names(&self) -> &[String] {
        &self.names
    }

    pub fn config(&self) -> &DseConfig {
        &self.cfg
    }

    /// `true` when the whole placement came from the design cache (no
    /// search ran).
    pub fn was_cached(&self) -> bool {
        self.cached
    }

    /// Derive each placement's schedule for the batch size the DSE planned
    /// for.
    pub fn schedule(self) -> FleetScheduled {
        let batch = self.cfg.batch;
        self.schedule_for_batch(batch)
    }

    /// [`FleetExplored::schedule`] for an explicit serving batch size: one
    /// [`BurstSchedule`] for a solo placement, one per partition for a
    /// shard, a [`SharedDmaSchedule`] composition for a co-located group —
    /// exactly what the narrower builders derive for the same outcome.
    pub fn schedule_for_batch(self, batch: u64) -> FleetScheduled {
        let schedules = self
            .outcome
            .placements
            .iter()
            .map(|p| match p {
                FleetPlacement::Solo { device, result, .. } => PlacementSchedule::Solo(
                    BurstSchedule::from_design(&result.design, &self.devices[*device], batch),
                ),
                FleetPlacement::Sharded { result, .. } => PlacementSchedule::Sharded(
                    result
                        .parts
                        .iter()
                        .map(|p| BurstSchedule::from_design(&p.result.design, &p.device, batch))
                        .collect(),
                ),
                FleetPlacement::Colocated { device, result, .. } => {
                    let tenants: Vec<(&str, f64, &Design, &Device)> = result
                        .tenants
                        .iter()
                        .map(|t| (t.name.as_str(), t.share, &t.result.design, &t.view))
                        .collect();
                    PlacementSchedule::Colocated(SharedDmaSchedule::compose(
                        &tenants,
                        &self.devices[*device],
                        batch,
                    ))
                }
            })
            .collect();
        FleetScheduled {
            outcome: self.outcome,
            names: self.names,
            devices: self.devices,
            schedules,
            output_len: 10,
        }
    }
}

/// The placement-appropriate schedule of one [`FleetPlacement`].
#[derive(Debug, Clone)]
pub enum PlacementSchedule {
    /// One burst schedule (solo placement).
    Solo(BurstSchedule),
    /// One burst schedule per partition, in chain order (sharded placement).
    Sharded(Vec<BurstSchedule>),
    /// The shared-DMA-port composition of every tenant's burst schedule
    /// (co-located placement).
    Colocated(SharedDmaSchedule),
}

/// One placement's simulation outcome inside a [`FleetSimReport`].
#[derive(Debug, Clone)]
pub enum PlacementSim {
    Solo(SimResult),
    Sharded(PartitionedSimResult),
    Colocated(ColocatedSimResult),
}

impl PlacementSim {
    pub fn makespan_s(&self) -> f64 {
        match self {
            PlacementSim::Solo(r) => r.makespan_s,
            PlacementSim::Sharded(r) => r.makespan_s,
            PlacementSim::Colocated(r) => r.makespan_s,
        }
    }

    pub fn total_stall_s(&self) -> f64 {
        match self {
            PlacementSim::Solo(r) => r.total_stall_s,
            PlacementSim::Sharded(r) => r.total_stall_s,
            PlacementSim::Colocated(r) => r.total_stall_s,
        }
    }

    /// Semantic fragment-iteration event count of this placement's sim.
    pub fn events(&self) -> u64 {
        match self {
            PlacementSim::Solo(r) => r.events,
            PlacementSim::Sharded(r) => r.events(),
            PlacementSim::Colocated(r) => r.events,
        }
    }

    /// Events the engine actually stepped; below [`Self::events`] when the
    /// steady-state fast-forward extrapolated the periodic tail.
    pub fn events_processed(&self) -> u64 {
        match self {
            PlacementSim::Solo(r) => r.events_processed,
            PlacementSim::Sharded(r) => r.events_processed(),
            PlacementSim::Colocated(r) => r.events_processed,
        }
    }

    /// Whether a trace run hit `max_trace_events` and dropped later events.
    pub fn truncated(&self) -> bool {
        match self {
            PlacementSim::Solo(r) => r.truncated,
            PlacementSim::Sharded(r) => r.truncated(),
            PlacementSim::Colocated(r) => r.truncated,
        }
    }
}

/// Fleet-level simulation rollup: per-placement sims plus the figures a
/// cluster operator asks first.
#[derive(Debug, Clone)]
pub struct FleetSimReport {
    /// One simulation per placement, in placement order.
    pub per_placement: Vec<PlacementSim>,
    /// Fleet makespan: the slowest placement's makespan (placements run on
    /// disjoint devices, concurrently).
    pub makespan_s: f64,
    /// Total stall time summed over every placement.
    pub total_stall_s: f64,
}

/// Stage 3 (fleet) — placements + per-placement schedules: the terminal
/// fleet artifact. Simulate it, render the placement table, or serve the
/// whole fleet behind one [`Router`].
#[derive(Debug, Clone)]
pub struct FleetScheduled {
    outcome: FleetResult,
    names: Vec<String>,
    devices: Vec<Device>,
    schedules: Vec<PlacementSchedule>,
    output_len: usize,
}

impl FleetScheduled {
    pub fn result(&self) -> &FleetResult {
        &self.outcome
    }

    pub fn placements(&self) -> &[FleetPlacement] {
        &self.outcome.placements
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// Model names in input order (placements index into this).
    pub fn model_names(&self) -> &[String] {
        &self.names
    }

    /// One schedule per placement, in placement order.
    pub fn schedules(&self) -> &[PlacementSchedule] {
        &self.schedules
    }

    /// Output vector length of the served checksum engines (default 10).
    pub fn with_output_len(mut self, output_len: usize) -> FleetScheduled {
        self.output_len = output_len;
        self
    }

    /// Flattened per-sample input length of a model, by name.
    pub fn input_len(&self, model: &str) -> Option<usize> {
        let idx = self.names.iter().position(|n| n == model)?;
        let placement = self.outcome.placement_of(idx)?;
        match placement {
            FleetPlacement::Solo { result, .. } => Some(input_len_of(&result.design)),
            FleetPlacement::Sharded { result, .. } => {
                Some(input_len_of(&result.parts[0].result.design))
            }
            FleetPlacement::Colocated { models, result, .. } => {
                let t = models.iter().position(|&m| m == idx)?;
                Some(input_len_of(&result.tenants[t].result.design))
            }
        }
    }

    /// Validate every placement in its own simulator (single-device event
    /// sim, partitioned chain sim, co-located shared-port sim) and roll the
    /// fleet figures up. Placements are independent — each models its own
    /// device(s) — so the sims fan across cores via
    /// [`crate::dse::parallel_cases`], which returns results in input order:
    /// the rollup (and `per_placement` indexing) is bit-identical to the
    /// old sequential walk.
    pub fn simulate(&self, cfg: &SimConfig) -> FleetSimReport {
        let per_placement: Vec<PlacementSim> =
            crate::dse::parallel_cases(&self.outcome.placements, |_, p| match p {
                FleetPlacement::Solo { device, result, .. } => {
                    PlacementSim::Solo(simulate(&result.design, &self.devices[*device], cfg))
                }
                FleetPlacement::Sharded { result, .. } => {
                    let refs: Vec<(&Design, &Device)> =
                        result.parts.iter().map(|p| (&p.result.design, &p.device)).collect();
                    PlacementSim::Sharded(simulate_partitioned(&refs, cfg))
                }
                FleetPlacement::Colocated { device, result, .. } => {
                    let stages: Vec<(&str, &Design, &Device)> = result
                        .tenants
                        .iter()
                        .map(|t| (t.name.as_str(), &t.result.design, &t.view))
                        .collect();
                    PlacementSim::Colocated(simulate_colocated(
                        &stages,
                        &self.devices[*device],
                        cfg,
                    ))
                }
            });
        let makespan_s =
            per_placement.iter().map(PlacementSim::makespan_s).fold(0.0, f64::max);
        let total_stall_s = per_placement.iter().map(PlacementSim::total_stall_s).sum();
        FleetSimReport { per_placement, makespan_s, total_stall_s }
    }

    /// Names of the devices a placement occupies, in chain order.
    fn device_names(&self, p: &FleetPlacement) -> String {
        let names: Vec<&str> =
            p.device_indices().iter().map(|&d| self.devices[d].name).collect();
        names.join(", ")
    }

    /// Human-readable fleet report: the pool header, then the placement
    /// table — one line per model with its devices, mode, θ and memory
    /// utilization.
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let pool: Vec<&str> = self.devices.iter().map(|d| d.name).collect();
        let objective = match self.outcome.objective {
            FleetObjective::MaxAggregateThroughput => "max-aggregate-throughput".to_string(),
            FleetObjective::MinDevicesAtSlo { p99_ms } => {
                format!("min-devices-at-slo(p99<={p99_ms:.1} ms)")
            }
        };
        let _ = writeln!(
            out,
            "{} models fleet-placed over {} devices [{}] ({objective}): \
             aggregate θ={:.1} fps, devices used {}/{}",
            self.names.len(),
            self.devices.len(),
            pool.join(", "),
            self.outcome.aggregate_throughput,
            self.outcome.devices_used,
            self.devices.len()
        );
        for p in &self.outcome.placements {
            match p {
                FleetPlacement::Solo { model, device, result } => {
                    let dev = &self.devices[*device];
                    let _ = writeln!(
                        out,
                        "  {:<16} solo      on [{}]: θ={:.1} fps, latency={:.2} ms, \
                         mem {:.0}%",
                        self.names[*model],
                        dev.name,
                        result.throughput,
                        result.latency_ms,
                        result.area.mem_utilization(dev) * 100.0
                    );
                }
                FleetPlacement::Sharded { model, result, .. } => {
                    let _ = writeln!(
                        out,
                        "  {:<16} sharded   on [{}]: θ={:.1} fps, latency={:.2} ms, \
                         cuts={:?}",
                        self.names[*model],
                        self.device_names(p),
                        result.throughput,
                        result.latency_ms(),
                        result.cuts
                    );
                }
                FleetPlacement::Colocated { device, result, .. } => {
                    for t in &result.tenants {
                        let _ = writeln!(
                            out,
                            "  {:<16} colocated on [{}] (share {:.0}%): θ={:.1} fps \
                             ({:.0}% of solo), mem {:.0}%",
                            t.name,
                            self.devices[*device].name,
                            t.share * 100.0,
                            t.result.throughput,
                            t.norm_throughput() * 100.0,
                            t.result.area.mem_utilization(&t.view) * 100.0
                        );
                    }
                }
            }
        }
        out
    }

    /// Boot the whole fleet's serving side behind one [`Router`]: a
    /// [`Server`] per solo placement (its own engine), a [`Server`] over a
    /// [`ChainedEngine`] per sharded placement, a [`ModelRegistry`] per
    /// co-located group — every stack registered under its device label,
    /// routed by model name.
    pub fn serve(&self, policy: BatchPolicy, opts: ServerOptions) -> Result<Router, Error> {
        let mut router = Router::new();
        for p in &self.outcome.placements {
            match p {
                FleetPlacement::Solo { model, device, result } => {
                    let input_len = input_len_of(&result.design);
                    let engine = SimOnlyEngine {
                        design: result.design.clone(),
                        device: self.devices[*device].clone(),
                        input_len,
                        output_len: self.output_len,
                    };
                    let server = Server::start_with_opts(
                        move || Ok(Box::new(engine.clone()) as _),
                        policy,
                        opts,
                    )
                    .map_err(|e| Error::Serve(e.to_string()))?;
                    router.add_server(
                        self.devices[*device].name,
                        &self.names[*model],
                        input_len,
                        server,
                    );
                }
                FleetPlacement::Sharded { model, devices, result } => {
                    let stages: Vec<(Design, Device)> = result
                        .parts
                        .iter()
                        .map(|p| (p.result.design.clone(), p.device.clone()))
                        .collect();
                    let input_len = input_len_of(&result.parts[0].result.design);
                    let engine = ChainedEngine::new(stages, input_len, self.output_len);
                    let server = Server::start_with_opts(
                        move || Ok(Box::new(engine.clone()) as _),
                        policy,
                        opts,
                    )
                    .map_err(|e| Error::Serve(e.to_string()))?;
                    let label: Vec<&str> =
                        devices.iter().map(|&d| self.devices[d].name).collect();
                    router.add_server(label.join("+"), &self.names[*model], input_len, server);
                }
                FleetPlacement::Colocated { device, result, .. } => {
                    let mut registry = ModelRegistry::new();
                    for t in &result.tenants {
                        let input_len = input_len_of(&t.result.design);
                        let engine = SimOnlyEngine {
                            design: t.result.design.clone(),
                            device: t.view.clone(),
                            input_len,
                            output_len: self.output_len,
                        };
                        registry.register(
                            ModelEntry { name: t.name.clone(), input_len, policy, options: opts },
                            move || Ok(Box::new(engine.clone()) as _),
                        )?;
                    }
                    router.add_registry(self.devices[*device].name, registry);
                }
            }
        }
        Ok(router)
    }
}
