//! Content-keyed in-memory design cache.
//!
//! A DSE run is a pure function of `(network, device, DseConfig)`, so its
//! result can be memoized. The cache key is **content-derived**, not
//! identity-derived: the network is keyed by its 128-bit FNV-1a content
//! fingerprint ([`Network::fingerprint`] — name, input shape, quantization,
//! every layer with all operator parameters), the device by all of its
//! resource/clock/bandwidth fields (so `with_mem_scale` variants key
//! separately), and the config by every hyperparameter (`φ`, `µ`, batch,
//! streaming flag, bandwidth margin bits, warm start). Two lookups with
//! equal content hit the same entry no matter how the values were
//! constructed; any content difference — a scaled memory budget, a
//! different quantization, one changed layer — misses. The fingerprint
//! replaced the canonical `.net` serialization that early versions embedded
//! verbatim: a key is now O(1) in network size instead of re-formatting
//! every layer on every lookup, at a collision risk (~2⁻⁶⁴ birthday bound
//! at 128 bits) far below any other failure mode of the tool.
//!
//! Infeasible outcomes are cached too (`None`), so a sweep that probes the
//! same infeasible point twice pays for it once.
//!
//! Concurrency: the map is behind a `Mutex`, but the DSE itself runs
//! *outside* the lock so parallel sweeps ([`crate::dse::parallel_cases`])
//! never serialize on the cache. Two workers racing on the same fresh key
//! may both compute it — identical results, one insert wins — which is
//! benign and keeps the hot path contention-free.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::device::Device;
use crate::dse::{
    self, colocate, fleet, partition, ColocatedResult, DseConfig, DseResult, FleetObjective,
    FleetResult, PartitionedResult,
};
use crate::ir::Network;

/// Snapshot of the cache counters (the eval counters the cache-hit tests
/// assert on).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache (no DSE work performed).
    pub hits: u64,
    /// Lookups that ran the DSE.
    pub misses: u64,
    /// Distinct design points currently stored.
    pub entries: usize,
    /// Per-schema breakdown of `hits`/`misses` — single-device,
    /// partitioned (multi-device), co-located (multi-tenant) and fleet
    /// lookups counted separately (they always sum to the aggregates).
    pub single_hits: u64,
    pub single_misses: u64,
    pub partitioned_hits: u64,
    pub partitioned_misses: u64,
    pub colocated_hits: u64,
    pub colocated_misses: u64,
    pub fleet_hits: u64,
    pub fleet_misses: u64,
}

/// Memoization table for DSE outcomes, keyed by design-point content.
/// Single-device, partitioned (multi-device), co-located (multi-tenant) and
/// fleet (multi-model × multi-device) outcomes live in separate maps under
/// disjoint key schemas — a 1-partition deployment, a 1-tenant co-location,
/// a 1×1 fleet and the plain single-device deployment of the same content
/// never collide, and a cached infeasible on one layout cannot leak to
/// another. A fleet lookup's *sub-evaluations* (each candidate solo, shard
/// or co-location the placement search probes) land in the first three maps
/// under their own schemas, so fleets share design points with the plain
/// pipelines; the fourth map stores only whole placement outcomes.
#[derive(Debug, Default)]
pub struct DesignCache {
    map: Mutex<HashMap<String, Option<DseResult>>>,
    parts: Mutex<HashMap<String, Option<PartitionedResult>>>,
    colo: Mutex<HashMap<String, Option<ColocatedResult>>>,
    fleet: Mutex<HashMap<String, Option<FleetResult>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    // per-schema breakdowns (each lookup bumps its schema counter AND the
    // aggregate above, so the aggregates stay exact sums)
    single_hits: AtomicU64,
    single_misses: AtomicU64,
    partitioned_hits: AtomicU64,
    partitioned_misses: AtomicU64,
    colocated_hits: AtomicU64,
    colocated_misses: AtomicU64,
    fleet_hits: AtomicU64,
    fleet_misses: AtomicU64,
}

impl DesignCache {
    pub fn new() -> DesignCache {
        DesignCache::default()
    }

    /// Append every [`Device`] field that feeds the analytic models (and the
    /// link model) to a key.
    fn push_device(k: &mut String, device: &Device) {
        let _ = write!(
            k,
            "|dev={}:{}:{}:{}:{}:{}:{:x}:{:x}:{:x}:{}:{:x}:{:x}",
            device.name,
            device.bram36,
            device.uram,
            device.dsp,
            device.lut,
            device.ff,
            device.bandwidth_bps.to_bits(),
            device.clk_comp_mhz.to_bits(),
            device.clk_dma_mhz.to_bits(),
            device.dma_port_bits,
            device.link_bandwidth_bps.to_bits(),
            device.link_latency_s.to_bits(),
        );
    }

    /// Append every DSE hyperparameter (floats via bit pattern: exact).
    fn push_cfg(k: &mut String, cfg: &DseConfig) {
        let _ = write!(
            k,
            "|cfg=phi{}:mu{}:b{}:s{}:bw{:x}:w{}",
            cfg.phi,
            cfg.mu,
            cfg.batch,
            cfg.allow_streaming,
            cfg.bw_margin.to_bits(),
            cfg.warm_start,
        );
    }

    /// Append the network's 128-bit content fingerprint to a key. Covers
    /// name, input shape, quantization (global + per-layer overrides) and
    /// every layer with all operator parameters — without building the
    /// O(layers) canonical serialization string on every lookup.
    fn push_network(k: &mut String, network: &Network) {
        let _ = write!(k, "net#{:032x}", network.fingerprint());
    }

    /// The content key of a design point: the network's 128-bit fingerprint
    /// plus every device field and DSE hyperparameter verbatim.
    pub fn key(network: &Network, device: &Device, cfg: &DseConfig) -> String {
        let mut k = String::with_capacity(256);
        Self::push_network(&mut k, network);
        Self::push_device(&mut k, device);
        Self::push_cfg(&mut k, cfg);
        k
    }

    /// Content key of a partitioned design point: the network plus the
    /// **whole device list** (count and order matter — a chain of two
    /// `zcu102`s is a different design point from one, even though every
    /// device field matches) and, when the caller pins the cut vector, the
    /// cuts themselves. Single- and multi-device keys never collide: they
    /// live in separate maps with different schemas.
    pub fn multi_key(
        network: &Network,
        devices: &[Device],
        cuts: Option<&[usize]>,
        cfg: &DseConfig,
    ) -> String {
        let mut k = String::with_capacity(256);
        Self::push_network(&mut k, network);
        let _ = write!(k, "|ndev={}", devices.len());
        for device in devices {
            Self::push_device(&mut k, device);
        }
        match cuts {
            None => k.push_str("|cut=auto"),
            Some(cuts) => {
                k.push_str("|cut=");
                for c in cuts {
                    let _ = write!(k, "{c},");
                }
            }
        }
        Self::push_cfg(&mut k, cfg);
        k
    }

    /// Content key of a co-located (multi-tenant) design point: the **full
    /// tenant list** (count and order matter — serving resnet18 alongside
    /// squeezenet is a different joint plan from resnet18 alone, and from
    /// squeezenet-then-resnet18 whose seeded shares permute) plus the one
    /// shared device and the config. Co-located keys never collide with
    /// single-device or partitioned keys: they live in a third map with its
    /// own schema.
    pub fn colo_key(networks: &[Network], device: &Device, cfg: &DseConfig) -> String {
        let mut k = String::with_capacity(256);
        let _ = write!(k, "|nten={}", networks.len());
        for network in networks {
            k.push('|');
            Self::push_network(&mut k, network);
        }
        Self::push_device(&mut k, device);
        Self::push_cfg(&mut k, cfg);
        k
    }

    /// Content key of a fleet design point: the **full model list** and the
    /// **full device pool** (count and order matter on both sides — the pool
    /// order is the chain order shard candidates are drawn in) plus the
    /// placement objective and the config. The `|fleet|` prefix and the
    /// objective tag keep this schema disjoint from the other three: a 1×1
    /// fleet never answers (or is answered by) the single-device key of the
    /// same content.
    pub fn fleet_key(
        networks: &[Network],
        devices: &[Device],
        objective: FleetObjective,
        cfg: &DseConfig,
    ) -> String {
        let mut k = String::with_capacity(256);
        let _ = write!(k, "|fleet|nmod={}", networks.len());
        for network in networks {
            k.push('|');
            Self::push_network(&mut k, network);
        }
        let _ = write!(k, "|ndev={}", devices.len());
        for device in devices {
            Self::push_device(&mut k, device);
        }
        match objective {
            FleetObjective::MaxAggregateThroughput => k.push_str("|obj=agg"),
            FleetObjective::MinDevicesAtSlo { p99_ms } => {
                let _ = write!(k, "|obj=slo:{:x}", p99_ms.to_bits());
            }
        }
        Self::push_cfg(&mut k, cfg);
        k
    }

    /// Return the cached outcome for this design point, running the DSE on a
    /// miss. The boolean is `true` when the result came from the cache.
    pub fn explore(
        &self,
        network: &Network,
        device: &Device,
        cfg: &DseConfig,
    ) -> (Option<DseResult>, bool) {
        let key = Self::key(network, device, cfg);
        if let Some(found) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.single_hits.fetch_add(1, Ordering::Relaxed);
            return (found.clone(), true);
        }
        // run outside the lock: DSE work must not serialize parallel sweeps
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.single_misses.fetch_add(1, Ordering::Relaxed);
        let result = dse::run(network, device, cfg);
        self.map.lock().unwrap().entry(key).or_insert_with(|| result.clone());
        (result, false)
    }

    /// Return the cached partitioned outcome for this multi-device design
    /// point, running the cut search + per-partition DSE on a miss. The
    /// boolean is `true` when the result came from the cache.
    pub fn explore_partitioned(
        &self,
        network: &Network,
        devices: &[Device],
        cuts: Option<&[usize]>,
        cfg: &DseConfig,
    ) -> (Option<PartitionedResult>, bool) {
        let key = Self::multi_key(network, devices, cuts, cfg);
        if let Some(found) = self.parts.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.partitioned_hits.fetch_add(1, Ordering::Relaxed);
            return (found.clone(), true);
        }
        // run outside the lock, like the single-device path
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.partitioned_misses.fetch_add(1, Ordering::Relaxed);
        let result = match cuts {
            None => partition::partition(network, devices, cfg),
            Some(cuts) => partition::partition_with_cuts(network, devices, cuts, cfg),
        };
        self.parts.lock().unwrap().entry(key).or_insert_with(|| result.clone());
        (result, false)
    }

    /// Return the cached co-located outcome for this multi-tenant design
    /// point, running the joint budget search on a miss. The boolean is
    /// `true` when the result came from the cache.
    pub fn explore_colocated(
        &self,
        networks: &[Network],
        device: &Device,
        cfg: &DseConfig,
    ) -> (Option<ColocatedResult>, bool) {
        let key = Self::colo_key(networks, device, cfg);
        if let Some(found) = self.colo.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.colocated_hits.fetch_add(1, Ordering::Relaxed);
            return (found.clone(), true);
        }
        // run outside the lock, like the other two paths
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.colocated_misses.fetch_add(1, Ordering::Relaxed);
        let result = colocate::colocate(networks, device, cfg);
        self.colo.lock().unwrap().entry(key).or_insert_with(|| result.clone());
        (result, false)
    }

    /// Return the cached fleet outcome for this (model list, device pool,
    /// objective) point, running the placement search on a miss. The search's
    /// sub-evaluations go through `self` too (same instance — see
    /// [`crate::dse::fleet::fleet_in`]), so candidate solo/shard/co-location
    /// points are shared with the plain pipelines while the whole-fleet
    /// outcome memoizes here. The boolean is `true` when the result came
    /// from the cache.
    pub fn explore_fleet(
        &self,
        networks: &[Network],
        devices: &[Device],
        objective: FleetObjective,
        cfg: &DseConfig,
    ) -> (Option<FleetResult>, bool) {
        let key = Self::fleet_key(networks, devices, objective, cfg);
        if let Some(found) = self.fleet.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.fleet_hits.fetch_add(1, Ordering::Relaxed);
            return (found.clone(), true);
        }
        // run outside the lock, like the other three paths (the nested
        // sub-lookups take the other maps' locks, never this one)
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.fleet_misses.fetch_add(1, Ordering::Relaxed);
        let result = fleet::fleet_in(self, networks, devices, objective, cfg);
        self.fleet.lock().unwrap().entry(key).or_insert_with(|| result.clone());
        (result, false)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
            single_hits: self.single_hits.load(Ordering::Relaxed),
            single_misses: self.single_misses.load(Ordering::Relaxed),
            partitioned_hits: self.partitioned_hits.load(Ordering::Relaxed),
            partitioned_misses: self.partitioned_misses.load(Ordering::Relaxed),
            colocated_hits: self.colocated_hits.load(Ordering::Relaxed),
            colocated_misses: self.colocated_misses.load(Ordering::Relaxed),
            fleet_hits: self.fleet_hits.load(Ordering::Relaxed),
            fleet_misses: self.fleet_misses.load(Ordering::Relaxed),
        }
    }

    /// Drop every entry (counters are kept — they are cumulative).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
        self.parts.lock().unwrap().clear();
        self.colo.lock().unwrap().clear();
        self.fleet.lock().unwrap().clear();
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
            + self.parts.lock().unwrap().len()
            + self.colo.lock().unwrap().len()
            + self.fleet.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-wide design cache every [`super::Planned::explore`] and
/// pipeline sweep shares. Lives for the whole process: repeated serve runs,
/// sweeps revisiting a point, and reports regenerating the same design all
/// skip the redundant DSE.
pub fn design_cache() -> &'static DesignCache {
    static CACHE: OnceLock<DesignCache> = OnceLock::new();
    CACHE.get_or_init(DesignCache::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn key_separates_content() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let base = DesignCache::key(&net, &dev, &cfg);
        // same content -> same key
        assert_eq!(base, DesignCache::key(&net.clone(), &dev.clone(), &cfg));
        // any content difference -> different key
        assert_ne!(base, DesignCache::key(&models::toy_cnn(Quant::W4A4), &dev, &cfg));
        assert_ne!(base, DesignCache::key(&net, &dev.with_mem_scale(0.5), &cfg));
        assert_ne!(base, DesignCache::key(&net, &Device::u250(), &cfg));
        assert_ne!(base, DesignCache::key(&net, &dev, &cfg.with_phi(2)));
        assert_ne!(base, DesignCache::key(&net, &dev, &cfg.with_mu(256)));
        assert_ne!(base, DesignCache::key(&net, &dev, &cfg.with_batch(8)));
        assert_ne!(base, DesignCache::key(&net, &dev, &DseConfig::vanilla()));
        assert_ne!(base, DesignCache::key(&net, &dev, &DseConfig::warm()));
        assert_ne!(base, DesignCache::key(&net, &dev, &cfg.with_bw_margin(0.8)));
    }

    #[test]
    fn network_keys_are_constant_size_and_layer_sensitive() {
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        // the fingerprint keeps keys O(1) in network size: a 50-layer model's
        // key is no longer than the toy's
        let toy_key = DesignCache::key(&models::toy_cnn(Quant::W8A8), &dev, &cfg);
        let big_key = DesignCache::key(&models::resnet50(Quant::W8A8), &dev, &cfg);
        assert_eq!(toy_key.len(), big_key.len());
        assert!(toy_key.starts_with("net#"), "{toy_key}");
        // a single changed layer still misses
        let mut edited = models::resnet50(Quant::W8A8);
        edited.layers[10].quant = Quant::W4A4;
        assert_ne!(big_key, DesignCache::key(&edited, &dev, &cfg));
    }

    #[test]
    fn hit_returns_identical_result_without_rerun() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let cache = DesignCache::new();
        let (a, cached_a) = cache.explore(&net, &dev, &cfg);
        let (b, cached_b) = cache.explore(&net, &dev, &cfg);
        assert!(!cached_a && cached_b);
        let (a, b) = (a.unwrap(), b.unwrap());
        assert_eq!(a.design.cfgs, b.design.cfgs);
        assert_eq!(a.design.off_bits, b.design.off_bits);
        assert_eq!(a.throughput, b.throughput);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn per_schema_counters_partition_the_aggregates() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let cache = DesignCache::new();
        // one miss + one hit on two different schemas
        let _ = cache.explore(&net, &dev, &cfg);
        let _ = cache.explore(&net, &dev, &cfg);
        let _ = cache.explore_partitioned(&net, &[dev.clone(), dev.clone()], None, &cfg);
        let _ = cache.explore_partitioned(&net, &[dev.clone(), dev.clone()], None, &cfg);
        let s = cache.stats();
        assert_eq!((s.single_hits, s.single_misses), (1, 1));
        assert_eq!((s.partitioned_hits, s.partitioned_misses), (1, 1));
        assert_eq!((s.colocated_hits, s.colocated_misses), (0, 0));
        assert_eq!((s.fleet_hits, s.fleet_misses), (0, 0));
        // the per-schema breakdown always sums to the aggregates
        assert_eq!(
            s.hits,
            s.single_hits + s.partitioned_hits + s.colocated_hits + s.fleet_hits
        );
        assert_eq!(
            s.misses,
            s.single_misses + s.partitioned_misses + s.colocated_misses + s.fleet_misses
        );
    }

    #[test]
    fn multi_key_separates_device_count_and_cuts() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let one = DesignCache::multi_key(&net, std::slice::from_ref(&dev), None, &cfg);
        let two = DesignCache::multi_key(&net, &[dev.clone(), dev.clone()], None, &cfg);
        // same device fields, different count -> different design points
        assert_ne!(one, two);
        // the single-device key schema never collides with the 1-partition one
        assert_ne!(one, DesignCache::key(&net, &dev, &cfg));
        // an explicit cut is a different point from the searched cut
        let pinned = DesignCache::multi_key(&net, &[dev.clone(), dev.clone()], Some(&[2]), &cfg);
        assert_ne!(two, pinned);
        // link parameters are part of the content
        let mut fat = dev.clone();
        fat.link_bandwidth_bps *= 2.0;
        let fat_key = DesignCache::multi_key(&net, &[dev.clone(), fat], None, &cfg);
        assert_ne!(two, fat_key);
    }

    #[test]
    fn partitioned_outcomes_are_cached_per_layout() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let cache = DesignCache::new();
        let (a, ca) = cache.explore_partitioned(&net, &[dev.clone(), dev.clone()], None, &cfg);
        let (b, cb) = cache.explore_partitioned(&net, &[dev.clone(), dev.clone()], None, &cfg);
        assert!(!ca && cb, "second lookup of the same layout must hit");
        let (a, b) = (a.unwrap(), b.unwrap());
        assert_eq!(a.cuts, b.cuts);
        assert_eq!(a.throughput, b.throughput);
        // a different layout is a different entry, not a hit
        let (c, cc) = cache.explore_partitioned(&net, std::slice::from_ref(&dev), None, &cfg);
        assert!(!cc);
        assert_eq!(c.unwrap().parts.len(), 1);
        assert_eq!(cache.stats().entries, 2);
    }

    #[test]
    fn colo_key_separates_tenant_lists_and_never_collides_with_other_schemas() {
        let a = models::toy_cnn(Quant::W8A8);
        let b = models::squeezenet(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let one = DesignCache::colo_key(std::slice::from_ref(&a), &dev, &cfg);
        let two = DesignCache::colo_key(&[a.clone(), b.clone()], &dev, &cfg);
        // tenant count and order are content
        assert_ne!(one, two);
        assert_ne!(two, DesignCache::colo_key(&[b.clone(), a.clone()], &dev, &cfg));
        // a 1-tenant co-location never collides with the single-device key
        // or the 1-partition key of the same content
        assert_ne!(one, DesignCache::key(&a, &dev, &cfg));
        assert_ne!(one, DesignCache::multi_key(&a, std::slice::from_ref(&dev), None, &cfg));
        // device and config content still separate
        assert_ne!(two, DesignCache::colo_key(&[a.clone(), b.clone()], &dev.with_mem_scale(0.5), &cfg));
        assert_ne!(two, DesignCache::colo_key(&[a, b], &dev, &cfg.with_batch(8)));
    }

    #[test]
    fn colocated_outcomes_are_cached_per_tenant_list() {
        let nets = [models::toy_cnn(Quant::W8A8), models::squeezenet(Quant::W8A8)];
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let cache = DesignCache::new();
        let (a, ca) = cache.explore_colocated(&nets, &dev, &cfg);
        let (b, cb) = cache.explore_colocated(&nets, &dev, &cfg);
        assert!(!ca && cb, "second lookup of the same tenant list must hit");
        let (a, b) = (a.unwrap(), b.unwrap());
        assert_eq!(a.tenants.len(), b.tenants.len());
        for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(ta.share, tb.share);
            assert_eq!(ta.result.throughput, tb.result.throughput);
        }
        // dropping a tenant is a different entry, not a hit
        let (c, cc) = cache.explore_colocated(&nets[..1], &dev, &cfg);
        assert!(!cc);
        assert_eq!(c.unwrap().tenants.len(), 1);
        assert_eq!(cache.stats().entries, 2);
    }

    #[test]
    fn fleet_key_separates_content_and_never_collides_with_other_schemas() {
        let a = models::toy_cnn(Quant::W8A8);
        let b = models::squeezenet(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let agg = FleetObjective::MaxAggregateThroughput;
        let one = DesignCache::fleet_key(
            std::slice::from_ref(&a),
            std::slice::from_ref(&dev),
            agg,
            &cfg,
        );
        // the 1×1 fleet key never collides with the single-device key, the
        // 1-partition key or the 1-tenant colocated key of the same content
        assert_ne!(one, DesignCache::key(&a, &dev, &cfg));
        assert_ne!(one, DesignCache::multi_key(&a, std::slice::from_ref(&dev), None, &cfg));
        assert_ne!(one, DesignCache::colo_key(std::slice::from_ref(&a), &dev, &cfg));
        // model list, pool, objective and config are all content
        let two = DesignCache::fleet_key(&[a.clone(), b.clone()], &[dev.clone(), dev.clone()], agg, &cfg);
        assert_ne!(one, two);
        assert_ne!(
            two,
            DesignCache::fleet_key(&[b.clone(), a.clone()], &[dev.clone(), dev.clone()], agg, &cfg)
        );
        assert_ne!(
            two,
            DesignCache::fleet_key(&[a.clone(), b.clone()], std::slice::from_ref(&dev), agg, &cfg)
        );
        assert_ne!(
            two,
            DesignCache::fleet_key(
                &[a.clone(), b.clone()],
                &[dev.clone(), dev.clone()],
                FleetObjective::MinDevicesAtSlo { p99_ms: 50.0 },
                &cfg
            )
        );
        assert_ne!(
            DesignCache::fleet_key(
                &[a.clone(), b.clone()],
                &[dev.clone(), dev.clone()],
                FleetObjective::MinDevicesAtSlo { p99_ms: 50.0 },
                &cfg
            ),
            DesignCache::fleet_key(
                &[a.clone(), b.clone()],
                &[dev.clone(), dev.clone()],
                FleetObjective::MinDevicesAtSlo { p99_ms: 60.0 },
                &cfg
            )
        );
        assert_ne!(two, DesignCache::fleet_key(&[a, b], &[dev.clone(), dev], agg, &cfg.with_batch(8)));
    }

    #[test]
    fn fleet_outcomes_are_cached_and_subevals_share_the_other_maps() {
        let nets = [models::toy_cnn(Quant::W8A8), models::squeezenet(Quant::W8A8)];
        let devs = [Device::zcu102(), Device::zc706()];
        let cfg = DseConfig::default();
        let cache = DesignCache::new();
        let agg = FleetObjective::MaxAggregateThroughput;
        let (a, ca) = cache.explore_fleet(&nets, &devs, agg, &cfg);
        let (b, cb) = cache.explore_fleet(&nets, &devs, agg, &cfg);
        assert!(!ca && cb, "second lookup of the same fleet point must hit");
        let (a, b) = (a.unwrap(), b.unwrap());
        assert_eq!(a.placements.len(), b.placements.len());
        assert_eq!(a.aggregate_throughput, b.aggregate_throughput);
        // the placement search's solo-matrix probes landed in the
        // single-device map: re-probing one is a hit, not a miss
        let before = cache.stats();
        let (_, hit) = cache.explore(&nets[0], &devs[0], &cfg);
        assert!(hit, "fleet sub-evaluations must populate the single-device schema");
        assert_eq!(cache.stats().hits, before.hits + 1);
    }

    #[test]
    fn infeasible_outcomes_are_cached() {
        // resnet18 W4A5 does not fit zedboard without streaming
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zedboard();
        let cache = DesignCache::new();
        let (r1, c1) = cache.explore(&net, &dev, &DseConfig::vanilla());
        let (r2, c2) = cache.explore(&net, &dev, &DseConfig::vanilla());
        assert!(r1.is_none() && r2.is_none());
        assert!(!c1 && c2, "second probe of the infeasible point must hit");
    }
}
