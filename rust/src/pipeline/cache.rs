//! Content-keyed in-memory design cache.
//!
//! A DSE run is a pure function of `(network, device, DseConfig)`, so its
//! result can be memoized. The cache key is **content-derived**, not
//! identity-derived: the network is keyed by its canonical `.net`
//! serialization (name, input shape, quantization, every layer), the device
//! by all of its resource/clock/bandwidth fields (so `with_mem_scale`
//! variants key separately), and the config by every hyperparameter
//! (`φ`, `µ`, batch, streaming flag, bandwidth margin bits, warm start).
//! Two lookups with equal content hit the same entry no matter how the
//! values were constructed; any content difference — a scaled memory
//! budget, a different quantization, one changed layer — misses.
//!
//! Infeasible outcomes are cached too (`None`), so a sweep that probes the
//! same infeasible point twice pays for it once.
//!
//! Concurrency: the map is behind a `Mutex`, but the DSE itself runs
//! *outside* the lock so parallel sweeps ([`crate::dse::parallel_cases`])
//! never serialize on the cache. Two workers racing on the same fresh key
//! may both compute it — identical results, one insert wins — which is
//! benign and keeps the hot path contention-free.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::device::Device;
use crate::dse::{self, DseConfig, DseResult};
use crate::ir::Network;

/// Snapshot of the cache counters (the eval counters the cache-hit tests
/// assert on).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache (no DSE work performed).
    pub hits: u64,
    /// Lookups that ran the DSE.
    pub misses: u64,
    /// Distinct design points currently stored.
    pub entries: usize,
}

/// Memoization table for DSE outcomes, keyed by design-point content.
#[derive(Debug, Default)]
pub struct DesignCache {
    map: Mutex<HashMap<String, Option<DseResult>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl DesignCache {
    pub fn new() -> DesignCache {
        DesignCache::default()
    }

    /// The canonical content key of a design point. Stored verbatim (not
    /// hashed down to 64 bits) so equal keys are *guaranteed* equal content.
    pub fn key(network: &Network, device: &Device, cfg: &DseConfig) -> String {
        let mut k = String::with_capacity(1024);
        // network content: canonical .net serialization covers name, input
        // shape, quantization (global + per-layer overrides) and every layer
        k.push_str(&crate::ir::serialize_network(network));
        // device content: every field that feeds the analytic models
        let _ = write!(
            k,
            "|dev={}:{}:{}:{}:{}:{}:{:x}:{:x}:{:x}:{}",
            device.name,
            device.bram36,
            device.uram,
            device.dsp,
            device.lut,
            device.ff,
            device.bandwidth_bps.to_bits(),
            device.clk_comp_mhz.to_bits(),
            device.clk_dma_mhz.to_bits(),
            device.dma_port_bits,
        );
        // every DSE hyperparameter (float via bit pattern: exact)
        let _ = write!(
            k,
            "|cfg=phi{}:mu{}:b{}:s{}:bw{:x}:w{}",
            cfg.phi,
            cfg.mu,
            cfg.batch,
            cfg.allow_streaming,
            cfg.bw_margin.to_bits(),
            cfg.warm_start,
        );
        k
    }

    /// Return the cached outcome for this design point, running the DSE on a
    /// miss. The boolean is `true` when the result came from the cache.
    pub fn explore(
        &self,
        network: &Network,
        device: &Device,
        cfg: &DseConfig,
    ) -> (Option<DseResult>, bool) {
        let key = Self::key(network, device, cfg);
        if let Some(found) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (found.clone(), true);
        }
        // run outside the lock: DSE work must not serialize parallel sweeps
        self.misses.fetch_add(1, Ordering::Relaxed);
        let result = dse::run(network, device, cfg);
        self.map.lock().unwrap().entry(key).or_insert_with(|| result.clone());
        (result, false)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.lock().unwrap().len(),
        }
    }

    /// Drop every entry (counters are kept — they are cumulative).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-wide design cache every [`super::Planned::explore`] and
/// pipeline sweep shares. Lives for the whole process: repeated serve runs,
/// sweeps revisiting a point, and reports regenerating the same design all
/// skip the redundant DSE.
pub fn design_cache() -> &'static DesignCache {
    static CACHE: OnceLock<DesignCache> = OnceLock::new();
    CACHE.get_or_init(DesignCache::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn key_separates_content() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let base = DesignCache::key(&net, &dev, &cfg);
        // same content -> same key
        assert_eq!(base, DesignCache::key(&net.clone(), &dev.clone(), &cfg));
        // any content difference -> different key
        assert_ne!(base, DesignCache::key(&models::toy_cnn(Quant::W4A4), &dev, &cfg));
        assert_ne!(base, DesignCache::key(&net, &dev.with_mem_scale(0.5), &cfg));
        assert_ne!(base, DesignCache::key(&net, &Device::u250(), &cfg));
        assert_ne!(base, DesignCache::key(&net, &dev, &cfg.with_phi(2)));
        assert_ne!(base, DesignCache::key(&net, &dev, &cfg.with_mu(256)));
        assert_ne!(base, DesignCache::key(&net, &dev, &cfg.with_batch(8)));
        assert_ne!(base, DesignCache::key(&net, &dev, &DseConfig::vanilla()));
        assert_ne!(base, DesignCache::key(&net, &dev, &DseConfig::warm()));
        assert_ne!(base, DesignCache::key(&net, &dev, &cfg.with_bw_margin(0.8)));
    }

    #[test]
    fn hit_returns_identical_result_without_rerun() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let cache = DesignCache::new();
        let (a, cached_a) = cache.explore(&net, &dev, &cfg);
        let (b, cached_b) = cache.explore(&net, &dev, &cfg);
        assert!(!cached_a && cached_b);
        let (a, b) = (a.unwrap(), b.unwrap());
        assert_eq!(a.design.cfgs, b.design.cfgs);
        assert_eq!(a.design.off_bits, b.design.off_bits);
        assert_eq!(a.throughput, b.throughput);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn infeasible_outcomes_are_cached() {
        // resnet18 W4A5 does not fit zedboard without streaming
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zedboard();
        let cache = DesignCache::new();
        let (r1, c1) = cache.explore(&net, &dev, &DseConfig::vanilla());
        let (r2, c2) = cache.explore(&net, &dev, &DseConfig::vanilla());
        assert!(r1.is_none() && r2.is_none());
        assert!(!c1 && c2, "second probe of the infeasible point must hit");
    }
}
