//! Weight pruning and encoding co-design — the paper's stated future work
//! (§VI: "explore software-hardware co-design, such as weight encoding and
//! pruning methods, to further enhance performance").
//!
//! The memory-management bottleneck AutoWS attacks is *weight bits*: fewer
//! stored/streamed bits mean fewer BRAMs for the static regions and less
//! bandwidth for the dynamic ones. This module models magnitude pruning plus
//! a stream-decodable encoding of the pruned weights and feeds the result
//! back through the unchanged DSE:
//!
//! 1. [`bits_per_weight`] — analytic storage cost of one weight under an
//!    [`Encoding`] at a given sparsity.
//! 2. [`compress_network`] — rewrite each layer's effective weight bitwidth
//!    (`quant.w_bits`, rounded *up*) so every downstream model — Eq. 1
//!    geometry, area, Eq. 5 bandwidth, the burst schedule — observes the
//!    compressed footprint with zero special-casing.
//! 3. [`CompressionReport`] — per-layer ratios, decoder area overhead, and a
//!    *synthetic* accuracy-degradation proxy for sweep-style studies (we
//!    have no trained weights; the proxy is a documented stand-in that makes
//!    the co-design trade-off curve well-defined, see DESIGN.md
//!    §Substitutions).

use crate::ir::Network;

/// Stream-decodable weight encodings.
///
/// All three are decodable at one weight per cycle with a small LUT decoder
/// between the weights memory and the PE array, which is what keeps the CE
/// timing model unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    /// No encoding: `L_W` bits per weight regardless of sparsity.
    Dense,
    /// Nonzero bitmap + packed nonzero values: `1 + (1−s)·L_W` bits/weight.
    Bitmap,
    /// Zero-run-length coding: each nonzero stores its value plus the length
    /// of the preceding zero run.
    Rle,
    /// Entropy-coded nonzeros over the bitmap: models a canonical Huffman
    /// code over the quantized value distribution (≈1.5 bits below raw for
    /// typical bell-shaped weight histograms, floored at 2 bits).
    Entropy,
}

impl std::fmt::Display for Encoding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Encoding::Dense => "dense",
            Encoding::Bitmap => "bitmap",
            Encoding::Rle => "rle",
            Encoding::Entropy => "entropy",
        };
        write!(f, "{s}")
    }
}

/// Expected storage bits per weight for bitwidth `l_w` at Bernoulli
/// sparsity `s` (fraction of zero weights) under `enc`.
pub fn bits_per_weight(l_w: u32, s: f64, enc: Encoding) -> f64 {
    let s = s.clamp(0.0, 0.999);
    let nz = 1.0 - s;
    match enc {
        Encoding::Dense => l_w as f64,
        Encoding::Bitmap => 1.0 + nz * l_w as f64,
        Encoding::Rle => {
            // Each nonzero carries its value plus a run-length field sized
            // for the expected zero-run (geometric with mean s/(1−s)),
            // plus 2 bits of field framing.
            let mean_run = s / nz;
            let run_bits = (mean_run + 1.0).log2().ceil().max(1.0) + 2.0;
            nz * (l_w as f64 + run_bits)
        }
        Encoding::Entropy => {
            // bitmap + entropy-coded nonzeros
            let coded = ((l_w as f64) - 1.5).max(2.0);
            1.0 + nz * coded
        }
    }
}

/// Pick the cheapest encoding at this bitwidth/sparsity point.
pub fn best_encoding(l_w: u32, s: f64) -> Encoding {
    [Encoding::Dense, Encoding::Bitmap, Encoding::Rle, Encoding::Entropy]
        .into_iter()
        .min_by(|a, b| {
            bits_per_weight(l_w, s, *a)
                .partial_cmp(&bits_per_weight(l_w, s, *b))
                .unwrap()
        })
        .unwrap()
}

/// Decoder LUT cost per CE: field extraction, run-length counter, and (for
/// entropy codes) a canonical-Huffman table walker, all scaled by the
/// memory word parallelism (one decoder lane per packed weight).
pub fn decoder_luts(enc: Encoding, lanes: u32) -> u32 {
    let per_lane = match enc {
        Encoding::Dense => 0,
        Encoding::Bitmap => 24,
        Encoding::Rle => 56,
        Encoding::Entropy => 120,
    };
    per_lane * lanes.max(1)
}

/// Compression configuration: a uniform target sparsity and an encoding
/// policy (fixed or best-per-layer).
#[derive(Debug, Clone, Copy)]
pub struct CompressionSpec {
    /// Target fraction of zero weights after magnitude pruning.
    pub sparsity: f64,
    /// `None` = choose [`best_encoding`] per layer.
    pub encoding: Option<Encoding>,
}

impl CompressionSpec {
    pub fn pruned(sparsity: f64) -> CompressionSpec {
        CompressionSpec { sparsity, encoding: None }
    }
}

/// Per-layer outcome of the compression pass.
#[derive(Debug, Clone)]
pub struct LayerCompression {
    pub layer: usize,
    pub encoding: Encoding,
    /// Effective bits/weight actually realized after integer rounding.
    pub eff_bits: u32,
    /// Analytic (un-rounded) bits/weight.
    pub ideal_bits: f64,
    pub decoder_luts: u32,
}

/// Whole-network compression report.
#[derive(Debug, Clone)]
pub struct CompressionReport {
    pub layers: Vec<LayerCompression>,
    pub weight_bits_before: u64,
    pub weight_bits_after: u64,
    pub decoder_luts: u32,
    /// Synthetic top-1 accuracy degradation proxy in percentage points —
    /// quadratic in sparsity, weighted by each layer's parameter share
    /// (layers holding more parameters tolerate pruning better, the standard
    /// magnitude-pruning observation). NOT a measurement; see module docs.
    pub accuracy_drop_proxy: f64,
}

impl CompressionReport {
    /// Overall compression ratio (≤ 1.0).
    pub fn ratio(&self) -> f64 {
        self.weight_bits_after as f64 / self.weight_bits_before.max(1) as f64
    }
}

/// Apply `spec` to a network: returns the rewritten network (effective
/// `w_bits` per layer, rounded up) plus the report.
///
/// Rounding up makes every downstream estimate conservative: the real
/// encoded stream would be marginally smaller than what the DSE plans for.
pub fn compress_network(net: &Network, spec: &CompressionSpec) -> (Network, CompressionReport) {
    assert!((0.0..1.0).contains(&spec.sparsity), "sparsity {} out of [0,1)", spec.sparsity);
    let mut out = net.clone();
    out.name = format!("{}-p{:02.0}", net.name, spec.sparsity * 100.0);
    let mut layers = Vec::new();
    let (mut before, mut after) = (0u64, 0u64);
    let mut total_decoder = 0u32;
    let total_params: u64 = net.layers.iter().map(|l| l.weight_count()).sum();
    let mut drop = 0.0;

    for (i, l) in net.layers.iter().enumerate() {
        if !l.has_weights() {
            continue;
        }
        let l_w = l.quant.w_bits;
        let enc = spec.encoding.unwrap_or_else(|| best_encoding(l_w, spec.sparsity));
        let ideal = bits_per_weight(l_w, spec.sparsity, enc);
        // never exceed the uncompressed bitwidth
        let eff = (ideal.ceil() as u32).clamp(1, l_w);
        out.layers[i].quant.w_bits = eff;
        let dec = decoder_luts(enc, 1);
        total_decoder += dec;
        before += l.weight_count() * l_w as u64;
        after += l.weight_count() * eff as u64;
        layers.push(LayerCompression {
            layer: i,
            encoding: enc,
            eff_bits: eff,
            ideal_bits: ideal,
            decoder_luts: dec,
        });
        // parameter-share-weighted quadratic proxy: smaller layers are more
        // sensitive (depthwise/first layers), so weight by 1/share.
        let share = l.weight_count() as f64 / total_params.max(1) as f64;
        let sensitivity = (1.0 - share).max(0.1);
        drop += 12.0 * spec.sparsity * spec.sparsity * sensitivity * share;
    }

    (
        out,
        CompressionReport {
            layers,
            weight_bits_before: before,
            weight_bits_after: after,
            decoder_luts: total_decoder,
            accuracy_drop_proxy: drop,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn dense_is_flat_in_sparsity() {
        assert_eq!(bits_per_weight(8, 0.0, Encoding::Dense), 8.0);
        assert_eq!(bits_per_weight(8, 0.9, Encoding::Dense), 8.0);
    }

    #[test]
    fn bitmap_crossover() {
        // at s=0: bitmap costs 1 extra bit; at high s it wins
        assert!(bits_per_weight(8, 0.0, Encoding::Bitmap) > 8.0);
        assert!(bits_per_weight(8, 0.8, Encoding::Bitmap) < 3.0);
    }

    #[test]
    fn monotone_decreasing_in_sparsity() {
        for enc in [Encoding::Bitmap, Encoding::Rle, Encoding::Entropy] {
            let mut last = f64::INFINITY;
            for step in 0..9 {
                let s = step as f64 / 10.0;
                let b = bits_per_weight(4, s, enc);
                assert!(b <= last + 1e-9, "{enc}: {b} at s={s} after {last}");
                last = b;
            }
        }
    }

    #[test]
    fn best_encoding_matches_cost_structure() {
        // Very narrow weights leave nothing for entropy coding to save:
        // dense wins at zero sparsity.
        assert_eq!(best_encoding(2, 0.0), Encoding::Dense);
        // Wide weights benefit from entropy coding even when dense.
        assert_eq!(best_encoding(8, 0.0), Encoding::Entropy);
        // High sparsity always beats dense.
        assert_ne!(best_encoding(8, 0.8), Encoding::Dense);
        assert_ne!(best_encoding(2, 0.8), Encoding::Dense);
    }

    #[test]
    fn compress_shrinks_weight_bits() {
        let net = models::resnet18(Quant::W8A8);
        let (cnet, rep) = compress_network(&net, &CompressionSpec::pruned(0.6));
        assert!(rep.ratio() < 0.75, "ratio {}", rep.ratio());
        assert!(rep.weight_bits_after < rep.weight_bits_before);
        assert_eq!(cnet.stats().params, net.stats().params, "pruning keeps geometry");
        assert!(cnet.stats().weight_bits < net.stats().weight_bits);
    }

    #[test]
    fn zero_sparsity_with_dense_is_identity() {
        let net = models::toy_cnn(Quant::W8A8);
        let spec = CompressionSpec { sparsity: 0.0, encoding: Some(Encoding::Dense) };
        let (cnet, rep) = compress_network(&net, &spec);
        assert_eq!(rep.ratio(), 1.0);
        assert_eq!(cnet.stats().weight_bits, net.stats().weight_bits);
        assert_eq!(rep.decoder_luts, 0);
    }

    #[test]
    fn effective_bits_never_exceed_original() {
        let net = models::mobilenet_v2(Quant::W4A4);
        for s in [0.0, 0.1, 0.3, 0.5, 0.7, 0.9] {
            let (_, rep) = compress_network(&net, &CompressionSpec::pruned(s));
            for lc in &rep.layers {
                assert!(lc.eff_bits <= 4, "s={s}: {lc:?}");
                assert!(lc.eff_bits >= 1);
            }
        }
    }

    #[test]
    fn accuracy_proxy_grows_with_sparsity() {
        let net = models::resnet18(Quant::W8A8);
        let mut last = -1.0;
        for s in [0.0, 0.3, 0.6, 0.9] {
            let (_, rep) = compress_network(&net, &CompressionSpec::pruned(s));
            assert!(rep.accuracy_drop_proxy >= last);
            last = rep.accuracy_drop_proxy;
        }
        assert!(last < 15.0, "proxy stays in plausible range: {last}");
    }

    #[test]
    fn compression_unlocks_smaller_devices() {
        // ResNet18 W8A8 does not fit a ZC706 vanilla; at 70% sparsity the
        // compressed model should need substantially less on-chip memory.
        let net = models::resnet18(Quant::W8A8);
        let dev = Device::zc706();
        let base = dse::run(&net, &dev, &DseConfig::default()).map(|r| r.throughput);
        let (cnet, _) = compress_network(&net, &CompressionSpec::pruned(0.7));
        let comp = dse::run(&cnet, &dev, &DseConfig::default()).map(|r| r.throughput);
        let c = comp.expect("compressed model must be feasible");
        if let Some(b) = base {
            assert!(c >= b * 0.95, "compression must not hurt: {c} vs {b}");
        }
    }

    #[test]
    fn decoder_cost_scales_with_encoding_complexity() {
        assert_eq!(decoder_luts(Encoding::Dense, 4), 0);
        assert!(decoder_luts(Encoding::Entropy, 4) > decoder_luts(Encoding::Rle, 4));
        assert!(decoder_luts(Encoding::Rle, 4) > decoder_luts(Encoding::Bitmap, 4));
    }
}
