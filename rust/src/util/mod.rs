//! Small self-contained utilities (this build is fully offline: no `rand`,
//! no external helpers).

mod rng;

pub use rng::XorShift64;
