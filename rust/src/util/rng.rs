//! Deterministic xorshift64* PRNG.
//!
//! Used by the stochastic DSE strategies (random search, simulated
//! annealing) and the property-test drivers. Seeded explicitly everywhere so
//! every run — and every CI failure — is reproducible.

/// xorshift64* (Vigna 2016): 64 bits of state, period 2^64 − 1, passes
/// BigCrush when the high bits are used. Plenty for design-space sampling.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create from a seed (0 is remapped — xorshift state must be nonzero).
    pub fn new(seed: u64) -> XorShift64 {
        XorShift64 { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`. Uses the high-bits multiply trick to avoid
    /// modulo bias for the small `n` used here.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        ((self.next_u64() >> 11) as u128 * n as u128 >> 53) as usize
    }

    /// Uniform float in `[0, 1)`.
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = XorShift64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn unit_in_range_with_sane_mean() {
        let mut r = XorShift64::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.unit();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((0.45..0.55).contains(&mean), "mean {mean}");
    }
}
