//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the DNN's *numerics* run — everything else in the
//! crate reasons about the accelerator's *timing*. Python is involved only
//! at artifact-build time (`make artifacts`); the request path is pure Rust.
//!
//! Interchange format is HLO **text**, not a serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! bundled XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids
//! and round-trips cleanly (see /opt/xla-example/README.md).
//!
//! The real client requires the `xla` bindings, which are not vendored in
//! this offline build; it is gated behind the `pjrt` cargo feature. Without
//! the feature the same API is served by a stub whose `load_hlo_text` fails
//! with an actionable message — the serving stack, tests and benches all
//! skip gracefully when artifacts (or PJRT itself) are unavailable.

use std::path::Path;

use anyhow::{anyhow, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

/// A PJRT client; loads executables.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
}

/// One compiled model variant, ready to execute.
pub struct LoadedModel {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Human-readable source path, for diagnostics.
    pub source: String,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))
            .context("is `make artifacts` up to date?")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(LoadedModel { exe, source: path.display().to_string() })
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Stub client: creation always succeeds so callers can construct the
    /// serving stack; loading an artifact is where the missing backend (or a
    /// missing artifact) is reported.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {})
    }

    pub fn platform(&self) -> String {
        "stub (built without the `pjrt` feature)".to_string()
    }

    /// Fails with an actionable message: a missing artifact is reported the
    /// same way the real client reports it; an existing artifact cannot be
    /// executed without the `pjrt` feature.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(anyhow!("parse {}: no such artifact", path.display())
                .context("is `make artifacts` up to date?"));
        }
        Err(anyhow!(
            "cannot execute {}: built without the `pjrt` feature (artifacts load only \
             with the xla bindings available)",
            path.display()
        ))
    }
}

/// A dense f32 tensor crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> Result<Tensor> {
        let expect: i64 = dims.iter().product();
        if expect as usize != data.len() {
            return Err(anyhow!("tensor data {} != dims {:?}", data.len(), dims));
        }
        Ok(Tensor { data, dims })
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        xla::Literal::vec1(&self.data)
            .reshape(&self.dims)
            .map_err(|e| anyhow!("reshape {:?}: {e:?}", self.dims))
    }
}

#[cfg(feature = "pjrt")]
impl LoadedModel {
    /// Execute with f32 inputs; returns all outputs (the artifacts are
    /// lowered with `return_tuple=True`, so the single device-result is a
    /// tuple literal we decompose).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.source))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                Tensor::new(data, dims)
            })
            .collect()
    }
}

#[cfg(not(feature = "pjrt"))]
impl LoadedModel {
    /// Unreachable in practice: the stub `Runtime` never hands out a
    /// `LoadedModel`. Kept so downstream engine code typechecks identically
    /// with and without the feature.
    pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Err(anyhow!("cannot execute {}: built without the `pjrt` feature", self.source))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::new(vec![1.0; 6], vec![2, 3]).is_ok());
        assert!(Tensor::new(vec![1.0; 5], vec![2, 3]).is_err());
    }

    #[test]
    fn stub_or_real_client_reports_missing_artifacts() {
        // With the `pjrt` feature this exercises the real client's error
        // path; without it, the stub's. Either way the message must point at
        // `make artifacts` (asserted again in tests/runtime_roundtrip.rs).
        let Ok(rt) = Runtime::cpu() else { return };
        let err = rt.load_hlo_text("/nonexistent/foo.hlo.txt").unwrap_err();
        assert!(format!("{err:#}").contains("artifacts"), "{err:#}");
    }

    // PJRT round-trip tests live in rust/tests/ — they require the
    // artifacts built by `make artifacts`.
}
