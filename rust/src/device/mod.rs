//! FPGA device library.
//!
//! Resource figures come from public AMD/Xilinx datasheets; the off-chip
//! bandwidths are the effective (not theoretical-peak) figures commonly used
//! in the accelerator literature. On-chip memory capacity counts BRAM plus
//! the distributed-LUTRAM allowance the paper's toolflow (fpgaConvNet) also
//! draws on, which is why the ZCU102 capacity normalizes Table III's 5.1 MB
//! at 99% utilization.

/// A target FPGA platform: the constraint vector `(A, B)` of paper Eq. 6
/// split by resource class.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub name: &'static str,
    /// Number of BRAM36 blocks (36 Kib each).
    pub bram36: u32,
    /// Number of URAM blocks (288 Kib each); 0 on devices without URAM.
    pub uram: u32,
    /// DSP48 slices.
    pub dsp: u32,
    /// Logic LUTs.
    pub lut: u32,
    /// Flip-flops.
    pub ff: u32,
    /// Effective off-chip bandwidth `B`, bits/second.
    pub bandwidth_bps: f64,
    /// Peak fabric clock for the compute domain, MHz (`clk_comp`).
    pub clk_comp_mhz: f64,
    /// DMA/memory-controller clock domain, MHz (`clk_dma`).
    pub clk_dma_mhz: f64,
    /// Width of the DMA/AXI data bus feeding the weight buffers, bits.
    /// The shared buffer's write port runs at this width in the `clk_dma`
    /// domain regardless of the (often much narrower) read-side `M_wid`.
    pub dma_port_bits: u64,
    /// Effective bandwidth of the device's inter-device streaming link
    /// (serial transceivers / network ports used to chain partitions of a
    /// sharded deployment), bits/second. The link between two devices runs
    /// at the slower endpoint's rate.
    pub link_bandwidth_bps: f64,
    /// One-way latency of the inter-device link (serialization + transport),
    /// seconds.
    pub link_latency_s: f64,
}

/// Capacity of one BRAM36 block in bits.
pub const BRAM36_BITS: u64 = 36 * 1024;
/// Maximum data width of one BRAM36 in simple dual-port mode.
pub const BRAM36_WIDTH: u64 = 72;
/// Maximum depth of one BRAM36 at max width.
pub const BRAM36_DEPTH: u64 = 512;
/// Capacity of one URAM block in bits.
pub const URAM_BITS: u64 = 288 * 1024;

impl Device {
    /// Total on-chip memory capacity in bits (BRAM + URAM).
    pub fn mem_bits(&self) -> u64 {
        self.bram36 as u64 * BRAM36_BITS + self.uram as u64 * URAM_BITS
    }

    /// Total on-chip memory capacity in megabytes (for Table III-style
    /// reporting: block count x max capacity per block).
    pub fn mem_mbytes(&self) -> f64 {
        self.mem_bits() as f64 / 8.0 / 1e6
    }

    /// On-chip memory measured in BRAM36-equivalents (URAM = 8 BRAM36).
    pub fn mem_bram_equiv(&self) -> u32 {
        self.bram36 + self.uram * 8
    }

    /// Off-chip bandwidth in Gbit/s.
    pub fn bandwidth_gbps(&self) -> f64 {
        self.bandwidth_bps / 1e9
    }

    /// Inter-device link bandwidth in Gbit/s.
    pub fn link_gbps(&self) -> f64 {
        self.link_bandwidth_bps / 1e9
    }

    /// Zynq-7020 (Zedboard): small embedded device, single DDR3 channel
    /// shared with the PS.
    pub fn zedboard() -> Device {
        Device {
            name: "zedboard",
            bram36: 280,
            uram: 0,
            dsp: 220,
            lut: 53_200,
            ff: 106_400,
            bandwidth_bps: 12.8e9, // 1.6 GB/s effective of DDR3-1066 x32
            clk_comp_mhz: 150.0,
            clk_dma_mhz: 200.0,
            dma_port_bits: 128,
            link_bandwidth_bps: 8e9, // 1 GbE x8 aggregation via PS
            link_latency_s: 2e-6,
        }
    }

    /// Zynq-7045 (ZC706).
    pub fn zc706() -> Device {
        Device {
            name: "zc706",
            bram36: 545,
            uram: 0,
            dsp: 900,
            lut: 218_600,
            ff: 437_200,
            bandwidth_bps: 60e9, // ~7.5 GB/s effective of DDR3-1866 x64
            clk_comp_mhz: 200.0,
            clk_dma_mhz: 250.0,
            dma_port_bits: 256,
            link_bandwidth_bps: 40e9, // 4x GTX lanes (Aurora)
            link_latency_s: 1.5e-6,
        }
    }

    /// Zynq UltraScale+ ZU9EG (ZCU102). Memory capacity includes the
    /// LUTRAM-as-memory allowance (~1 MB) on top of 912 BRAM36, matching
    /// the paper's Table III normalization (5.1 MB == 99%).
    pub fn zcu102() -> Device {
        Device {
            name: "zcu102",
            bram36: 912 + 240, // 240 BRAM36-equivalents of distributed LUTRAM
            uram: 0,
            dsp: 2520,
            lut: 274_080,
            ff: 548_160,
            bandwidth_bps: 136.5e9, // ~17 GB/s effective of DDR4-2400 x64
            clk_comp_mhz: 250.0,
            clk_dma_mhz: 300.0,
            dma_port_bits: 512,
            link_bandwidth_bps: 80e9, // 4x SFP+ cages over GTH (Aurora)
            link_latency_s: 1e-6,
        }
    }

    /// Alveo U50: HBM2 device.
    pub fn u50() -> Device {
        Device {
            name: "u50",
            bram36: 1344,
            uram: 640,
            dsp: 5952,
            lut: 872_000,
            ff: 1_743_000,
            bandwidth_bps: 1_600e9, // 200 GB/s effective HBM2
            clk_comp_mhz: 300.0,
            clk_dma_mhz: 450.0,
            dma_port_bits: 4096,
            link_bandwidth_bps: 100e9, // 1x QSFP28 (100 GbE)
            link_latency_s: 0.8e-6,
        }
    }

    /// Alveo U250: large DDR4 device.
    pub fn u250() -> Device {
        Device {
            name: "u250",
            bram36: 2688,
            uram: 1280,
            dsp: 12288,
            lut: 1_728_000,
            ff: 3_456_000,
            bandwidth_bps: 512e9, // 64 GB/s effective of 4x DDR4-2400
            clk_comp_mhz: 300.0,
            clk_dma_mhz: 450.0,
            dma_port_bits: 2048,
            link_bandwidth_bps: 200e9, // 2x QSFP28 (100 GbE each)
            link_latency_s: 0.8e-6,
        }
    }

    /// Look up a device by name (case-insensitive).
    pub fn by_name(name: &str) -> Option<Device> {
        match name.to_ascii_lowercase().as_str() {
            "zedboard" => Some(Device::zedboard()),
            "zc706" => Some(Device::zc706()),
            "zcu102" => Some(Device::zcu102()),
            "u50" => Some(Device::u50()),
            "u250" => Some(Device::u250()),
            _ => None,
        }
    }

    /// The library's board names, small to large — the `known` list a typed
    /// [`crate::Error::UnknownDevice`] reports on a lookup miss.
    pub fn known_names() -> Vec<String> {
        Device::all().iter().map(|d| d.name.to_string()).collect()
    }

    /// All devices used in the paper's evaluation, small to large.
    pub fn all() -> Vec<Device> {
        vec![
            Device::zedboard(),
            Device::zc706(),
            Device::zcu102(),
            Device::u50(),
            Device::u250(),
        ]
    }

    /// Scale the on-chip memory budget by `factor` while keeping compute and
    /// bandwidth fixed — the Fig. 6 `A_mem` sweep axis.
    pub fn with_mem_scale(&self, factor: f64) -> Device {
        let mut d = self.clone();
        d.bram36 = (d.bram36 as f64 * factor).round() as u32;
        d.uram = (d.uram as f64 * factor).round() as u32;
        d
    }

    /// A budget-clamped view of this device holding `share` of every
    /// partitionable resource — the per-tenant planning target of a
    /// co-located deployment ([`crate::dse::colocate`]).
    ///
    /// DSP/LUT/FF/BRAM/URAM are **floored** (never rounded up), so any set of
    /// views whose shares sum to ≤ 1 is guaranteed to sum within the physical
    /// device; off-chip bandwidth scales continuously, which carves the
    /// single DMA port into per-tenant slices the burst schedule (Eq. 8–10)
    /// can be derived against per tenant. Clocks, the DMA bus width and the
    /// inter-device link are physical per-port properties and stay unscaled.
    /// `share >= 1` returns the device unchanged (bit-identical single-tenant
    /// golden path).
    pub fn with_share(&self, share: f64) -> Device {
        if share >= 1.0 {
            return self.clone();
        }
        let share = share.max(0.0);
        let mut d = self.clone();
        d.bram36 = (d.bram36 as f64 * share).floor() as u32;
        d.uram = (d.uram as f64 * share).floor() as u32;
        d.dsp = (d.dsp as f64 * share).floor() as u32;
        d.lut = (d.lut as f64 * share).floor() as u32;
        d.ff = (d.ff as f64 * share).floor() as u32;
        d.bandwidth_bps *= share;
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_ordering_by_memory() {
        let devs = Device::all();
        let caps: Vec<u64> = devs.iter().map(|d| d.mem_bits()).collect();
        for w in caps.windows(2) {
            assert!(w[0] < w[1], "devices should be ordered small to large");
        }
    }

    #[test]
    fn zcu102_capacity_matches_table3_normalization() {
        // Table III: 5.1 MB == 99% utilization -> capacity ~5.15 MB.
        let mb = Device::zcu102().mem_mbytes();
        assert!((4.9..5.5).contains(&mb), "zcu102 mem {mb} MB");
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Device::by_name("ZCU102").unwrap().name, "zcu102");
        assert_eq!(Device::by_name("u50").unwrap().dsp, 5952);
        assert!(Device::by_name("nonexistent").is_none());
    }

    #[test]
    fn link_parameters_are_sane() {
        for d in Device::all() {
            assert!(d.link_bandwidth_bps > 0.0, "{}", d.name);
            assert!(d.link_latency_s > 0.0 && d.link_latency_s < 1e-3, "{}", d.name);
            // the chain link is never faster than the DDR/HBM interface on
            // the big boards and stays in the same order of magnitude
            assert!(d.link_bandwidth_bps <= d.bandwidth_bps * 2.0, "{}", d.name);
        }
    }

    #[test]
    fn share_views_partition_the_device() {
        let d = Device::zcu102();
        // full share is the identity (single-tenant golden path)
        assert_eq!(d.with_share(1.0), d);
        assert_eq!(d.with_share(1.5), d);
        // floored shares can never oversubscribe the physical device
        let shares = [0.37, 0.21, 0.42];
        let views: Vec<Device> = shares.iter().map(|&s| d.with_share(s)).collect();
        assert!(views.iter().map(|v| v.bram36).sum::<u32>() <= d.bram36);
        assert!(views.iter().map(|v| v.dsp).sum::<u32>() <= d.dsp);
        assert!(views.iter().map(|v| v.lut).sum::<u32>() <= d.lut);
        let bw: f64 = views.iter().map(|v| v.bandwidth_bps).sum();
        assert!(bw <= d.bandwidth_bps * (1.0 + 1e-9));
        // per-port physics are not carved up
        for v in &views {
            assert_eq!(v.clk_comp_mhz, d.clk_comp_mhz);
            assert_eq!(v.clk_dma_mhz, d.clk_dma_mhz);
            assert_eq!(v.dma_port_bits, d.dma_port_bits);
            assert_eq!(v.link_bandwidth_bps, d.link_bandwidth_bps);
        }
    }

    #[test]
    fn mem_scale_sweep() {
        let d = Device::zcu102();
        let half = d.with_mem_scale(0.5);
        assert!((half.mem_bits() as f64 / d.mem_bits() as f64 - 0.5).abs() < 0.01);
        assert_eq!(half.dsp, d.dsp);
        assert_eq!(half.bandwidth_bps, d.bandwidth_bps);
    }

    #[test]
    fn u50_fits_resnet50_w8a8_barely() {
        // ResNet50 W8A8 weights = 25.6 MB; U50 on-chip ~29 MB -> vanilla
        // feasible but memory-starved (paper Table II: 15.0 ms vs 3.4 ms).
        let d = Device::u50();
        assert!(d.mem_mbytes() > 25.6);
        assert!(d.mem_mbytes() < 40.0);
    }
}
