//! Network: an ordered chain of layers forming the accelerator pipeline.

use super::{Layer, OpKind, PoolKind, Quant};

/// A DNN model `D`: the ordered set of layers `l ∈ D`, each mapped to one
/// Compute Engine (paper §IV).
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Input image channels/spatial for `β_io` accounting.
    pub input_shape: (u32, u32, u32),
    /// Default quantization (individual layers may override).
    pub quant: Quant,
}

/// Aggregate statistics of a network (paper Table I columns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkStats {
    pub params: u64,
    pub macs: u64,
    pub weight_layers: usize,
    pub total_layers: usize,
    pub weight_bits: u64,
    pub activation_peak: u64,
}

impl Network {
    pub fn new(name: impl Into<String>, input_shape: (u32, u32, u32), quant: Quant) -> Self {
        Network { name: name.into(), layers: Vec::new(), input_shape, quant }
    }

    /// Append a layer, checking shape continuity against the previous layer.
    /// Panics on a shape mismatch — model builders are static code, so a
    /// mismatch is a bug, not an input error.
    pub fn push(&mut self, layer: Layer) {
        if let Some(prev) = self.layers.last() {
            assert_eq!(
                (layer.c_in, layer.h_in, layer.w_in),
                (prev.c_out, prev.h_out(), prev.w_out()),
                "shape mismatch appending layer `{}` after `{}`",
                layer.name,
                prev.name
            );
        } else {
            assert_eq!(
                (layer.c_in, layer.h_in, layer.w_in),
                self.input_shape,
                "first layer `{}` does not match network input shape",
                layer.name
            );
        }
        self.layers.push(layer);
    }

    /// Append without shape checking — used for branch-merge points where the
    /// chain order intentionally differs from dataflow order (downsample
    /// convs on residual skip paths).
    pub fn push_unchecked(&mut self, layer: Layer) {
        self.layers.push(layer);
    }

    /// Indices of weight-carrying layers (the ones with a weights memory).
    pub fn weight_layers(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.has_weights())
            .map(|(i, _)| i)
            .collect()
    }

    /// Paper Table I statistics.
    pub fn stats(&self) -> NetworkStats {
        NetworkStats {
            params: self.layers.iter().map(|l| l.weight_count()).sum(),
            macs: self.layers.iter().map(|l| l.macs()).sum(),
            weight_layers: self.layers.iter().filter(|l| l.has_weights()).count(),
            total_layers: self.layers.len(),
            weight_bits: self.layers.iter().map(|l| l.weight_bits()).sum(),
            activation_peak: self
                .layers
                .iter()
                .map(|l| l.input_count() * l.quant.a_bits as u64)
                .max()
                .unwrap_or(0),
        }
    }

    /// Bandwidth cost `β_io` (bits/s) for streaming the network input into
    /// the first CE and the prediction out of the last CE, at a given
    /// end-to-end throughput (samples/s). Paper §IV-A, Fig. 1.
    pub fn beta_io(&self, throughput: f64) -> f64 {
        let first = &self.layers[0];
        let last = self.layers.last().unwrap();
        let in_bits = first.input_count() * first.quant.a_bits as u64;
        let out_bits = last.output_count() * last.quant.a_bits as u64;
        (in_bits + out_bits) as f64 * throughput
    }

    /// Re-quantize every layer of the network.
    pub fn with_quant(mut self, quant: Quant) -> Self {
        self.quant = quant;
        for l in &mut self.layers {
            l.quant = quant;
        }
        self
    }

    /// 128-bit FNV-1a content fingerprint: name, input shape, default
    /// quantization and every layer's full definition (name, operator with
    /// all parameters, dimensions, per-layer quantization, skip source).
    ///
    /// Streams raw field bytes straight into the hash — no intermediate
    /// canonical-serialization string — so cache keys
    /// ([`crate::pipeline::DesignCache`]) stop paying O(layers) string
    /// formatting per lookup. Two networks with equal content always hash
    /// equal; at 128 bits, distinct content colliding is negligible
    /// (~2⁻⁶⁴ birthday bound over any realistic design-point population).
    pub fn fingerprint(&self) -> u128 {
        let mut h = Fnv128::new();
        h.str(&self.name);
        let (c, hh, w) = self.input_shape;
        h.u32(c);
        h.u32(hh);
        h.u32(w);
        h.u32(self.quant.w_bits);
        h.u32(self.quant.a_bits);
        h.u32(self.layers.len() as u32);
        for l in &self.layers {
            h.str(&l.name);
            match l.op {
                OpKind::Conv { kernel, stride, pad, groups } => {
                    h.u32(0);
                    h.u32(kernel);
                    h.u32(stride);
                    h.u32(pad);
                    h.u32(groups);
                }
                OpKind::Fc => h.u32(1),
                OpKind::Pool { kernel, stride, pad, kind } => {
                    h.u32(2);
                    h.u32(kernel);
                    h.u32(stride);
                    h.u32(pad);
                    h.u32(match kind {
                        PoolKind::Max => 0,
                        PoolKind::Avg => 1,
                    });
                }
                OpKind::GlobalAvgPool => h.u32(3),
                OpKind::EltwiseAdd => h.u32(4),
                OpKind::Relu => h.u32(5),
            }
            h.u32(l.c_in);
            h.u32(l.c_out);
            h.u32(l.h_in);
            h.u32(l.w_in);
            h.u32(l.quant.w_bits);
            h.u32(l.quant.a_bits);
            match l.skip_from {
                None => h.u32(0),
                Some(s) => {
                    h.u32(1);
                    h.u32(s as u32);
                }
            }
        }
        h.finish()
    }
}

/// FNV-1a over 128 bits (the standard offset basis and prime).
struct Fnv128(u128);

impl Fnv128 {
    const OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
    const PRIME: u128 = 0x0000000001000000000000000000013B;

    fn new() -> Fnv128 {
        Fnv128(Self::OFFSET)
    }

    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u128;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    fn u32(&mut self, v: u32) {
        self.bytes(&v.to_le_bytes());
    }

    /// Length-prefixed so `("ab","c")` and `("a","bc")` never collide.
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.bytes(s.as_bytes());
    }

    fn finish(&self) -> u128 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::OpKind;

    fn tiny() -> Network {
        let mut n = Network::new("tiny", (3, 8, 8), Quant::W8A8);
        n.push(Layer::conv("c1", 3, 16, 8, 8, 3, 1, 1, Quant::W8A8));
        n.push(Layer::conv("c2", 16, 32, 8, 8, 3, 2, 1, Quant::W8A8));
        n.push(Layer {
            name: "gap".into(),
            op: OpKind::GlobalAvgPool,
            c_in: 32,
            c_out: 32,
            h_in: 4,
            w_in: 4,
            quant: Quant::W8A8,
            skip_from: None,
        });
        n.push(Layer::fc("fc", 32, 10, Quant::W8A8));
        n
    }

    #[test]
    fn stats_aggregate() {
        let n = tiny();
        let s = n.stats();
        assert_eq!(s.weight_layers, 3);
        assert_eq!(s.total_layers, 4);
        assert_eq!(s.params, 3 * 16 * 9 + 16 * 32 * 9 + 32 * 10);
        assert!(s.macs > s.params as u64);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn push_rejects_shape_mismatch() {
        let mut n = Network::new("bad", (3, 8, 8), Quant::W8A8);
        n.push(Layer::conv("c1", 3, 16, 8, 8, 3, 1, 1, Quant::W8A8));
        n.push(Layer::conv("c2", 99, 32, 8, 8, 3, 1, 1, Quant::W8A8));
    }

    #[test]
    fn beta_io_scales_with_throughput() {
        let n = tiny();
        let b1 = n.beta_io(1.0);
        let b2 = n.beta_io(100.0);
        assert!((b2 / b1 - 100.0).abs() < 1e-9);
        // input 3*8*8*8 bits + output 10*8 bits
        assert_eq!(b1 as u64, 3 * 8 * 8 * 8 + 10 * 8);
    }

    #[test]
    fn requantize() {
        let n = tiny().with_quant(Quant::W4A4);
        assert!(n.layers.iter().all(|l| l.quant == Quant::W4A4));
        assert_eq!(n.stats().weight_bits, n.stats().params * 4);
    }

    #[test]
    fn fingerprint_is_stable_over_equal_content() {
        assert_eq!(tiny().fingerprint(), tiny().fingerprint());
        assert_eq!(tiny().fingerprint(), tiny().clone().fingerprint());
    }

    #[test]
    fn fingerprint_separates_every_field_class() {
        let base = tiny().fingerprint();

        let mut n = tiny();
        n.name = "tiny2".into();
        assert_ne!(n.fingerprint(), base, "name");

        let mut n = tiny();
        n.input_shape = (3, 8, 9);
        assert_ne!(n.fingerprint(), base, "input shape");

        let n = tiny().with_quant(Quant::W4A4);
        assert_ne!(n.fingerprint(), base, "quantization");

        let mut n = tiny();
        n.layers[1].c_out += 1;
        assert_ne!(n.fingerprint(), base, "layer dims");

        let mut n = tiny();
        if let OpKind::Conv { ref mut stride, .. } = n.layers[1].op {
            *stride = 1;
        }
        assert_ne!(n.fingerprint(), base, "op params");

        let mut n = tiny();
        n.layers[2].skip_from = Some(0);
        assert_ne!(n.fingerprint(), base, "skip source");

        let mut n = tiny();
        n.layers.pop();
        assert_ne!(n.fingerprint(), base, "layer count");
    }
}
