//! Layer definition: one pipeline stage, mapped onto one Compute Engine.

use super::Quant;

/// The kind of pooling performed by a [`OpKind::Pool`] layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Operation performed by a layer.
///
/// Weight-carrying operations (`Conv`, `Fc`) get a fragmented weights memory
/// in their CE (paper Fig. 3); the rest are pure streaming operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// 2-D convolution. `groups == c_in` expresses a depthwise convolution
    /// (MobileNetV2); `groups == 1` a dense convolution.
    Conv {
        kernel: u32,
        stride: u32,
        pad: u32,
        groups: u32,
    },
    /// Fully connected layer; generalizes to Conv with `k = h = w = 1`
    /// (paper §III-B).
    Fc,
    /// Spatial pooling window.
    Pool {
        kernel: u32,
        stride: u32,
        pad: u32,
        kind: PoolKind,
    },
    /// Global average pool: reduces spatial dims to 1x1.
    GlobalAvgPool,
    /// Elementwise addition of the main path and a skip path (residual).
    EltwiseAdd,
    /// Standalone activation (usually fused into the producing CE; kept for
    /// graphs imported from frameworks that materialize it).
    Relu,
}

/// One layer of the network == one Compute Engine of the accelerator.
///
/// Dimension symbols follow paper Fig. 2: `c` input channels, `f` output
/// filters, `k` kernel size, input spatial `h x w`, output spatial
/// `h_out x w_out` (the paper's ĥ, ŵ).
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub op: OpKind,
    /// Input channels `c`.
    pub c_in: u32,
    /// Output filters `f`.
    pub c_out: u32,
    /// Input spatial height `h`.
    pub h_in: u32,
    /// Input spatial width `w`.
    pub w_in: u32,
    /// Quantization of this layer's weights/activations.
    pub quant: Quant,
    /// For `EltwiseAdd`: index of the layer whose output feeds the skip path.
    pub skip_from: Option<usize>,
}

impl Layer {
    /// Convenience constructor for a dense convolution.
    pub fn conv(
        name: impl Into<String>,
        c_in: u32,
        c_out: u32,
        h_in: u32,
        w_in: u32,
        kernel: u32,
        stride: u32,
        pad: u32,
        quant: Quant,
    ) -> Self {
        Layer {
            name: name.into(),
            op: OpKind::Conv { kernel, stride, pad, groups: 1 },
            c_in,
            c_out,
            h_in,
            w_in,
            quant,
            skip_from: None,
        }
    }

    /// Convenience constructor for a depthwise convolution (`groups == c`).
    pub fn depthwise(
        name: impl Into<String>,
        c: u32,
        h_in: u32,
        w_in: u32,
        kernel: u32,
        stride: u32,
        pad: u32,
        quant: Quant,
    ) -> Self {
        Layer {
            name: name.into(),
            op: OpKind::Conv { kernel, stride, pad, groups: c },
            c_in: c,
            c_out: c,
            h_in,
            w_in,
            quant,
            skip_from: None,
        }
    }

    /// Convenience constructor for a fully connected layer.
    pub fn fc(name: impl Into<String>, c_in: u32, c_out: u32, quant: Quant) -> Self {
        Layer {
            name: name.into(),
            op: OpKind::Fc,
            c_in,
            c_out,
            h_in: 1,
            w_in: 1,
            quant,
            skip_from: None,
        }
    }

    /// Kernel size `k` of this layer (1 for pointwise ops and FC).
    pub fn kernel(&self) -> u32 {
        match self.op {
            OpKind::Conv { kernel, .. } => kernel,
            OpKind::Pool { kernel, .. } => kernel,
            _ => 1,
        }
    }

    /// Output spatial height ĥ.
    pub fn h_out(&self) -> u32 {
        match self.op {
            OpKind::Conv { kernel, stride, pad, .. } | OpKind::Pool { kernel, stride, pad, .. } => {
                (self.h_in + 2 * pad - kernel) / stride + 1
            }
            OpKind::GlobalAvgPool | OpKind::Fc => 1,
            OpKind::EltwiseAdd | OpKind::Relu => self.h_in,
        }
    }

    /// Output spatial width ŵ.
    pub fn w_out(&self) -> u32 {
        match self.op {
            OpKind::Conv { kernel, stride, pad, .. } | OpKind::Pool { kernel, stride, pad, .. } => {
                (self.w_in + 2 * pad - kernel) / stride + 1
            }
            OpKind::GlobalAvgPool | OpKind::Fc => 1,
            OpKind::EltwiseAdd | OpKind::Relu => self.w_in,
        }
    }

    /// Whether the CE for this layer carries a weights memory.
    pub fn has_weights(&self) -> bool {
        matches!(self.op, OpKind::Conv { .. } | OpKind::Fc)
    }

    /// Number of weight parameters.
    pub fn weight_count(&self) -> u64 {
        match self.op {
            OpKind::Conv { kernel, groups, .. } => {
                (self.c_out as u64) * (self.c_in as u64 / groups as u64) * (kernel as u64).pow(2)
            }
            OpKind::Fc => self.c_out as u64 * self.c_in as u64,
            _ => 0,
        }
    }

    /// Total weight storage in bits (`weight_count * L_W`).
    pub fn weight_bits(&self) -> u64 {
        self.weight_count() * self.quant.w_bits as u64
    }

    /// Multiply-accumulate operations per inference sample.
    pub fn macs(&self) -> u64 {
        match self.op {
            OpKind::Conv { .. } => {
                self.weight_count() * self.h_out() as u64 * self.w_out() as u64
            }
            OpKind::Fc => self.weight_count(),
            _ => 0,
        }
    }

    /// Number of input activation values consumed per inference sample.
    pub fn input_count(&self) -> u64 {
        self.c_in as u64 * self.h_in as u64 * self.w_in as u64
    }

    /// Number of output activation values produced per inference sample.
    pub fn output_count(&self) -> u64 {
        self.c_out as u64 * self.h_out() as u64 * self.w_out() as u64
    }

    /// Effective channel depth per filter seen by the weights memory —
    /// for grouped conv this is `c / groups`.
    pub fn c_per_group(&self) -> u32 {
        match self.op {
            OpKind::Conv { groups, .. } => self.c_in / groups,
            _ => self.c_in,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_shape() {
        let l = Layer::conv("c1", 3, 64, 224, 224, 7, 2, 3, Quant::W8A8);
        assert_eq!(l.h_out(), 112);
        assert_eq!(l.w_out(), 112);
        assert_eq!(l.weight_count(), 64 * 3 * 49);
        assert_eq!(l.macs(), 64 * 3 * 49 * 112 * 112);
    }

    #[test]
    fn same_pad_conv_preserves_shape() {
        let l = Layer::conv("c", 64, 64, 56, 56, 3, 1, 1, Quant::W4A4);
        assert_eq!(l.h_out(), 56);
        assert_eq!(l.w_out(), 56);
    }

    #[test]
    fn depthwise_weights_and_macs() {
        let l = Layer::depthwise("dw", 32, 112, 112, 3, 1, 1, Quant::W8A8);
        assert_eq!(l.weight_count(), 32 * 9);
        assert_eq!(l.macs(), 32 * 9 * 112 * 112);
        assert_eq!(l.c_per_group(), 1);
    }

    #[test]
    fn fc_generalizes_conv() {
        let l = Layer::fc("fc", 512, 1000, Quant::W4A5);
        assert_eq!(l.kernel(), 1);
        assert_eq!(l.h_out(), 1);
        assert_eq!(l.weight_count(), 512_000);
        assert_eq!(l.macs(), 512_000);
        assert_eq!(l.weight_bits(), 512_000 * 4);
    }

    #[test]
    fn pool_has_no_weights() {
        let l = Layer {
            name: "p".into(),
            op: OpKind::Pool { kernel: 3, stride: 2, pad: 1, kind: PoolKind::Max },
            c_in: 64,
            c_out: 64,
            h_in: 112,
            w_in: 112,
            quant: Quant::W8A8,
            skip_from: None,
        };
        assert!(!l.has_weights());
        assert_eq!(l.weight_count(), 0);
        assert_eq!(l.h_out(), 56);
    }

    #[test]
    fn eltwise_passthrough_shape() {
        let l = Layer {
            name: "add".into(),
            op: OpKind::EltwiseAdd,
            c_in: 256,
            c_out: 256,
            h_in: 14,
            w_in: 14,
            quant: Quant::W8A8,
            skip_from: Some(3),
        };
        assert_eq!(l.h_out(), 14);
        assert_eq!(l.output_count(), 256 * 14 * 14);
    }
}
