//! DNN graph intermediate representation.
//!
//! A [`Network`] is an ordered chain of [`Layer`]s — the layer-wise pipelined
//! architecture maps each layer to one Compute Engine, connected by FIFOs
//! (paper §IV). Residual connections are represented by [`OpKind::EltwiseAdd`]
//! layers carrying a `skip_from` back-reference; on hardware the skip path is
//! a bypass FIFO and does not change the chain timing model.

mod graph;
mod layer;
pub mod textfmt;

pub use graph::{Network, NetworkStats};
pub use layer::{Layer, OpKind, PoolKind};
pub use textfmt::{parse_network, serialize_network, NetParseError};

/// Quantization scheme: weights and activations bitwidths (paper Table I/II:
/// W4A4, W4A5, W8A8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Quant {
    /// Weights bitwidth `L_W`.
    pub w_bits: u32,
    /// Activations bitwidth `L_A`.
    pub a_bits: u32,
}

impl Quant {
    pub const W4A4: Quant = Quant { w_bits: 4, a_bits: 4 };
    pub const W4A5: Quant = Quant { w_bits: 4, a_bits: 5 };
    pub const W8A8: Quant = Quant { w_bits: 8, a_bits: 8 };
    pub const F32: Quant = Quant { w_bits: 32, a_bits: 32 };

    pub fn label(&self) -> String {
        format!("W{}A{}", self.w_bits, self.a_bits)
    }

    /// Parse a quantization label (`w4a4`, `W8A8`, `f32`, ...). Arbitrary
    /// `w<N>a<M>` pairs are accepted so custom schemes can be configured.
    pub fn parse(s: &str) -> Option<Quant> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "f32" | "fp32" | "float32" => return Some(Quant::F32),
            _ => {}
        }
        let rest = lower.strip_prefix('w')?;
        let (w, a) = rest.split_once('a')?;
        let w_bits: u32 = w.parse().ok()?;
        let a_bits: u32 = a.parse().ok()?;
        if w_bits == 0 || a_bits == 0 || w_bits > 32 || a_bits > 32 {
            return None;
        }
        Some(Quant { w_bits, a_bits })
    }
}

impl std::fmt::Display for Quant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}
