//! Textual network description format (`.net` files).
//!
//! Lets users hand an arbitrary chain-structured DNN to the toolflow without
//! writing a Rust builder — the launcher's `model.file` config option.
//!
//! ```text
//! # AutoWS network description
//! network mynet
//! input 3 32 32
//! quant w8a8
//!
//! conv name=c1 out=16 k=3 s=1 p=1
//! relu
//! pool k=2 s=2 kind=max
//! depthwise k=3 s=1 p=1
//! conv out=32 k=1                 # pointwise
//! eltwise skip=3                  # residual add, skip path from layer 3
//! globalavgpool
//! fc out=10 quant=w4a5            # per-layer quant override
//! ```
//!
//! Input channel/spatial dimensions of every layer are inferred by chaining
//! from the previous layer, so only the operator's own parameters appear.
//! The serializer emits the same format; `parse(serialize(n)) == n`.

use super::{Layer, Network, OpKind, PoolKind, Quant};

/// A `.net` parse failure with its 1-based line number.
#[derive(Debug, Clone, PartialEq)]
pub struct NetParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for NetParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for NetParseError {}

fn err(line: usize, message: impl Into<String>) -> NetParseError {
    NetParseError { line, message: message.into() }
}

/// Key=value attributes of one layer line.
struct Attrs<'a> {
    line: usize,
    pairs: Vec<(&'a str, &'a str)>,
    used: Vec<bool>,
}

impl<'a> Attrs<'a> {
    fn parse(tokens: &[&'a str], line: usize) -> Result<Attrs<'a>, NetParseError> {
        let mut pairs = Vec::new();
        for t in tokens {
            let (k, v) = t
                .split_once('=')
                .ok_or_else(|| err(line, format!("expected key=value, got `{t}`")))?;
            pairs.push((k, v));
        }
        let used = vec![false; pairs.len()];
        Ok(Attrs { line, pairs, used })
    }

    fn get(&mut self, key: &str) -> Option<&'a str> {
        for (i, (k, v)) in self.pairs.iter().enumerate() {
            if *k == key {
                self.used[i] = true;
                return Some(v);
            }
        }
        None
    }

    fn num(&mut self, key: &str) -> Result<Option<u32>, NetParseError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| err(self.line, format!("{key}: cannot parse `{v}`"))),
        }
    }

    fn num_or(&mut self, key: &str, default: u32) -> Result<u32, NetParseError> {
        Ok(self.num(key)?.unwrap_or(default))
    }

    fn require(&mut self, key: &str) -> Result<u32, NetParseError> {
        self.num(key)?.ok_or_else(|| err(self.line, format!("missing required `{key}=`")))
    }

    /// Error on unconsumed attributes — a typo'd key silently ignored would
    /// produce a wrong accelerator.
    fn finish(self) -> Result<(), NetParseError> {
        for (i, (k, _)) in self.pairs.iter().enumerate() {
            if !self.used[i] {
                return Err(err(self.line, format!("unknown attribute `{k}`")));
            }
        }
        Ok(())
    }
}

/// Parse a `.net` description. `default_quant` applies to layers without a
/// per-layer `quant=` override and is itself overridden by a `quant` header.
pub fn parse_network(text: &str, default_quant: Quant) -> Result<Network, NetParseError> {
    let mut name = String::from("custom");
    let mut input: Option<(u32, u32, u32)> = None;
    let mut net_quant = default_quant;
    let mut net: Option<Network> = None;
    let mut counts = std::collections::HashMap::<&'static str, u32>::new();

    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let tokens: Vec<&str> = line.split_whitespace().collect();
        let op = tokens[0].to_ascii_lowercase();

        // --- headers (before the first layer) ---
        match op.as_str() {
            "network" => {
                if net.is_some() {
                    return Err(err(line_no, "`network` header must precede layers"));
                }
                name = tokens.get(1).unwrap_or(&"custom").to_string();
                continue;
            }
            "input" => {
                if tokens.len() != 4 {
                    return Err(err(line_no, "usage: input <channels> <height> <width>"));
                }
                let dims: Result<Vec<u32>, _> = tokens[1..4].iter().map(|t| t.parse()).collect();
                let d = dims.map_err(|_| err(line_no, "input dims must be integers"))?;
                if d.iter().any(|&x| x == 0) {
                    return Err(err(line_no, "input dims must be positive"));
                }
                input = Some((d[0], d[1], d[2]));
                continue;
            }
            "quant" => {
                if net.is_some() {
                    return Err(err(line_no, "`quant` header must precede layers"));
                }
                let label = tokens.get(1).ok_or_else(|| err(line_no, "usage: quant <label>"))?;
                net_quant = Quant::parse(label)
                    .ok_or_else(|| err(line_no, format!("bad quant label `{label}`")))?;
                continue;
            }
            _ => {}
        }

        // --- layer lines ---
        let input_shape =
            input.ok_or_else(|| err(line_no, "`input` header required before layers"))?;
        let net_ref = net.get_or_insert_with(|| Network::new(name.clone(), input_shape, net_quant));
        let (c_in, h_in, w_in) = match net_ref.layers.last() {
            Some(prev) => (prev.c_out, prev.h_out(), prev.w_out()),
            None => input_shape,
        };

        let mut attrs = Attrs::parse(&tokens[1..], line_no)?;
        let quant = match attrs.get("quant") {
            None => net_quant,
            Some(q) => Quant::parse(q)
                .ok_or_else(|| err(line_no, format!("bad quant label `{q}`")))?,
        };
        let auto_name = |counts: &mut std::collections::HashMap<&'static str, u32>,
                         kind: &'static str| {
            let c = counts.entry(kind).or_insert(0);
            *c += 1;
            format!("{kind}{c}")
        };

        let layer = match op.as_str() {
            "conv" => {
                let out = attrs.require("out")?;
                let k = attrs.num_or("k", 1)?;
                let s = attrs.num_or("s", 1)?;
                let p = attrs.num_or("p", 0)?;
                let g = attrs.num_or("groups", 1)?;
                if k == 0 || s == 0 || g == 0 {
                    return Err(err(line_no, "k, s, groups must be positive"));
                }
                if c_in % g != 0 || out % g != 0 {
                    return Err(err(line_no, format!("groups={g} does not divide c={c_in}/f={out}")));
                }
                let name = attrs
                    .get("name")
                    .map(String::from)
                    .unwrap_or_else(|| auto_name(&mut counts, "conv"));
                Layer {
                    name,
                    op: OpKind::Conv { kernel: k, stride: s, pad: p, groups: g },
                    c_in,
                    c_out: out,
                    h_in,
                    w_in,
                    quant,
                    skip_from: None,
                }
            }
            "depthwise" => {
                let k = attrs.num_or("k", 3)?;
                let s = attrs.num_or("s", 1)?;
                let p = attrs.num_or("p", (k - 1) / 2)?;
                let name = attrs
                    .get("name")
                    .map(String::from)
                    .unwrap_or_else(|| auto_name(&mut counts, "dw"));
                let mut l = Layer::depthwise(name, c_in, h_in, w_in, k, s, p, quant);
                l.quant = quant;
                l
            }
            "fc" => {
                let out = attrs.require("out")?;
                let name = attrs
                    .get("name")
                    .map(String::from)
                    .unwrap_or_else(|| auto_name(&mut counts, "fc"));
                // Spatial input is implicitly flattened (c·h·w features), the
                // same convention the zoo builders use (VGG16's fc6).
                Layer::fc(name, c_in * h_in * w_in, out, quant)
            }
            "pool" => {
                let k = attrs.require("k")?;
                let s = attrs.num_or("s", k)?;
                let p = attrs.num_or("p", 0)?;
                let kind = match attrs.get("kind").unwrap_or("max") {
                    "max" => PoolKind::Max,
                    "avg" => PoolKind::Avg,
                    other => return Err(err(line_no, format!("bad pool kind `{other}`"))),
                };
                let name = attrs
                    .get("name")
                    .map(String::from)
                    .unwrap_or_else(|| auto_name(&mut counts, "pool"));
                Layer {
                    name,
                    op: OpKind::Pool { kernel: k, stride: s, pad: p, kind },
                    c_in,
                    c_out: c_in,
                    h_in,
                    w_in,
                    quant,
                    skip_from: None,
                }
            }
            "globalavgpool" | "gap" => Layer {
                name: attrs
                    .get("name")
                    .map(String::from)
                    .unwrap_or_else(|| auto_name(&mut counts, "gap")),
                op: OpKind::GlobalAvgPool,
                c_in,
                c_out: c_in,
                h_in,
                w_in,
                quant,
                skip_from: None,
            },
            "relu" => Layer {
                name: attrs
                    .get("name")
                    .map(String::from)
                    .unwrap_or_else(|| auto_name(&mut counts, "relu")),
                op: OpKind::Relu,
                c_in,
                c_out: c_in,
                h_in,
                w_in,
                quant,
                skip_from: None,
            },
            "eltwise" => {
                let skip = attrs.require("skip")? as usize;
                let cur = net_ref.layers.len();
                if skip >= cur {
                    return Err(err(
                        line_no,
                        format!("eltwise skip={skip} must reference an earlier layer (< {cur})"),
                    ));
                }
                let src = &net_ref.layers[skip];
                if (src.c_out, src.h_out(), src.w_out()) != (c_in, h_in, w_in) {
                    return Err(err(
                        line_no,
                        format!(
                            "eltwise skip={skip} shape {}x{}x{} does not match main path {}x{}x{}",
                            src.c_out,
                            src.h_out(),
                            src.w_out(),
                            c_in,
                            h_in,
                            w_in
                        ),
                    ));
                }
                Layer {
                    name: attrs
                        .get("name")
                        .map(String::from)
                        .unwrap_or_else(|| auto_name(&mut counts, "add")),
                    op: OpKind::EltwiseAdd,
                    c_in,
                    c_out: c_in,
                    h_in,
                    w_in,
                    quant,
                    skip_from: Some(skip),
                }
            }
            other => return Err(err(line_no, format!("unknown operator `{other}`"))),
        };
        attrs.finish()?;
        // Shapes are chained from the previous layer above, so continuity
        // holds by construction; `push_unchecked` also covers the fc-flatten
        // case where c_in is intentionally c·h·w.
        net_ref.push_unchecked(layer);
    }

    let net = net.ok_or_else(|| err(text.lines().count().max(1), "no layers in description"))?;
    Ok(net)
}

/// Serialize a network to the `.net` format parsed by [`parse_network`].
pub fn serialize_network(net: &Network) -> String {
    let mut out = String::new();
    out.push_str(&format!("network {}\n", net.name));
    let (c, h, w) = net.input_shape;
    out.push_str(&format!("input {c} {h} {w}\n"));
    out.push_str(&format!("quant {}\n\n", net.quant.label().to_ascii_lowercase()));
    for l in &net.layers {
        let quant_sfx = if l.quant == net.quant {
            String::new()
        } else {
            format!(" quant={}", l.quant.label().to_ascii_lowercase())
        };
        let line = match l.op {
            OpKind::Conv { kernel, stride, pad, groups } if groups == l.c_in && l.c_in == l.c_out => {
                format!("depthwise name={} k={kernel} s={stride} p={pad}", l.name)
            }
            OpKind::Conv { kernel, stride, pad, groups } => {
                let g = if groups > 1 { format!(" groups={groups}") } else { String::new() };
                format!("conv name={} out={} k={kernel} s={stride} p={pad}{g}", l.name, l.c_out)
            }
            OpKind::Fc => format!("fc name={} out={}", l.name, l.c_out),
            OpKind::Pool { kernel, stride, pad, kind } => {
                let kind = match kind {
                    PoolKind::Max => "max",
                    PoolKind::Avg => "avg",
                };
                format!("pool name={} k={kernel} s={stride} p={pad} kind={kind}", l.name)
            }
            OpKind::GlobalAvgPool => format!("globalavgpool name={}", l.name),
            OpKind::Relu => format!("relu name={}", l.name),
            OpKind::EltwiseAdd => {
                format!("eltwise name={} skip={}", l.name, l.skip_from.unwrap_or(0))
            }
        };
        out.push_str(&line);
        out.push_str(&quant_sfx);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    const SAMPLE: &str = "
# a small residual CNN
network sample
input 3 32 32
quant w8a8

conv name=stem out=16 k=3 s=1 p=1
relu
conv out=16 k=3 s=1 p=1
eltwise skip=1
pool k=2 s=2 kind=max
depthwise k=3
conv out=32 k=1
globalavgpool
fc out=10 quant=w4a5
";

    #[test]
    fn parse_sample() {
        let n = parse_network(SAMPLE, Quant::W8A8).unwrap();
        assert_eq!(n.name, "sample");
        assert_eq!(n.input_shape, (3, 32, 32));
        assert_eq!(n.layers.len(), 9);
        assert_eq!(n.layers[0].name, "stem");
        assert_eq!(n.layers[3].skip_from, Some(1));
        // shapes chained correctly: pool halves 32 -> 16
        assert_eq!(n.layers[4].h_out(), 16);
        // depthwise inherits channels
        assert_eq!(n.layers[5].c_out, 16);
        // per-layer quant override
        assert_eq!(n.layers[8].quant, Quant::W4A5);
        assert_eq!(n.layers[0].quant, Quant::W8A8);
    }

    #[test]
    fn roundtrip_sample() {
        let n = parse_network(SAMPLE, Quant::W8A8).unwrap();
        let text = serialize_network(&n);
        let n2 = parse_network(&text, Quant::W8A8).unwrap();
        assert_eq!(n.layers.len(), n2.layers.len());
        for (a, b) in n.layers.iter().zip(&n2.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.op, b.op);
            assert_eq!((a.c_in, a.c_out, a.h_in, a.w_in), (b.c_in, b.c_out, b.h_in, b.w_in));
            assert_eq!(a.quant, b.quant);
            assert_eq!(a.skip_from, b.skip_from);
        }
        assert_eq!(n.stats(), n2.stats());
    }

    #[test]
    fn roundtrip_zoo_chain_models() {
        // Chain-only zoo models survive serialize -> parse with equal stats.
        for name in ["toy", "vgg16"] {
            let n = models::by_name(name, Quant::W8A8).unwrap();
            let n2 = parse_network(&serialize_network(&n), Quant::W8A8)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(n.stats(), n2.stats(), "{name}");
        }
    }

    #[test]
    fn missing_input_header() {
        let e = parse_network("conv out=8 k=3", Quant::W8A8).unwrap_err();
        assert!(e.message.contains("input"), "{e}");
    }

    #[test]
    fn unknown_operator() {
        let e = parse_network("input 3 8 8\nflurb out=2", Quant::W8A8).unwrap_err();
        assert!(e.message.contains("unknown operator"), "{e}");
        assert_eq!(e.line, 2);
    }

    #[test]
    fn unknown_attribute_rejected() {
        let e = parse_network("input 3 8 8\nconv out=8 k=3 blorp=2", Quant::W8A8).unwrap_err();
        assert!(e.message.contains("unknown attribute"), "{e}");
    }

    #[test]
    fn fc_flattens_spatial_input() {
        let n = parse_network("input 3 8 8\nfc out=10", Quant::W8A8).unwrap();
        assert_eq!(n.layers[0].c_in, 3 * 8 * 8);
        assert_eq!(n.layers[0].weight_count(), 3 * 8 * 8 * 10);
    }

    #[test]
    fn eltwise_shape_mismatch() {
        let e = parse_network(
            "input 3 8 8\nconv out=4 k=3 s=1 p=1\nconv out=8 k=3 s=1 p=1\neltwise skip=0",
            Quant::W8A8,
        )
        .unwrap_err();
        assert!(e.message.contains("does not match"), "{e}");
    }

    #[test]
    fn eltwise_forward_reference_rejected() {
        let e = parse_network(
            "input 3 8 8\nconv out=3 k=3 p=1\neltwise skip=5",
            Quant::W8A8,
        )
        .unwrap_err();
        assert!(e.message.contains("earlier layer"), "{e}");
    }

    #[test]
    fn groups_must_divide() {
        let e = parse_network("input 3 8 8\nconv out=8 k=3 groups=2", Quant::W8A8).unwrap_err();
        assert!(e.message.contains("groups"), "{e}");
    }

    #[test]
    fn empty_description() {
        assert!(parse_network("", Quant::W8A8).is_err());
        assert!(parse_network("# only comments\n", Quant::W8A8).is_err());
    }

    #[test]
    fn quant_header_applies() {
        let n = parse_network("network q\ninput 3 8 8\nquant w4a4\nconv out=4 k=3 p=1", Quant::W8A8)
            .unwrap();
        assert_eq!(n.quant, Quant::W4A4);
        assert_eq!(n.layers[0].quant, Quant::W4A4);
    }
}
