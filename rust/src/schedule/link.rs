//! Inter-device streaming link of a sharded deployment.
//!
//! In a partitioned pipeline, the FIFO between the last CE of one device
//! and the first CE of the next is carried over a serial link ([`Device`]'s
//! `link_bandwidth_bps` / `link_latency_s`). Like the DMA port inside a
//! device, the link is a shared, rate-limited resource: its per-sample
//! transfer time joins the per-partition bottlenecks in the chain's
//! steady-state period, and when it loses that race the downstream
//! partition stalls — attributed by the partitioned simulator the same way
//! DMA contention is attributed within a device.

use crate::device::Device;
use crate::ir::Layer;

/// One boundary of a device chain: the activation stream from partition `i`
/// to partition `i + 1`.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    /// Activation bits crossing the boundary per sample.
    pub boundary_bits: u64,
    /// Effective link bandwidth (slower endpoint), bits/s.
    pub bandwidth_bps: f64,
    /// One-way hop latency, seconds.
    pub latency_s: f64,
}

impl LinkSpec {
    /// The link carrying `upstream_last`'s output activations from `tx` to
    /// `rx` (boundary traffic, bandwidth and latency all derived from the
    /// single definitions in [`crate::dse::partition`]).
    pub fn between(upstream_last: &Layer, tx: &Device, rx: &Device) -> LinkSpec {
        LinkSpec {
            boundary_bits: crate::dse::partition::layer_boundary_bits(upstream_last),
            bandwidth_bps: crate::dse::partition::link_bandwidth(tx, rx),
            latency_s: crate::dse::partition::link_latency(tx, rx),
        }
    }

    /// The links of a partition chain, in order: one per consecutive stage
    /// pair, from the upstream partition's last layer and the two devices.
    /// The single place chain links are derived (report and simulator both
    /// call this).
    pub fn chain(stages: &[(&crate::dse::Design, &Device)]) -> Vec<LinkSpec> {
        stages
            .windows(2)
            .map(|w| {
                let (up_design, up_dev) = w[0];
                let (_, down_dev) = w[1];
                let last = up_design.network.layers.last().expect("non-empty partition");
                LinkSpec::between(last, up_dev, down_dev)
            })
            .collect()
    }

    /// Per-sample transfer time, seconds.
    pub fn transfer_s(&self) -> f64 {
        self.boundary_bits as f64 / self.bandwidth_bps
    }

    /// Samples/s the link sustains in steady state.
    pub fn max_rate(&self) -> f64 {
        self.bandwidth_bps / (self.boundary_bits as f64).max(1.0)
    }

    /// Busy fraction of the link at a given chain throughput (samples/s).
    pub fn utilization(&self, throughput: f64) -> f64 {
        (self.boundary_bits as f64 * throughput / self.bandwidth_bps).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;

    #[test]
    fn link_math_is_consistent() {
        let l = Layer::conv("c", 8, 16, 16, 16, 3, 1, 1, Quant::W8A8);
        let tx = Device::zcu102();
        let rx = Device::u250();
        let link = LinkSpec::between(&l, &tx, &rx);
        // slower endpoint wins
        assert_eq!(link.bandwidth_bps, tx.link_bandwidth_bps.min(rx.link_bandwidth_bps));
        assert_eq!(link.boundary_bits, l.output_count() * 8);
        // utilization at the link's own max rate is exactly 1
        let u = link.utilization(link.max_rate());
        assert!((u - 1.0).abs() < 1e-9, "{u}");
        assert!(link.transfer_s() > 0.0);
    }
}
