//! Deterministic DMA scheduling — paper §IV-B, Fig. 5.
//!
//! Per device, a single DMA port is routed to the streaming CEs through a
//! demultiplexer driven by a static configuration sequence. Per streaming
//! layer and per fragment iteration the schedule alternates a **write
//! burst** filling the shared buffer (Eq. 8) with a **read interval**
//! during which the PE array drains the static region and then the buffer
//! (Eq. 9); write-burst balancing (Eq. 10) makes every layer perform the
//! same number `r` of bursts per batch so the bursts interleave without
//! stalls.
//!
//! In a sharded deployment each partition owns its own DMA port and
//! [`BurstSchedule`]; consecutive partitions are joined by a [`LinkSpec`]
//! carrying the boundary activations.
//!
//! In a co-located deployment several tenants share ONE port: each tenant's
//! burst schedule is derived against its provisioned bandwidth slice and
//! the slices compose under the port-level cap ([`SharedDmaSchedule`]), so
//! the Eq. 8–10 feasibility argument still holds per tenant.

mod burst;
mod dma;
mod link;
mod port;

pub(crate) use burst::gcd_u64;
pub use burst::{BurstEntry, BurstSchedule};
pub use dma::{demux_sequence, DemuxSlot};
pub use link::LinkSpec;
pub use port::{SharedDmaSchedule, TenantSlice};
