//! Deterministic DMA scheduling — paper §IV-B, Fig. 5.
//!
//! Per device, a single DMA port is routed to the streaming CEs through a
//! demultiplexer driven by a static configuration sequence. Per streaming
//! layer and per fragment iteration the schedule alternates a **write
//! burst** filling the shared buffer (Eq. 8) with a **read interval**
//! during which the PE array drains the static region and then the buffer
//! (Eq. 9); write-burst balancing (Eq. 10) makes every layer perform the
//! same number `r` of bursts per batch so the bursts interleave without
//! stalls.
//!
//! In a sharded deployment each partition owns its own DMA port and
//! [`BurstSchedule`]; consecutive partitions are joined by a [`LinkSpec`]
//! carrying the boundary activations.

mod burst;
mod dma;
mod link;

pub use burst::{BurstEntry, BurstSchedule};
pub use dma::{demux_sequence, DemuxSlot};
pub use link::LinkSpec;
