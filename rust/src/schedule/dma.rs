//! Demultiplexer configuration sequence — paper §IV-B.
//!
//! "We employ a demultiplexer to manage the routing between the DMA port and
//! multiple CEs. The demultiplexer is controlled by a configuration sequence
//! that outlines the order and the duration of serving each individual CE."

use super::BurstSchedule;

/// One slot of the demux configuration sequence: serve `layer` for
/// `duration` seconds starting `offset` seconds into the balanced window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DemuxSlot {
    pub layer: usize,
    pub offset: f64,
    pub duration: f64,
}

/// Generate the static demux sequence for one balanced window: streaming
/// layers are served back-to-back in pipeline order. The sequence repeats
/// `r` times per batch (identical every window — this determinism is what
/// lets the hardware use a simple counter-driven controller instead of an
/// arbiter).
pub fn demux_sequence(schedule: &BurstSchedule) -> Vec<DemuxSlot> {
    let mut slots = Vec::with_capacity(schedule.entries.len());
    let mut cursor = 0.0;
    for e in &schedule.entries {
        slots.push(DemuxSlot { layer: e.layer, offset: cursor, duration: e.t_wr });
        cursor += e.t_wr;
    }
    slots
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;
    use crate::schedule::BurstSchedule;

    #[test]
    fn slots_are_contiguous_and_ordered() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let s = BurstSchedule::from_design(&r.design, &dev, 1);
        let seq = demux_sequence(&s);
        assert_eq!(seq.len(), s.entries.len());
        let mut cursor = 0.0;
        for slot in &seq {
            assert!((slot.offset - cursor).abs() < 1e-12, "slots must be back-to-back");
            cursor = slot.offset + slot.duration;
        }
        // total service time fits in the window when schedulable
        if s.schedulable() && !seq.is_empty() {
            let min_rd = s.entries.iter().map(|e| e.t_rd).fold(f64::INFINITY, f64::min);
            assert!(cursor <= min_rd * 1.0001);
        }
    }
}
