//! Burst timing — paper Eq. 8 and Eq. 9.

use crate::device::Device;
use crate::dse::Design;

/// Timing of one streaming layer's write/read pattern.
#[derive(Debug, Clone, PartialEq)]
pub struct BurstEntry {
    /// Layer index in the network chain.
    pub layer: usize,
    /// Write-burst duration `t_wr = M_wid·u_off / (B − β_io)` in seconds
    /// (Eq. 8), additionally capped by the buffer write port
    /// (`M_wid · clk_dma`) — the second clock domain.
    pub t_wr: f64,
    /// Read-interval duration `t_rd = (u_on + u_off) / (s_l · clk_comp)` in
    /// seconds (Eq. 9).
    pub t_rd: f64,
    /// Static-region portion of the read interval, seconds.
    pub t_rd_static: f64,
    /// Buffer portion of the read interval, seconds.
    pub t_rd_buffer: f64,
    /// Repeat count `r_l = b·ĥ·ŵ·n` (Eq. 3).
    pub r: u64,
    /// Pipeline start offset of this CE (seconds): its first read begins
    /// later than upstream CEs by the pipeline depth (Fig. 5, bottom-left).
    pub start_offset: f64,
}

/// The complete DMA schedule of a design on a device (one DMA port; a
/// sharded deployment derives one schedule per partition).
#[derive(Debug, Clone, PartialEq)]
pub struct BurstSchedule {
    pub entries: Vec<BurstEntry>,
    /// Effective DMA bandwidth available to weights: `B − β_io` (bits/s).
    pub weight_bandwidth_bps: f64,
    /// Batch size the repeat counts were computed for.
    pub batch: u64,
}

impl BurstSchedule {
    /// Build the schedule for every streaming layer of `design`.
    pub fn from_design(design: &Design, device: &Device, batch: u64) -> BurstSchedule {
        let beta_io = design.io_bandwidth();
        let bw = (device.bandwidth_bps - beta_io).max(1.0);
        let clk = design.clk_comp_mhz * 1e6;
        let clk_dma = device.clk_dma_mhz * 1e6;

        let mut offset = 0.0;
        let mut offsets = vec![0.0; design.len()];
        for i in 0..design.len() {
            offsets[i] = offset;
            // downstream CEs start after this CE's fill delay
            offset += crate::ce::fill_cycles(&design.network.layers[i], &design.cfgs[i]) as f64
                / clk;
        }

        // One batch takes `b · cycles_max` compute cycles; each streaming
        // layer cycles through its fragments `r` times in that span, so its
        // read window is `b·cycles_max / (r·clk)`. For a compute-bound CE
        // this equals Eq. 9's `(u_on+u_off)/(s_l·clk_comp)` exactly; for a
        // stream-bound CE it correctly dilates the window to the rate the
        // weights are actually consumed at.
        let cycles_max = design.cycles_of(design.slowest()) as f64;

        let entries = design
            .streaming_layers()
            .into_iter()
            .map(|i| {
                let frag = design.cfgs[i].frag;
                let m_wid = crate::ce::CeModel::new(
                    &design.network.layers[i],
                    design.cfgs[i],
                    design.clk_comp_mhz,
                )
                .m_wid_bits();
                let r = design.repeats(i, batch);
                // Eq. 8, capped by the buffer's write-port rate (the DMA bus
                // width in the clk_dma domain — the write side of the
                // dual-port buffer is wider than the read-side M_wid).
                let write_rate = bw.min(device.dma_port_bits as f64 * clk_dma);
                let t_wr = m_wid as f64 * frag.u_off as f64 / write_rate;
                // Eq. 9: the window, split pro-rata into its two phases.
                let t_rd = cycles_max * batch as f64 / (r as f64 * clk);
                let off_frac = frag.off_chip_ratio();
                BurstEntry {
                    layer: i,
                    t_wr,
                    t_rd,
                    t_rd_static: t_rd * (1.0 - off_frac),
                    t_rd_buffer: t_rd * off_frac,
                    r,
                    start_offset: offsets[i],
                }
            })
            .collect();

        BurstSchedule { entries, weight_bandwidth_bps: bw, batch }
    }

    /// Stall-free condition: within one read interval, the DMA must fit one
    /// write burst of *every* streaming layer (they share the port). With
    /// balanced bursts all `t_rd` are equal, so this is
    /// `Σ_l t_wr_l ≤ min_l t_rd_l`.
    pub fn schedulable(&self) -> bool {
        if self.entries.is_empty() {
            return true;
        }
        let total_wr: f64 = self.entries.iter().map(|e| e.t_wr).sum();
        let min_rd = self.entries.iter().map(|e| e.t_rd).fold(f64::INFINITY, f64::min);
        total_wr <= min_rd * 1.0001
    }

    /// DMA port utilization: busy fraction over one balanced window.
    pub fn dma_utilization(&self) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        let total_wr: f64 = self.entries.iter().map(|e| e.t_wr).sum();
        let min_rd = self.entries.iter().map(|e| e.t_rd).fold(f64::INFINITY, f64::min);
        total_wr / min_rd
    }

    /// Are the burst counts balanced (Eq. 10): all `r_l` equal?
    pub fn balanced(&self) -> bool {
        self.entries.windows(2).all(|w| w[0].r == w[1].r)
    }

    /// Hyperperiod structure of the burst train: `(g, n)` with
    /// `g = gcd_l r_l` and `n_l = r_l / g`.
    ///
    /// `from_design` fixes `t_rd_l = b·cycles_max / (r_l·clk)`, so the
    /// product `r_l · t_rd_l` is the same for every slot — slot rates are
    /// proportional to their repeat counts, every slot completes exactly
    /// `n_l` iterations per `Σ_l n_l`-event round in steady state, and the
    /// whole train finishes after `g` rounds. Balanced schedules (Eq. 10)
    /// degenerate to `n_l = 1` everywhere with `g = r`. Returns
    /// `(0, [])` for an empty (all-on-chip) schedule.
    pub fn hyperperiod(&self) -> (u64, Vec<u64>) {
        if self.entries.is_empty() {
            return (0, Vec::new());
        }
        let g = self.entries.iter().fold(0u64, |acc, e| gcd_u64(acc, e.r));
        (g, self.entries.iter().map(|e| e.r / g).collect())
    }
}

/// Greatest common divisor (Euclid; `gcd(0, x) = x`).
pub(crate) fn gcd_u64(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    fn streamed_design() -> (Design, Device) {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        (r.design, dev)
    }

    #[test]
    fn dse_output_is_balanced_and_schedulable() {
        let (d, dev) = streamed_design();
        let s = BurstSchedule::from_design(&d, &dev, 1);
        assert!(!s.entries.is_empty(), "zcu102/resnet18-W4A5 should stream some layers");
        assert!(s.balanced(), "write burst balancing must hold (Eq. 10)");
        assert!(s.schedulable(), "DSE designs must be stall-free");
        assert!(s.dma_utilization() <= 1.0001);
    }

    #[test]
    fn eq8_eq9_dimensional_sanity() {
        let (d, dev) = streamed_design();
        let s = BurstSchedule::from_design(&d, &dev, 1);
        for e in &s.entries {
            assert!(e.t_wr > 0.0 && e.t_wr < 1.0, "burst {} s", e.t_wr);
            assert!(e.t_rd > 0.0 && e.t_rd < 1.0);
            assert!((e.t_rd_static + e.t_rd_buffer - e.t_rd).abs() < 1e-12);
            assert!(e.r > 0);
        }
    }

    #[test]
    fn offsets_increase_along_pipeline() {
        let (d, dev) = streamed_design();
        let s = BurstSchedule::from_design(&d, &dev, 1);
        for w in s.entries.windows(2) {
            assert!(w[0].start_offset <= w[1].start_offset);
        }
    }

    #[test]
    fn hyperperiod_of_balanced_schedule_is_one_iteration_per_slot() {
        let (d, dev) = streamed_design();
        let s = BurstSchedule::from_design(&d, &dev, 4);
        let (g, n) = s.hyperperiod();
        assert_eq!(g, s.entries[0].r, "balanced: g = r");
        assert!(n.iter().all(|&x| x == 1), "balanced: one event per slot per round");
        // the invariant the fast-forward relies on: Σ n_l · g = Σ r_l
        let total: u64 = s.entries.iter().map(|e| e.r).sum();
        assert_eq!(g * n.iter().sum::<u64>(), total);
    }

    #[test]
    fn hyperperiod_of_unbalanced_counts() {
        let (d, dev) = streamed_design();
        let mut s = BurstSchedule::from_design(&d, &dev, 1);
        assert!(s.entries.len() >= 2);
        s.entries[0].r = 4;
        s.entries[1].r = 6;
        for e in &mut s.entries[2..] {
            e.r = 2;
        }
        let (g, n) = s.hyperperiod();
        assert_eq!(g, 2);
        assert_eq!(n[0], 2);
        assert_eq!(n[1], 3);
        assert!(n[2..].iter().all(|&x| x == 1));
    }

    #[test]
    fn gcd_basics() {
        assert_eq!(gcd_u64(0, 7), 7);
        assert_eq!(gcd_u64(7, 0), 7);
        assert_eq!(gcd_u64(12, 18), 6);
        assert_eq!(gcd_u64(1, 1_000_000), 1);
    }

    #[test]
    fn empty_schedule_for_all_onchip_design() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::u250();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let s = BurstSchedule::from_design(&r.design, &dev, 1);
        assert!(s.entries.is_empty());
        assert!(s.schedulable());
        assert_eq!(s.dma_utilization(), 0.0);
        assert_eq!(s.hyperperiod(), (0, Vec::new()));
    }
}
