//! Shared-DMA-port arbitration for co-located tenants.
//!
//! A co-located deployment plans every tenant against a bandwidth *slice*
//! of the one physical DMA port ([`crate::device::Device::with_share`]), so
//! each tenant's [`BurstSchedule`] — and with it the paper's Eq. 8–10
//! stall-freedom argument — holds *per tenant* against its slice. This
//! module composes those per-tenant schedules under the port-level cap:
//! the composition is feasible iff every tenant's schedule is feasible
//! against its slice AND the slices themselves (equivalently, the summed
//! weight+IO bandwidth demand) fit the physical port.
//!
//! That separation is deliberate: slice feasibility is the per-tenant
//! Eq. 8–10 proof unchanged, and the port-level sum is a one-line budget
//! check — exactly the "bandwidth as a budgeted resource" property that
//! makes co-location analyzable at all. The co-located simulator
//! ([`crate::sim::simulate_colocated`]) validates the same composition
//! event by event, interleaving the tenants' burst trains on one port.

use super::burst::BurstSchedule;
use crate::device::Device;
use crate::dse::Design;

/// One tenant's slice of the shared port: its burst schedule (derived
/// against its budget-clamped device view) plus its demand bookkeeping.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSlice {
    /// Tenant label (network name).
    pub name: String,
    /// Fraction of the port's bandwidth provisioned to this tenant.
    pub share: f64,
    /// The tenant's DMA schedule against its slice (Eq. 8–10 per tenant).
    pub schedule: BurstSchedule,
    /// The tenant design's total off-chip demand `β_io + Σ s_l·β_l`, bits/s.
    pub demand_bps: f64,
}

/// The composed DMA schedule of every tenant sharing one physical port.
#[derive(Debug, Clone, PartialEq)]
pub struct SharedDmaSchedule {
    /// One slice per tenant, in plan order.
    pub slices: Vec<TenantSlice>,
    /// The physical port's bandwidth (the unclamped device), bits/s.
    pub port_bandwidth_bps: f64,
    /// Batch size the repeat counts were computed for.
    pub batch: u64,
}

impl SharedDmaSchedule {
    /// Compose the port schedule from `(name, share, design, view)` tenants.
    /// `device` is the *physical* device; each `view` must be the
    /// budget-clamped variant the tenant's design was explored against, so
    /// its burst timing (Eq. 8) is derived from its provisioned slice.
    pub fn compose(
        tenants: &[(&str, f64, &Design, &Device)],
        device: &Device,
        batch: u64,
    ) -> SharedDmaSchedule {
        let slices = tenants
            .iter()
            .map(|&(name, share, design, view)| TenantSlice {
                name: name.to_string(),
                share,
                schedule: BurstSchedule::from_design(design, view, batch),
                demand_bps: design.total_bandwidth(),
            })
            .collect();
        SharedDmaSchedule {
            slices,
            port_bandwidth_bps: device.bandwidth_bps,
            batch,
        }
    }

    /// Busy fraction of the physical port: summed tenant demand over the
    /// port's bandwidth. ≤ 1 whenever the tenants' shares sum to ≤ 1 (each
    /// design's demand is capped by its slice).
    pub fn port_utilization(&self) -> f64 {
        if self.port_bandwidth_bps <= 0.0 {
            return 0.0;
        }
        self.slices.iter().map(|s| s.demand_bps).sum::<f64>() / self.port_bandwidth_bps
    }

    /// Summed provisioned shares (≤ 1 for a valid co-location).
    pub fn total_share(&self) -> f64 {
        self.slices.iter().map(|s| s.share).sum()
    }

    /// The composed feasibility argument: every tenant's schedule is
    /// stall-free against its slice (per-tenant Eq. 8–10) and the slices
    /// plus their demands fit the physical port.
    pub fn schedulable(&self) -> bool {
        self.slices.iter().all(|s| s.schedule.schedulable())
            && self.total_share() <= 1.0 + 1e-9
            && self.port_utilization() <= 1.0 + 1e-9
    }

    /// A tenant's slice by name.
    pub fn slice(&self, name: &str) -> Option<&TenantSlice> {
        self.slices.iter().find(|s| s.name == name)
    }

    /// Streaming burst entries across all tenants (reporting).
    pub fn total_entries(&self) -> usize {
        self.slices.iter().map(|s| s.schedule.entries.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{colocate, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn composed_port_respects_the_physical_cap() {
        let nets = [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let joint = colocate::colocate(&nets, &dev, &cfg).unwrap();
        let tenants: Vec<(&str, f64, &Design, &Device)> = joint
            .tenants
            .iter()
            .map(|t| (t.name.as_str(), t.share, &t.result.design, &t.view))
            .collect();
        let port = SharedDmaSchedule::compose(&tenants, &dev, 1);
        assert_eq!(port.slices.len(), 2);
        assert!(port.total_share() <= 1.0 + 1e-9, "{}", port.total_share());
        assert!(port.port_utilization() <= 1.0 + 1e-9, "{}", port.port_utilization());
        assert!(port.schedulable(), "composed schedule must stay feasible");
        assert!(port.slice("resnet18").is_some());
        assert!(port.slice("nope").is_none());
    }

    #[test]
    fn single_tenant_slice_is_the_plain_schedule() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let r = crate::dse::run(&net, &dev, &cfg).unwrap();
        let direct = BurstSchedule::from_design(&r.design, &dev, 1);
        let port =
            SharedDmaSchedule::compose(&[(net.name.as_str(), 1.0, &r.design, &dev)], &dev, 1);
        assert_eq!(port.slices[0].schedule, direct, "1-tenant schedule is bit-identical");
        assert_eq!(port.total_entries(), direct.entries.len());
    }

    #[test]
    fn an_oversubscribed_composition_reports_unschedulable() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = crate::dse::run(&net, &dev, &DseConfig::default()).unwrap();
        // two full-share copies of the same design cannot share one port
        let port = SharedDmaSchedule::compose(
            &[("a", 1.0, &r.design, &dev), ("b", 1.0, &r.design, &dev)],
            &dev,
            1,
        );
        assert!(port.total_share() > 1.0);
        assert!(!port.schedulable());
    }
}
