//! The pre-fast-forward event engines, preserved as the equivalence oracle
//! (mirroring [`crate::dse::reference`], PR 2's oracle for the incremental
//! DSE engine).
//!
//! These are the PR-8-era simulators verbatim: a `BinaryHeap<Request>` per
//! run, every fragment iteration of every slot event-stepped, no steady
//! state detection — O(batch · Σ r) always. Differences from the
//! preserved code are limited to the [`SimResult`] shape (the new
//! `events_processed`/`truncated` fields are filled honestly: the oracle
//! steps everything, so `events_processed == events`) and the trace-cap
//! accounting, which carries the PR 9 fix so trace prefixes stay
//! comparable across engines.
//!
//! `tests/sim_equivalence.rs` pins the fast engines to these across the
//! model zoo × device grid (bit-exact with `fast_forward: false`, ≤ 1e-9
//! relative once extrapolation engages), and `benches/sim_perf.rs` measures
//! the speedup against them for `BENCH_sim.json`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::colocated::{ColocatedSimResult, TenantSim};
use super::engine::{ideal_finish, SimConfig, SimResult};
use super::partitioned::{simulate_partitioned_with, PartitionedSimResult};
use super::trace::{TraceEvent, TraceKind};
use crate::device::Device;
use crate::dse::Design;
use crate::schedule::BurstSchedule;

#[derive(Debug, Clone, Copy, PartialEq)]
struct Request {
    time: f64,
    layer_slot: usize, // index into the schedule entries
    iteration: u64,
}

impl Eq for Request {}
impl Ord for Request {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by (time, layer): reversed for BinaryHeap
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.layer_slot.cmp(&self.layer_slot))
    }
}
impl PartialOrd for Request {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Pre-fast-forward single-device engine: every event through the heap.
pub fn simulate(design: &Design, device: &Device, cfg: &SimConfig) -> SimResult {
    let schedule = BurstSchedule::from_design(design, device, cfg.batch);
    let ideal_finish = ideal_finish(design, cfg.batch);

    let mut per_layer_stall = vec![0.0; design.len()];
    let mut per_layer_contention = vec![0.0; design.len()];
    let mut traces = Vec::new();

    if schedule.entries.is_empty() {
        return SimResult {
            makespan_s: ideal_finish,
            latency_ms: ideal_finish * 1e3,
            total_stall_s: 0.0,
            per_layer_stall_s: per_layer_stall,
            per_layer_contention_s: per_layer_contention,
            dma_busy_frac: 0.0,
            events: 0,
            events_processed: 0,
            truncated: false,
            traces,
        };
    }

    // Per streaming CE: cursor of its sequential read chain.
    let n_slots = schedule.entries.len();
    let mut prev_read_end: Vec<f64> = schedule.entries.iter().map(|e| e.start_offset).collect();
    let mut heap: BinaryHeap<Request> = BinaryHeap::with_capacity(n_slots * 2);
    for (slot, e) in schedule.entries.iter().enumerate() {
        // first write requested when the CE's window opens
        heap.push(Request { time: e.start_offset.max(0.0), layer_slot: slot, iteration: 0 });
    }

    let mut dma_free = 0.0_f64;
    let mut dma_busy = 0.0_f64;
    let mut events = 0_u64;
    let mut max_read_end = 0.0_f64;
    let mut truncated = false;

    while let Some(req) = heap.pop() {
        let e = &schedule.entries[req.layer_slot];
        // DMA burst (write side, clk_dma domain folded into t_wr)
        let w_start = req.time.max(dma_free);
        let w_end = w_start + e.t_wr;
        dma_free = w_end;
        dma_busy += e.t_wr;

        // CE read iteration (compute-clock domain). The buffer phase chases
        // the write pointer (fine-grained RAW): it cannot finish before the
        // write finishes, but overlaps it word-by-word.
        let s_start = prev_read_end[req.layer_slot];
        let s_end = s_start + e.t_rd_static;
        let unconstrained_end = s_end + e.t_rd_buffer;
        let r_end = unconstrained_end.max(w_end);
        let stall = r_end - unconstrained_end;
        let b_start = s_end;
        prev_read_end[req.layer_slot] = r_end;
        per_layer_stall[e.layer] += stall;
        // Attribution: had the port been free at request time the write
        // would have ended at `req.time + t_wr`; any stall beyond that point
        // is queueing behind other layers' bursts (contention), the rest is
        // the burst itself outrunning the read window (intrinsic RAW wait).
        if stall > 0.0 {
            let uncontended_end = req.time + e.t_wr;
            let intrinsic = (uncontended_end - unconstrained_end).max(0.0).min(stall);
            per_layer_contention[e.layer] += stall - intrinsic;
        }
        max_read_end = max_read_end.max(r_end);
        events += 1;

        if cfg.trace && !truncated {
            let needed = if stall > 0.0 { 4 } else { 3 };
            if traces.len() + needed <= cfg.max_trace_events {
                traces.push(TraceEvent { layer: e.layer, kind: TraceKind::WriteBurst, start: w_start, end: w_end });
                traces.push(TraceEvent { layer: e.layer, kind: TraceKind::ReadStatic, start: s_start, end: s_end });
                if stall > 0.0 {
                    traces.push(TraceEvent { layer: e.layer, kind: TraceKind::Stall, start: s_end, end: b_start });
                }
                traces.push(TraceEvent { layer: e.layer, kind: TraceKind::ReadBuffer, start: b_start, end: r_end });
            } else {
                truncated = true;
            }
        }

        if req.iteration + 1 < e.r {
            // buffer freed once its read phase completes
            heap.push(Request { time: r_end, layer_slot: req.layer_slot, iteration: req.iteration + 1 });
        }
    }

    let makespan = ideal_finish.max(max_read_end);
    let total_stall: f64 = per_layer_stall.iter().sum();
    SimResult {
        makespan_s: makespan,
        latency_ms: makespan * 1e3,
        total_stall_s: total_stall,
        per_layer_stall_s: per_layer_stall,
        per_layer_contention_s: per_layer_contention,
        dma_busy_frac: if makespan > 0.0 { dma_busy / makespan } else { 0.0 },
        events,
        events_processed: events,
        truncated,
        traces,
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct JointRequest {
    time: f64,
    tenant: usize,
    slot: usize,
    iteration: u64,
}

impl Eq for JointRequest {}
impl Ord for JointRequest {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by (time, tenant, slot): reversed for BinaryHeap
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.tenant.cmp(&self.tenant))
            .then(other.slot.cmp(&self.slot))
    }
}
impl PartialOrd for JointRequest {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Pre-fast-forward co-located engine: the joint heap over every tenant's
/// burst train (see [`super::simulate_colocated`] for the port model).
pub fn simulate_colocated(
    tenants: &[(&str, &Design, &Device)],
    device: &Device,
    cfg: &SimConfig,
) -> ColocatedSimResult {
    assert!(!tenants.is_empty(), "simulate_colocated needs at least one tenant");

    // 1-tenant: the single-device event simulation, verbatim.
    if tenants.len() == 1 {
        let (name, design, view) = tenants[0];
        let r = simulate(design, view, cfg);
        return ColocatedSimResult {
            makespan_s: r.makespan_s,
            latency_ms: r.latency_ms,
            per_tenant: vec![TenantSim {
                name: name.to_string(),
                makespan_s: r.makespan_s,
                latency_ms: r.latency_ms,
                total_stall_s: r.total_stall_s,
                contention_s: r.per_layer_contention_s.iter().sum(),
                events: r.events,
            }],
            port_busy_frac: r.dma_busy_frac,
            total_stall_s: r.total_stall_s,
            events: r.events,
            events_processed: r.events_processed,
            truncated: r.truncated,
        };
    }

    let n = tenants.len();
    let schedules = super::colocated::port_view_schedules(tenants, device, cfg);
    let ideal: Vec<f64> =
        tenants.iter().map(|&(_, design, _)| ideal_finish(design, cfg.batch)).collect();

    // Per (tenant, slot): cursor of that CE's sequential read chain.
    let mut prev_read_end: Vec<Vec<f64>> = schedules
        .iter()
        .map(|s| s.entries.iter().map(|e| e.start_offset).collect())
        .collect();
    let mut heap: BinaryHeap<JointRequest> = BinaryHeap::new();
    for (t, s) in schedules.iter().enumerate() {
        for (slot, e) in s.entries.iter().enumerate() {
            heap.push(JointRequest {
                time: e.start_offset.max(0.0),
                tenant: t,
                slot,
                iteration: 0,
            });
        }
    }

    let mut dma_free = 0.0_f64;
    let mut dma_busy = 0.0_f64;
    let mut stall_per_tenant = vec![0.0_f64; n];
    let mut contention_per_tenant = vec![0.0_f64; n];
    let mut events_per_tenant = vec![0_u64; n];
    let mut max_read_end = vec![0.0_f64; n];

    while let Some(req) = heap.pop() {
        let e = &schedules[req.tenant].entries[req.slot];
        // the shared physical port serves one burst at a time, across ALL
        // tenants, FIFO in request-arrival order
        let w_start = req.time.max(dma_free);
        let w_end = w_start + e.t_wr;
        dma_free = w_end;
        dma_busy += e.t_wr;

        let s_start = prev_read_end[req.tenant][req.slot];
        let s_end = s_start + e.t_rd_static;
        let unconstrained_end = s_end + e.t_rd_buffer;
        let r_end = unconstrained_end.max(w_end);
        let stall = r_end - unconstrained_end;
        prev_read_end[req.tenant][req.slot] = r_end;
        stall_per_tenant[req.tenant] += stall;
        if stall > 0.0 {
            let uncontended_end = req.time + e.t_wr;
            let intrinsic = (uncontended_end - unconstrained_end).max(0.0).min(stall);
            contention_per_tenant[req.tenant] += stall - intrinsic;
        }
        max_read_end[req.tenant] = max_read_end[req.tenant].max(r_end);
        events_per_tenant[req.tenant] += 1;

        if req.iteration + 1 < e.r {
            heap.push(JointRequest {
                time: r_end,
                tenant: req.tenant,
                slot: req.slot,
                iteration: req.iteration + 1,
            });
        }
    }

    let per_tenant: Vec<TenantSim> = (0..n)
        .map(|t| {
            let makespan = ideal[t].max(max_read_end[t]);
            TenantSim {
                name: tenants[t].0.to_string(),
                makespan_s: makespan,
                latency_ms: makespan * 1e3,
                total_stall_s: stall_per_tenant[t],
                contention_s: contention_per_tenant[t],
                events: events_per_tenant[t],
            }
        })
        .collect();

    let makespan = per_tenant.iter().map(|t| t.makespan_s).fold(0.0_f64, f64::max);
    let events: u64 = events_per_tenant.iter().sum();
    ColocatedSimResult {
        makespan_s: makespan,
        latency_ms: makespan * 1e3,
        port_busy_frac: if makespan > 0.0 { dma_busy / makespan } else { 0.0 },
        total_stall_s: stall_per_tenant.iter().sum(),
        events,
        events_processed: events,
        truncated: false,
        per_tenant,
    }
}

/// Pre-fast-forward partitioned simulation: the shared chain/link
/// composition over this module's per-partition engine.
pub fn simulate_partitioned(
    stages: &[(&Design, &Device)],
    cfg: &SimConfig,
) -> PartitionedSimResult {
    simulate_partitioned_with(stages, cfg, simulate)
}
