//! Simulation of a co-located deployment: the tenants' burst trains
//! interleave on ONE shared DDR/DMA port.
//!
//! The model is **time-division** of the physical port. The planning-side
//! bandwidth slices ([`crate::device::Device::with_share`]) bound each
//! tenant's *demand* (its Eq. 8–10 argument holds against its slice), but
//! the physical port is not N slow ports: a burst on the bus moves at the
//! full rate left after every tenant's IO streams (`B − Σ β_io`, capped by
//! the buffer write port), and sharing manifests as **queueing** — the
//! port serves one burst at a time across *all* tenants, FIFO in
//! request-arrival order, the same arbitration the single-device engine
//! uses between layers, lifted to tenants. (Stretching burst durations to
//! the slice rate AND serializing them exclusively would count the split
//! twice and report phantom stalls for plans the composition argument
//! declares feasible.) Stall is attributed per tenant exactly like
//! intra-device DMA contention: the part of a read-stall that queueing
//! (behind any burst, own or foreign) caused is contention; the remainder
//! is the tenant's own intrinsic Read-After-Write wait.
//!
//! The 1-tenant case returns the single-device event simulation verbatim
//! (bit-identical; enforced by `tests/colocated_deploy.rs`), mirroring the
//! 1-partition shortcut of [`super::simulate_partitioned`] — with one
//! tenant there are no foreign IO streams, so the two models coincide.
//!
//! **Fast-forward**: the joint loop runs on the same indexed
//! [`SlotQueue`] over flattened `(tenant, slot)` ids and the same
//! round-boundary steady-state detector as the single-device engine, with
//! the hyperperiod taken over *every* tenant's repeat counts. The joint
//! orbit only locks when the tenants' trains are commensurate (equal
//! per-round time advance — e.g. replicas of one plan); heterogeneous
//! tenants simply never detect and take the full event loop, which the
//! allocation-free queue still speeds up. `sim::reference` keeps the heap
//! version as the oracle.

use super::engine::{ideal_finish, simulate, SimConfig};
use super::queue::SlotQueue;
use super::steady::Detector;
use crate::device::Device;
use crate::dse::Design;
use crate::schedule::{gcd_u64, BurstSchedule};

/// Steady-state figures of one tenant in the joint simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSim {
    /// Tenant label (network name).
    pub name: String,
    /// Wall-clock of the tenant's batch through its pipeline, seconds.
    pub makespan_s: f64,
    /// Tenant latency in ms (makespan, mirroring `SimResult::latency_ms`).
    pub latency_ms: f64,
    /// Total stall across the tenant's streaming CEs, seconds.
    pub total_stall_s: f64,
    /// Of the stall, the part attributable to the shared port being held by
    /// another burst when the write was requested (port contention); the
    /// remainder is intrinsic Read-After-Write wait.
    pub contention_s: f64,
    /// Fragment-iteration events of this tenant.
    pub events: u64,
}

/// Outcome of a co-located simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct ColocatedSimResult {
    /// Wall-clock until every tenant's batch finished, seconds.
    pub makespan_s: f64,
    /// Joint latency in ms (makespan).
    pub latency_ms: f64,
    /// Per-tenant figures, in plan order.
    pub per_tenant: Vec<TenantSim>,
    /// Busy fraction of the shared physical port over the joint makespan.
    pub port_busy_frac: f64,
    /// Summed stall across tenants, seconds.
    pub total_stall_s: f64,
    /// Summed events across tenants (semantic count, `Σ r` over all slots).
    pub events: u64,
    /// Events the joint loop actually stepped; below `events` when the
    /// steady-state fast-forward extrapolated the periodic tail.
    pub events_processed: u64,
    /// A trace run hit `max_trace_events` and dropped later events. Only the
    /// 1-tenant path can trace; the joint loop never does, so it reports
    /// `false` honestly.
    pub truncated: bool,
}

/// Per-tenant burst schedules against the physical port's residual rate —
/// the time-division timing model (see the module docs). Shared with
/// [`super::reference::simulate_colocated`] so both engines interleave
/// identical trains.
pub(crate) fn port_view_schedules(
    tenants: &[(&str, &Design, &Device)],
    device: &Device,
    cfg: &SimConfig,
) -> Vec<BurstSchedule> {
    // `from_design` subtracts the design's own β_io from the device it is
    // given, so handing it a view whose bandwidth is `B_phys − Σ β_io
    // (others)` makes its Eq. 8 rate exactly `B_phys − Σ β_io(all)`
    // (floored at 1 bps inside `from_design`); read windows and offsets are
    // bandwidth-free.
    let total_io: f64 = tenants.iter().map(|&(_, design, _)| design.io_bandwidth()).sum();
    tenants
        .iter()
        .map(|&(_, design, view)| {
            let mut port_view = view.clone();
            port_view.bandwidth_bps = device.bandwidth_bps - (total_io - design.io_bandwidth());
            BurstSchedule::from_design(design, &port_view, cfg.batch)
        })
        .collect()
}

/// Simulate `(name, design, view)` tenants sharing one physical DMA port
/// of `device` (the unclamped shared device). Each `view` must be the
/// budget-clamped device the tenant's design was explored against; burst
/// *timing* in the joint sim derives from the physical port's residual
/// rate (time-division — see the module docs), while the views supply the
/// per-tenant clock/port parameters.
pub fn simulate_colocated(
    tenants: &[(&str, &Design, &Device)],
    device: &Device,
    cfg: &SimConfig,
) -> ColocatedSimResult {
    assert!(!tenants.is_empty(), "simulate_colocated needs at least one tenant");

    // 1-tenant: the single-device event simulation, verbatim.
    if tenants.len() == 1 {
        let (name, design, view) = tenants[0];
        let r = simulate(design, view, cfg);
        return ColocatedSimResult {
            makespan_s: r.makespan_s,
            latency_ms: r.latency_ms,
            per_tenant: vec![TenantSim {
                name: name.to_string(),
                makespan_s: r.makespan_s,
                latency_ms: r.latency_ms,
                total_stall_s: r.total_stall_s,
                contention_s: r.per_layer_contention_s.iter().sum(),
                events: r.events,
            }],
            port_busy_frac: r.dma_busy_frac,
            total_stall_s: r.total_stall_s,
            events: r.events,
            events_processed: r.events_processed,
            truncated: r.truncated,
        };
    }

    let n = tenants.len();
    let schedules = port_view_schedules(tenants, device, cfg);

    // Ideal (stall-free) per-tenant pipeline time: fill + batch drains of
    // the tenant's bottleneck CE — the engine's own definition.
    let ideal: Vec<f64> =
        tenants.iter().map(|&(_, design, _)| ideal_finish(design, cfg.batch)).collect();

    // Flatten to global slot ids in (tenant, slot) lexicographic order —
    // the same order the reference heap breaks ties in, so both engines
    // pop events identically.
    struct FlatSlot {
        tenant: usize,
        t_wr: f64,
        t_rd_static: f64,
        t_rd_buffer: f64,
        r: u64,
        start_offset: f64,
    }
    let slots: Vec<FlatSlot> = schedules
        .iter()
        .enumerate()
        .flat_map(|(t, s)| {
            s.entries.iter().map(move |e| FlatSlot {
                tenant: t,
                t_wr: e.t_wr,
                t_rd_static: e.t_rd_static,
                t_rd_buffer: e.t_rd_buffer,
                r: e.r,
                start_offset: e.start_offset,
            })
        })
        .collect();
    let n_slots = slots.len();
    let total_events: u64 = slots.iter().map(|s| s.r).sum();

    let mut prev_read_end: Vec<f64> = slots.iter().map(|s| s.start_offset).collect();
    let mut iters = vec![0u64; n_slots];
    let mut queue = SlotQueue::with_slots(n_slots);
    for (id, s) in slots.iter().enumerate() {
        queue.push(id, s.start_offset.max(0.0));
    }

    let mut dma_free = 0.0_f64;
    let mut dma_busy = 0.0_f64;
    let mut stall_per_tenant = vec![0.0_f64; n];
    let mut contention_per_tenant = vec![0.0_f64; n];
    let mut events_per_tenant = vec![0_u64; n];
    let mut max_read_end = vec![0.0_f64; n];
    let mut processed = 0_u64;
    let mut skipped = 0_u64;

    // Joint hyperperiod: gcd over EVERY tenant's repeat counts. Only a
    // commensurate joint orbit can match (uniform dt across all cursors);
    // otherwise the detector never fires and the loop runs to completion.
    let g = slots.iter().fold(0u64, |acc, s| gcd_u64(acc, s.r));
    let n_per_round: Vec<u64> = slots.iter().map(|s| s.r / g.max(1)).collect();
    let round_events: u64 = n_per_round.iter().sum();
    let mut detector =
        if cfg.fast_forward && !cfg.trace && g >= 4 { Some(Detector::new()) } else { None };

    while let Some((id, time)) = queue.pop() {
        let e = &slots[id];
        // the shared physical port serves one burst at a time, across ALL
        // tenants, FIFO in request-arrival order
        let w_start = time.max(dma_free);
        let w_end = w_start + e.t_wr;
        dma_free = w_end;
        dma_busy += e.t_wr;

        let s_start = prev_read_end[id];
        let s_end = s_start + e.t_rd_static;
        let unconstrained_end = s_end + e.t_rd_buffer;
        let r_end = unconstrained_end.max(w_end);
        let stall = r_end - unconstrained_end;
        prev_read_end[id] = r_end;
        stall_per_tenant[e.tenant] += stall;
        // Attribution mirrors the single-device engine: had the port been
        // free at request time the write would have ended at
        // `time + t_wr`; stall beyond that is queueing on the shared
        // port (contention — own layers or other tenants), the rest is
        // intrinsic RAW wait.
        if stall > 0.0 {
            let uncontended_end = time + e.t_wr;
            let intrinsic = (uncontended_end - unconstrained_end).max(0.0).min(stall);
            contention_per_tenant[e.tenant] += stall - intrinsic;
        }
        max_read_end[e.tenant] = max_read_end[e.tenant].max(r_end);
        events_per_tenant[e.tenant] += 1;
        processed += 1;
        iters[id] += 1;

        if iters[id] < e.r {
            queue.push(id, r_end);
        }

        if detector.is_some() && processed % round_events == 0 {
            let delta = detector.as_mut().unwrap().observe(
                &iters,
                &prev_read_end,
                dma_free,
                dma_busy,
                &stall_per_tenant,
                &contention_per_tenant,
                &n_per_round,
            );
            if let Some(delta) = delta {
                let rounds_left = slots
                    .iter()
                    .enumerate()
                    .map(|(id, s)| (s.r - iters[id]) / n_per_round[id])
                    .min()
                    .unwrap_or(0);
                if rounds_left > 0 {
                    let rf = rounds_left as f64;
                    let shift = delta.dt * rf;
                    dma_free += shift;
                    dma_busy += delta.dma_busy * rf;
                    for t in 0..n {
                        stall_per_tenant[t] += delta.stall[t] * rf;
                        contention_per_tenant[t] += delta.contention[t] * rf;
                    }
                    queue.clear();
                    for (id, s) in slots.iter().enumerate() {
                        prev_read_end[id] += shift;
                        iters[id] += n_per_round[id] * rounds_left;
                        events_per_tenant[s.tenant] += n_per_round[id] * rounds_left;
                        max_read_end[s.tenant] = max_read_end[s.tenant].max(prev_read_end[id]);
                        if iters[id] < s.r {
                            queue.push(id, prev_read_end[id]);
                        }
                    }
                    skipped += round_events * rounds_left;
                }
                detector = None;
            }
        }
    }

    debug_assert_eq!(processed + skipped, total_events, "every scheduled event accounted for");

    let per_tenant: Vec<TenantSim> = (0..n)
        .map(|t| {
            let makespan = ideal[t].max(max_read_end[t]);
            TenantSim {
                name: tenants[t].0.to_string(),
                makespan_s: makespan,
                latency_ms: makespan * 1e3,
                total_stall_s: stall_per_tenant[t],
                contention_s: contention_per_tenant[t],
                events: events_per_tenant[t],
            }
        })
        .collect();

    let makespan = per_tenant.iter().map(|t| t.makespan_s).fold(0.0_f64, f64::max);
    ColocatedSimResult {
        makespan_s: makespan,
        latency_ms: makespan * 1e3,
        port_busy_frac: if makespan > 0.0 { dma_busy / makespan } else { 0.0 },
        total_stall_s: stall_per_tenant.iter().sum(),
        events: processed + skipped,
        events_processed: processed,
        truncated: false,
        per_tenant,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, colocate, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn one_tenant_is_bit_identical_to_simulate() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let cfg = SimConfig::default();
        let direct = simulate(&r.design, &dev, &cfg);
        let joint = simulate_colocated(&[("resnet18", &r.design, &dev)], &dev, &cfg);
        assert_eq!(joint.makespan_s, direct.makespan_s);
        assert_eq!(joint.latency_ms, direct.latency_ms);
        assert_eq!(joint.total_stall_s, direct.total_stall_s);
        assert_eq!(joint.port_busy_frac, direct.dma_busy_frac);
        assert_eq!(joint.events, direct.events);
        assert_eq!(joint.events_processed, direct.events_processed);
        assert_eq!(joint.per_tenant.len(), 1);
    }

    #[test]
    fn two_tenants_share_the_port_within_budget() {
        let nets = [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let joint = colocate::colocate(&nets, &dev, &cfg).unwrap();
        let stages: Vec<(&str, &Design, &Device)> = joint
            .tenants
            .iter()
            .map(|t| (t.name.as_str(), &t.result.design, &t.view))
            .collect();
        let sim = simulate_colocated(&stages, &dev, &SimConfig { batch: 4, ..Default::default() });
        assert_eq!(sim.per_tenant.len(), 2);
        assert!(sim.makespan_s > 0.0);
        // the shared port can never be more than fully busy
        assert!((0.0..=1.0 + 1e-9).contains(&sim.port_busy_frac), "{}", sim.port_busy_frac);
        // the provisioned slices keep cross-tenant interference bounded:
        // each tenant's stall stays a small fraction of its makespan
        for t in &sim.per_tenant {
            assert!(t.makespan_s > 0.0, "{}", t.name);
            assert!(
                t.total_stall_s <= 0.5 * t.makespan_s,
                "{}: stall {} vs makespan {}",
                t.name,
                t.total_stall_s,
                t.makespan_s
            );
            assert!(t.contention_s <= t.total_stall_s + 1e-12);
        }
    }

    #[test]
    fn oversubscribed_port_attributes_contention() {
        // Two copies of a streaming design, each planned for the FULL port:
        // interleaving their burst trains must oversubscribe the port and
        // show up as cross-tenant contention stall.
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let batch = 4u64;
        let cfg = SimConfig { batch, ..Default::default() };
        let solo = simulate(&r.design, &dev, &cfg);
        let joint = simulate_colocated(
            &[("a", &r.design, &dev), ("b", &r.design, &dev)],
            &dev,
            &cfg,
        );
        let joint_stall: f64 = joint.per_tenant.iter().map(|t| t.total_stall_s).sum();
        assert!(
            joint_stall > 2.0 * solo.total_stall_s,
            "doubled full-rate trains must stall more: joint {} vs 2x solo {}",
            joint_stall,
            2.0 * solo.total_stall_s
        );
        let contention: f64 = joint.per_tenant.iter().map(|t| t.contention_s).sum();
        assert!(contention > 0.0, "the extra stall is port contention");
    }

    #[test]
    fn joint_fast_forward_matches_the_reference_heap() {
        // identical replicas: the joint trains are commensurate, so the
        // steady-state detector can engage on the shared port too
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let cfg = SimConfig { batch: 8, ..Default::default() };
        let tenants = [("a", &r.design, &dev), ("b", &r.design, &dev)];
        let fast = simulate_colocated(&tenants, &dev, &cfg);
        let oracle = crate::sim::reference::simulate_colocated(&tenants, &dev, &cfg);
        assert_eq!(fast.events, oracle.events, "semantic event count is engine-independent");
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1e-300);
        assert!(close(fast.makespan_s, oracle.makespan_s));
        assert!(
            close(fast.total_stall_s, oracle.total_stall_s)
                || (fast.total_stall_s - oracle.total_stall_s).abs() < 1e-12 * oracle.makespan_s
        );
        assert!(close(fast.port_busy_frac, oracle.port_busy_frac));
        for (f, o) in fast.per_tenant.iter().zip(&oracle.per_tenant) {
            assert!(close(f.makespan_s, o.makespan_s), "{}: {} vs {}", f.name, f.makespan_s, o.makespan_s);
            assert_eq!(f.events, o.events);
        }
        // with fast-forward off the joint loop is bit-identical to the heap
        let off = SimConfig { fast_forward: false, ..cfg };
        let full = simulate_colocated(&tenants, &dev, &off);
        let oracle_off = crate::sim::reference::simulate_colocated(&tenants, &dev, &off);
        assert_eq!(full, oracle_off);
    }
}
