//! Simulation of a co-located deployment: the tenants' burst trains
//! interleave on ONE shared DDR/DMA port.
//!
//! The model is **time-division** of the physical port. The planning-side
//! bandwidth slices ([`crate::device::Device::with_share`]) bound each
//! tenant's *demand* (its Eq. 8–10 argument holds against its slice), but
//! the physical port is not N slow ports: a burst on the bus moves at the
//! full rate left after every tenant's IO streams (`B − Σ β_io`, capped by
//! the buffer write port), and sharing manifests as **queueing** — the
//! port serves one burst at a time across *all* tenants, FIFO in
//! request-arrival order, the same arbitration the single-device engine
//! uses between layers, lifted to tenants. (Stretching burst durations to
//! the slice rate AND serializing them exclusively would count the split
//! twice and report phantom stalls for plans the composition argument
//! declares feasible.) Stall is attributed per tenant exactly like
//! intra-device DMA contention: the part of a read-stall that queueing
//! (behind any burst, own or foreign) caused is contention; the remainder
//! is the tenant's own intrinsic Read-After-Write wait.
//!
//! The 1-tenant case returns the single-device event simulation verbatim
//! (bit-identical; enforced by `tests/colocated_deploy.rs`), mirroring the
//! 1-partition shortcut of [`super::simulate_partitioned`] — with one
//! tenant there are no foreign IO streams, so the two models coincide.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::engine::{ideal_finish, simulate, SimConfig};
use crate::device::Device;
use crate::dse::Design;
use crate::schedule::BurstSchedule;

/// Steady-state figures of one tenant in the joint simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSim {
    /// Tenant label (network name).
    pub name: String,
    /// Wall-clock of the tenant's batch through its pipeline, seconds.
    pub makespan_s: f64,
    /// Tenant latency in ms (makespan, mirroring `SimResult::latency_ms`).
    pub latency_ms: f64,
    /// Total stall across the tenant's streaming CEs, seconds.
    pub total_stall_s: f64,
    /// Of the stall, the part attributable to the shared port being held by
    /// another burst when the write was requested (port contention); the
    /// remainder is intrinsic Read-After-Write wait.
    pub contention_s: f64,
    /// Fragment-iteration events of this tenant.
    pub events: u64,
}

/// Outcome of a co-located simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct ColocatedSimResult {
    /// Wall-clock until every tenant's batch finished, seconds.
    pub makespan_s: f64,
    /// Joint latency in ms (makespan).
    pub latency_ms: f64,
    /// Per-tenant figures, in plan order.
    pub per_tenant: Vec<TenantSim>,
    /// Busy fraction of the shared physical port over the joint makespan.
    pub port_busy_frac: f64,
    /// Summed stall across tenants, seconds.
    pub total_stall_s: f64,
    /// Summed events across tenants.
    pub events: u64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct Request {
    time: f64,
    tenant: usize,
    slot: usize,
    iteration: u64,
}

impl Eq for Request {}
impl Ord for Request {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by (time, tenant, slot): reversed for BinaryHeap
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.tenant.cmp(&self.tenant))
            .then(other.slot.cmp(&self.slot))
    }
}
impl PartialOrd for Request {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulate `(name, design, view)` tenants sharing one physical DMA port
/// of `device` (the unclamped shared device). Each `view` must be the
/// budget-clamped device the tenant's design was explored against; burst
/// *timing* in the joint sim derives from the physical port's residual
/// rate (time-division — see the module docs), while the views supply the
/// per-tenant clock/port parameters.
pub fn simulate_colocated(
    tenants: &[(&str, &Design, &Device)],
    device: &Device,
    cfg: &SimConfig,
) -> ColocatedSimResult {
    assert!(!tenants.is_empty(), "simulate_colocated needs at least one tenant");

    // 1-tenant: the single-device event simulation, verbatim.
    if tenants.len() == 1 {
        let (name, design, view) = tenants[0];
        let r = simulate(design, view, cfg);
        return ColocatedSimResult {
            makespan_s: r.makespan_s,
            latency_ms: r.latency_ms,
            per_tenant: vec![TenantSim {
                name: name.to_string(),
                makespan_s: r.makespan_s,
                latency_ms: r.latency_ms,
                total_stall_s: r.total_stall_s,
                contention_s: r.per_layer_contention_s.iter().sum(),
                events: r.events,
            }],
            port_busy_frac: r.dma_busy_frac,
            total_stall_s: r.total_stall_s,
            events: r.events,
        };
    }

    let n = tenants.len();
    // Time-division burst timing: a burst on the physical bus advances at
    // the rate left after EVERY tenant's IO streams. `from_design`
    // subtracts the design's own β_io from the device it is given, so
    // handing it a view whose bandwidth is `B_phys − Σ β_io(others)` makes
    // its Eq. 8 rate exactly `B_phys − Σ β_io(all)` (floored at 1 bps
    // inside `from_design`); read windows and offsets are bandwidth-free.
    let total_io: f64 = tenants.iter().map(|&(_, design, _)| design.io_bandwidth()).sum();
    let schedules: Vec<BurstSchedule> = tenants
        .iter()
        .map(|&(_, design, view)| {
            let mut port_view = view.clone();
            port_view.bandwidth_bps =
                device.bandwidth_bps - (total_io - design.io_bandwidth());
            BurstSchedule::from_design(design, &port_view, cfg.batch)
        })
        .collect();

    // Ideal (stall-free) per-tenant pipeline time: fill + batch drains of
    // the tenant's bottleneck CE — the engine's own definition.
    let ideal: Vec<f64> =
        tenants.iter().map(|&(_, design, _)| ideal_finish(design, cfg.batch)).collect();

    // Per (tenant, slot): cursor of that CE's sequential read chain.
    let mut prev_read_end: Vec<Vec<f64>> = schedules
        .iter()
        .map(|s| s.entries.iter().map(|e| e.start_offset).collect())
        .collect();
    let mut heap: BinaryHeap<Request> = BinaryHeap::new();
    for (t, s) in schedules.iter().enumerate() {
        for (slot, e) in s.entries.iter().enumerate() {
            heap.push(Request { time: e.start_offset.max(0.0), tenant: t, slot, iteration: 0 });
        }
    }

    let mut dma_free = 0.0_f64;
    let mut dma_busy = 0.0_f64;
    let mut stall_per_tenant = vec![0.0_f64; n];
    let mut contention_per_tenant = vec![0.0_f64; n];
    let mut events_per_tenant = vec![0_u64; n];
    let mut max_read_end = vec![0.0_f64; n];

    while let Some(req) = heap.pop() {
        let e = &schedules[req.tenant].entries[req.slot];
        // the shared physical port serves one burst at a time, across ALL
        // tenants, FIFO in request-arrival order
        let w_start = req.time.max(dma_free);
        let w_end = w_start + e.t_wr;
        dma_free = w_end;
        dma_busy += e.t_wr;

        let s_start = prev_read_end[req.tenant][req.slot];
        let s_end = s_start + e.t_rd_static;
        let unconstrained_end = s_end + e.t_rd_buffer;
        let r_end = unconstrained_end.max(w_end);
        let stall = r_end - unconstrained_end;
        prev_read_end[req.tenant][req.slot] = r_end;
        stall_per_tenant[req.tenant] += stall;
        // Attribution mirrors the single-device engine: had the port been
        // free at request time the write would have ended at
        // `req.time + t_wr`; stall beyond that is queueing on the shared
        // port (contention — own layers or other tenants), the rest is
        // intrinsic RAW wait.
        if stall > 0.0 {
            let uncontended_end = req.time + e.t_wr;
            let intrinsic = (uncontended_end - unconstrained_end).max(0.0).min(stall);
            contention_per_tenant[req.tenant] += stall - intrinsic;
        }
        max_read_end[req.tenant] = max_read_end[req.tenant].max(r_end);
        events_per_tenant[req.tenant] += 1;

        if req.iteration + 1 < e.r {
            heap.push(Request {
                time: r_end,
                tenant: req.tenant,
                slot: req.slot,
                iteration: req.iteration + 1,
            });
        }
    }

    let per_tenant: Vec<TenantSim> = (0..n)
        .map(|t| {
            let makespan = ideal[t].max(max_read_end[t]);
            TenantSim {
                name: tenants[t].0.to_string(),
                makespan_s: makespan,
                latency_ms: makespan * 1e3,
                total_stall_s: stall_per_tenant[t],
                contention_s: contention_per_tenant[t],
                events: events_per_tenant[t],
            }
        })
        .collect();

    let makespan = per_tenant.iter().map(|t| t.makespan_s).fold(0.0_f64, f64::max);
    ColocatedSimResult {
        makespan_s: makespan,
        latency_ms: makespan * 1e3,
        port_busy_frac: if makespan > 0.0 { dma_busy / makespan } else { 0.0 },
        total_stall_s: stall_per_tenant.iter().sum(),
        events: events_per_tenant.iter().sum(),
        per_tenant,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, colocate, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn one_tenant_is_bit_identical_to_simulate() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let cfg = SimConfig::default();
        let direct = simulate(&r.design, &dev, &cfg);
        let joint = simulate_colocated(&[("resnet18", &r.design, &dev)], &dev, &cfg);
        assert_eq!(joint.makespan_s, direct.makespan_s);
        assert_eq!(joint.latency_ms, direct.latency_ms);
        assert_eq!(joint.total_stall_s, direct.total_stall_s);
        assert_eq!(joint.port_busy_frac, direct.dma_busy_frac);
        assert_eq!(joint.events, direct.events);
        assert_eq!(joint.per_tenant.len(), 1);
    }

    #[test]
    fn two_tenants_share_the_port_within_budget() {
        let nets = [models::resnet18(Quant::W4A5), models::squeezenet(Quant::W8A8)];
        let dev = Device::zcu102();
        let cfg = DseConfig::default();
        let joint = colocate::colocate(&nets, &dev, &cfg).unwrap();
        let stages: Vec<(&str, &Design, &Device)> = joint
            .tenants
            .iter()
            .map(|t| (t.name.as_str(), &t.result.design, &t.view))
            .collect();
        let sim = simulate_colocated(&stages, &dev, &SimConfig { batch: 4, ..Default::default() });
        assert_eq!(sim.per_tenant.len(), 2);
        assert!(sim.makespan_s > 0.0);
        // the shared port can never be more than fully busy
        assert!((0.0..=1.0 + 1e-9).contains(&sim.port_busy_frac), "{}", sim.port_busy_frac);
        // the provisioned slices keep cross-tenant interference bounded:
        // each tenant's stall stays a small fraction of its makespan
        for t in &sim.per_tenant {
            assert!(t.makespan_s > 0.0, "{}", t.name);
            assert!(
                t.total_stall_s <= 0.5 * t.makespan_s,
                "{}: stall {} vs makespan {}",
                t.name,
                t.total_stall_s,
                t.makespan_s
            );
            assert!(t.contention_s <= t.total_stall_s + 1e-12);
        }
    }

    #[test]
    fn oversubscribed_port_attributes_contention() {
        // Two copies of a streaming design, each planned for the FULL port:
        // interleaving their burst trains must oversubscribe the port and
        // show up as cross-tenant contention stall.
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let batch = 4u64;
        let cfg = SimConfig { batch, ..Default::default() };
        let solo = simulate(&r.design, &dev, &cfg);
        let joint = simulate_colocated(
            &[("a", &r.design, &dev), ("b", &r.design, &dev)],
            &dev,
            &cfg,
        );
        let joint_stall: f64 = joint.per_tenant.iter().map(|t| t.total_stall_s).sum();
        assert!(
            joint_stall > 2.0 * solo.total_stall_s,
            "doubled full-rate trains must stall more: joint {} vs 2x solo {}",
            joint_stall,
            2.0 * solo.total_stall_s
        );
        let contention: f64 = joint.per_tenant.iter().map(|t| t.contention_s).sum();
        assert!(contention > 0.0, "the extra stall is port contention");
    }
}
