//! Steady-state detection for the fast-forward engines.
//!
//! A static burst schedule makes the event stream eventually periodic: the
//! dynamics are only `+` and `max` over fixed per-slot increments, so once
//! the warm-up transient (pipeline fill offsets, initial port queueing)
//! dies out, the whole system state advances by one uniform time delta per
//! hyperperiod round — the same events in the same order, translated in
//! time. The detector samples the state vector at round boundaries (every
//! `Σ_l n_l` processed events, see
//! [`crate::schedule::BurstSchedule::hyperperiod`]) and declares steady
//! state when three consecutive snapshots show two identical windows:
//! exact per-slot event counts (`n_l` each), a uniform time advance on
//! every read cursor and on the DMA port clock, and repeating per-window
//! increments of the stall/contention/busy accumulators.
//!
//! Time comparisons allow only FP rounding noise (a few ulp at the state's
//! magnitude plus a `1e-10` relative-to-delta floor): because a translated
//! re-execution of a round performs the identical operation sequence, true
//! steady state matches to the ulp, while a still-converging transient
//! misses and the engine simply keeps stepping — a false negative costs
//! events, never correctness.

/// State vector sampled at one round boundary.
#[derive(Debug, Clone)]
struct Snapshot {
    iters: Vec<u64>,
    read_end: Vec<f64>,
    dma_free: f64,
    dma_busy: f64,
    stall: Vec<f64>,
    contention: Vec<f64>,
}

/// Per-round increments of the detected periodic orbit.
#[derive(Debug, Clone)]
pub(crate) struct RoundDelta {
    /// Uniform time advance of every cursor per round, seconds.
    pub dt: f64,
    /// DMA-port busy time accrued per round, seconds.
    pub dma_busy: f64,
    /// Per-accumulator stall increment per round (layer or tenant indexed,
    /// matching whatever the caller accumulates into).
    pub stall: Vec<f64>,
    /// Per-accumulator contention increment per round.
    pub contention: Vec<f64>,
}

/// Rolling three-snapshot window over round boundaries.
#[derive(Debug)]
pub(crate) struct Detector {
    snaps: Vec<Snapshot>,
}

impl Detector {
    pub fn new() -> Detector {
        Detector { snaps: Vec::with_capacity(3) }
    }

    /// Record a round-boundary snapshot; returns the per-round deltas once
    /// the last two windows match exactly (up to FP rounding).
    #[allow(clippy::too_many_arguments)]
    pub fn observe(
        &mut self,
        iters: &[u64],
        read_end: &[f64],
        dma_free: f64,
        dma_busy: f64,
        stall: &[f64],
        contention: &[f64],
        n_per_round: &[u64],
    ) -> Option<RoundDelta> {
        let cur = Snapshot {
            iters: iters.to_vec(),
            read_end: read_end.to_vec(),
            dma_free,
            dma_busy,
            stall: stall.to_vec(),
            contention: contention.to_vec(),
        };
        if self.snaps.len() == 3 {
            self.snaps.rotate_left(1);
            self.snaps[2] = cur;
        } else {
            self.snaps.push(cur);
        }
        if self.snaps.len() < 3 {
            return None;
        }
        let (a, b, c) = (&self.snaps[0], &self.snaps[1], &self.snaps[2]);

        // Event counts must advance by exactly n_l per slot in BOTH windows
        // — integer, no tolerance.
        for s in 0..n_per_round.len() {
            if c.iters[s] - b.iters[s] != n_per_round[s] || b.iters[s] - a.iters[s] != n_per_round[s]
            {
                return None;
            }
        }

        let dt = c.dma_free - b.dma_free;
        if !dt.is_finite() || dt <= 0.0 {
            return None;
        }
        // Rounding-noise tolerance: a handful of ulp at the compared
        // magnitude, plus a tiny relative-to-dt floor. Scaled this tight,
        // only a genuinely locked orbit matches; extrapolating it amplifies
        // at most ulp-level error (well inside the 1e-9 equivalence gate).
        let near = |x: f64, y: f64, mag: f64| {
            (x - y).abs() <= 1e-10 * dt + 64.0 * f64::EPSILON * mag.abs().max(dt)
        };
        if !near(b.dma_free - a.dma_free, dt, c.dma_free) {
            return None;
        }
        for s in 0..n_per_round.len() {
            let mag = c.read_end[s];
            if !near(c.read_end[s] - b.read_end[s], dt, mag)
                || !near(b.read_end[s] - a.read_end[s], dt, mag)
            {
                return None;
            }
        }
        if !near(c.dma_busy - b.dma_busy, b.dma_busy - a.dma_busy, c.dma_busy) {
            return None;
        }
        for l in 0..stall.len() {
            if !near(c.stall[l] - b.stall[l], b.stall[l] - a.stall[l], c.stall[l]) {
                return None;
            }
            if !near(
                c.contention[l] - b.contention[l],
                b.contention[l] - a.contention[l],
                c.contention[l],
            ) {
                return None;
            }
        }

        Some(RoundDelta {
            dt,
            dma_busy: c.dma_busy - b.dma_busy,
            stall: stall.iter().zip(&b.stall).map(|(cv, bv)| cv - bv).collect(),
            contention: contention.iter().zip(&b.contention).map(|(cv, bv)| cv - bv).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shifted(base: &[f64], k: f64, dt: f64) -> Vec<f64> {
        base.iter().map(|v| v + k * dt).collect()
    }

    #[test]
    fn detects_a_perfectly_periodic_orbit_on_the_third_snapshot() {
        let mut d = Detector::new();
        let n_per = [2u64, 1];
        let base = [1.0e-3, 1.5e-3];
        let dt = 2.5e-4;
        for k in 0..3u64 {
            let iters = [10 + 2 * k, 5 + k];
            let got = d.observe(
                &iters,
                &shifted(&base, k as f64, dt),
                2.0e-3 + k as f64 * dt,
                4.0e-4 + k as f64 * 1e-5,
                &[0.0, 0.0],
                &[0.0, 0.0],
                &n_per,
            );
            if k < 2 {
                assert!(got.is_none(), "needs three snapshots");
            } else {
                let delta = got.expect("periodic orbit detected");
                assert!((delta.dt - dt).abs() < 1e-18);
                assert!((delta.dma_busy - 1e-5).abs() < 1e-18);
                assert_eq!(delta.stall, vec![0.0, 0.0]);
            }
        }
    }

    #[test]
    fn rejects_drifting_times_and_wrong_counts() {
        // time drift far beyond rounding noise: no detection
        let mut d = Detector::new();
        let n_per = [1u64];
        for k in 0..5u64 {
            let drift = 1e-6 * (k as f64) * (k as f64); // accelerating
            let got = d.observe(
                &[k],
                &[1e-3 + drift],
                1e-3 + drift,
                1e-4,
                &[0.0],
                &[0.0],
                &n_per,
            );
            assert!(got.is_none(), "drifting orbit must not detect (k={k})");
        }
        // exact times but a count glitch in the middle window: no detection
        let mut d = Detector::new();
        let counts = [0u64, 1, 3, 4];
        for (k, &n) in counts.iter().enumerate() {
            let t = 1e-3 + k as f64 * 1e-4;
            let got = d.observe(&[n], &[t], t, 1e-4, &[0.0], &[0.0], &n_per);
            assert!(got.is_none(), "count glitch must not detect (k={k})");
        }
    }

    #[test]
    fn repeating_stall_increments_are_part_of_the_orbit() {
        let mut d = Detector::new();
        let n_per = [1u64];
        let dt = 1e-4;
        let mut last = None;
        for k in 0..3u64 {
            let t = 1e-3 + k as f64 * dt;
            last = d.observe(
                &[k],
                &[t],
                t,
                1e-4 + k as f64 * 2e-5,
                &[3e-6 * k as f64],
                &[1e-6 * k as f64],
                &n_per,
            );
        }
        let delta = last.expect("stalling but periodic orbit detected");
        assert!((delta.stall[0] - 3e-6).abs() < 1e-18);
        assert!((delta.contention[0] - 1e-6).abs() < 1e-18);
    }
}
