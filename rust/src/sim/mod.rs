//! Event-driven simulator of the pipelined accelerator — validates the
//! analytic models and quantifies the DMA stalls the write-burst balancing
//! strategy eliminates (paper Fig. 5).
//!
//! Granularity: one event per weight-fragment iteration (not per cycle) —
//! within an iteration the CE behaviour is exactly periodic, so this loses
//! no timing information while keeping ResNet-scale simulations in the
//! microsecond range. Two clock domains are modeled: reads advance in
//! `clk_comp` time scaled by the slow-down factor `s_l`; DMA write bursts
//! advance at the effective off-chip rate capped by the buffer write port in
//! `clk_dma` (Eq. 8).
//!
//! Sharded deployments run one event simulation per partition (each with
//! its own DMA port) composed with an analytic model of the inter-device
//! FIFO links — see [`simulate_partitioned`].
//!
//! Co-located deployments share ONE physical DMA port across tenants: the
//! joint event simulation interleaves every tenant's burst train on the
//! port and attributes queueing stall as contention — see
//! [`simulate_colocated`].
//!
//! The engines **fast-forward** through the steady state (PR 9): a static
//! burst schedule makes the event stream periodic after warm-up, so the
//! engine detects the repeating hyperperiod round and extrapolates the
//! remaining iterations in O(1) per slot instead of stepping
//! O(batch · Σ r) events ([`SimConfig::fast_forward`], on by default).
//! The pre-fast-forward engines survive as [`reference`] — the equivalence
//! oracle `tests/sim_equivalence.rs` and `benches/sim_perf.rs` pin the
//! fast engines against.

mod colocated;
mod engine;
mod fifo;
mod partitioned;
mod queue;
pub mod reference;
mod steady;
mod trace;

pub use colocated::{simulate_colocated, ColocatedSimResult, TenantSim};
pub use engine::{simulate, SimConfig, SimResult};
pub use fifo::{fifo_depths, worst_link, FifoSizing, FIFO_ALLOWANCE};
pub use partitioned::{
    simulate_partitioned, ChainBottleneck, LinkStat, PartitionedSimResult,
};
pub use trace::{fig5_scenario, render_gantt, to_csv, TraceEvent, TraceKind};
