//! The discrete-event engine.
//!
//! Shared resource: the DMA port (demux-routed, FIFO in request-arrival
//! order, which for balanced designs degenerates to the paper's static
//! round-robin sequence). Per streaming CE, fragment iteration `j`:
//!
//! ```text
//! read_j  = [static phase] then [buffer phase]
//!           the buffer phase *chases* write_j: the per-address
//!           Read-After-Write check (paper §III-B) lets the PE read words
//!           the DMA has already written, so the phase finishes at
//!           max(static_end + t_rd_buffer, write_j end)
//! write_j = DMA burst of t_wr seconds; requires the shared buffer free,
//!           i.e. read_{j-1}'s buffer phase complete
//! ```
//!
//! Stall := extra time the buffer phase takes beyond its unconstrained
//! duration because the write had not finished.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::trace::{TraceEvent, TraceKind};
use crate::device::Device;
use crate::dse::Design;
use crate::schedule::BurstSchedule;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub batch: u64,
    /// Record per-event traces (Fig. 5 rendering); off for latency runs.
    pub trace: bool,
    pub max_trace_events: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { batch: 1, trace: false, max_trace_events: 4096 }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// Wall-clock of the batch through the accelerator, seconds.
    pub makespan_s: f64,
    /// Single-sample latency estimate in ms (fill + steady drain + stalls).
    pub latency_ms: f64,
    /// Total stall time summed over streaming CEs, seconds.
    pub total_stall_s: f64,
    /// Stall per layer index.
    pub per_layer_stall_s: Vec<f64>,
    /// Of each layer's stall, the part attributable to DMA-port contention:
    /// the write burst could not start when requested because another
    /// layer's burst held the port. The remainder is intrinsic
    /// Read-After-Write wait (the burst itself was too slow for the window).
    pub per_layer_contention_s: Vec<f64>,
    /// Fraction of the makespan the DMA port was busy.
    pub dma_busy_frac: f64,
    /// Number of fragment-iteration events processed.
    pub events: u64,
    /// Optional event trace.
    pub traces: Vec<TraceEvent>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct Request {
    time: f64,
    layer_slot: usize, // index into the schedule entries
    iteration: u64,
}

impl Eq for Request {}
impl Ord for Request {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by (time, layer): reversed for BinaryHeap
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.layer_slot.cmp(&self.layer_slot))
    }
}
impl PartialOrd for Request {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Ideal (stall-free) pipeline time of a batch: fill of every CE plus
/// `batch` drains of the bottleneck CE. The single definition shared by the
/// single-device run and the co-located per-tenant accounting — the two
/// must never drift.
pub(crate) fn ideal_finish(design: &Design, batch: u64) -> f64 {
    let clk = design.clk_comp_mhz * 1e6;
    let fill: f64 = (0..design.len())
        .map(|i| crate::ce::fill_cycles(&design.network.layers[i], &design.cfgs[i]) as f64 / clk)
        .sum();
    fill + batch as f64 * (design.cycles_of(design.slowest()) as f64 / clk)
}

/// Run the simulation of `design` on `device`.
pub fn simulate(design: &Design, device: &Device, cfg: &SimConfig) -> SimResult {
    let schedule = BurstSchedule::from_design(design, device, cfg.batch);
    let ideal_finish = ideal_finish(design, cfg.batch);

    let mut per_layer_stall = vec![0.0; design.len()];
    let mut per_layer_contention = vec![0.0; design.len()];
    let mut traces = Vec::new();

    if schedule.entries.is_empty() {
        return SimResult {
            makespan_s: ideal_finish,
            latency_ms: ideal_finish * 1e3,
            total_stall_s: 0.0,
            per_layer_stall_s: per_layer_stall,
            per_layer_contention_s: per_layer_contention,
            dma_busy_frac: 0.0,
            events: 0,
            traces,
        };
    }

    // Per streaming CE: cursor of its sequential read chain.
    let n_slots = schedule.entries.len();
    let mut prev_read_end: Vec<f64> = schedule.entries.iter().map(|e| e.start_offset).collect();
    let mut heap: BinaryHeap<Request> = BinaryHeap::with_capacity(n_slots * 2);
    for (slot, e) in schedule.entries.iter().enumerate() {
        // first write requested when the CE's window opens
        heap.push(Request { time: e.start_offset.max(0.0), layer_slot: slot, iteration: 0 });
    }

    let mut dma_free = 0.0_f64;
    let mut dma_busy = 0.0_f64;
    let mut events = 0_u64;
    let mut max_read_end = 0.0_f64;

    while let Some(req) = heap.pop() {
        let e = &schedule.entries[req.layer_slot];
        // DMA burst (write side, clk_dma domain folded into t_wr)
        let w_start = req.time.max(dma_free);
        let w_end = w_start + e.t_wr;
        dma_free = w_end;
        dma_busy += e.t_wr;

        // CE read iteration (compute-clock domain). The buffer phase chases
        // the write pointer (fine-grained RAW): it cannot finish before the
        // write finishes, but overlaps it word-by-word.
        let s_start = prev_read_end[req.layer_slot];
        let s_end = s_start + e.t_rd_static;
        let unconstrained_end = s_end + e.t_rd_buffer;
        let r_end = unconstrained_end.max(w_end);
        let stall = r_end - unconstrained_end;
        let b_start = s_end;
        prev_read_end[req.layer_slot] = r_end;
        per_layer_stall[e.layer] += stall;
        // Attribution: had the port been free at request time the write
        // would have ended at `req.time + t_wr`; any stall beyond that point
        // is queueing behind other layers' bursts (contention), the rest is
        // the burst itself outrunning the read window (intrinsic RAW wait).
        if stall > 0.0 {
            let uncontended_end = req.time + e.t_wr;
            let intrinsic = (uncontended_end - unconstrained_end).max(0.0).min(stall);
            per_layer_contention[e.layer] += stall - intrinsic;
        }
        max_read_end = max_read_end.max(r_end);
        events += 1;

        if cfg.trace && traces.len() + 4 <= cfg.max_trace_events {
            traces.push(TraceEvent { layer: e.layer, kind: TraceKind::WriteBurst, start: w_start, end: w_end });
            traces.push(TraceEvent { layer: e.layer, kind: TraceKind::ReadStatic, start: s_start, end: s_end });
            if stall > 0.0 {
                traces.push(TraceEvent { layer: e.layer, kind: TraceKind::Stall, start: s_end, end: b_start });
            }
            traces.push(TraceEvent { layer: e.layer, kind: TraceKind::ReadBuffer, start: b_start, end: r_end });
        }

        if req.iteration + 1 < e.r {
            // buffer freed once its read phase completes
            heap.push(Request { time: r_end, layer_slot: req.layer_slot, iteration: req.iteration + 1 });
        }
    }

    let makespan = ideal_finish.max(max_read_end);
    let total_stall: f64 = per_layer_stall.iter().sum();
    SimResult {
        makespan_s: makespan,
        latency_ms: makespan * 1e3,
        total_stall_s: total_stall,
        per_layer_stall_s: per_layer_stall,
        per_layer_contention_s: per_layer_contention,
        dma_busy_frac: if makespan > 0.0 { dma_busy / makespan } else { 0.0 },
        events,
        traces,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn all_onchip_design_matches_analytic_exactly() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::u250();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let sim = simulate(&r.design, &dev, &SimConfig::default());
        assert_eq!(sim.total_stall_s, 0.0);
        assert_eq!(sim.events, 0);
        let rel = (sim.latency_ms - r.latency_ms).abs() / r.latency_ms;
        assert!(rel < 1e-9, "sim {} vs analytic {}", sim.latency_ms, r.latency_ms);
    }

    #[test]
    fn balanced_streaming_design_is_nearly_stall_free() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        assert!(r.design.any_streaming());
        let sim = simulate(&r.design, &dev, &SimConfig::default());
        // stalls below 10% of makespan: write-burst balancing works
        assert!(
            sim.total_stall_s < 0.10 * sim.makespan_s,
            "stalls {} vs makespan {}",
            sim.total_stall_s,
            sim.makespan_s
        );
        // sim latency close to analytic prediction
        let rel = (sim.latency_ms - r.latency_ms).abs() / r.latency_ms;
        assert!(rel < 0.25, "sim {} vs analytic {} ms", sim.latency_ms, r.latency_ms);
    }

    #[test]
    fn batch_scales_makespan_linearly() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let s1 = simulate(&r.design, &dev, &SimConfig { batch: 1, ..Default::default() });
        let s8 = simulate(&r.design, &dev, &SimConfig { batch: 8, ..Default::default() });
        let ratio = s8.makespan_s / s1.makespan_s;
        assert!((4.0..9.0).contains(&ratio), "batch-8 / batch-1 = {ratio}");
    }

    #[test]
    fn dma_busy_fraction_is_sane() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let sim = simulate(&r.design, &dev, &SimConfig::default());
        assert!((0.0..=1.0).contains(&sim.dma_busy_frac), "{}", sim.dma_busy_frac);
    }
}
