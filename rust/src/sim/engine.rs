//! The discrete-event engine, with steady-state fast-forward.
//!
//! Shared resource: the DMA port (demux-routed, FIFO in request-arrival
//! order, which for balanced designs degenerates to the paper's static
//! round-robin sequence). Per streaming CE, fragment iteration `j`:
//!
//! ```text
//! read_j  = [static phase] then [buffer phase]
//!           the buffer phase *chases* write_j: the per-address
//!           Read-After-Write check (paper §III-B) lets the PE read words
//!           the DMA has already written, so the phase finishes at
//!           max(static_end + t_rd_buffer, write_j end)
//! write_j = DMA burst of t_wr seconds; requires the shared buffer free,
//!           i.e. read_{j-1}'s buffer phase complete
//! ```
//!
//! Stall := extra time the buffer phase takes beyond its unconstrained
//! duration because the write had not finished.
//!
//! **Fast-forward** (PR 9): the schedule is static, so the event stream is
//! eventually periodic with the hyperperiod of the burst train
//! ([`BurstSchedule::hyperperiod`]). The engine steps events normally
//! through the warm-up transient, sampling the state vector at round
//! boundaries; once [`super::steady`] sees the same round twice, the
//! remaining `R` whole rounds collapse to one multiply-add per slot (times
//! shift by `R·dt`, accumulators gain `R` round-increments) and only the
//! exact tail — the last partial round — is event-stepped. Cost drops from
//! O(batch · Σ r) to O(warm-up + one round + tail). Designs that never
//! settle, and trace runs, take the full event loop; `sim::reference`
//! preserves the pre-fast-forward engine as the equivalence oracle
//! (`tests/sim_equivalence.rs`, `benches/sim_perf.rs`).

use super::queue::SlotQueue;
use super::steady::Detector;
use super::trace::{TraceEvent, TraceKind};
use crate::device::Device;
use crate::dse::Design;
use crate::schedule::BurstSchedule;

/// Don't bother detecting unless the train runs at least this many rounds:
/// three are needed to observe two matching windows, and anything shorter
/// has no tail worth skipping.
const MIN_ROUNDS: u64 = 4;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub batch: u64,
    /// Record per-event traces (Fig. 5 rendering); off for latency runs.
    pub trace: bool,
    pub max_trace_events: usize,
    /// Detect the steady-state period and extrapolate the remaining
    /// iterations analytically. Equivalent to the full event loop within FP
    /// rounding (gated ≤ 1e-9 relative vs [`super::reference`]); disable to
    /// force every event through the loop. Trace runs always step fully.
    pub fast_forward: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { batch: 1, trace: false, max_trace_events: 4096, fast_forward: true }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// Wall-clock of the batch through the accelerator, seconds.
    pub makespan_s: f64,
    /// Single-sample latency estimate in ms (fill + steady drain + stalls).
    pub latency_ms: f64,
    /// Total stall time summed over streaming CEs, seconds.
    pub total_stall_s: f64,
    /// Stall per layer index.
    pub per_layer_stall_s: Vec<f64>,
    /// Of each layer's stall, the part attributable to DMA-port contention:
    /// the write burst could not start when requested because another
    /// layer's burst held the port. The remainder is intrinsic
    /// Read-After-Write wait (the burst itself was too slow for the window).
    pub per_layer_contention_s: Vec<f64>,
    /// Fraction of the makespan the DMA port was busy.
    pub dma_busy_frac: f64,
    /// Fragment-iteration events in the schedule (`Σ_l r_l`): the semantic
    /// event count, identical whether or not the engine fast-forwarded.
    pub events: u64,
    /// Events the engine actually stepped through the loop; below `events`
    /// when the periodic tail was extrapolated. Diagnostic only — excluded
    /// from the reference-equivalence contract.
    pub events_processed: u64,
    /// A trace run hit `max_trace_events` and dropped later events (the
    /// Fig. 5 rendering is a prefix, not the whole batch).
    pub truncated: bool,
    /// Optional event trace.
    pub traces: Vec<TraceEvent>,
}

/// Ideal (stall-free) pipeline time of a batch: fill of every CE plus
/// `batch` drains of the bottleneck CE. The single definition shared by the
/// single-device run and the co-located per-tenant accounting — the two
/// must never drift.
pub(crate) fn ideal_finish(design: &Design, batch: u64) -> f64 {
    let clk = design.clk_comp_mhz * 1e6;
    let fill: f64 = (0..design.len())
        .map(|i| crate::ce::fill_cycles(&design.network.layers[i], &design.cfgs[i]) as f64 / clk)
        .sum();
    fill + batch as f64 * (design.cycles_of(design.slowest()) as f64 / clk)
}

/// Run the simulation of `design` on `device`.
pub fn simulate(design: &Design, device: &Device, cfg: &SimConfig) -> SimResult {
    let schedule = BurstSchedule::from_design(design, device, cfg.batch);
    let ideal_finish = ideal_finish(design, cfg.batch);

    let mut per_layer_stall = vec![0.0; design.len()];
    let mut per_layer_contention = vec![0.0; design.len()];
    let mut traces = Vec::new();

    if schedule.entries.is_empty() {
        crate::telemetry::counters().sim_runs.incr();
        return SimResult {
            makespan_s: ideal_finish,
            latency_ms: ideal_finish * 1e3,
            total_stall_s: 0.0,
            per_layer_stall_s: per_layer_stall,
            per_layer_contention_s: per_layer_contention,
            dma_busy_frac: 0.0,
            events: 0,
            events_processed: 0,
            truncated: false,
            traces,
        };
    }

    let entries = &schedule.entries;
    let n_slots = entries.len();
    let total_events: u64 = entries.iter().map(|e| e.r).sum();

    // Per streaming CE: cursor of its sequential read chain, and how many
    // of its r iterations have completed.
    let mut prev_read_end: Vec<f64> = entries.iter().map(|e| e.start_offset).collect();
    let mut iters = vec![0u64; n_slots];
    let mut queue = SlotQueue::with_slots(n_slots);
    for (slot, e) in entries.iter().enumerate() {
        // first write requested when the CE's window opens
        queue.push(slot, e.start_offset.max(0.0));
    }

    let mut dma_free = 0.0_f64;
    let mut dma_busy = 0.0_f64;
    let mut processed = 0_u64;
    let mut skipped = 0_u64;
    let mut max_read_end = 0.0_f64;
    let mut truncated = false;
    let mut ff_rounds = 0_u64;

    let (rounds_total, n_per_round) = schedule.hyperperiod();
    let round_events: u64 = n_per_round.iter().sum();
    let mut detector = if cfg.fast_forward && !cfg.trace && rounds_total >= MIN_ROUNDS {
        Some(Detector::new())
    } else {
        None
    };

    while let Some((slot, time)) = queue.pop() {
        let e = &entries[slot];
        // DMA burst (write side, clk_dma domain folded into t_wr)
        let w_start = time.max(dma_free);
        let w_end = w_start + e.t_wr;
        dma_free = w_end;
        dma_busy += e.t_wr;

        // CE read iteration (compute-clock domain). The buffer phase chases
        // the write pointer (fine-grained RAW): it cannot finish before the
        // write finishes, but overlaps it word-by-word.
        let s_start = prev_read_end[slot];
        let s_end = s_start + e.t_rd_static;
        let unconstrained_end = s_end + e.t_rd_buffer;
        let r_end = unconstrained_end.max(w_end);
        let stall = r_end - unconstrained_end;
        let b_start = s_end;
        prev_read_end[slot] = r_end;
        per_layer_stall[e.layer] += stall;
        // Attribution: had the port been free at request time the write
        // would have ended at `time + t_wr`; any stall beyond that point
        // is queueing behind other layers' bursts (contention), the rest is
        // the burst itself outrunning the read window (intrinsic RAW wait).
        if stall > 0.0 {
            let uncontended_end = time + e.t_wr;
            let intrinsic = (uncontended_end - unconstrained_end).max(0.0).min(stall);
            per_layer_contention[e.layer] += stall - intrinsic;
        }
        max_read_end = max_read_end.max(r_end);
        processed += 1;
        iters[slot] += 1;

        if cfg.trace && !truncated {
            // reserve exactly what this event pushes (the stall bar only
            // exists when the RAW check bit); stop at the first event that
            // does not fit so the trace is always a strict prefix
            let needed = if stall > 0.0 { 4 } else { 3 };
            if traces.len() + needed <= cfg.max_trace_events {
                traces.push(TraceEvent { layer: e.layer, kind: TraceKind::WriteBurst, start: w_start, end: w_end });
                traces.push(TraceEvent { layer: e.layer, kind: TraceKind::ReadStatic, start: s_start, end: s_end });
                if stall > 0.0 {
                    traces.push(TraceEvent { layer: e.layer, kind: TraceKind::Stall, start: s_end, end: b_start });
                }
                traces.push(TraceEvent { layer: e.layer, kind: TraceKind::ReadBuffer, start: b_start, end: r_end });
            } else {
                truncated = true;
            }
        }

        if iters[slot] < e.r {
            // buffer freed once its read phase completes
            queue.push(slot, r_end);
        }

        // Round boundary: sample the state vector; once two consecutive
        // rounds match, collapse the remaining whole rounds analytically
        // and event-step only the exact tail.
        if detector.is_some() && processed % round_events == 0 {
            let delta = detector.as_mut().unwrap().observe(
                &iters,
                &prev_read_end,
                dma_free,
                dma_busy,
                &per_layer_stall,
                &per_layer_contention,
                &n_per_round,
            );
            if let Some(delta) = delta {
                let rounds_left = entries
                    .iter()
                    .enumerate()
                    .map(|(s, e)| (e.r - iters[s]) / n_per_round[s])
                    .min()
                    .unwrap_or(0);
                if rounds_left > 0 {
                    let rf = rounds_left as f64;
                    let shift = delta.dt * rf;
                    dma_free += shift;
                    dma_busy += delta.dma_busy * rf;
                    for l in 0..per_layer_stall.len() {
                        per_layer_stall[l] += delta.stall[l] * rf;
                        per_layer_contention[l] += delta.contention[l] * rf;
                    }
                    queue.clear();
                    for (s, e) in entries.iter().enumerate() {
                        prev_read_end[s] += shift;
                        iters[s] += n_per_round[s] * rounds_left;
                        max_read_end = max_read_end.max(prev_read_end[s]);
                        if iters[s] < e.r {
                            queue.push(s, prev_read_end[s]);
                        }
                    }
                    skipped += round_events * rounds_left;
                    ff_rounds = rounds_left;
                }
                // one extrapolation per run; the tail is simulated exactly
                detector = None;
            }
        }
    }

    debug_assert_eq!(processed + skipped, total_events, "every scheduled event accounted for");

    // fast-forward diagnostics into the process-wide telemetry registry
    // (relaxed counter bumps; the sim loop itself is untouched)
    let g = crate::telemetry::counters();
    g.sim_runs.incr();
    g.sim_events.add(processed + skipped);
    g.sim_events_processed.add(processed);
    if skipped > 0 {
        g.sim_fast_forwards.incr();
        g.sim_rounds_skipped.add(ff_rounds);
    }

    let makespan = ideal_finish.max(max_read_end);
    let total_stall: f64 = per_layer_stall.iter().sum();
    SimResult {
        makespan_s: makespan,
        latency_ms: makespan * 1e3,
        total_stall_s: total_stall,
        per_layer_stall_s: per_layer_stall,
        per_layer_contention_s: per_layer_contention,
        dma_busy_frac: if makespan > 0.0 { dma_busy / makespan } else { 0.0 },
        events: processed + skipped,
        events_processed: processed,
        truncated,
        traces,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn all_onchip_design_matches_analytic_exactly() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::u250();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let sim = simulate(&r.design, &dev, &SimConfig::default());
        assert_eq!(sim.total_stall_s, 0.0);
        assert_eq!(sim.events, 0);
        assert_eq!(sim.events_processed, 0);
        assert!(!sim.truncated);
        let rel = (sim.latency_ms - r.latency_ms).abs() / r.latency_ms;
        assert!(rel < 1e-9, "sim {} vs analytic {}", sim.latency_ms, r.latency_ms);
    }

    #[test]
    fn balanced_streaming_design_is_nearly_stall_free() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        assert!(r.design.any_streaming());
        let sim = simulate(&r.design, &dev, &SimConfig::default());
        // stalls below 10% of makespan: write-burst balancing works
        assert!(
            sim.total_stall_s < 0.10 * sim.makespan_s,
            "stalls {} vs makespan {}",
            sim.total_stall_s,
            sim.makespan_s
        );
        // sim latency close to analytic prediction
        let rel = (sim.latency_ms - r.latency_ms).abs() / r.latency_ms;
        assert!(rel < 0.25, "sim {} vs analytic {} ms", sim.latency_ms, r.latency_ms);
    }

    #[test]
    fn batch_scales_makespan_linearly() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let s1 = simulate(&r.design, &dev, &SimConfig { batch: 1, ..Default::default() });
        let s8 = simulate(&r.design, &dev, &SimConfig { batch: 8, ..Default::default() });
        let ratio = s8.makespan_s / s1.makespan_s;
        assert!((4.0..9.0).contains(&ratio), "batch-8 / batch-1 = {ratio}");
    }

    #[test]
    fn dma_busy_fraction_is_sane() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let sim = simulate(&r.design, &dev, &SimConfig::default());
        assert!((0.0..=1.0).contains(&sim.dma_busy_frac), "{}", sim.dma_busy_frac);
    }

    #[test]
    fn fast_forward_skips_most_events_and_matches_the_full_loop() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let batch = 8u64;
        let fast = simulate(&r.design, &dev, &SimConfig { batch, ..Default::default() });
        let full = simulate(
            &r.design,
            &dev,
            &SimConfig { batch, fast_forward: false, ..Default::default() },
        );
        // semantic event count unchanged; the loop stepped only a sliver
        assert_eq!(fast.events, full.events);
        assert_eq!(full.events_processed, full.events);
        assert!(
            fast.events_processed * 10 < fast.events,
            "fast-forward must engage on a balanced schedule: stepped {} of {}",
            fast.events_processed,
            fast.events
        );
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1e-300);
        assert!(close(fast.makespan_s, full.makespan_s), "{} vs {}", fast.makespan_s, full.makespan_s);
        assert!(close(fast.total_stall_s, full.total_stall_s) || (fast.total_stall_s - full.total_stall_s).abs() < 1e-12 * full.makespan_s);
        assert!(close(fast.dma_busy_frac, full.dma_busy_frac));
        for (a, b) in fast.per_layer_stall_s.iter().zip(&full.per_layer_stall_s) {
            assert!(close(*a, *b) || (a - b).abs() < 1e-12 * full.makespan_s, "{a} vs {b}");
        }
    }

    #[test]
    fn fast_forward_off_is_bit_identical_to_the_reference_oracle() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let cfg = SimConfig { batch: 4, fast_forward: false, ..Default::default() };
        let full = simulate(&r.design, &dev, &cfg);
        let oracle = crate::sim::reference::simulate(&r.design, &dev, &cfg);
        assert_eq!(full, oracle, "indexed queue must not change the event order");
    }

    #[test]
    fn trace_cap_reserves_exactly_what_is_pushed_and_reports_truncation() {
        let (d, dev) = crate::sim::fig5_scenario(true);
        // generous cap: everything fits, nothing truncated
        let all = simulate(
            &d,
            &dev,
            &SimConfig { batch: 1, trace: true, max_trace_events: 4096, ..Default::default() },
        );
        assert!(!all.truncated);
        assert!(!all.traces.is_empty());
        // tight cap: the prefix packs exactly as many whole events as fit
        // (an event is 3 intervals, 4 when it stalls — the old `len + 4 <=
        // cap` check wrongly reserved 4 for stall-free events too)
        let cap = 7usize;
        let cut = simulate(
            &d,
            &dev,
            &SimConfig { batch: 1, trace: true, max_trace_events: cap, ..Default::default() },
        );
        assert!(cut.truncated, "events beyond the cap must be reported");
        assert!(cut.traces.len() <= cap);
        let mut sizes = Vec::new();
        let mut cur = 0usize;
        for t in &all.traces {
            if t.kind == TraceKind::WriteBurst && cur > 0 {
                sizes.push(cur);
                cur = 0;
            }
            cur += 1;
        }
        sizes.push(cur);
        let mut expect = 0usize;
        for s in sizes {
            if expect + s > cap {
                break;
            }
            expect += s;
        }
        assert_eq!(cut.traces.len(), expect, "cap packs whole events exactly");
        assert_eq!(cut.traces[..], all.traces[..cut.traces.len()], "truncation keeps a prefix");
        // trace runs never fast-forward: every event was stepped
        assert_eq!(cut.events_processed, cut.events);
    }
}
