//! Inter-CE FIFO sizing analysis.
//!
//! The layer-wise pipeline connects CEs with handshaked FIFOs (paper §IV-A:
//! "CEs are interconnected using FIFOs to accommodate variations in
//! processing rates and data port width"). The area model charges a fixed
//! 256-word FIFO per link; this module computes the *required* depth from
//! the producer/consumer rate patterns so that a design can be checked for
//! backpressure risk — and the fixed allowance validated — without running
//! the cycle simulator.
//!
//! Model: within one output row, a producer emits `ŵ·f` values over its row
//! period and the consumer drains at its own steady rate. Windowed consumers
//! (conv/pool with `k > 1`) additionally hold back `(k−1)` rows in their
//! line buffers before producing anything, which the *line buffer* (not the
//! FIFO) absorbs; the FIFO only has to cover the short-term rate mismatch
//! plus the consumer's per-window dead time. The dominant term is the
//! classic rate-mismatch bound:
//!
//! ```text
//! depth ≥ burst · max(0, 1 − drain_rate / fill_rate) + slack
//! ```

use crate::dse::Design;

/// Sizing of one inter-CE link (producer layer `from` → consumer `from+1`).
#[derive(Debug, Clone)]
pub struct FifoSizing {
    /// Producer layer index.
    pub from: usize,
    /// Required depth in words of the producer's output stream.
    pub required_depth: u64,
    /// Producer's steady output rate, values per compute cycle.
    pub fill_rate: f64,
    /// Consumer's steady intake rate, values per compute cycle.
    pub drain_rate: f64,
    /// Whether the fixed 256-word allowance of the area model covers it.
    pub within_allowance: bool,
}

/// The fixed per-link FIFO allowance charged by the area model.
pub const FIFO_ALLOWANCE: u64 = 256;

/// Compute required FIFO depths for every adjacent CE pair of a design.
pub fn fifo_depths(design: &Design) -> Vec<FifoSizing> {
    let n = design.len();
    let mut out = Vec::with_capacity(n.saturating_sub(1));
    for i in 0..n.saturating_sub(1) {
        let prod = &design.network.layers[i];
        let cons = &design.network.layers[i + 1];

        // Steady rates in values per cycle (bottleneck-scaled: every CE
        // actually runs at the pipeline rate, so scale by the slowdown).
        let prod_cycles = design.cycles_of(i) as f64;
        let cons_cycles = design.cycles_of(i + 1) as f64;
        let pipeline_cycles = prod_cycles.max(cons_cycles);
        let fill_rate = prod.output_count() as f64 / pipeline_cycles;
        let drain_rate = cons.input_count() as f64 / pipeline_cycles;

        // Burst granularity: one output row of the producer. Consumers with
        // k>1 windows drain rows through their line buffers; the FIFO sees
        // at most a row of skew.
        let burst = (prod.w_out() as u64 * prod.c_out as u64).max(1);

        let mismatch = if fill_rate > drain_rate && fill_rate > 0.0 {
            (burst as f64 * (1.0 - drain_rate / fill_rate)).ceil() as u64
        } else {
            0
        };
        // handshake slack: a few words of pipeline registering either side
        let required = mismatch + 8;
        out.push(FifoSizing {
            from: i,
            required_depth: required,
            fill_rate,
            drain_rate,
            within_allowance: required <= FIFO_ALLOWANCE,
        });
    }
    out
}

/// Worst-case link of a design (largest required depth).
pub fn worst_link(design: &Design) -> Option<FifoSizing> {
    fifo_depths(design)
        .into_iter()
        .max_by(|a, b| a.required_depth.cmp(&b.required_depth))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    fn designed(model: &str, q: Quant, dev: &Device) -> Design {
        let net = models::by_name(model, q).unwrap();
        dse::run(&net, dev, &DseConfig::default()).unwrap().design
    }

    #[test]
    fn every_link_has_positive_depth() {
        let d = designed("resnet18", Quant::W4A5, &Device::zcu102());
        let sizes = fifo_depths(&d);
        assert_eq!(sizes.len(), d.len() - 1);
        for s in &sizes {
            assert!(s.required_depth >= 8, "{s:?}");
            assert!(s.fill_rate >= 0.0 && s.drain_rate >= 0.0);
        }
    }

    #[test]
    fn dse_designs_fit_the_allowance() {
        // The greedy DSE balances processing rates, so required depths stay
        // within the area model's fixed 256-word FIFO on the paper's
        // evaluated pairs.
        for (m, q, dev) in [
            ("resnet18", Quant::W4A5, Device::zcu102()),
            ("mobilenetv2", Quant::W4A4, Device::zc706()),
            ("toy", Quant::W8A8, Device::zcu102()),
        ] {
            let d = designed(m, q, &dev);
            let worst = worst_link(&d).unwrap();
            assert!(
                worst.within_allowance,
                "{m}: link {} needs {} words",
                worst.from,
                worst.required_depth
            );
        }
    }

    #[test]
    fn rate_matched_links_need_only_slack() {
        let d = designed("toy", Quant::W8A8, &Device::u250());
        for s in fifo_depths(&d) {
            if s.drain_rate >= s.fill_rate {
                assert_eq!(s.required_depth, 8, "{s:?}");
            }
        }
    }

    #[test]
    fn serial_design_rates_are_tiny() {
        // All-serial CEs process ~1 value/cycle at the bottleneck rate scale.
        let net = models::toy_cnn(Quant::W8A8);
        let d = Design::initialize(&net, &Device::zcu102());
        for s in fifo_depths(&d) {
            assert!(s.fill_rate <= 1.5, "{s:?}");
        }
    }
}
