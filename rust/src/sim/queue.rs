//! Indexed min-queue over burst slots.
//!
//! The event engines maintain one outstanding request per streaming slot
//! (a slot's next burst is only requested when its previous read interval
//! completes), so the pending set is bounded by the slot count known at
//! schedule-build time. That turns the `BinaryHeap<Request>` of the
//! pre-PR-9 engine — one allocation-backed heap node per event, plus the
//! struct churn of push/pop — into an indexed binary heap over slot ids:
//! every buffer is allocated once, sized from the schedule, and a
//! fast-forward can [`SlotQueue::clear`] and rebuild the pending set in
//! O(slots) when it re-seeds the tail simulation.
//!
//! Ordering matches the reference engine's `Request` ordering exactly:
//! minimum `(time, slot)`, ties broken toward the lower slot id, so the two
//! engines pop events in the same sequence and stay bit-identical until the
//! first extrapolation.

/// Marker for "slot not currently queued" in the position index.
const ABSENT: usize = usize::MAX;

/// Preallocated indexed binary min-heap keyed by `(time, slot id)`.
#[derive(Debug)]
pub(crate) struct SlotQueue {
    /// Heap of slot ids, ordered by `(key[slot], slot)`.
    heap: Vec<usize>,
    /// `pos[slot]` = index of `slot` in `heap`, or [`ABSENT`].
    pos: Vec<usize>,
    /// `key[slot]` = request time of the slot's pending event.
    key: Vec<f64>,
}

impl SlotQueue {
    /// An empty queue able to hold `n_slots` distinct slots.
    pub fn with_slots(n_slots: usize) -> SlotQueue {
        SlotQueue { heap: Vec::with_capacity(n_slots), pos: vec![ABSENT; n_slots], key: vec![0.0; n_slots] }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Queue `slot`'s next event at `time`. The slot must not already be
    /// queued (one outstanding request per slot, by construction).
    pub fn push(&mut self, slot: usize, time: f64) {
        debug_assert_eq!(self.pos[slot], ABSENT, "slot {slot} already queued");
        self.key[slot] = time;
        self.pos[slot] = self.heap.len();
        self.heap.push(slot);
        self.sift_up(self.heap.len() - 1);
    }

    /// Pop the earliest pending `(slot, time)`; ties go to the lower slot.
    pub fn pop(&mut self) -> Option<(usize, f64)> {
        let top = *self.heap.first()?;
        let last = self.heap.pop().expect("non-empty heap");
        self.pos[top] = ABSENT;
        if top != last {
            self.heap[0] = last;
            self.pos[last] = 0;
            self.sift_down(0);
        }
        Some((top, self.key[top]))
    }

    /// Drop every pending event (keeps the allocations).
    pub fn clear(&mut self) {
        for &slot in &self.heap {
            self.pos[slot] = ABSENT;
        }
        self.heap.clear();
    }

    /// Strict `(key, slot)` order — total because event times are finite.
    #[inline]
    fn less(&self, a: usize, b: usize) -> bool {
        match self.key[a].partial_cmp(&self.key[b]) {
            Some(std::cmp::Ordering::Less) => true,
            Some(std::cmp::Ordering::Greater) => false,
            _ => a < b,
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.less(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                self.pos[self.heap[i]] = i;
                self.pos[self.heap[parent]] = parent;
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let mut best = i;
            for child in [2 * i + 1, 2 * i + 2] {
                if child < n && self.less(self.heap[child], self.heap[best]) {
                    best = child;
                }
            }
            if best == i {
                break;
            }
            self.heap.swap(i, best);
            self.pos[self.heap[i]] = i;
            self.pos[self.heap[best]] = best;
            i = best;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_slot_tiebreak() {
        let mut q = SlotQueue::with_slots(5);
        q.push(3, 2.0);
        q.push(0, 1.0);
        q.push(4, 1.0);
        q.push(1, 3.0);
        assert_eq!(q.pop(), Some((0, 1.0)), "earliest time, lower slot on tie");
        assert_eq!(q.pop(), Some((4, 1.0)));
        assert_eq!(q.pop(), Some((3, 2.0)));
        assert_eq!(q.pop(), Some((1, 3.0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn reuse_after_pop_and_clear() {
        let mut q = SlotQueue::with_slots(3);
        q.push(1, 5.0);
        q.push(2, 4.0);
        assert_eq!(q.pop(), Some((2, 4.0)));
        q.push(2, 6.0); // re-queue the popped slot
        assert_eq!(q.len(), 2);
        q.clear();
        assert!(q.is_empty());
        q.push(0, 9.0);
        q.push(2, 8.0);
        q.push(1, 7.0);
        assert_eq!(q.pop(), Some((1, 7.0)));
        assert_eq!(q.pop(), Some((2, 8.0)));
        assert_eq!(q.pop(), Some((0, 9.0)));
    }

    #[test]
    fn interleaved_push_pop_matches_a_sorted_stream() {
        // drive a synthetic self-requeueing workload: each pop schedules the
        // slot again later, like the engine's read-chain successor events
        let mut q = SlotQueue::with_slots(4);
        for slot in 0..4 {
            q.push(slot, slot as f64 * 0.25);
        }
        let mut last = f64::NEG_INFINITY;
        for step in 0..64 {
            let (slot, t) = q.pop().expect("queue stays populated");
            assert!(t >= last, "monotone event times: {t} after {last}");
            last = t;
            if step < 60 {
                q.push(slot, t + 1.0 + slot as f64 * 0.125);
            }
        }
    }
}
