//! Trace events and the Fig. 5 two-layer scenario builder.

use crate::ce::CeModel;
use crate::device::Device;
use crate::dse::Design;
use crate::ir::{Layer, Network, Quant};

/// Kind of a traced interval (the bars of Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// DMA writing a fragment into the shared buffer.
    WriteBurst,
    /// PE array reading the static on-chip region.
    ReadStatic,
    /// PE array reading the shared buffer.
    ReadBuffer,
    /// PE array stalled on the Read-After-Write check.
    Stall,
}

/// One traced interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub layer: usize,
    pub kind: TraceKind,
    pub start: f64,
    pub end: f64,
}

impl TraceKind {
    /// Stable label for CSV export and rendering.
    pub fn label(&self) -> &'static str {
        match self {
            TraceKind::WriteBurst => "write",
            TraceKind::ReadStatic => "read_static",
            TraceKind::ReadBuffer => "read_buffer",
            TraceKind::Stall => "stall",
        }
    }

    /// One-character glyph for the Gantt rendering.
    fn glyph(&self) -> char {
        match self {
            TraceKind::WriteBurst => 'W',
            TraceKind::ReadStatic => 's',
            TraceKind::ReadBuffer => 'b',
            TraceKind::Stall => 'X',
        }
    }
}

/// Export traces as CSV (`layer,kind,start_us,end_us`) for external
/// waveform/plotting tools.
pub fn to_csv(traces: &[TraceEvent]) -> String {
    let mut out = String::from("layer,kind,start_us,end_us\n");
    for t in traces {
        out.push_str(&format!(
            "{},{},{:.4},{:.4}\n",
            t.layer,
            t.kind.label(),
            t.start * 1e6,
            t.end * 1e6
        ));
    }
    out
}

/// Render a Fig. 5-style ASCII Gantt chart: two rows per layer (DMA write
/// channel and CE read channel), `width` characters across the trace span.
pub fn render_gantt(traces: &[TraceEvent], width: usize) -> String {
    if traces.is_empty() {
        return String::from("(no trace events)\n");
    }
    let width = width.max(16);
    let t0 = traces.iter().map(|t| t.start).fold(f64::INFINITY, f64::min);
    let t1 = traces.iter().map(|t| t.end).fold(0.0_f64, f64::max);
    let span = (t1 - t0).max(1e-12);
    let mut layers: Vec<usize> = traces.iter().map(|t| t.layer).collect();
    layers.sort_unstable();
    layers.dedup();

    let mut out = String::new();
    out.push_str(&format!(
        "time span {:.2} us  (W=write burst, s=static read, b=buffer read, X=stall)\n",
        span * 1e6
    ));
    for &layer in &layers {
        for write_channel in [true, false] {
            let mut row = vec![' '; width];
            for t in traces.iter().filter(|t| t.layer == layer) {
                if (t.kind == TraceKind::WriteBurst) != write_channel {
                    continue;
                }
                let a = (((t.start - t0) / span) * (width as f64 - 1.0)) as usize;
                let b = (((t.end - t0) / span) * (width as f64 - 1.0)) as usize;
                for cell in row.iter_mut().take(b.min(width - 1) + 1).skip(a) {
                    *cell = t.kind.glyph();
                }
            }
            let label = if write_channel { "dma wr" } else { "ce rd" };
            out.push_str(&format!("l{layer} {label:>7} |{}|\n", row.iter().collect::<String>()));
        }
    }
    out
}

/// Build the two-layer write/read scheduling example of paper Fig. 5.
///
/// Layer `l1` produces a 4x-larger output map than `l2`, so with naive
/// fragmentation (`n = 1` everywhere) `r_l1 = 4·r_l2` — the imbalanced case
/// of Fig. 5(a) where `l2`'s big bursts stall `l1`. With
/// `balanced = true`, `l2` gets `n = 4` so that `r_l1 = r_l2` (Fig. 5(b)).
/// Both layers stream half of their weights.
pub fn fig5_scenario(balanced: bool) -> (Design, Device) {
    let q = Quant::W8A8;
    let mut net = Network::new("fig5", (8, 16, 16), q);
    net.push(Layer::conv("l1", 8, 16, 16, 16, 3, 2, 1, q)); // out 8x8 = 64 px
    net.push(Layer::conv("l2", 16, 32, 8, 8, 3, 2, 1, q)); // out 4x4 = 16 px

    let dev = Device {
        name: "fig5-dev",
        bram36: 64,
        uram: 0,
        dsp: 128,
        lut: 100_000,
        ff: 200_000,
        bandwidth_bps: 32e9,
        clk_comp_mhz: 100.0,
        clk_dma_mhz: 200.0,
        dma_port_bits: 512,
        link_bandwidth_bps: 16e9,
        link_latency_s: 1e-6,
    };

    let mut d = Design::initialize(&net, &dev);
    // modest parallelism so reads take a realistic number of cycles
    for i in 0..2 {
        d.cfgs[i].kp = 9;
        d.cfgs[i].cp = 2;
        d.cfgs[i].fp = 2;
    }
    // Evict 1/4 of l1 and 1/2 of l2. Imbalanced (n = 1 everywhere), l2's
    // single write burst is longer than an entire l1 read window, so it
    // inevitably delays l1's small bursts past their slack — the Fig. 5(a)
    // stalls. Balanced (n = 4 for l2, Eq. 10), l2's bursts shrink to
    // window-sized pieces that interleave with l1's without contention.
    for (i, frac) in [(0usize, 4u64), (1, 2)] {
        let m = CeModel::new(&d.network.layers[i], d.cfgs[i], d.clk_comp_mhz);
        let m_dep = m.m_dep();
        d.off_bits[i] = (m_dep / frac) * m.m_wid_bits();
        let n = if i == 1 && balanced { 4 } else { 1 };
        d.set_fragmentation(i, n);
    }
    (d, dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, SimConfig};

    #[test]
    fn fig5_scenario_has_expected_repeat_ratio() {
        let (imb, _) = fig5_scenario(false);
        let r1 = imb.repeats(0, 1);
        let r2 = imb.repeats(1, 1);
        assert_eq!(r1, 4 * r2, "imbalanced: r_l1 = 4·r_l2 ({r1} vs {r2})");

        let (bal, _) = fig5_scenario(true);
        assert_eq!(bal.repeats(0, 1), bal.repeats(1, 1), "balanced: equal r");
    }

    /// The paper's Fig. 5 claim: balancing the burst counts removes the
    /// stalls the imbalanced schedule suffers.
    #[test]
    fn balancing_removes_stalls() {
        let (imb, dev) = fig5_scenario(false);
        let (bal, _) = fig5_scenario(true);
        let cfg = SimConfig { batch: 4, ..Default::default() };
        let s_imb = simulate(&imb, &dev, &cfg);
        let s_bal = simulate(&bal, &dev, &cfg);
        assert!(
            s_bal.total_stall_s < s_imb.total_stall_s,
            "balanced stalls {} must be below imbalanced {}",
            s_bal.total_stall_s,
            s_imb.total_stall_s
        );
        assert!(s_bal.makespan_s <= s_imb.makespan_s * 1.001);
    }

    #[test]
    fn traces_are_well_formed() {
        let (d, dev) = fig5_scenario(true);
        let s = simulate(
            &d,
            &dev,
            &SimConfig { batch: 1, trace: true, max_trace_events: 512, ..Default::default() },
        );
        assert!(!s.traces.is_empty());
        for t in &s.traces {
            assert!(t.end >= t.start, "{t:?}");
            assert!(t.layer < 2);
        }
    }

    #[test]
    fn csv_export_has_header_and_rows() {
        let (d, dev) = fig5_scenario(true);
        let s = simulate(
            &d,
            &dev,
            &SimConfig { batch: 1, trace: true, max_trace_events: 64, ..Default::default() },
        );
        let csv = to_csv(&s.traces);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "layer,kind,start_us,end_us");
        assert_eq!(lines.len(), s.traces.len() + 1);
        for l in &lines[1..] {
            assert_eq!(l.split(',').count(), 4, "{l}");
        }
    }

    #[test]
    fn gantt_renders_both_channels() {
        let (d, dev) = fig5_scenario(false);
        let s = simulate(
            &d,
            &dev,
            &SimConfig { batch: 2, trace: true, max_trace_events: 512, ..Default::default() },
        );
        let g = render_gantt(&s.traces, 100);
        assert!(g.contains("dma wr"));
        assert!(g.contains("ce rd"));
        assert!(g.contains('W'), "write bursts visible:\n{g}");
        assert!(g.contains('s') || g.contains('b'), "reads visible:\n{g}");
        // imbalanced scenario shows stalls
        assert!(g.contains('X'), "stalls visible in imbalanced trace:\n{g}");
        assert_eq!(render_gantt(&[], 80), "(no trace events)\n");
    }

    #[test]
    fn stall_attribution_partitions_total() {
        let (d, dev) = fig5_scenario(false);
        let s = simulate(&d, &dev, &SimConfig { batch: 4, ..Default::default() });
        assert!(s.total_stall_s > 0.0, "imbalanced scenario must stall");
        for (i, (&stall, &cont)) in
            s.per_layer_stall_s.iter().zip(&s.per_layer_contention_s).enumerate()
        {
            assert!(cont >= 0.0, "layer {i}");
            assert!(cont <= stall + 1e-12, "layer {i}: contention {cont} > stall {stall}");
        }
        // Fig. 5(a)'s mechanism: l1's stalls are DMA contention (waiting for
        // l2's oversized burst), not intrinsic RAW.
        let contention: f64 = s.per_layer_contention_s.iter().sum();
        assert!(contention > 0.0, "imbalance must manifest as port contention");
    }
}
