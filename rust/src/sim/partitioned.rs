//! Simulation of a sharded deployment: per-partition event simulation plus
//! an analytic model of the inter-device FIFO links.
//!
//! Each partition runs through the unchanged event engine ([`simulate`])
//! with its own DMA port; the links between partitions are modeled
//! analytically — a link is exactly periodic in steady state (one boundary
//! activation tensor per sample), so, like the intra-device fragment
//! iterations, nothing is lost by not event-stepping it. A link whose
//! per-sample transfer time exceeds every partition's compute period
//! becomes the chain bottleneck and the time the downstream partitions
//! spend waiting on it is attributed as link stall, mirroring how DMA-port
//! contention is attributed within a device.
//!
//! The 1-partition case returns the single-device simulation verbatim
//! (bit-identical; enforced by `tests/partitioned_deploy.rs`).

use super::engine::{simulate, SimConfig, SimResult};
use crate::device::Device;
use crate::dse::Design;
use crate::schedule::LinkSpec;

/// What limits the chain's steady-state rate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChainBottleneck {
    /// Partition `i`'s compute pipeline.
    Partition(usize),
    /// The link between partitions `i` and `i + 1`.
    Link(usize),
}

/// Steady-state figures of one inter-device link.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkStat {
    pub spec: LinkSpec,
    /// Busy fraction of the link over the chain's steady-state period.
    pub utilization: f64,
    /// Time the chain loses to this link across the batch versus the
    /// compute-only period. Charged to the bottleneck link only (the chain
    /// drains at one rate — per-link charging would double-count), so this
    /// is zero unless this link sets [`PartitionedSimResult::bottleneck`].
    pub stall_s: f64,
}

/// Outcome of a partitioned simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionedSimResult {
    /// Wall-clock of the batch through the whole chain, seconds.
    pub makespan_s: f64,
    /// Chain latency in ms (makespan, mirroring [`SimResult::latency_ms`]).
    pub latency_ms: f64,
    /// Steady-state chain period per sample, seconds (compute and links).
    pub steady_period_s: f64,
    /// What limits the steady-state rate.
    pub bottleneck: ChainBottleneck,
    /// Unchanged per-partition event simulations, in chain order.
    pub per_partition: Vec<SimResult>,
    /// One entry per inter-device boundary.
    pub links: Vec<LinkStat>,
    /// Intra-partition stalls plus link stalls, seconds.
    pub total_stall_s: f64,
}

impl PartitionedSimResult {
    /// Total DMA + link events is not meaningful across devices; expose the
    /// per-partition event counts summed for reporting symmetry.
    pub fn events(&self) -> u64 {
        self.per_partition.iter().map(|p| p.events).sum()
    }

    /// Events the per-partition engines actually stepped, summed (below
    /// [`Self::events`] when the fast-forward extrapolated).
    pub fn events_processed(&self) -> u64 {
        self.per_partition.iter().map(|p| p.events_processed).sum()
    }

    /// Whether any partition's trace hit `max_trace_events` and dropped
    /// later events.
    pub fn truncated(&self) -> bool {
        self.per_partition.iter().any(|p| p.truncated)
    }
}

/// Simulate a chain of `(design, device)` partitions connected by streaming
/// links. Stages must be in chain order; consecutive stages are joined by a
/// [`LinkSpec`] derived from the upstream partition's last layer and the
/// two devices' link parameters.
pub fn simulate_partitioned(
    stages: &[(&Design, &Device)],
    cfg: &SimConfig,
) -> PartitionedSimResult {
    simulate_partitioned_with(stages, cfg, simulate)
}

/// The chain/link composition, parametrized over the per-partition engine
/// so [`super::reference`] reuses it verbatim around the pre-fast-forward
/// engine — the analytic link model is engine-independent.
pub(crate) fn simulate_partitioned_with(
    stages: &[(&Design, &Device)],
    cfg: &SimConfig,
    engine: impl Fn(&Design, &Device, &SimConfig) -> SimResult,
) -> PartitionedSimResult {
    assert!(!stages.is_empty(), "simulate_partitioned needs at least one stage");

    let per_partition: Vec<SimResult> =
        stages.iter().map(|(design, device)| engine(design, device, cfg)).collect();

    let links: Vec<LinkSpec> = LinkSpec::chain(stages);

    // Steady-state period: slowest compute stage vs slowest link.
    let periods: Vec<f64> = stages
        .iter()
        .map(|(d, _)| d.cycles_of(d.slowest()) as f64 / (d.clk_comp_mhz * 1e6))
        .collect();
    let compute_period = periods.iter().copied().fold(0.0_f64, f64::max);
    let mut steady_period = compute_period;
    let mut bottleneck = ChainBottleneck::Partition(
        periods
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0),
    );
    for (i, link) in links.iter().enumerate() {
        if link.transfer_s() > steady_period {
            steady_period = link.transfer_s();
            bottleneck = ChainBottleneck::Link(i);
        }
    }

    // Link stats: utilization over the steady period. Stall is charged to
    // the bottleneck link only — the chain drains at ONE rate, so the time
    // lost versus the compute-only period belongs to the link that sets it
    // (charging every slow link independently would double-count).
    let link_stats: Vec<LinkStat> = links
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let utilization = spec.transfer_s() / steady_period.max(f64::MIN_POSITIVE);
            let stall_s = if bottleneck == ChainBottleneck::Link(i) {
                cfg.batch as f64 * (steady_period - compute_period)
            } else {
                0.0
            };
            LinkStat { spec: spec.clone(), utilization, stall_s }
        })
        .collect();

    // 1-partition: the single-device simulation verbatim.
    if links.is_empty() {
        let only = &per_partition[0];
        let makespan = only.makespan_s;
        let total_stall = only.total_stall_s;
        return PartitionedSimResult {
            makespan_s: makespan,
            latency_ms: makespan * 1e3,
            steady_period_s: steady_period,
            bottleneck,
            per_partition,
            links: link_stats,
            total_stall_s: total_stall,
        };
    }

    // Chain composition: partition p starts once the first sample has made
    // it through everything upstream (fill + one drain per stage, plus each
    // hop's latency and transfer).
    let mut offsets = Vec::with_capacity(stages.len());
    let mut offset = 0.0_f64;
    for (i, (design, _)) in stages.iter().enumerate() {
        offsets.push(offset);
        if i < links.len() {
            let first_sample_s = design.latency_ms(1) * 1e-3;
            offset += first_sample_s + links[i].latency_s + links[i].transfer_s();
        }
    }
    let staged_finish = offsets
        .iter()
        .zip(&per_partition)
        .map(|(o, p)| o + p.makespan_s)
        .fold(0.0_f64, f64::max);
    // When a link is the bottleneck the downstream stages drain at the link
    // rate, not their own: the last stage cannot finish before its offset +
    // fill + batch link-limited periods.
    let (last_design, _) = stages.last().expect("non-empty chain");
    let last_fill_s = last_design.latency_ms(0) * 1e-3;
    let throttled_finish = offsets.last().expect("non-empty chain")
        + last_fill_s
        + cfg.batch as f64 * steady_period;
    let makespan = staged_finish.max(throttled_finish);

    let total_stall = per_partition.iter().map(|p| p.total_stall_s).sum::<f64>()
        + link_stats.iter().map(|l| l.stall_s).sum::<f64>();

    PartitionedSimResult {
        makespan_s: makespan,
        latency_ms: makespan * 1e3,
        steady_period_s: steady_period,
        bottleneck,
        per_partition,
        links: link_stats,
        total_stall_s: total_stall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, partition, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn one_stage_is_bit_identical_to_simulate() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let cfg = SimConfig::default();
        let direct = simulate(&r.design, &dev, &cfg);
        let chained = simulate_partitioned(&[(&r.design, &dev)], &cfg);
        assert_eq!(chained.per_partition[0], direct);
        assert_eq!(chained.makespan_s, direct.makespan_s);
        assert!(chained.links.is_empty());
        assert_eq!(chained.bottleneck, ChainBottleneck::Partition(0));
    }

    #[test]
    fn two_stage_chain_pipelines_rather_than_serializes() {
        let net = models::resnet18(Quant::W4A5);
        let devs = [Device::zcu102(), Device::zcu102()];
        let p = partition::partition(&net, &devs, &DseConfig::default()).unwrap();
        let stages: Vec<(&crate::dse::Design, &Device)> = p
            .parts
            .iter()
            .map(|part| (&part.result.design, &part.device))
            .collect();
        let cfg = SimConfig { batch: 16, ..Default::default() };
        let sim = simulate_partitioned(&stages, &cfg);
        assert_eq!(sim.per_partition.len(), 2);
        assert_eq!(sim.links.len(), 1);
        let serial: f64 = sim.per_partition.iter().map(|s| s.makespan_s).sum();
        // the chain overlaps the two partitions: far better than running
        // them back to back, but no faster than the slower of the two
        assert!(sim.makespan_s < serial, "chain {} vs serial {}", sim.makespan_s, serial);
        let slowest = sim
            .per_partition
            .iter()
            .map(|s| s.makespan_s)
            .fold(0.0_f64, f64::max);
        assert!(sim.makespan_s >= slowest * 0.999);
        let u = sim.links[0].utilization;
        assert!((0.0..=1.0 + 1e-9).contains(&u), "{u}");
    }

    #[test]
    fn only_the_bottleneck_link_is_charged_stall() {
        // three-stage toy chain where BOTH links are slower than compute:
        // the stall must equal batch x (slowest link - compute period)
        // charged once, not the sum over both slow links
        let net = models::toy_cnn(Quant::W8A8);
        let cuts = [2usize, 4];
        let mut mid = Device::zcu102();
        mid.link_bandwidth_bps = 1e6; // throttles both of its links
        let devs = [Device::zcu102(), mid, Device::zcu102()];
        let p = partition::partition_with_cuts(&net, &devs, &cuts, &DseConfig::default())
            .expect("pinned 3-way toy split is feasible");
        let stages: Vec<(&crate::dse::Design, &Device)> = p
            .parts
            .iter()
            .map(|part| (&part.result.design, &part.device))
            .collect();
        let batch = 4u64;
        let sim = simulate_partitioned(&stages, &SimConfig { batch, ..Default::default() });
        let compute_period = stages
            .iter()
            .map(|(d, _)| d.cycles_of(d.slowest()) as f64 / (d.clk_comp_mhz * 1e6))
            .fold(0.0_f64, f64::max);
        // both links outlast compute, so per-link charging would be 2 items
        for l in &sim.links {
            assert!(l.spec.transfer_s() > compute_period, "test premise: slow links");
        }
        assert!(matches!(sim.bottleneck, ChainBottleneck::Link(_)), "{:?}", sim.bottleneck);
        let charged: Vec<&LinkStat> = sim.links.iter().filter(|l| l.stall_s > 0.0).collect();
        assert_eq!(charged.len(), 1, "exactly one link carries the stall");
        let total: f64 = sim.links.iter().map(|l| l.stall_s).sum();
        assert!(
            (total - batch as f64 * (sim.steady_period_s - compute_period)).abs() < 1e-12,
            "stall accounts once for the chain's rate loss: {total}"
        );
    }

    #[test]
    fn starved_link_becomes_the_bottleneck_and_stalls() {
        let net = models::toy_cnn(Quant::W8A8);
        let mut tx = Device::zcu102();
        let rx = Device::zcu102();
        tx.link_bandwidth_bps = 1e6; // pathological 1 Mbps chain link
        let devs = [tx, rx];
        let p = partition::partition(&net, &devs, &DseConfig::default()).unwrap();
        let stages: Vec<(&crate::dse::Design, &Device)> = p
            .parts
            .iter()
            .map(|part| (&part.result.design, &part.device))
            .collect();
        let sim = simulate_partitioned(&stages, &SimConfig { batch: 4, ..Default::default() });
        assert!(matches!(sim.bottleneck, ChainBottleneck::Link(0)), "{:?}", sim.bottleneck);
        assert!(sim.links[0].stall_s > 0.0);
        assert!((sim.links[0].utilization - 1.0).abs() < 1e-9);
        // the throttled finish dominates: makespan scales with the link rate
        assert!(sim.makespan_s >= 4.0 * sim.steady_period_s);
    }
}
