//! Area model `a(V)` — paper §III-C.
//!
//! The paper fits regression models to post-synthesis Vivado samples; we use
//! deterministic analytic fits with coefficients calibrated to the published
//! per-resource costs of the same toolflow family (fpgaConvNet [3],
//! FINN [2]). The DSE consumes `a(V)` only as a monotone cost, so the code
//! path exercised is identical (see DESIGN.md §Substitutions).
//!
//! BRAM is modeled geometrically: a BRAM36 provides at most 72 data bits x
//! 512 words, so a memory of width `M_wid` and depth `D` costs
//! `ceil(M_wid/72) · ceil(D/512)` blocks. This quantization waste is exactly
//! the under-utilization effect FINN reports and is what makes "vanilla"
//! designs memory-infeasible on small devices.

use super::CeConfig;
use crate::device::{Device, BRAM36_BITS, BRAM36_DEPTH, BRAM36_WIDTH};
use crate::ir::{Layer, OpKind};

/// BRAM block counts split into the paper's Table III categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BramBreakdown {
    /// Static on-chip weight storage (`wt_mem`).
    pub wt_mem: u32,
    /// Shared dual-port buffer for off-chip weights (`wt_buff`).
    pub wt_buff: u32,
    /// Inter-CE FIFOs, line buffers, accumulators (`act_fifo`).
    pub act_fifo: u32,
}

impl BramBreakdown {
    pub fn total(&self) -> u32 {
        self.wt_mem + self.wt_buff + self.act_fifo
    }

    /// Usage in megabytes, Table III convention: block count x max capacity.
    pub fn mbytes(&self) -> f64 {
        self.total() as f64 * BRAM36_BITS as f64 / 8.0 / 1e6
    }
}

impl std::ops::Add for BramBreakdown {
    type Output = BramBreakdown;
    fn add(self, o: BramBreakdown) -> BramBreakdown {
        BramBreakdown {
            wt_mem: self.wt_mem + o.wt_mem,
            wt_buff: self.wt_buff + o.wt_buff,
            act_fifo: self.act_fifo + o.act_fifo,
        }
    }
}

/// Componentwise difference. Only valid when `o` is a component of `self`
/// (e.g. removing one CE's contribution from a running total).
impl std::ops::Sub for BramBreakdown {
    type Output = BramBreakdown;
    fn sub(self, o: BramBreakdown) -> BramBreakdown {
        BramBreakdown {
            wt_mem: self.wt_mem - o.wt_mem,
            wt_buff: self.wt_buff - o.wt_buff,
            act_fifo: self.act_fifo - o.act_fifo,
        }
    }
}

/// Area vector of one CE (or a sum over CEs).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Area {
    pub dsp: u32,
    pub lut: u32,
    pub ff: u32,
    pub bram: BramBreakdown,
}

impl std::ops::Add for Area {
    type Output = Area;
    fn add(self, o: Area) -> Area {
        Area {
            dsp: self.dsp + o.dsp,
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
        }
    }
}

/// Componentwise difference. Only valid when `o` is a component of `self`
/// (e.g. removing one CE's contribution from a running total) — used by the
/// incremental aggregate maintenance in [`crate::dse::Design`].
impl std::ops::Sub for Area {
    type Output = Area;
    fn sub(self, o: Area) -> Area {
        Area {
            dsp: self.dsp - o.dsp,
            lut: self.lut - o.lut,
            ff: self.ff - o.ff,
            bram: self.bram - o.bram,
        }
    }
}

impl std::iter::Sum for Area {
    fn sum<I: Iterator<Item = Area>>(iter: I) -> Area {
        iter.fold(Area::default(), |a, b| a + b)
    }
}

impl Area {
    /// Does this area fit within the device, counting URAM as extra
    /// BRAM36-equivalents for weight storage?
    pub fn fits(&self, dev: &Device) -> bool {
        self.dsp <= dev.dsp && self.lut <= dev.lut && self.ff <= dev.ff
            && self.bram.total() <= dev.mem_bram_equiv()
    }

    /// Memory utilization relative to the device's on-chip capacity
    /// (1.0 == 100%; > 1.0 means infeasible, as in Table III's "172%").
    pub fn mem_utilization(&self, dev: &Device) -> f64 {
        self.bram.total() as f64 / dev.mem_bram_equiv() as f64
    }
}

/// DSP slices per parallel MAC as a function of bitwidths: DSP48E2 packs two
/// sub-8-bit MACs (and four 4-bit MACs with shared-input tricks); ≤ 4-bit
/// multiplies commonly fall back to LUTs entirely in FINN-style designs, but
/// we keep a small DSP share for the accumulate chain.
fn dsp_per_mac(w_bits: u32, a_bits: u32) -> f64 {
    let m = w_bits.max(a_bits);
    match m {
        0..=5 => 0.25,
        6..=8 => 0.5,
        9..=18 => 1.0,
        _ => 5.0, // f32 MAC
    }
}

/// LUTs per parallel MAC (multiplier slivers, accumulate and mux glue —
/// the bulk of the multiply lives in the DSP, see `dsp_per_mac`).
fn lut_per_mac(w_bits: u32, a_bits: u32) -> f64 {
    let m = (w_bits.max(a_bits)) as f64;
    3.5 * m + 12.0
}

/// BRAM36 width/depth configuration modes (simple dual-port): 32768x1 ... 512x72.
const BRAM_MODES: [(u64, u64); 7] =
    [(1, 32768), (2, 16384), (4, 8192), (9, 4096), (18, 2048), (36, 1024), (72, 512)];

/// BRAM36 blocks for a memory of `width` bits x `depth` words.
///
/// Narrow words (≤ 72 bits) use the block's native width modes, so
/// consecutive words pack into the block's capacity; words wider than one
/// block's port need `ceil(width/72)` parallel columns of `ceil(depth/512)`
/// blocks each. The capacity waste of that wide/shallow geometry is the
/// under-utilization effect FINN [2] reports, and it grows with the unroll
/// factors — this is what makes highly-parallel "vanilla" designs
/// memory-infeasible even when the raw bit count would fit.
pub fn bram_blocks(width_bits: u64, depth: u64) -> u32 {
    if width_bits == 0 || depth == 0 {
        return 0;
    }
    if width_bits <= BRAM36_WIDTH {
        // smallest width mode that fits the word
        let (_, mode_depth) =
            BRAM_MODES.iter().find(|(w, _)| *w >= width_bits).copied().unwrap();
        depth.div_ceil(mode_depth) as u32
    } else {
        (width_bits.div_ceil(BRAM36_WIDTH) * depth.div_ceil(BRAM36_DEPTH)) as u32
    }
}

/// Full area model for one CE.
pub fn area(layer: &Layer, cfg: &CeConfig, m_wid_bits: u64) -> Area {
    let par = cfg.parallelism();
    let (w, a) = (layer.quant.w_bits, layer.quant.a_bits);

    // --- compute fabric ---
    let (dsp, lut_pe) = if layer.has_weights() {
        (
            (par as f64 * dsp_per_mac(w, a)).ceil() as u32,
            (par as f64 * lut_per_mac(w, a)) as u32,
        )
    } else {
        // pool/eltwise/relu PEs: comparators/adders only, no DSP
        (0, (cfg.cp as f64 * 8.0 * a as f64 / 2.0) as u32)
    };

    // control FSM + address counters + RAW check (paper §III-B)
    let lut_ctrl = 600 + if cfg.frag.is_streaming() { 400 } else { 0 };
    // data forking tree (conv only): f copies of the activation stream
    let lut_fork = match layer.op {
        OpKind::Conv { .. } => (cfg.fp as f64 * cfg.cp as f64 * a as f64 * 1.5) as u32,
        _ => 0,
    };
    let lut = lut_pe + lut_ctrl + lut_fork;
    let ff = lut * 2; // pipeline registers track LUT usage closely

    // --- memories ---
    // static weight region: width M_wid x depth M_on_dep
    let wt_mem = bram_blocks(m_wid_bits, cfg.frag.m_on_dep());
    // shared dynamic buffer: dual-port, width M_wid x depth u_off
    let wt_buff = bram_blocks(m_wid_bits, cfg.frag.u_off);

    // line buffers for the sliding window: (k-1) rows x w pixels x c values
    let line_bits = match layer.op {
        OpKind::Conv { kernel, .. } | OpKind::Pool { kernel, .. } if kernel > 1 => {
            (kernel as u64 - 1) * layer.w_in as u64 * layer.c_in as u64 * a as u64
        }
        _ => 0,
    };
    // Line buffers are narrow-and-deep, so they are capacity-bound; the
    // inter-CE FIFO is 256 words of the output stream width.
    let line_blocks = if line_bits > 0 { line_bits.div_ceil(BRAM36_BITS) as u32 } else { 0 };
    let act_fifo = line_blocks + bram_blocks(cfg.fp as u64 * a as u64, 256);

    Area {
        dsp,
        lut,
        ff,
        bram: BramBreakdown { wt_mem, wt_buff, act_fifo },
    }
}

#[cfg(test)]
mod tests {
    use super::super::memory;
    use super::*;
    use crate::ce::Fragmentation;
    use crate::ir::Quant;

    fn conv_cfg(kp: u32, cp: u32, fp: u32, off: u64, n: u32) -> (Layer, CeConfig) {
        let l = Layer::conv("c", 64, 128, 28, 28, 3, 1, 1, Quant::W4A5);
        let m_dep = memory::m_dep(&l, kp, cp, fp);
        let cfg = CeConfig { kp, cp, fp, frag: Fragmentation::new(m_dep, off, n) };
        (l, cfg)
    }

    #[test]
    fn bram_geometry_quantizes() {
        // 72 bits x 512 deep exactly = 1 block
        assert_eq!(bram_blocks(72, 512), 1);
        // 73 bits -> 2 blocks wide
        assert_eq!(bram_blocks(73, 512), 2);
        // 513 deep -> 2 blocks deep
        assert_eq!(bram_blocks(72, 513), 2);
        assert_eq!(bram_blocks(0, 100), 0);
    }

    #[test]
    fn eviction_shrinks_wt_mem_adds_wt_buff() {
        let (l, on) = conv_cfg(1, 4, 4, 0, 1);
        let (_, half) = conv_cfg(1, 4, 4, memory::m_dep(&l, 1, 4, 4) / 2, 4);
        let wid = memory::m_wid_bits(&l, 1, 4, 4);
        let a_on = area(&l, &on, wid);
        let a_half = area(&l, &half, wid);
        assert!(a_half.bram.wt_mem < a_on.bram.wt_mem);
        assert_eq!(a_on.bram.wt_buff, 0);
        assert!(a_half.bram.wt_buff > 0);
        // buffer is much smaller than what it saved
        assert!(a_half.bram.wt_buff < a_on.bram.wt_mem - a_half.bram.wt_mem);
    }

    #[test]
    fn dsp_scales_with_parallelism() {
        let (l, c1) = conv_cfg(1, 1, 1, 0, 1);
        let (_, c16) = conv_cfg(1, 4, 4, 0, 1);
        let a1 = area(&l, &c1, memory::m_wid_bits(&l, 1, 1, 1));
        let a16 = area(&l, &c16, memory::m_wid_bits(&l, 1, 4, 4));
        // W4A5 packs 4 MACs/DSP: 16 parallel MACs -> 4 DSPs vs 1 (ceil) serial
        assert_eq!(a16.dsp, 4, "{:?}", a16);
        assert!(a16.dsp > a1.dsp);
    }

    #[test]
    fn quantization_waste_visible_at_wide_words() {
        // Wide word + shallow depth wastes BRAM capacity (FINN effect):
        // utilization of capacity < 50%
        let (l, cfg) = conv_cfg(9, 16, 16, 0, 1);
        let wid = memory::m_wid_bits(&l, 9, 16, 16); // 9*16*16*4 = 9216 bits
        let a = area(&l, &cfg, wid);
        let capacity_bits = a.bram.wt_mem as u64 * BRAM36_BITS;
        assert!(capacity_bits as f64 > 1.3 * l.weight_bits() as f64);
    }

    #[test]
    fn fits_checks_all_resources() {
        let dev = crate::device::Device::zedboard();
        let a = Area { dsp: 221, ..Default::default() };
        assert!(!a.fits(&dev));
        let a = Area { dsp: 10, lut: 1000, ff: 100, bram: BramBreakdown { wt_mem: 10, wt_buff: 0, act_fifo: 2 } };
        assert!(a.fits(&dev));
    }
}
