//! Off-chip bandwidth model `β(V)` — paper Eq. 5.

use super::memory::Fragmentation;

/// Average off-chip bandwidth required by one CE in bits/second:
///
/// ```text
/// β(V) = M_wid · clk_comp · u_off / (u_on + u_off)
/// ```
///
/// The product of the first two terms is the PE array's weight-word consume
/// rate in bits/s; the scaling term is the fraction of those words that must
/// come from off-chip. The dual-port shared buffer lets the DMA write while
/// the PEs read either region, so the *average* rate is what matters
/// (paper §III-C); the burst-level schedule is handled in
/// [`crate::schedule`].
pub fn beta_bps(m_wid_bits: u64, clk_comp_mhz: f64, frag: &Fragmentation) -> f64 {
    m_wid_bits as f64 * clk_comp_mhz * 1e6 * frag.off_chip_ratio()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_on_chip_needs_no_bandwidth() {
        let f = Fragmentation::all_on_chip(4096);
        assert_eq!(beta_bps(64, 200.0, &f), 0.0);
    }

    #[test]
    fn all_off_chip_needs_full_word_rate() {
        let f = Fragmentation::new(4096, 4096, 4);
        let b = beta_bps(64, 200.0, &f);
        assert!((b - 64.0 * 200e6).abs() < 1.0);
    }

    #[test]
    fn eq5_half_streamed() {
        let f = Fragmentation::new(1024, 512, 2);
        let b = beta_bps(32, 100.0, &f);
        assert!((b - 32.0 * 100e6 * 0.5).abs() < 1.0);
    }

    #[test]
    fn bandwidth_monotone_in_offchip_share() {
        let mut last = -1.0;
        for off in [0u64, 128, 256, 512, 768, 1024] {
            let f = Fragmentation::new(1024, off, 4);
            let b = beta_bps(48, 250.0, &f);
            assert!(b >= last, "β must be monotone in evicted share");
            last = b;
        }
    }
}
