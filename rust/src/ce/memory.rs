//! Weights-memory fragmentation — paper §III-B, Fig. 3, Eq. 1–2.

use crate::ir::Layer;

/// Required weights-memory depth in words — paper Eq. 1:
/// `M_dep = f_t · c_t · k_t²` where `f_t = f/f_p`, `c_t = c/c_p`,
/// `k_t² = k²/k_p` (we fold the paper's `k_p²` into the single factor `kp`
/// unrolling over kernel positions).
pub fn m_dep(layer: &Layer, kp: u32, cp: u32, fp: u32) -> u64 {
    if !layer.has_weights() {
        return 0;
    }
    let k2 = (layer.kernel() as u64).pow(2);
    let f_t = (layer.c_out as u64).div_ceil(fp as u64);
    let c_t = (layer.c_per_group() as u64).div_ceil(cp as u64);
    let k_t = k2.div_ceil(kp as u64);
    f_t * c_t * k_t
}

/// Memory word width in bits — paper Eq. 1: `M_wid = f_p · c_p · k_p² · L_W`.
pub fn m_wid_bits(layer: &Layer, kp: u32, cp: u32, fp: u32) -> u64 {
    if !layer.has_weights() {
        return 0;
    }
    kp as u64 * cp as u64 * fp as u64 * layer.quant.w_bits as u64
}

/// Fragmentation of the weights memory into `n` static/dynamic fragment
/// pairs (paper Fig. 3, Eq. 2):
///
/// ```text
/// M_on_dep  = u_on  · n      (static, stays on-chip)
/// M_off_dep = u_off · n      (dynamic, streamed through the shared buffer)
/// M_dep     = M_on_dep + M_off_dep
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fragmentation {
    /// Number of fragment pairs `n` (≥ 1 for weight layers).
    pub n: u32,
    /// Words per on-chip fragment `u_on`.
    pub u_on: u64,
    /// Words per off-chip fragment `u_off`.
    pub u_off: u64,
}

impl Fragmentation {
    /// Everything static on-chip: one fragment, `u_off = 0`.
    pub fn all_on_chip(m_dep: u64) -> Fragmentation {
        Fragmentation { n: 1, u_on: m_dep, u_off: 0 }
    }

    /// Build a fragmentation covering `m_dep` total words with `m_off` of
    /// them dynamic, split over `n` fragments. Per-fragment depths are
    /// rounded up so that `n · (u_on + u_off) ≥ m_dep` always holds (the
    /// pad words are dead addresses the counters skip over).
    pub fn new(m_dep: u64, m_off: u64, n: u32) -> Fragmentation {
        assert!(n >= 1, "fragment count must be >= 1");
        let m_off = m_off.min(m_dep);
        let u = m_dep.div_ceil(n as u64); // total depth per fragment pair
        let u_off = m_off.div_ceil(n as u64).min(u);
        Fragmentation { n, u_on: u - u_off, u_off }
    }

    /// `M_on_dep = u_on · n`.
    pub fn m_on_dep(&self) -> u64 {
        self.u_on * self.n as u64
    }

    /// `M_off_dep = u_off · n`.
    pub fn m_off_dep(&self) -> u64 {
        self.u_off * self.n as u64
    }

    /// `M_dep = M_on_dep + M_off_dep`.
    pub fn m_dep(&self) -> u64 {
        self.m_on_dep() + self.m_off_dep()
    }

    /// Fraction of the weight words that are dynamic (streamed), the
    /// `u_off / (u_on + u_off)` scaling term of paper Eq. 5.
    pub fn off_chip_ratio(&self) -> f64 {
        if self.u_on + self.u_off == 0 {
            return 0.0;
        }
        self.u_off as f64 / (self.u_on + self.u_off) as f64
    }

    /// True when any portion of the weights is streamed from off-chip.
    pub fn is_streaming(&self) -> bool {
        self.u_off > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;

    #[test]
    fn eq1_depth_width_product_conserves_bits() {
        let l = Layer::conv("c", 64, 128, 14, 14, 3, 1, 1, Quant::W8A8);
        for (kp, cp, fp) in [(1, 1, 1), (9, 4, 16), (3, 64, 128)] {
            let bits = m_dep(&l, kp, cp, fp) * m_wid_bits(&l, kp, cp, fp);
            assert_eq!(bits, l.weight_bits(), "kp={kp} cp={cp} fp={fp}");
        }
    }

    #[test]
    fn depthwise_uses_group_depth() {
        let l = Layer::depthwise("dw", 96, 28, 28, 3, 1, 1, Quant::W8A8);
        // c_per_group = 1, so depth = (f/fp) * 1 * k2/kp
        assert_eq!(m_dep(&l, 1, 1, 1), 96 * 9);
        assert_eq!(m_dep(&l, 9, 1, 96), 1);
    }

    #[test]
    fn non_weight_layer_has_no_memory() {
        let l = Layer {
            name: "add".into(),
            op: crate::ir::OpKind::EltwiseAdd,
            c_in: 64,
            c_out: 64,
            h_in: 14,
            w_in: 14,
            quant: Quant::W8A8,
            skip_from: Some(0),
        };
        assert_eq!(m_dep(&l, 1, 1, 1), 0);
        assert_eq!(m_wid_bits(&l, 1, 1, 1), 0);
    }

    #[test]
    fn eq2_fragmentation_partition() {
        let f = Fragmentation::new(1000, 400, 4);
        assert_eq!(f.n, 4);
        assert_eq!(f.u_on + f.u_off, 250);
        assert_eq!(f.u_off, 100);
        assert_eq!(f.m_dep(), 1000);
        assert_eq!(f.m_off_dep(), 400);
        assert!((f.off_chip_ratio() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn fragmentation_rounds_up_with_padding() {
        let f = Fragmentation::new(1000, 300, 7);
        // covers at least the requested words
        assert!(f.m_dep() >= 1000);
        assert!(f.m_off_dep() >= 300);
        assert!(f.is_streaming());
    }

    #[test]
    fn all_off_chip_allowed() {
        let f = Fragmentation::new(512, 512, 2);
        assert_eq!(f.u_on, 0);
        assert_eq!(f.m_off_dep(), 512);
        assert!((f.off_chip_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn off_request_capped_at_total() {
        let f = Fragmentation::new(100, 5000, 1);
        assert_eq!(f.m_off_dep(), 100);
        assert_eq!(f.u_on, 0);
    }
}
