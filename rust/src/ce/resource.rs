//! Memory resource-type assignment and BRAM overclocking.
//!
//! The baseline area model implements every weight memory in BRAM36 blocks.
//! The toolflows the paper builds on expose more freedom:
//!
//! - **fpgaConvNet [3] / hls4ml [4]** choose the storage primitive per layer
//!   (BRAM vs distributed LUTRAM); DNNExplorer [10] folds that choice into
//!   the DSE. UltraScale+ parts add URAM (288 Kib, 72-bit fixed width).
//! - **FINN [2]** observed that wide-and-shallow weight memories leave BRAM
//!   capacity stranded and recovered it by *overclocking*: run the BRAM at
//!   `ω·clk_comp` and serve the PE array through a `1:ω` gearbox, so a port
//!   of width `M_wid/ω` sustains the same words-per-compute-cycle.
//!
//! This module implements both as a post-DSE assignment pass
//! ([`assign_memory_tech`]): each weight memory is placed in the technology
//! with the lowest *scarcity-weighted* cost on the target device. The pass
//! never changes timing — every technology option provides one full memory
//! word per compute cycle — so θ, β and the burst schedule are untouched;
//! only the area vector changes.

use super::area::{bram_blocks, Area};
use crate::device::Device;
use crate::dse::Design;

/// URAM288 geometry: fixed 72-bit ports, 4096 words deep.
pub const URAM_WIDTH: u64 = 72;
/// URAM288 depth at the fixed width.
pub const URAM_DEPTH: u64 = 4096;
/// Effective LUTRAM storage density: bits of distributed RAM per LUT
/// consumed. A SLICEM LUT6 stores 64 bits but address decode, replication
/// for read ports, and placement overhead put the practical figure near 32.
pub const LUTRAM_BITS_PER_LUT: u64 = 32;

/// Storage technology for one layer's static weight region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemTech {
    /// BRAM36 blocks at the native width modes (the baseline model).
    Bram,
    /// BRAM36 blocks overclocked by the given gearbox ratio ω ≥ 2 (FINN).
    BramOverclocked(u32),
    /// URAM288 blocks (only on devices that have URAM).
    Uram,
    /// Distributed LUTRAM (costs LUTs instead of memory blocks).
    Lutram,
}

impl std::fmt::Display for MemTech {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemTech::Bram => write!(f, "bram"),
            MemTech::BramOverclocked(w) => write!(f, "bram@{w}x"),
            MemTech::Uram => write!(f, "uram"),
            MemTech::Lutram => write!(f, "lutram"),
        }
    }
}

/// URAM288 blocks for a `width x depth` memory. URAM ports are fixed at 72
/// bits, so wide words always need parallel columns.
pub fn uram_blocks(width_bits: u64, depth: u64) -> u32 {
    if width_bits == 0 || depth == 0 {
        return 0;
    }
    (width_bits.div_ceil(URAM_WIDTH) * depth.div_ceil(URAM_DEPTH)) as u32
}

/// LUTs consumed by a LUTRAM implementation of a `width x depth` memory.
pub fn lutram_luts(width_bits: u64, depth: u64) -> u32 {
    ((width_bits * depth).div_ceil(LUTRAM_BITS_PER_LUT)) as u32
}

/// BRAM36 blocks when overclocked by `omega`: the port narrows to
/// `ceil(width/ω)` and the depth stretches to `depth·ω` — same bits, better
/// packing for wide-and-shallow geometries (FINN's recovery trick).
pub fn bram_blocks_overclocked(width_bits: u64, depth: u64, omega: u32) -> u32 {
    if omega <= 1 {
        return bram_blocks(width_bits, depth);
    }
    bram_blocks(width_bits.div_ceil(omega as u64), depth * omega as u64)
}

/// Gearbox LUT overhead of an ω:1 overclocked memory interface (the
/// serializer/deserializer between the two clock domains).
fn gearbox_luts(width_bits: u64, omega: u32) -> u32 {
    if omega <= 1 {
        0
    } else {
        (width_bits as u32) * 2 + 64 * omega
    }
}

/// Options of the assignment pass.
#[derive(Debug, Clone, Copy)]
pub struct TechOptions {
    /// Allow URAM placement (ignored on devices with no URAM).
    pub use_uram: bool,
    /// Allow LUTRAM placement for small memories.
    pub use_lutram: bool,
    /// Maximum overclocking ratio ω (1 = disabled). Bounded by how much
    /// faster than `clk_comp` the fabric BRAM can run; FINN uses up to ~2.5x,
    /// we cap at the device's `clk_dma/clk_comp` ratio rounded down.
    pub max_overclock: u32,
    /// Memories above this bit count are not eligible for LUTRAM (routing
    /// pressure makes huge distributed RAMs impractical).
    pub lutram_bits_cap: u64,
}

impl Default for TechOptions {
    fn default() -> Self {
        TechOptions { use_uram: true, use_lutram: true, max_overclock: 2, lutram_bits_cap: 1 << 16 }
    }
}

impl TechOptions {
    /// Clamp the overclock ratio to the device's clock headroom.
    pub fn for_device(dev: &Device) -> TechOptions {
        let headroom = (dev.clk_dma_mhz / dev.clk_comp_mhz).floor().max(1.0) as u32;
        TechOptions { max_overclock: headroom.min(4), ..Default::default() }
    }
}

/// Technology choice for one layer's static weight region.
#[derive(Debug, Clone, Copy)]
pub struct TechChoice {
    pub layer: usize,
    pub tech: MemTech,
    /// BRAM36 blocks consumed (0 for URAM/LUTRAM placements).
    pub bram: u32,
    /// URAM blocks consumed.
    pub uram: u32,
    /// Extra LUTs consumed (LUTRAM storage or overclock gearbox).
    pub luts: u32,
}

/// Result of the assignment pass over a whole design.
#[derive(Debug, Clone)]
pub struct TechPlan {
    pub choices: Vec<TechChoice>,
    /// BRAM36 blocks the baseline (all-BRAM) implementation would use for
    /// the same static regions.
    pub baseline_bram: u32,
    /// Totals after assignment.
    pub bram: u32,
    pub uram: u32,
    pub extra_luts: u32,
}

impl TechPlan {
    /// BRAM36-equivalents saved versus the all-BRAM baseline (URAM spending
    /// is converted at the device's 8:1 equivalence).
    pub fn bram_saved(&self) -> i64 {
        self.baseline_bram as i64 - self.bram as i64 - self.uram as i64 * 8
    }

    /// Total area delta to apply on top of a design's baseline area.
    pub fn apply(&self, mut area: Area) -> Area {
        let saved = self.baseline_bram - self.bram; // blocks moved off BRAM
        area.bram.wt_mem -= saved.min(area.bram.wt_mem);
        area.lut += self.extra_luts;
        area
    }
}

/// Assign a storage technology to every weight layer's *static* region.
///
/// Greedy scarcity-weighted choice: for each memory, each candidate
/// technology is priced as `resource_used / resource_available` summed over
/// the resources it touches, and the cheapest feasible candidate wins.
/// Running totals guarantee the plan never over-commits URAM or LUTs.
pub fn assign_memory_tech(design: &Design, device: &Device, opts: &TechOptions) -> TechPlan {
    let mut choices = Vec::new();
    let mut baseline_bram = 0u32;
    let (mut used_bram, mut used_uram, mut used_luts) = (0u32, 0u32, 0u32);
    // LUT headroom beyond what the design's compute already uses.
    let lut_budget = device.lut.saturating_sub(design.total_area().lut);
    let uram_budget = if opts.use_uram { device.uram } else { 0 };

    // Biggest memories first: they dominate and should get first pick of the
    // scarce technologies.
    let mut order: Vec<usize> = (0..design.len())
        .filter(|&i| design.network.layers[i].has_weights())
        .collect();
    let geom = |i: usize| {
        let m = crate::ce::CeModel::new(
            &design.network.layers[i],
            design.cfgs[i],
            design.clk_comp_mhz,
        );
        (m.m_wid_bits(), design.cfgs[i].frag.m_on_dep())
    };
    order.sort_by_key(|&i| {
        let (w, d) = geom(i);
        std::cmp::Reverse(w * d)
    });

    for i in order {
        let (width, depth) = geom(i);
        let base = bram_blocks(width, depth);
        baseline_bram += base;
        if base == 0 {
            continue; // fully-evicted or zero-size static region
        }

        // candidate list: (tech, bram, uram, luts)
        let mut cands: Vec<(MemTech, u32, u32, u32)> = vec![(MemTech::Bram, base, 0, 0)];
        for omega in 2..=opts.max_overclock {
            let b = bram_blocks_overclocked(width, depth, omega);
            if b < base {
                cands.push((MemTech::BramOverclocked(omega), b, 0, gearbox_luts(width, omega)));
            }
        }
        if uram_budget > 0 {
            cands.push((MemTech::Uram, 0, uram_blocks(width, depth), 0));
        }
        let bits = width * depth;
        if opts.use_lutram && bits <= opts.lutram_bits_cap {
            cands.push((MemTech::Lutram, 0, 0, lutram_luts(width, depth)));
        }

        // Scarcity-weighted cost: each resource is priced against its *own*
        // pool, so a device with idle URAM (or LUT headroom) sees those as
        // cheap relative to contended BRAM. Infeasible candidates (pool
        // already committed) are skipped.
        let bram_pool = device.bram36.max(1) as f64;
        let uram_pool = uram_budget.max(1) as f64;
        let lut_pool = lut_budget.max(1) as f64;
        let best = cands
            .into_iter()
            .filter(|&(_, _, u, l)| used_uram + u <= uram_budget && used_luts + l <= lut_budget)
            .min_by(|a, b| {
                let cost = |c: &(MemTech, u32, u32, u32)| {
                    c.1 as f64 / bram_pool + c.2 as f64 / uram_pool + c.3 as f64 / lut_pool
                };
                cost(a).partial_cmp(&cost(b)).unwrap()
            })
            .unwrap_or((MemTech::Bram, base, 0, 0));

        used_bram += best.1;
        used_uram += best.2;
        used_luts += best.3;
        choices.push(TechChoice { layer: i, tech: best.0, bram: best.1, uram: best.2, luts: best.3 });
    }

    TechPlan { choices, baseline_bram, bram: used_bram, uram: used_uram, extra_luts: used_luts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn uram_geometry() {
        assert_eq!(uram_blocks(72, 4096), 1);
        assert_eq!(uram_blocks(73, 4096), 2);
        assert_eq!(uram_blocks(72, 4097), 2);
        assert_eq!(uram_blocks(0, 100), 0);
    }

    #[test]
    fn overclock_recovers_wide_shallow_waste() {
        // 144 bits x 256 words: plain = 2 columns x 1 = 2 blocks at half
        // depth utilization; 2x overclock = 72 bits x 512 = exactly 1 block.
        assert_eq!(bram_blocks(144, 256), 2);
        assert_eq!(bram_blocks_overclocked(144, 256, 2), 1);
        // ω=1 falls back to the plain model
        assert_eq!(bram_blocks_overclocked(144, 256, 1), 2);
    }

    #[test]
    fn overclock_never_helps_deep_narrow() {
        // 8 bits x 32768: already capacity-bound, ω only makes it deeper.
        assert!(bram_blocks_overclocked(8, 32768, 2) >= bram_blocks(8, 32768));
    }

    #[test]
    fn lutram_density() {
        assert_eq!(lutram_luts(8, 128), 32); // 1024 bits / 32
        assert_eq!(lutram_luts(0, 10), 0);
    }

    #[test]
    fn plan_on_uram_device_moves_big_memories_to_uram() {
        let net = models::resnet50(Quant::W8A8);
        let dev = Device::u50();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let plan = assign_memory_tech(&r.design, &dev, &TechOptions::for_device(&dev));
        assert!(plan.uram > 0, "U50 plans should use URAM");
        assert!(plan.uram <= dev.uram);
        assert!(plan.bram_saved() != 0 || plan.uram > 0);
    }

    #[test]
    fn plan_without_uram_uses_lutram_or_overclock_only() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102(); // no URAM
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let plan = assign_memory_tech(&r.design, &dev, &TechOptions::for_device(&dev));
        assert_eq!(plan.uram, 0);
        assert!(plan.bram <= plan.baseline_bram, "assignment must never cost extra BRAM");
        for c in &plan.choices {
            assert_ne!(c.tech, MemTech::Uram);
        }
    }

    #[test]
    fn plan_respects_lut_budget() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let plan = assign_memory_tech(&r.design, &dev, &TechOptions::for_device(&dev));
        let total_lut = r.design.total_area().lut + plan.extra_luts;
        assert!(total_lut <= dev.lut, "extra LUTs {} blow the device", plan.extra_luts);
    }

    #[test]
    fn disabled_options_fall_back_to_bram() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::u250();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let opts = TechOptions { use_uram: false, use_lutram: false, max_overclock: 1, ..Default::default() };
        let plan = assign_memory_tech(&r.design, &dev, &opts);
        assert_eq!(plan.bram, plan.baseline_bram);
        assert_eq!(plan.uram, 0);
        assert_eq!(plan.extra_luts, 0);
        assert!(plan.choices.iter().all(|c| c.tech == MemTech::Bram));
    }

    #[test]
    fn apply_updates_area_vector() {
        let net = models::resnet18(Quant::W4A5);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let plan = assign_memory_tech(&r.design, &dev, &TechOptions::for_device(&dev));
        let before = r.design.total_area();
        let after = plan.apply(before);
        assert!(after.bram.total() <= before.bram.total());
        assert!(after.lut >= before.lut);
    }
}
