//! Throughput model `θ(V)` — paper §III-C.
//!
//! The analytic model is cycle-accurate for the stall-free case (the DMA
//! scheduler's write-burst balancing makes the stall-free assumption hold;
//! the event simulator in [`crate::sim`] validates it and quantifies stalls
//! when it does not).

use super::{memory, CeConfig};
use crate::ir::{Layer, OpKind};

/// Cycles for one inference sample to traverse this CE.
///
/// For weight layers the PE array reads one memory word per cycle and each
/// output pixel consumes the full memory depth, so
/// `cycles = ĥ · ŵ · M_dep` (batch = 1). The CE can additionally be bound by
/// its input or output stream ports (width `c_p` / `f_p` words per cycle).
/// Non-weight layers are stream-bound.
pub fn cycles_per_sample(layer: &Layer, cfg: &CeConfig) -> u64 {
    let pixels_out = layer.h_out() as u64 * layer.w_out() as u64;
    match layer.op {
        OpKind::Conv { .. } | OpKind::Fc => {
            let compute = pixels_out * memory::m_dep(layer, cfg.kp, cfg.cp, cfg.fp);
            let input = stream_cycles(layer.input_count(), input_parallel(layer, cfg));
            let output = stream_cycles(layer.output_count(), cfg.fp);
            compute.max(input).max(output).max(1)
        }
        OpKind::Pool { kernel, .. } => {
            // window reduction: k^2/kp values folded per output value
            let k2 = (kernel as u64).pow(2);
            let compute = pixels_out
                * (layer.c_in as u64).div_ceil(cfg.cp as u64)
                * k2.div_ceil(cfg.kp as u64);
            compute.max(stream_cycles(layer.input_count(), cfg.cp)).max(1)
        }
        OpKind::GlobalAvgPool | OpKind::Relu => {
            stream_cycles(layer.input_count(), cfg.cp).max(1)
        }
        OpKind::EltwiseAdd => {
            // two input streams consumed in lockstep
            stream_cycles(layer.input_count(), cfg.cp).max(1)
        }
    }
}

fn stream_cycles(values: u64, width: u32) -> u64 {
    values.div_ceil(width as u64)
}

/// Input channels consumed per cycle. A dense convolution forks its `c_p`
/// input channels to all filters; a grouped/depthwise convolution's filter
/// unroll `f_p` additionally spans `f_p·groups/f` groups, each with its own
/// input channels (for depthwise, `f_p` filters == `f_p` input channels).
fn input_parallel(layer: &Layer, cfg: &CeConfig) -> u32 {
    match layer.op {
        OpKind::Conv { groups, .. } if groups > 1 => {
            let groups_in_parallel =
                ((cfg.fp as u64 * groups as u64) / layer.c_out.max(1) as u64).max(1);
            (cfg.cp as u64 * groups_in_parallel).min(layer.c_in as u64) as u32
        }
        _ => cfg.cp,
    }
}

/// Pipeline-fill latency contribution of this CE in cycles: the delay before
/// its first output emerges once its first input arrives. For windowed ops
/// this is `(k-1)` input rows plus `k` pixels; for reductions it is the full
/// reduction; for streaming ops a single cycle.
pub fn fill_cycles(layer: &Layer, cfg: &CeConfig) -> u64 {
    match layer.op {
        OpKind::Conv { kernel, .. } | OpKind::Pool { kernel, .. } => {
            let row = layer.w_in as u64 * (layer.c_in as u64).div_ceil(cfg.cp as u64);
            (kernel as u64 - 1) * row
                + kernel as u64
                + memory::m_dep(layer, cfg.kp, cfg.cp, cfg.fp)
        }
        OpKind::Fc => memory::m_dep(layer, cfg.kp, cfg.cp, cfg.fp),
        OpKind::GlobalAvgPool => stream_cycles(layer.input_count(), cfg.cp),
        OpKind::EltwiseAdd | OpKind::Relu => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ce::Fragmentation;
    use crate::ir::{PoolKind, Quant};

    fn cfg(kp: u32, cp: u32, fp: u32) -> CeConfig {
        CeConfig { kp, cp, fp, frag: Fragmentation::all_on_chip(0) }
    }

    #[test]
    fn serial_conv_cycles_equal_macs() {
        let l = Layer::conv("c", 16, 32, 8, 8, 3, 1, 1, Quant::W8A8);
        // serial: one MAC per cycle
        assert_eq!(cycles_per_sample(&l, &cfg(1, 1, 1)), l.macs());
    }

    #[test]
    fn full_unroll_is_stream_bound() {
        let l = Layer::conv("c", 16, 32, 8, 8, 3, 1, 1, Quant::W8A8);
        let c = cycles_per_sample(&l, &cfg(9, 16, 32));
        // compute would be h*w = 64 cycles; input stream is 8*8*16/16 = 64
        assert_eq!(c, 64);
    }

    #[test]
    fn fc_cycles() {
        let l = Layer::fc("fc", 512, 1000, Quant::W4A4);
        assert_eq!(cycles_per_sample(&l, &cfg(1, 1, 1)), 512_000);
        assert_eq!(cycles_per_sample(&l, &cfg(1, 8, 10)), 6400);
    }

    #[test]
    fn pool_cycles() {
        let l = Layer {
            name: "p".into(),
            op: OpKind::Pool { kernel: 2, stride: 2, pad: 0, kind: PoolKind::Max },
            c_in: 64,
            c_out: 64,
            h_in: 8,
            w_in: 8,
            quant: Quant::W8A8,
            skip_from: None,
        };
        // 16 output pixels * 64 channels * 4 window values
        assert_eq!(cycles_per_sample(&l, &cfg(1, 1, 1)), 16 * 64 * 4);
    }

    #[test]
    fn fill_is_much_smaller_than_body_for_large_maps() {
        let l = Layer::conv("c", 64, 64, 56, 56, 3, 1, 1, Quant::W8A8);
        let c = cfg(1, 4, 4);
        assert!(fill_cycles(&l, &c) * 10 < cycles_per_sample(&l, &c));
    }
}
