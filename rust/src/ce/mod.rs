//! Compute Engine (CE) template — paper §III.
//!
//! One CE per layer. The configuration vector `V` (paper Eq. 4) is
//! [`CeConfig`]: unroll factors `k_p, c_p, f_p` controlling compute
//! parallelism, and fragmentation parameters `n, u_on, u_off` controlling the
//! weights memory structure (Fig. 3). [`CeModel`] binds a config to a layer
//! and evaluates the analytic models: area `a(V)`, off-chip bandwidth `β(V)`,
//! and throughput `θ(V)`.

mod area;
mod bandwidth;
mod memory;
mod perf;
mod resource;

pub use area::{bram_blocks, Area, BramBreakdown};
pub use memory::Fragmentation;
pub use perf::fill_cycles;
pub use resource::{
    assign_memory_tech, bram_blocks_overclocked, lutram_luts, uram_blocks, MemTech, TechChoice,
    TechOptions, TechPlan,
};

use crate::ir::Layer;

/// The tunable variables `V` of one CE (paper Eq. 4).
///
/// `k_p` here unrolls over the `k²` kernel positions (the paper's `k_p²`
/// written as a single factor), `c_p` over input channels, `f_p` over output
/// filters. `n, u_on, u_off` define the weights-memory fragmentation
/// (Eq. 2): `n` fragment pairs, each `u_on` words static on-chip and `u_off`
/// words dynamic (reloaded from off-chip through the shared buffer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CeConfig {
    pub kp: u32,
    pub cp: u32,
    pub fp: u32,
    pub frag: Fragmentation,
}

impl CeConfig {
    /// Minimal configuration: no parallelism, all weights on-chip in a
    /// single fragment (the DSE INITIALIZE state, Algorithm 1).
    pub fn initial(layer: &Layer) -> CeConfig {
        CeConfig {
            kp: 1,
            cp: 1,
            fp: 1,
            frag: Fragmentation::all_on_chip(memory::m_dep(layer, 1, 1, 1)),
        }
    }

    /// Total compute parallelism (MACs per cycle).
    pub fn parallelism(&self) -> u64 {
        self.kp as u64 * self.cp as u64 * self.fp as u64
    }
}

/// A CE config bound to its layer plus the compute-clock: evaluates the
/// analytic models of paper §III-C.
#[derive(Debug, Clone)]
pub struct CeModel {
    pub layer: Layer,
    pub cfg: CeConfig,
    pub clk_comp_mhz: f64,
}

impl CeModel {
    pub fn new(layer: &Layer, cfg: CeConfig, clk_comp_mhz: f64) -> CeModel {
        CeModel { layer: layer.clone(), cfg, clk_comp_mhz }
    }

    /// On-chip memory depth required without fragmentation — paper Eq. 1
    /// `M_dep = f_t · c_t · k_t²` (words).
    pub fn m_dep(&self) -> u64 {
        memory::m_dep(&self.layer, self.cfg.kp, self.cfg.cp, self.cfg.fp)
    }

    /// Memory word width — paper Eq. 1 `M_wid = f_p · c_p · k_p² · L_W` (bits).
    pub fn m_wid_bits(&self) -> u64 {
        memory::m_wid_bits(&self.layer, self.cfg.kp, self.cfg.cp, self.cfg.fp)
    }

    /// Cycles to process one inference sample through this CE.
    pub fn cycles_per_sample(&self) -> u64 {
        perf::cycles_per_sample(&self.layer, &self.cfg)
    }

    /// Throughput `θ(V)` in samples/second (paper Eq. 4).
    pub fn throughput(&self) -> f64 {
        self.clk_comp_mhz * 1e6 / self.cycles_per_sample() as f64
    }

    /// Average off-chip bandwidth `β(V)` in bits/second (paper Eq. 5).
    /// Zero when all weights are static on-chip.
    pub fn beta_bps(&self) -> f64 {
        bandwidth::beta_bps(self.m_wid_bits(), self.clk_comp_mhz, &self.cfg.frag)
    }

    /// Area `a(V)` (paper Eq. 4): DSP/LUT/FF/BRAM, with the BRAM usage broken
    /// down into the Table III categories.
    pub fn area(&self) -> Area {
        area::area(&self.layer, &self.cfg, self.m_wid_bits())
    }

    /// Weight-reuse repetition count `r = b·ĥ·ŵ·n` (paper Eq. 3): how many
    /// times the PE array cycles through the fragment sequence per batch of
    /// `b` samples.
    pub fn repeats(&self, batch: u64) -> u64 {
        batch
            * self.layer.h_out() as u64
            * self.layer.w_out() as u64
            * self.cfg.frag.n as u64
    }
}

// --- borrow-based hot-path evaluation -------------------------------------
//
// `CeModel::new` clones its `Layer` (with its `String` name); fine for API
// ergonomics, measurably wasteful inside the greedy DSE loops that evaluate
// thousands of candidates (§Perf). These free functions evaluate the same
// analytic models against a borrowed layer.

/// `M_dep` (Eq. 1) without constructing a [`CeModel`].
#[inline]
pub fn eval_m_dep(layer: &Layer, cfg: &CeConfig) -> u64 {
    memory::m_dep(layer, cfg.kp, cfg.cp, cfg.fp)
}

/// `M_wid` in bits (Eq. 1) without constructing a [`CeModel`].
#[inline]
pub fn eval_m_wid_bits(layer: &Layer, cfg: &CeConfig) -> u64 {
    memory::m_wid_bits(layer, cfg.kp, cfg.cp, cfg.fp)
}

/// Cycles per sample without constructing a [`CeModel`].
#[inline]
pub fn eval_cycles(layer: &Layer, cfg: &CeConfig) -> u64 {
    perf::cycles_per_sample(layer, cfg)
}

/// Area `a(V)` without constructing a [`CeModel`].
#[inline]
pub fn eval_area(layer: &Layer, cfg: &CeConfig) -> Area {
    area::area(layer, cfg, eval_m_wid_bits(layer, cfg))
}

/// Bandwidth `β(V)` in bits/s (Eq. 5) without constructing a [`CeModel`].
#[inline]
pub fn eval_beta(layer: &Layer, cfg: &CeConfig, clk_comp_mhz: f64) -> f64 {
    bandwidth::beta_bps(eval_m_wid_bits(layer, cfg), clk_comp_mhz, &cfg.frag)
}

/// Divisors of `x` in ascending order — the legal unroll values for a
/// dimension of size `x`.
pub fn divisors(x: u32) -> Vec<u32> {
    let mut d: Vec<u32> = (1..=x).filter(|v| x % v == 0).collect();
    d.sort_unstable();
    d
}

/// Memoized [`divisors`] (§Perf): the DSE's proposal loops re-derive the
/// legal unroll set of the same handful of dimension sizes thousands of
/// times per run. The sets are tiny and the distinct sizes per process are
/// bounded by the model zoo's layer shapes, so entries are leaked into
/// `'static` slices once and shared lock-free afterwards.
pub fn divisors_cached(x: u32) -> &'static [u32] {
    use std::collections::HashMap;
    use std::sync::{OnceLock, RwLock};
    static CACHE: OnceLock<RwLock<HashMap<u32, &'static [u32]>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| RwLock::new(HashMap::new()));
    if let Some(&hit) = cache.read().unwrap().get(&x) {
        return hit;
    }
    let slice: &'static [u32] = Box::leak(divisors(x).into_boxed_slice());
    let mut w = cache.write().unwrap();
    // a racing thread may have inserted meanwhile; keep the first entry
    *w.entry(x).or_insert(slice)
}

/// Smallest legal unroll value strictly greater than `current + step - 1`,
/// i.e. advance `current` by at least `step` within the divisors of `x`
/// (Algorithm 1 INCREMENT_UNROLL with hyperparameter φ = `step`).
pub fn next_unroll(x: u32, current: u32, step: u32) -> Option<u32> {
    divisors_cached(x).iter().copied().find(|&d| d >= current + step)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Layer, Quant};

    fn conv() -> Layer {
        Layer::conv("c", 64, 128, 28, 28, 3, 1, 1, Quant::W4A5)
    }

    #[test]
    fn initial_config_is_serial_all_onchip() {
        let l = conv();
        let cfg = CeConfig::initial(&l);
        assert_eq!((cfg.kp, cfg.cp, cfg.fp), (1, 1, 1));
        assert_eq!(cfg.frag.m_off_dep(), 0);
        assert_eq!(cfg.frag.m_dep(), 64 * 128 * 9);
    }

    #[test]
    fn eq1_memory_geometry() {
        let l = conv();
        let m = CeModel::new(&l, CeConfig::initial(&l), 200.0);
        // f_t*c_t*k_t^2 with all unrolls 1 = f*c*k^2
        assert_eq!(m.m_dep(), 128 * 64 * 9);
        assert_eq!(m.m_wid_bits(), 4); // 1*1*1*L_W
    }

    #[test]
    fn unrolling_shrinks_depth_widens_words() {
        let l = conv();
        let mut cfg = CeConfig::initial(&l);
        cfg.kp = 9;
        cfg.cp = 8;
        cfg.fp = 4;
        cfg.frag = Fragmentation::all_on_chip(memory::m_dep(&l, 9, 8, 4));
        let m = CeModel::new(&l, cfg, 200.0);
        assert_eq!(m.m_dep(), (128 / 4) * (64 / 8) * 1);
        assert_eq!(m.m_wid_bits(), 9 * 8 * 4 * 4);
        // total bits conserved
        assert_eq!(m.m_dep() * m.m_wid_bits(), 128 * 64 * 9 * 4);
    }

    #[test]
    fn throughput_scales_with_parallelism() {
        let l = conv();
        let slow = CeModel::new(&l, CeConfig::initial(&l), 200.0);
        let mut cfg = CeConfig::initial(&l);
        cfg.cp = 8;
        cfg.frag = Fragmentation::all_on_chip(memory::m_dep(&l, 1, 8, 1));
        let fast = CeModel::new(&l, cfg, 200.0);
        assert!((fast.throughput() / slow.throughput() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn eq3_repeats() {
        let l = conv();
        let mut cfg = CeConfig::initial(&l);
        cfg.frag = Fragmentation::new(cfg.frag.m_dep(), cfg.frag.m_dep() / 2, 4);
        let m = CeModel::new(&l, cfg, 200.0);
        assert_eq!(m.repeats(1), 28 * 28 * 4);
        assert_eq!(m.repeats(8), 8 * 28 * 28 * 4);
    }

    #[test]
    fn divisors_cache_matches_fresh_computation() {
        for x in [1u32, 2, 9, 10, 64, 128, 1000, 2048] {
            assert_eq!(divisors_cached(x), divisors(x).as_slice());
            // second lookup hits the cache and returns the same slice
            assert_eq!(divisors_cached(x), divisors(x).as_slice());
        }
    }

    #[test]
    fn divisor_helpers() {
        assert_eq!(divisors(9), vec![1, 3, 9]);
        assert_eq!(next_unroll(64, 1, 1), Some(2));
        assert_eq!(next_unroll(64, 16, 4), Some(32));
        assert_eq!(next_unroll(64, 64, 1), None);
        // step lands between divisors: round up to next divisor
        assert_eq!(next_unroll(9, 1, 2), Some(3));
    }
}
