//! Comparison architectures of paper Table II.
//!
//! - [`vanilla`]: the "vanilla layer-pipelined" baseline — fpgaConvNet-style
//!   designs with all weights on-chip (infeasible when they do not fit).
//! - [`sequential`]: the "layer-sequential" baseline — a single
//!   time-multiplexed compute engine (Vitis-AI-DPU-like) with all weights
//!   and activations off-chip, tiled and double-buffered.

pub mod sequential;

pub use sequential::{sequential_latency_ms, SequentialModel, SequentialResult};

use crate::device::Device;
use crate::dse::{self, DseConfig, DseResult};
use crate::ir::Network;

/// Run the vanilla layer-pipelined baseline: Algorithm 1 with eviction
/// disabled. `None` == the "X" cells of Table II.
pub fn vanilla(network: &Network, device: &Device) -> Option<DseResult> {
    dse::run(network, device, &DseConfig::vanilla())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn vanilla_is_dse_without_streaming() {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let r = vanilla(&net, &dev).unwrap();
        assert!(!r.design.any_streaming());
    }
}
