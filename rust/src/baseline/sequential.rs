//! Layer-sequential baseline — a single Compute Engine executing the
//! network layer by layer with time multiplexing (paper §I, §II; the
//! Vitis AI / DnnWeaver / Angel-Eye architecture class).
//!
//! Both weights and activations live off-chip; tiling plus double buffering
//! overlap data movement with compute, so each layer costs
//! `max(compute, transfer)` plus a fixed per-layer dispatch overhead.

use crate::device::Device;
use crate::ir::{Network, Quant};

/// Calibration constants for the sequential engine.
#[derive(Debug, Clone, Copy)]
pub struct SequentialModel {
    /// Fraction of the device's DSPs provisioned for the engine's MAC array
    /// (general-purpose overlays never claim the full fabric).
    pub dsp_share: f64,
    /// Average utilization of the MAC array across layer shapes (tiling
    /// edge effects, depthwise under-utilization, ...).
    pub mac_utilization: f64,
    /// Fraction of peak off-chip bandwidth sustained in practice.
    pub bandwidth_eff: f64,
    /// Per-layer dispatch/configuration overhead in microseconds.
    pub dispatch_us: f64,
}

impl Default for SequentialModel {
    fn default() -> Self {
        SequentialModel {
            dsp_share: 0.5,
            mac_utilization: 1.0, // folded into quant_efficiency
            bandwidth_eff: 0.7,
            dispatch_us: 15.0,
        }
    }
}

/// Average MAC-array efficiency of the engine class cited for each quant
/// level in paper Table II: the W4 designs ([11] Mix&Match, [12] FILM-QNN)
/// are academic bit-level accelerators running well below the DSP roofline,
/// while the W8 figures come from the production Vitis AI DPU [1].
/// Calibrated so the layer-sequential column of Table II lands on the cited
/// numbers (see EXPERIMENTS.md).
fn quant_efficiency(q: Quant) -> f64 {
    let m = q.w_bits.max(q.a_bits);
    match m {
        0..=5 => 0.125,
        6..=8 => 0.35,
        _ => 0.30,
    }
}

/// MACs per DSP slice per cycle for a quantization: DSP48 packing of
/// narrow multiplies (the inverse of the area model's `dsp_per_mac`).
pub fn macs_per_dsp(q: Quant) -> f64 {
    let m = q.w_bits.max(q.a_bits);
    match m {
        0..=5 => 4.0,
        6..=8 => 2.0,
        9..=18 => 1.0,
        _ => 0.2,
    }
}

/// Per-layer and total latency of the sequential baseline.
#[derive(Debug, Clone)]
pub struct SequentialResult {
    pub latency_ms: f64,
    /// Per-layer (compute_ms, transfer_ms) breakdown.
    pub per_layer: Vec<(f64, f64)>,
    /// Fraction of layers that were compute-bound.
    pub compute_bound_frac: f64,
}

/// Evaluate the sequential baseline for `network` on `device`.
pub fn sequential(network: &Network, device: &Device, model: &SequentialModel) -> SequentialResult {
    let clk = device.clk_comp_mhz * 1e6;
    let bw = device.bandwidth_bps * model.bandwidth_eff;

    let mut per_layer = Vec::with_capacity(network.layers.len());
    let mut total_s = 0.0;
    let mut compute_bound = 0usize;

    for l in &network.layers {
        let macs_per_cycle = device.dsp as f64
            * model.dsp_share
            * macs_per_dsp(l.quant)
            * model.mac_utilization
            * quant_efficiency(l.quant);
        let compute_s = l.macs() as f64 / (macs_per_cycle * clk);
        let bits = l.weight_bits()
            + l.input_count() * l.quant.a_bits as u64
            + l.output_count() * l.quant.a_bits as u64;
        let transfer_s = bits as f64 / bw;
        // double buffering: compute and transfer overlap
        let layer_s = compute_s.max(transfer_s) + model.dispatch_us * 1e-6;
        per_layer.push((compute_s * 1e3, transfer_s * 1e3));
        if compute_s >= transfer_s {
            compute_bound += 1;
        }
        total_s += layer_s;
    }

    SequentialResult {
        latency_ms: total_s * 1e3,
        compute_bound_frac: compute_bound as f64 / network.layers.len().max(1) as f64,
        per_layer,
    }
}

/// Convenience: just the latency.
pub fn sequential_latency_ms(network: &Network, device: &Device) -> f64 {
    sequential(network, device, &SequentialModel::default()).latency_ms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn sequential_always_feasible() {
        // the architecture's defining property: works on any device
        for dev in Device::all() {
            let net = models::resnet50(Quant::W8A8);
            let r = sequential(&net, &dev, &SequentialModel::default());
            assert!(r.latency_ms.is_finite() && r.latency_ms > 0.0, "{}", dev.name);
        }
    }

    #[test]
    fn bigger_device_is_faster() {
        let net = models::resnet18(Quant::W4A4);
        let small = sequential_latency_ms(&net, &Device::zedboard());
        let large = sequential_latency_ms(&net, &Device::u250());
        assert!(large < small / 5.0, "zedboard {small} vs u250 {large}");
    }

    #[test]
    fn quant_efficiency_reflects_cited_engine_classes() {
        // Calibration check against the cited numbers: resnet18-W4A4 on
        // ZC706 is ~40 ms in [11]; resnet18-W8A8 on U50 is ~3.0 ms in [1].
        let zc706 = sequential_latency_ms(&models::resnet18(Quant::W4A4), &Device::zc706());
        assert!((25.0..60.0).contains(&zc706), "{zc706}");
        let u50 = sequential_latency_ms(&models::resnet18(Quant::W8A8), &Device::u50());
        assert!((1.5..5.0).contains(&u50), "{u50}");
    }

    #[test]
    fn zedboard_mobilenet_order_of_magnitude() {
        // paper Table II cites 8.3 ms (W4A4 [11]); our substrate should land
        // in the same decade.
        let ms = sequential_latency_ms(&models::mobilenet_v2(Quant::W4A4), &Device::zedboard());
        assert!((5.0..80.0).contains(&ms), "{ms} ms");
    }
}
