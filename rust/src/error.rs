//! Crate-level error type for the public API surface.
//!
//! The pipeline ([`crate::pipeline`]), the launcher config
//! ([`crate::config`]) and the CLI all report failures through this enum
//! instead of stringly `anyhow!` errors, so callers can match on the
//! failure class (an infeasible design point is routine in a sweep; an
//! unknown device name is a caller bug).

use std::fmt;

use crate::config::ConfigError;

/// Everything that can go wrong between naming a model and serving it.
#[derive(Debug)]
pub enum Error {
    /// Model name not in the zoo ([`crate::models::by_name`]) or not in a
    /// serving registry ([`crate::coordinator::ModelRegistry`]).
    UnknownModel(String),
    /// Registering a model name the registry already serves.
    DuplicateModel(String),
    /// A request's flattened input length does not match the model's.
    InputLength { model: String, expected: usize, got: usize },
    /// Device name not in the library ([`crate::device::Device::by_name`]).
    /// Carries the known board names so a CLI `--devices` typo reports what
    /// WOULD have worked, not just what didn't.
    UnknownDevice { name: String, known: Vec<String> },
    /// Quantization label that [`crate::ir::Quant::parse`] rejects.
    UnknownQuant(String),
    /// Filesystem failure with the offending path.
    Io { path: String, source: std::io::Error },
    /// `.net` description parse failure with the offending path.
    NetParse { path: String, source: crate::ir::NetParseError },
    /// Design-checkpoint parse failure ([`crate::dse::parse_design`]).
    DesignFormat(String),
    /// Run-configuration failure (TOML parse or semantic validation).
    Config(ConfigError),
    /// The DSE found no feasible design for this (model, device) pair.
    /// Routine for vanilla baselines on small devices (paper Table II "X").
    Infeasible { model: String, device: String, vanilla: bool },
    /// Serving-stack failure (engine boot, artifact load, submit/recv).
    Serve(String),
    /// Admission control rejected a submit: the server already has
    /// `in_flight` requests queued or executing against a cap of `cap`
    /// ([`crate::coordinator::ServerOptions::queue_cap`]). Back off and
    /// retry — the bounded queue is what keeps an overloaded server from
    /// growing its backlog (and its latency tail) without bound.
    Overloaded { in_flight: usize, cap: usize },
    /// The server is shutting down: the request was queued but never
    /// dispatched to an engine. Replaces the opaque "receiver disconnected"
    /// failure callers used to see when a response channel was dropped at
    /// shutdown.
    ShuttingDown,
    /// CLI usage error (unknown command/flag, unparsable value).
    Usage(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::UnknownModel(name) => write!(f, "unknown model `{name}`"),
            Error::DuplicateModel(name) => write!(f, "model `{name}` already registered"),
            Error::InputLength { model, expected, got } => {
                write!(f, "model `{model}` expects input length {expected}, got {got}")
            }
            Error::UnknownDevice { name, known } => {
                write!(f, "unknown device `{name}` (known: {})", known.join(", "))
            }
            Error::UnknownQuant(label) => {
                write!(f, "unknown quantization `{label}` (w4a4|w4a5|w8a8|f32|w<N>a<M>)")
            }
            Error::Io { path, source } => write!(f, "`{path}`: {source}"),
            Error::NetParse { path, source } => write!(f, "{path}: {source}"),
            Error::DesignFormat(msg) => write!(f, "design checkpoint: {msg}"),
            Error::Config(e) => write!(f, "{e}"),
            Error::Infeasible { model, device, vanilla } => {
                write!(f, "no feasible design for {model} on {device} (vanilla={vanilla})")
            }
            Error::Serve(msg) => write!(f, "serving: {msg}"),
            Error::Overloaded { in_flight, cap } => {
                write!(f, "queue full: {in_flight} in flight (cap {cap})")
            }
            Error::ShuttingDown => {
                write!(f, "server shutting down: request was not dispatched")
            }
            Error::Usage(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            Error::NetParse { source, .. } => Some(source),
            Error::Config(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ConfigError> for Error {
    fn from(e: ConfigError) -> Self {
        Error::Config(e)
    }
}

impl Error {
    /// True when the failure is a routine infeasibility (sweeps skip these
    /// points rather than aborting).
    pub fn is_infeasible(&self) -> bool {
        matches!(self, Error::Infeasible { .. })
    }
}
