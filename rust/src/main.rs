//! AutoWS command-line interface — a thin shell over [`autows::pipeline`]
//! (self-contained arg parsing; this build is fully offline).
//!
//! ```text
//! autows report <table1|tech|compress|strategies|table2|table3|fig5|fig6|fig7|yolo|all>
//! autows dse      [--model M] [--device D] [--quant Q] [--vanilla] [--phi N] [--mu N]
//! autows simulate [--model M] [--device D] [--quant Q] [--batch N]
//! autows serve    [--artifact PATH] [--requests N] [--max-batch N] [--workers K]
//!                 [--dispatch-shards S] [--device D]
//! autows run      --config configs/resnet18_zcu102.toml
//! autows dse|simulate|serve --models m1,m2,... --devices d1,d2,...
//!                 [--objective agg|slo:<ms>]   # fleet placement
//! ```

use std::collections::HashMap;

use autows::config::RunSpec;
use autows::coordinator::{BatchPolicy, MetricsHandle, ServerOptions};
use autows::dse::{self, DseConfig, FleetObjective};
use autows::ir::Quant;
use autows::pipeline::{drive_synthetic, drive_synthetic_tenant, Deployment, EngineSpec};
use autows::report;
use autows::sim::{render_gantt, to_csv, SimConfig};
use autows::telemetry::{
    chrome_trace_sim, chrome_trace_spans, json_snapshot, prometheus_text, StatsReporter,
    TelemetrySnapshot,
};
use autows::Error;

/// One recognized flag: its name and whether it consumes a value.
struct FlagSpec {
    name: &'static str,
    takes_value: bool,
}

const fn val(name: &'static str) -> FlagSpec {
    FlagSpec { name, takes_value: true }
}

const fn bool_flag(name: &'static str) -> FlagSpec {
    FlagSpec { name, takes_value: false }
}

/// Strict `--key value` / `--flag` parser: flags not in `spec` are usage
/// errors (a typo'd `--modle` must not silently run with defaults).
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(cmd: &str, argv: &[String], spec: &[FlagSpec]) -> Result<Args, Error> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                positional.push(a.clone());
                continue;
            };
            let Some(f) = spec.iter().find(|f| f.name == key) else {
                let known: Vec<String> =
                    spec.iter().map(|f| format!("--{}", f.name)).collect();
                return Err(Error::Usage(format!(
                    "unknown flag `--{key}` for `autows {cmd}` (recognized: {})\n{USAGE}",
                    if known.is_empty() { "none".to_string() } else { known.join(" ") }
                )));
            };
            let value = if f.takes_value {
                // a following `--flag` is not a value — refuse instead of
                // silently swallowing the next flag
                match it.next() {
                    Some(v) if !v.starts_with("--") => v.clone(),
                    _ => return Err(Error::Usage(format!("--{key} requires a value"))),
                }
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), value);
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, Error> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{key}: cannot parse `{v}`"))),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn parse_quant(s: &str) -> Result<Quant, Error> {
    Quant::parse(s).ok_or_else(|| Error::UnknownQuant(s.to_string()))
}

/// Parse `--devices d1,d2,...` into a device chain for a sharded
/// deployment. Rejects combining with `--device`.
fn parse_device_chain(args: &Args) -> Result<Option<Vec<String>>, Error> {
    let Some(list) = args.flags.get("devices") else {
        return Ok(None);
    };
    if args.has("device") {
        return Err(Error::Usage("give either --device or --devices, not both".to_string()));
    }
    let names: Vec<String> = list
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        return Err(Error::Usage("--devices: empty device list".to_string()));
    }
    Ok(Some(names))
}

/// Parse `--models m1,m2,...` into a tenant list for a co-located
/// deployment. Rejects combining with `--model` (ambiguous). `--models`
/// together with `--devices` is the fleet mode, handled by [`parse_fleet`]
/// BEFORE this runs.
fn parse_model_list(args: &Args) -> Result<Option<Vec<String>>, Error> {
    let Some(list) = args.flags.get("models") else {
        return Ok(None);
    };
    if args.has("model") {
        return Err(Error::Usage("give either --model or --models, not both".to_string()));
    }
    if args.has("devices") {
        return Err(Error::Usage(
            "--models co-locates on ONE device; combine with --devices only via the fleet \
             mode (both flags at once place N models onto the pool)"
                .to_string(),
        ));
    }
    let names: Vec<String> = list
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        return Err(Error::Usage("--models: empty model list".to_string()));
    }
    Ok(Some(names))
}

/// Fleet mode: `--models m1,m2,...` together with `--devices d1,d2,...`
/// places the model set onto the device pool (the N×M generalization of
/// sharding and co-location). Checked BEFORE the narrower parsers so the
/// flag combination routes here instead of being rejected.
fn parse_fleet(args: &Args) -> Result<Option<(Vec<String>, Vec<String>)>, Error> {
    let (Some(models), Some(devices)) = (args.flags.get("models"), args.flags.get("devices"))
    else {
        return Ok(None);
    };
    if args.has("model") {
        return Err(Error::Usage("give either --model or --models, not both".to_string()));
    }
    if args.has("device") {
        return Err(Error::Usage("give either --device or --devices, not both".to_string()));
    }
    let split = |list: &str, what: &str| -> Result<Vec<String>, Error> {
        let names: Vec<String> = list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if names.is_empty() {
            return Err(Error::Usage(format!("--{what}: empty list")));
        }
        Ok(names)
    };
    Ok(Some((split(models, "models")?, split(devices, "devices")?)))
}

/// Parse `--objective agg` / `--objective slo:<ms>` into a
/// [`FleetObjective`] (default: maximize aggregate throughput).
fn parse_objective(args: &Args) -> Result<FleetObjective, Error> {
    let Some(v) = args.flags.get("objective") else {
        return Ok(FleetObjective::MaxAggregateThroughput);
    };
    if v == "agg" || v == "max-aggregate-throughput" {
        return Ok(FleetObjective::MaxAggregateThroughput);
    }
    if let Some(ms) = v.strip_prefix("slo:") {
        let p99_ms: f64 = ms
            .parse()
            .map_err(|_| Error::Usage(format!("--objective slo:<ms>: cannot parse `{ms}`")))?;
        if p99_ms <= 0.0 {
            return Err(Error::Usage(
                "--objective slo:<ms>: the SLO must be positive".to_string(),
            ));
        }
        return Ok(FleetObjective::MinDevicesAtSlo { p99_ms });
    }
    Err(Error::Usage(format!("--objective: `{v}` is not `agg` or `slo:<ms>`")))
}

/// Reject a stray `--objective` outside fleet mode (it would silently do
/// nothing).
fn reject_objective(args: &Args) -> Result<(), Error> {
    if args.has("objective") {
        return Err(Error::Usage(
            "--objective applies to fleet placement (--models together with --devices)"
                .to_string(),
        ));
    }
    Ok(())
}

/// The fleet stage-0 builder for `--models` × `--devices` (every model
/// shares the one `--quant` the CLI takes).
fn fleet_builder(
    models: &[String],
    devices: &[String],
    quant: Quant,
) -> Result<autows::pipeline::FleetPlanned, Error> {
    let pool: Vec<&str> = devices.iter().map(String::as_str).collect();
    Deployment::fleet(
        models.iter().map(|m| Deployment::for_model(m.as_str()).quant(quant)),
        &pool,
    )
}

/// The co-located stage-0 builder for a `--models` tenant list (every
/// tenant shares the one `--quant` the CLI takes).
fn colocate_builder(models: &[String], quant: Quant) -> autows::pipeline::ColocatedDeployment {
    Deployment::colocate(models.iter().map(|m| Deployment::for_model(m.as_str()).quant(quant)))
}

/// Minimal JSON string escaping (quotes and backslashes; names here are
/// plain identifiers, control characters cannot reach a model/device name).
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// A finite f64 as a JSON number (non-finite values cannot appear in a
/// simulation summary, but emit a valid document regardless).
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Write the `--json` simulation summary, reporting the path on success.
fn write_json_summary(path: &str, text: &str) -> Result<(), Error> {
    std::fs::write(path, text)
        .map_err(|source| Error::Io { path: path.to_string(), source })?;
    println!("simulation summary written to {path}");
    Ok(())
}

/// Event cap for `simulate --trace-out` runs: large enough for whole-batch
/// traces of the zoo models, bounded so a misjudged batch cannot OOM.
const TRACE_EVENT_CAP: usize = 200_000;

/// The serve telemetry flags, shared by every serve path:
/// `--metrics-out PATH` (Prometheus text, or a JSON snapshot when the path
/// ends in `.json`), `--trace-out PATH` (Chrome trace-event / Perfetto
/// spans), `--stats-interval SECS` (periodic one-line stats to stderr).
struct TelemetryCli {
    metrics_out: Option<String>,
    trace_out: Option<String>,
    stats_interval_s: Option<f64>,
}

impl TelemetryCli {
    fn from_args(args: &Args) -> Result<TelemetryCli, Error> {
        let stats_interval_s = match args.flags.get("stats-interval") {
            None => None,
            Some(v) => {
                let secs: f64 = v.parse().map_err(|_| {
                    Error::Usage(format!("--stats-interval: cannot parse `{v}`"))
                })?;
                if !secs.is_finite() || secs <= 0.0 {
                    return Err(Error::Usage(
                        "--stats-interval: the interval must be positive seconds".to_string(),
                    ));
                }
                Some(secs)
            }
        };
        Ok(TelemetryCli {
            metrics_out: args.flags.get("metrics-out").cloned(),
            trace_out: args.flags.get("trace-out").cloned(),
            stats_interval_s,
        })
    }

    /// Spawn the periodic stderr reporter when `--stats-interval` was given.
    fn start_stats(&self, handles: Vec<MetricsHandle>) -> Option<StatsReporter> {
        self.stats_interval_s.map(|secs| {
            StatsReporter::start(handles, std::time::Duration::from_secs_f64(secs))
        })
    }

    /// Write `--metrics-out` (format by extension) and `--trace-out` from
    /// the final telemetry snapshot.
    fn emit(&self, t: &TelemetrySnapshot) -> Result<(), Error> {
        if let Some(path) = &self.metrics_out {
            let text =
                if path.ends_with(".json") { json_snapshot(t) } else { prometheus_text(t) };
            std::fs::write(path, text)
                .map_err(|source| Error::Io { path: path.clone(), source })?;
            println!("metrics written to {path}");
        }
        if let Some(path) = &self.trace_out {
            let text = chrome_trace_spans(&t.spans);
            std::fs::write(path, text)
                .map_err(|source| Error::Io { path: path.clone(), source })?;
            println!("span trace written to {path}");
        }
        Ok(())
    }
}

const USAGE: &str = "usage: autows <report|dse|simulate|serve|run> [options]
  report <table1|tech|compress|strategies|table2|table3|fig5|fig6|fig7|yolo|all>
  dse      --model resnet18 --device zcu102 --quant w4a5 [--vanilla] [--phi 1] [--mu 512]
           [--warm] [--save PATH] [--tech]
  simulate --model resnet18 --device zcu102 --quant w4a5 [--batch 1] [--design PATH]
           [--json PATH]       # machine-readable simulation summary
           [--trace-out PATH]  # single-model event trace: .csv, .json
                               # (Chrome trace-event / Perfetto), or text gantt
  serve    --artifact artifacts/toy_cnn_b8.hlo.txt [--requests 64] [--max-batch 8] [--workers 1] [--device zcu102]
           (--models m1,m2 [--quant w8a8] serves co-located sim-only tenants;
            --workers K fans execution out to a K-engine pool;
            --dispatch-shards S pins the batching-front shard count, 0 = auto)
           [--metrics-out PATH]    # Prometheus text, or JSON when PATH ends .json
           [--trace-out PATH]      # serving spans as Chrome trace-event (Perfetto) JSON
           [--stats-interval SECS] # periodic one-line stats to stderr
  run      --config configs/resnet18_zcu102.toml   # full pipeline from a config file

  dse/simulate/serve also accept --devices d1,d2,... to shard the model
  across a chain of devices (e.g. --devices zcu102,zcu102), or
  --models m1,m2,... to co-locate several models on the ONE --device
  (e.g. --models resnet18,squeezenet --device zcu102).

  --models AND --devices together is the FLEET mode: place N models onto
  the device pool (per model: solo, sharded, or co-located), optionally
  under --objective agg (default, max aggregate throughput) or
  --objective slo:<ms> (fewest devices meeting a p99 SLO), e.g.
  autows dse --models resnet50,resnet18,squeezenet \\
             --devices zc706,zcu102,zcu102 --quant w8a8 --objective slo:50
  serve routes fleet requests through one router (least outstanding
  requests across replicas) and reports per-model rollups.";

fn main() {
    if let Err(e) = run_cli() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run_cli() -> Result<(), Error> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let rest = &argv[1..];
    match cmd.as_str() {
        "report" => cmd_report(&Args::parse("report", rest, &[])?),
        "dse" => cmd_dse(&Args::parse(
            "dse",
            rest,
            &[
                val("model"),
                val("models"),
                val("device"),
                val("devices"),
                val("quant"),
                val("phi"),
                val("mu"),
                val("save"),
                val("objective"),
                bool_flag("vanilla"),
                bool_flag("warm"),
                bool_flag("tech"),
            ],
        )?),
        "simulate" => cmd_simulate(&Args::parse(
            "simulate",
            rest,
            &[
                val("model"),
                val("models"),
                val("device"),
                val("devices"),
                val("quant"),
                val("batch"),
                val("design"),
                val("json"),
                val("trace-out"),
                val("objective"),
            ],
        )?),
        "serve" => cmd_serve(&Args::parse(
            "serve",
            rest,
            &[
                val("artifact"),
                val("requests"),
                val("max-batch"),
                val("workers"),
                val("dispatch-shards"),
                val("device"),
                val("devices"),
                val("models"),
                val("quant"),
                val("objective"),
                val("metrics-out"),
                val("trace-out"),
                val("stats-interval"),
            ],
        )?),
        "run" => cmd_run(&Args::parse("run", rest, &[val("config")])?),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(Error::Usage(format!("unknown command `{other}`\n{USAGE}"))),
    }
}

fn cmd_report(args: &Args) -> Result<(), Error> {
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    let out = match which {
        "table1" => report::table1(),
        "table2" => report::table2(),
        "table3" => report::table3(),
        "fig5" => report::fig5(),
        "fig5-gantt" => report::fig5_gantt(),
        "fig6" => report::fig6(),
        "fig6-chart" => report::fig6_chart(),
        "fig7" => report::fig7(),
        "fig7-chart" => report::fig7_chart(),
        "yolo" => report::yolo(),
        "tech" => report::tech(),
        "compress" => report::compress(),
        "strategies" => report::strategies(),
        "all" => [
            report::table1(),
            report::table2(),
            report::table3(),
            report::fig5(),
            report::fig5_gantt(),
            report::fig6(),
            report::fig6_chart(),
            report::fig7(),
            report::fig7_chart(),
            report::yolo(),
            report::tech(),
            report::compress(),
            report::strategies(),
        ]
        .join("\n"),
        other => return Err(Error::Usage(format!("unknown report `{other}`"))),
    };
    println!("{out}");
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<(), Error> {
    let model = args.get("model", "resnet18");
    let device = args.get("device", "zcu102");
    let quant = parse_quant(&args.get("quant", "w4a5"))?;
    let cfg = DseConfig::default()
        .with_phi(args.get_num("phi", 1u32)?)
        .with_mu(args.get_num("mu", 512u64)?)
        .with_streaming(!args.has("vanilla"))
        .with_warm_start(args.has("warm"));

    if let Some((models, pool)) = parse_fleet(args)? {
        if args.has("save") || args.has("tech") {
            return Err(Error::Usage(
                "--save and --tech are single-model options (not valid with --models)"
                    .to_string(),
            ));
        }
        let objective = parse_objective(args)?;
        let plan = fleet_builder(&models, &pool, quant)?.with_objective(objective);
        match plan.explore(&cfg) {
            Err(e) if e.is_infeasible() => {
                println!(
                    "INFEASIBLE: [{}] do not place on [{}] (vanilla={})",
                    models.join(", "),
                    pool.join(", "),
                    args.has("vanilla")
                );
            }
            other => print!("{}", other?.schedule().report()),
        }
        return Ok(());
    }
    reject_objective(args)?;

    if let Some(models) = parse_model_list(args)? {
        if args.has("save") || args.has("tech") {
            return Err(Error::Usage(
                "--save and --tech are single-model options (not valid with --models)"
                    .to_string(),
            ));
        }
        let plan = colocate_builder(&models, quant).on_device(device.as_str())?;
        match plan.explore(&cfg) {
            Err(e) if e.is_infeasible() => {
                println!(
                    "INFEASIBLE: [{}] do not co-locate on {device} (vanilla={})",
                    models.join(", "),
                    args.has("vanilla")
                );
            }
            other => print!("{}", other?.schedule().report()),
        }
        return Ok(());
    }

    if let Some(chain) = parse_device_chain(args)? {
        if args.has("save") || args.has("tech") {
            return Err(Error::Usage(
                "--save and --tech are single-device options (not valid with --devices)"
                    .to_string(),
            ));
        }
        let plan = Deployment::for_model(&model).quant(quant).on_devices(&chain)?;
        match plan.explore(&cfg) {
            Err(e) if e.is_infeasible() => {
                println!(
                    "INFEASIBLE: {model} does not shard across [{}] (vanilla={})",
                    chain.join(", "),
                    args.has("vanilla")
                );
            }
            other => print!("{}", other?.schedule().report()),
        }
        return Ok(());
    }

    let plan = Deployment::for_model(&model).quant(quant).on_device(device.as_str())?;
    let scheduled = match plan.explore(&cfg) {
        Err(e) if e.is_infeasible() => {
            println!("INFEASIBLE: {model} does not fit {device} (vanilla={})", args.has("vanilla"));
            return Ok(());
        }
        other => other?.schedule(),
    };
    print!("{}", scheduled.report());
    if let Some(path) = args.flags.get("save") {
        let text = dse::serialize_design(scheduled.design(), scheduled.device());
        std::fs::write(path, text)
            .map_err(|source| Error::Io { path: path.clone(), source })?;
        println!("design checkpoint written to {path}");
    }
    if args.has("tech") {
        use autows::ce::{assign_memory_tech, MemTech, TechOptions};
        let dev = scheduled.device();
        let plan = assign_memory_tech(scheduled.design(), dev, &TechOptions::for_device(dev));
        println!(
            "memory tech plan: {} BRAM (baseline {}), {} URAM, +{} LUTs, saved {} BRAM36-equiv",
            plan.bram, plan.baseline_bram, plan.uram, plan.extra_luts, plan.bram_saved()
        );
        for c in &plan.choices {
            if c.tech != MemTech::Bram {
                println!(
                    "  {:<24} -> {} (bram={} uram={} luts={})",
                    scheduled.design().network.layers[c.layer].name,
                    c.tech,
                    c.bram,
                    c.uram,
                    c.luts
                );
            }
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), Error> {
    let model = args.get("model", "resnet18");
    let device = args.get("device", "zcu102");
    let quant = parse_quant(&args.get("quant", "w4a5"))?;
    let batch: u64 = args.get_num("batch", 1u64)?;
    let json_path = args.flags.get("json").cloned();
    let trace_out = args.flags.get("trace-out").cloned();
    // event traces are a single-model, single-device diagnostic
    let reject_trace_out = |what: &str| -> Result<(), Error> {
        if trace_out.is_some() {
            return Err(Error::Usage(format!(
                "--trace-out traces the single-model simulation (not valid with {what})"
            )));
        }
        Ok(())
    };

    if let Some((models, pool)) = parse_fleet(args)? {
        if args.has("design") {
            return Err(Error::Usage(
                "--design checkpoints are single-model (not valid with --models)".to_string(),
            ));
        }
        reject_trace_out("--models/--devices")?;
        let objective = parse_objective(args)?;
        let scheduled = fleet_builder(&models, &pool, quant)?
            .with_objective(objective)
            .explore(&DseConfig::default())?
            .schedule_for_batch(batch);
        let sim = scheduled.simulate(&SimConfig { batch, ..Default::default() });
        print!("{}", scheduled.report());
        println!(
            "fleet sim batch={batch}: makespan={:.3} ms, stalls={:.1} us",
            sim.makespan_s * 1e3,
            sim.total_stall_s * 1e6
        );
        if let Some(path) = json_path {
            let names = scheduled.model_names();
            let objective_label = match scheduled.result().objective {
                FleetObjective::MaxAggregateThroughput => {
                    "max-aggregate-throughput".to_string()
                }
                FleetObjective::MinDevicesAtSlo { p99_ms } => format!("slo:{p99_ms}"),
            };
            let placements: Vec<String> = scheduled
                .placements()
                .iter()
                .zip(&sim.per_placement)
                .map(|(p, ps)| {
                    let label: Vec<&str> =
                        p.model_indices().iter().map(|&m| names[m].as_str()).collect();
                    let devs: Vec<String> = p
                        .device_indices()
                        .iter()
                        .map(|&d| format!("\"{}\"", json_escape(scheduled.devices()[d].name)))
                        .collect();
                    format!(
                        "{{\"model\":\"{}\",\"mode\":\"{}\",\"devices\":[{}],\
                         \"throughput_rps\":{},\"makespan_ms\":{},\"stall_us\":{},\
                         \"events\":{},\"events_processed\":{},\"truncated\":{}}}",
                        json_escape(&label.join("+")),
                        p.mode(),
                        devs.join(","),
                        jnum(p.throughput()),
                        jnum(ps.makespan_s() * 1e3),
                        jnum(ps.total_stall_s() * 1e6),
                        ps.events(),
                        ps.events_processed(),
                        ps.truncated()
                    )
                })
                .collect();
            let model_names: Vec<String> =
                names.iter().map(|m| format!("\"{}\"", json_escape(m))).collect();
            let pool_names: Vec<String> = scheduled
                .devices()
                .iter()
                .map(|d| format!("\"{}\"", json_escape(d.name)))
                .collect();
            let events: u64 = sim.per_placement.iter().map(|p| p.events()).sum();
            let events_processed: u64 =
                sim.per_placement.iter().map(|p| p.events_processed()).sum();
            let truncated = sim.per_placement.iter().any(|p| p.truncated());
            let doc = format!(
                "{{\"mode\":\"fleet\",\"models\":[{}],\"quant\":\"{}\",\"devices\":[{}],\
                 \"objective\":\"{}\",\"batch\":{},\"aggregate_throughput_rps\":{},\
                 \"devices_used\":{},\"makespan_ms\":{},\"stall_us\":{},\"events\":{},\
                 \"events_processed\":{},\"truncated\":{},\"placements\":[{}]}}\n",
                model_names.join(","),
                quant,
                pool_names.join(","),
                objective_label,
                batch,
                jnum(scheduled.result().aggregate_throughput),
                scheduled.result().devices_used,
                jnum(sim.makespan_s * 1e3),
                jnum(sim.total_stall_s * 1e6),
                events,
                events_processed,
                truncated,
                placements.join(",")
            );
            write_json_summary(&path, &doc)?;
        }
        return Ok(());
    }
    reject_objective(args)?;

    if let Some(models) = parse_model_list(args)? {
        if args.has("design") {
            return Err(Error::Usage(
                "--design checkpoints are single-model (not valid with --models)".to_string(),
            ));
        }
        reject_trace_out("--models")?;
        let scheduled = colocate_builder(&models, quant)
            .on_device(device.as_str())?
            .explore(&DseConfig::default())?
            .schedule_for_batch(batch);
        let sim = scheduled.simulate(&SimConfig { batch, ..Default::default() });
        println!(
            "[{}] co-located on {device} batch={batch}: makespan={:.3} ms, stalls={:.1} us, \
             port busy {:.0}%, {} events",
            models.join(", "),
            sim.makespan_s * 1e3,
            sim.total_stall_s * 1e6,
            sim.port_busy_frac * 100.0,
            sim.events
        );
        for t in &sim.per_tenant {
            println!(
                "  {}: makespan={:.3} ms, stalls={:.1} us (contention {:.1} us), {} events",
                t.name,
                t.makespan_s * 1e3,
                t.total_stall_s * 1e6,
                t.contention_s * 1e6,
                t.events
            );
        }
        if let Some(path) = json_path {
            let tenants: Vec<String> = sim
                .per_tenant
                .iter()
                .map(|t| {
                    format!(
                        "{{\"name\":\"{}\",\"makespan_ms\":{},\"stall_us\":{},\
                         \"contention_us\":{},\"events\":{}}}",
                        json_escape(&t.name),
                        jnum(t.makespan_s * 1e3),
                        jnum(t.total_stall_s * 1e6),
                        jnum(t.contention_s * 1e6),
                        t.events
                    )
                })
                .collect();
            // canonical tenant names (a zoo alias like "toy" resolves to
            // network name "toy_cnn"), so the list joins with tenants[].name
            let names: Vec<String> = scheduled
                .tenant_names()
                .iter()
                .map(|m| format!("\"{}\"", json_escape(m)))
                .collect();
            let doc = format!(
                "{{\"mode\":\"colocated\",\"models\":[{}],\"quant\":\"{}\",\
                 \"device\":\"{}\",\"batch\":{},\
                 \"makespan_ms\":{},\"stall_us\":{},\"port_busy_frac\":{},\"events\":{},\
                 \"events_processed\":{},\"truncated\":{},\"tenants\":[{}]}}\n",
                names.join(","),
                quant,
                json_escape(&device),
                batch,
                jnum(sim.makespan_s * 1e3),
                jnum(sim.total_stall_s * 1e6),
                jnum(sim.port_busy_frac),
                sim.events,
                sim.events_processed,
                sim.truncated,
                tenants.join(",")
            );
            write_json_summary(&path, &doc)?;
        }
        return Ok(());
    }

    if let Some(chain) = parse_device_chain(args)? {
        if args.has("design") {
            return Err(Error::Usage(
                "--design checkpoints are single-device (not valid with --devices)".to_string(),
            ));
        }
        reject_trace_out("--devices")?;
        let scheduled = Deployment::for_model(&model)
            .quant(quant)
            .on_devices(&chain)?
            .explore(&DseConfig::default())?
            .schedule_for_batch(batch);
        let sim = scheduled.simulate(&SimConfig { batch, ..Default::default() });
        println!(
            "{model}-{quant} sharded across [{}] batch={batch}: makespan={:.3} ms, \
             stalls={:.1} us, steady period={:.2} us, bottleneck={:?}, {} events",
            chain.join(", "),
            sim.makespan_s * 1e3,
            sim.total_stall_s * 1e6,
            sim.steady_period_s * 1e6,
            sim.bottleneck,
            sim.events()
        );
        if let Some(path) = json_path {
            let devices: Vec<String> =
                chain.iter().map(|d| format!("\"{}\"", json_escape(d))).collect();
            let doc = format!(
                "{{\"mode\":\"sharded\",\"model\":\"{}\",\"quant\":\"{}\",\"devices\":[{}],\
                 \"batch\":{},\"makespan_ms\":{},\"stall_us\":{},\"steady_period_us\":{},\
                 \"bottleneck\":\"{:?}\",\"events\":{},\"events_processed\":{},\
                 \"truncated\":{}}}\n",
                json_escape(&model),
                quant,
                devices.join(","),
                batch,
                jnum(sim.makespan_s * 1e3),
                jnum(sim.total_stall_s * 1e6),
                jnum(sim.steady_period_s * 1e6),
                sim.bottleneck,
                sim.events(),
                sim.events_processed(),
                sim.truncated()
            );
            write_json_summary(&path, &doc)?;
        }
        return Ok(());
    }

    let plan = Deployment::for_model(&model).quant(quant).on_device(device.as_str())?;
    // either reload a DSE checkpoint or re-run the search (cached)
    let explored = match args.flags.get("design") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|source| Error::Io { path: path.clone(), source })?;
            let design = dse::parse_design(&text, plan.network(), plan.device())
                .map_err(|e| Error::DesignFormat(e.to_string()))?;
            plan.adopt_design(design)
        }
        None => plan.explore(&DseConfig::default())?,
    };
    let scheduled = explored.schedule_for_batch(batch);
    let analytic_ms = scheduled.design().latency_ms(1);
    // a --trace-out run records the full event trace (no fast-forward)
    let sim_cfg = if trace_out.is_some() {
        SimConfig { batch, trace: true, max_trace_events: TRACE_EVENT_CAP, ..Default::default() }
    } else {
        SimConfig { batch, ..Default::default() }
    };
    let sim = scheduled.simulate(&sim_cfg);
    println!(
        "{model}-{quant} on {device} batch={batch}: makespan={:.3} ms, stalls={:.1} us, \
         DMA busy {:.0}%, {} events (analytic latency {:.3} ms)",
        sim.makespan_s * 1e3,
        sim.total_stall_s * 1e6,
        sim.dma_busy_frac * 100.0,
        sim.events,
        analytic_ms
    );
    if let Some(path) = json_path {
        let doc = format!(
            "{{\"mode\":\"single\",\"model\":\"{}\",\"quant\":\"{}\",\"device\":\"{}\",\
             \"batch\":{},\"makespan_ms\":{},\"stall_us\":{},\"dma_busy_frac\":{},\
             \"events\":{},\"events_processed\":{},\"truncated\":{},\
             \"analytic_latency_ms\":{}}}\n",
            json_escape(&model),
            quant,
            json_escape(&device),
            batch,
            jnum(sim.makespan_s * 1e3),
            jnum(sim.total_stall_s * 1e6),
            jnum(sim.dma_busy_frac),
            sim.events,
            sim.events_processed,
            sim.truncated,
            jnum(analytic_ms)
        );
        write_json_summary(&path, &doc)?;
    }
    if let Some(path) = trace_out {
        let text = if path.ends_with(".csv") {
            to_csv(&sim.traces)
        } else if path.ends_with(".json") {
            chrome_trace_sim(&sim.traces)
        } else {
            render_gantt(&sim.traces, 100)
        };
        std::fs::write(&path, text)
            .map_err(|source| Error::Io { path: path.clone(), source })?;
        if sim.truncated {
            eprintln!(
                "note: trace hit the {TRACE_EVENT_CAP}-event cap; {path} holds a prefix"
            );
        }
        println!("simulation trace written to {path}");
    }
    Ok(())
}

/// `autows run --config <file>`: the launcher — the whole pipeline from a
/// reproducible config artifact ([`RunSpec::execute`]).
fn cmd_run(args: &Args) -> Result<(), Error> {
    let path = args.get("config", "configs/resnet18_zcu102.toml");
    let spec = RunSpec::from_file(&path)?;
    spec.execute()
}

fn cmd_serve(args: &Args) -> Result<(), Error> {
    let artifact = args.get("artifact", "artifacts/toy_cnn_b8.hlo.txt");
    let requests: usize = args.get_num("requests", 64usize)?;
    let max_batch: usize = args.get_num("max-batch", 8usize)?;
    let workers: usize = args.get_num("workers", 1usize)?;
    let dispatch_shards: usize = args.get_num("dispatch-shards", 0usize)?;
    let device = args.get("device", "zcu102");
    let opts = ServerOptions { workers, dispatch_shards, ..Default::default() };
    let tele = TelemetryCli::from_args(args)?;

    if let Some((models, pool)) = parse_fleet(args)? {
        if args.has("artifact") {
            return Err(Error::Usage(
                "--artifact serving is single-model; fleet serving runs sim-only engines \
                 behind the router"
                    .to_string(),
            ));
        }
        let quant = parse_quant(&args.get("quant", "w8a8"))?;
        let objective = parse_objective(args)?;
        let scheduled = fleet_builder(&models, &pool, quant)?
            .with_objective(objective)
            .explore(&DseConfig::default())?
            .schedule_for_batch(max_batch as u64);
        let router = scheduled.serve(
            BatchPolicy { max_batch, max_wait: std::time::Duration::from_millis(2) },
            opts,
        )?;
        let stats =
            tele.start_stats(router.metrics_handles().into_iter().map(|(_, h)| h).collect());
        let t0 = std::time::Instant::now();
        for name in scheduled.model_names() {
            let input_len = scheduled.input_len(name).expect("names come from the plan");
            let mut pending = Vec::with_capacity(requests);
            for _ in 0..requests {
                pending.push(router.submit(name, vec![0.5; input_len])?);
            }
            for rx in pending {
                rx.recv()
                    .map_err(|_| Error::Serve("router: reply channel dropped".to_string()))??;
            }
        }
        let elapsed = t0.elapsed();
        println!(
            "{} requests x {} models across {} devices in {:.1} ms:",
            requests,
            models.len(),
            scheduled.result().devices_used,
            elapsed.as_secs_f64() * 1e3
        );
        for name in scheduled.model_names() {
            let m = router.model_metrics(name).expect("routed above");
            println!(
                "  {name}: throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
                m.throughput_rps, m.p50_ms, m.p99_ms, m.mean_batch
            );
        }
        if let Some(s) = stats {
            s.stop();
        }
        tele.emit(&router.telemetry())?;
        router.shutdown();
        return Ok(());
    }
    reject_objective(args)?;

    if let Some(models) = parse_model_list(args)? {
        if args.has("artifact") {
            return Err(Error::Usage(
                "--artifact serving is single-model; --models serves one sim-only engine \
                 per tenant"
                    .to_string(),
            ));
        }
        // honor --quant so serve plans the same joint design the user just
        // explored with `dse --models` (whose --quant defaults to w4a5)
        let quant = parse_quant(&args.get("quant", "w8a8"))?;
        let scheduled = colocate_builder(&models, quant)
            .on_device(device.as_str())?
            .explore(&DseConfig::default())?
            .schedule_for_batch(max_batch as u64);
        let registry = scheduled.serve(
            BatchPolicy { max_batch, max_wait: std::time::Duration::from_millis(2) },
            opts,
        )?;
        let stats =
            tele.start_stats(registry.metrics_handles().into_iter().map(|(_, h)| h).collect());
        let t0 = std::time::Instant::now();
        for name in scheduled.tenant_names() {
            let input_len = scheduled.input_len(name).expect("names come from the plan");
            drive_synthetic_tenant(&registry, name, requests, input_len)?;
        }
        let elapsed = t0.elapsed();
        println!(
            "{} requests x {} tenants on one {device} in {:.1} ms:",
            requests,
            models.len(),
            elapsed.as_secs_f64() * 1e3
        );
        for name in scheduled.tenant_names() {
            let m = registry.metrics(name).expect("registered above");
            println!(
                "  {name}: throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
                m.throughput_rps, m.p50_ms, m.p99_ms, m.mean_batch
            );
        }
        if let Some(s) = stats {
            s.stop();
        }
        tele.emit(&registry.telemetry())?;
        registry.shutdown();
        return Ok(());
    }

    // the artifact/chain serve paths are pinned to the bundled toy-W8A8
    // artifact; a silently ignored --quant would be a footgun
    if args.has("quant") {
        return Err(Error::Usage(
            "serve --quant applies to co-located --models serving only (artifact and \
             chain serving are fixed to the toy W8A8 artifact)"
                .to_string(),
        ));
    }

    if let Some(chain) = parse_device_chain(args)? {
        if args.has("artifact") {
            return Err(Error::Usage(
                "--artifact serving is single-device; --devices serves the sim-only chain"
                    .to_string(),
            ));
        }
        let scheduled = Deployment::for_model("toy")
            .quant(Quant::W8A8)
            .on_devices(&chain)?
            .explore(&DseConfig::default())?
            .schedule_for_batch(max_batch as u64);
        let server = scheduled.serve(
            BatchPolicy { max_batch, max_wait: std::time::Duration::from_millis(2) },
            opts,
        )?;
        let stats = tele.start_stats(vec![server.metrics_handle()]);
        let t0 = std::time::Instant::now();
        drive_synthetic(&server, requests, scheduled.input_len())?;
        let elapsed = t0.elapsed();
        let m = server.metrics();
        println!(
            "{requests} requests through the {}-partition chain in {:.1} ms: \
             throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
            chain.len(),
            elapsed.as_secs_f64() * 1e3,
            m.throughput_rps,
            m.p50_ms,
            m.p99_ms,
            m.mean_batch
        );
        if let Some(s) = stats {
            s.stop();
        }
        tele.emit(&server.telemetry())?;
        server.shutdown();
        return Ok(());
    }

    let scheduled = Deployment::for_model("toy")
        .quant(Quant::W8A8)
        .on_device(device.as_str())?
        .explore(&DseConfig::default())?
        .schedule_for_batch(max_batch as u64)
        .with_engine(EngineSpec::Pjrt {
            artifact,
            input_shape: (3, 32, 32),
            artifact_batch: max_batch,
        });
    let server = scheduled.serve(
        BatchPolicy { max_batch, max_wait: std::time::Duration::from_millis(2) },
        opts,
    )?;

    let stats = tele.start_stats(vec![server.metrics_handle()]);
    let t0 = std::time::Instant::now();
    drive_synthetic(&server, requests, scheduled.input_len())?;
    let elapsed = t0.elapsed();
    let m = server.metrics();
    println!(
        "{requests} requests in {:.1} ms: throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, \
         mean batch {:.1}, simulated accelerator time {:.3} ms",
        elapsed.as_secs_f64() * 1e3,
        m.throughput_rps,
        m.p50_ms,
        m.p99_ms,
        m.mean_batch,
        m.sim_accel_s * 1e3
    );
    if let Some(s) = stats {
        s.stop();
    }
    tele.emit(&server.telemetry())?;
    server.shutdown();
    Ok(())
}
