//! AutoWS command-line interface (self-contained arg parsing — this build
//! is fully offline).
//!
//! ```text
//! autows report <table1|tech|compress|strategies|table2|table3|fig5|fig6|fig7|yolo|all>
//! autows dse      [--model M] [--device D] [--quant Q] [--vanilla] [--phi N] [--mu N]
//! autows simulate [--model M] [--device D] [--quant Q] [--batch N]
//! autows serve    [--artifact PATH] [--requests N] [--max-batch N] [--device D]
//! ```

use anyhow::{anyhow, bail, Result};

use autows::config::RunSpec;
use autows::coordinator::{BatchPolicy, PjrtEngine, Server};
use autows::device::Device;
use autows::dse::{self, DseConfig};
use autows::ir::Quant;
use autows::runtime::Runtime;
use autows::schedule::BurstSchedule;
use autows::sim::{simulate, SimConfig};
use autows::{models, report};

/// Minimal `--key value` / `--flag` parser.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(),
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: cannot parse `{v}`")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn parse_quant(s: &str) -> Result<Quant> {
    match s.to_ascii_lowercase().as_str() {
        "w4a4" => Ok(Quant::W4A4),
        "w4a5" => Ok(Quant::W4A5),
        "w8a8" => Ok(Quant::W8A8),
        "f32" => Ok(Quant::F32),
        _ => bail!("unknown quantization `{s}` (w4a4|w4a5|w8a8|f32)"),
    }
}

const USAGE: &str = "usage: autows <report|dse|simulate|serve|run> [options]
  report <table1|tech|compress|strategies|table2|table3|fig5|fig6|fig7|yolo|all>
  dse      --model resnet18 --device zcu102 --quant w4a5 [--vanilla] [--phi 1] [--mu 512]
  simulate --model resnet18 --device zcu102 --quant w4a5 [--batch 1]
  serve    --artifact artifacts/toy_cnn_b8.hlo.txt [--requests 64] [--max-batch 8] [--device zcu102]
  run      --config configs/resnet18_zcu102.toml   # full pipeline from a config file";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "report" => cmd_report(&args),
        "dse" => cmd_dse(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    let out = match which {
        "table1" => report::table1(),
        "table2" => report::table2(),
        "table3" => report::table3(),
        "fig5" => report::fig5(),
        "fig5-gantt" => report::fig5_gantt(),
        "fig6" => report::fig6(),
        "fig6-chart" => report::fig6_chart(),
        "fig7" => report::fig7(),
        "fig7-chart" => report::fig7_chart(),
        "yolo" => report::yolo(),
        "tech" => report::tech(),
        "compress" => report::compress(),
        "strategies" => report::strategies(),
        "all" => [
            report::table1(),
            report::table2(),
            report::table3(),
            report::fig5(),
            report::fig5_gantt(),
            report::fig6(),
            report::fig6_chart(),
            report::fig7(),
            report::fig7_chart(),
            report::yolo(),
            report::tech(),
            report::compress(),
            report::strategies(),
        ]
        .join("\n"),
        other => bail!("unknown report `{other}`"),
    };
    println!("{out}");
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let model = args.get("model", "resnet18");
    let device = args.get("device", "zcu102");
    let q = parse_quant(&args.get("quant", "w4a5"))?;
    let vanilla = args.has("vanilla");
    let cfg = DseConfig {
        phi: args.get_num("phi", 1u32)?,
        mu: args.get_num("mu", 512u64)?,
        allow_streaming: !vanilla,
        warm_start: args.has("warm"),
        ..Default::default()
    };
    let net = models::by_name(&model, q).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let dev = Device::by_name(&device).ok_or_else(|| anyhow!("unknown device {device}"))?;
    match dse::run(&net, &dev, &cfg) {
        None => println!("INFEASIBLE: {model} does not fit {device} (vanilla={vanilla})"),
        Some(r) => {
            println!(
                "{model}-{q} on {device}: θ={:.1} fps, latency={:.2} ms, iterations={}",
                r.throughput, r.latency_ms, r.iterations
            );
            println!(
                "area: dsp={} lut={} bram={} ({:.0}% mem)  bandwidth={:.2}/{:.2} Gbps",
                r.area.dsp,
                r.area.lut,
                r.area.bram.total(),
                r.area.mem_utilization(&dev) * 100.0,
                r.bandwidth_bps / 1e9,
                dev.bandwidth_gbps()
            );
            if let Some(path) = args.flags.get("save") {
                std::fs::write(path, dse::serialize_design(&r.design, &dev))?;
                println!("design checkpoint written to {path}");
            }
            let sched = BurstSchedule::from_design(&r.design, &dev, 1);
            println!(
                "streaming layers: {} (balanced={}, DMA util {:.0}%)",
                sched.entries.len(),
                sched.balanced(),
                sched.dma_utilization() * 100.0
            );
            for (i, l) in r.design.network.layers.iter().enumerate() {
                if !l.has_weights() {
                    continue;
                }
                let c = &r.design.cfgs[i];
                println!(
                    "  {:<24} kp={:<2} cp={:<3} fp={:<3} n={:<3} u_on={:<6} u_off={:<6} off={:.0}%",
                    l.name,
                    c.kp,
                    c.cp,
                    c.fp,
                    c.frag.n,
                    c.frag.u_on,
                    c.frag.u_off,
                    c.frag.off_chip_ratio() * 100.0
                );
            }
            if args.has("tech") {
                use autows::ce::{assign_memory_tech, TechOptions};
                let plan = assign_memory_tech(&r.design, &dev, &TechOptions::for_device(&dev));
                println!(
                    "memory tech plan: {} BRAM (baseline {}), {} URAM, +{} LUTs, saved {} BRAM36-equiv",
                    plan.bram, plan.baseline_bram, plan.uram, plan.extra_luts, plan.bram_saved()
                );
                for c in &plan.choices {
                    if c.tech != autows::ce::MemTech::Bram {
                        println!(
                            "  {:<24} -> {} (bram={} uram={} luts={})",
                            r.design.network.layers[c.layer].name, c.tech, c.bram, c.uram, c.luts
                        );
                    }
                }
            }
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = args.get("model", "resnet18");
    let device = args.get("device", "zcu102");
    let q = parse_quant(&args.get("quant", "w4a5"))?;
    let batch: u64 = args.get_num("batch", 1u64)?;
    let net = models::by_name(&model, q).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let dev = Device::by_name(&device).ok_or_else(|| anyhow!("unknown device {device}"))?;
    // either reload a DSE checkpoint or re-run the search
    let design = match args.flags.get("design") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            dse::parse_design(&text, &net, &dev).map_err(|e| anyhow!("{e}"))?
        }
        None => {
            dse::run(&net, &dev, &DseConfig::default())
                .ok_or_else(|| anyhow!("no feasible design"))?
                .design
        }
    };
    let analytic_ms = design.latency_ms(1);
    let sim = simulate(&design, &dev, &SimConfig { batch, ..Default::default() });
    println!(
        "{model}-{q} on {device} batch={batch}: makespan={:.3} ms, stalls={:.1} us, \
         DMA busy {:.0}%, {} events (analytic latency {:.3} ms)",
        sim.makespan_s * 1e3,
        sim.total_stall_s * 1e6,
        sim.dma_busy_frac * 100.0,
        sim.events,
        analytic_ms
    );
    Ok(())
}

/// `autows run --config <file>`: the launcher. Resolves the model and device
/// from the config, runs the DSE, validates the design in the cycle-accurate
/// simulator, optionally sweeps the memory budget and runs a serving session.
fn cmd_run(args: &Args) -> Result<()> {
    let path = args.get("config", "configs/resnet18_zcu102.toml");
    let spec = RunSpec::from_file(&path).map_err(|e| anyhow!("{e}"))?;
    let net = spec.build_network().map_err(|e| anyhow!("{e}"))?;
    println!("== {} ==", spec.title);
    let s = net.stats();
    println!(
        "model {} ({}): {} layers, {:.2}M params, {:.2}G MACs on {}",
        net.name,
        spec.quant,
        s.total_layers,
        s.params as f64 / 1e6,
        s.macs as f64 / 1e9,
        spec.device.name
    );

    // DSE
    let r = match dse::run(&net, &spec.device, &spec.dse) {
        None => {
            println!("DSE: INFEASIBLE (vanilla={})", !spec.dse.allow_streaming);
            return Ok(());
        }
        Some(r) => r,
    };
    println!(
        "DSE: θ={:.1} fps, latency={:.2} ms, mem {:.0}%, bw {:.2}/{:.2} Gbps, {} streaming layers",
        r.throughput,
        r.latency_ms,
        r.area.mem_utilization(&spec.device) * 100.0,
        r.bandwidth_bps / 1e9,
        spec.device.bandwidth_gbps(),
        r.design.streaming_layers().len()
    );

    // Simulation
    let sim = simulate(&r.design, &spec.device, &SimConfig { batch: spec.sim_batch, ..Default::default() });
    println!(
        "sim (batch={}): makespan={:.3} ms, stalls={:.1} us, DMA busy {:.0}%",
        spec.sim_batch,
        sim.makespan_s * 1e3,
        sim.total_stall_s * 1e6,
        sim.dma_busy_frac * 100.0
    );

    // Optional memory sweep
    if !spec.mem_sweep.is_empty() {
        println!("mem sweep (A_mem scale -> fps):");
        for &scale in &spec.mem_sweep {
            let dev = spec.device.with_mem_scale(scale);
            match dse::run(&net, &dev, &spec.dse) {
                None => println!("  {scale:>5.2}x  infeasible"),
                Some(p) => println!("  {scale:>5.2}x  {:.1} fps", p.throughput),
            }
        }
    }

    // Optional serving session
    if let Some(serve) = &spec.serve {
        println!("serving {} requests (max batch {}):", serve.requests, serve.max_batch);
        let design = r.design.clone();
        let dev = spec.device.clone();
        let artifact = serve.artifact.clone();
        let max_batch = serve.max_batch;
        let server = Server::start_with(
            move || {
                let rt = Runtime::cpu()?;
                let model = rt.load_hlo_text(&artifact)?;
                Ok(Box::new(PjrtEngine::new(model, design, dev, (3, 32, 32), max_batch)) as _)
            },
            BatchPolicy {
                max_batch: serve.max_batch,
                max_wait: std::time::Duration::from_millis(serve.max_wait_ms),
            },
        )?;
        let receivers: Vec<_> = (0..serve.requests)
            .map(|i| {
                let input: Vec<f32> =
                    (0..3 * 32 * 32).map(|j| ((i * 31 + j) % 255) as f32 / 255.0).collect();
                server.submit(input)
            })
            .collect::<Result<_>>()?;
        for rx in receivers {
            rx.recv()??;
        }
        let m = server.metrics();
        println!(
            "  throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
            m.throughput_rps, m.p50_ms, m.p99_ms, m.mean_batch
        );
        server.shutdown();
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifact = args.get("artifact", "artifacts/toy_cnn_b8.hlo.txt");
    let requests: usize = args.get_num("requests", 64usize)?;
    let max_batch: usize = args.get_num("max-batch", 8usize)?;
    let device = args.get("device", "zcu102");

    let q = Quant::W8A8;
    let net = models::toy_cnn(q);
    let dev = Device::by_name(&device).ok_or_else(|| anyhow!("unknown device {device}"))?;
    let plan = dse::run(&net, &dev, &DseConfig::default()).ok_or_else(|| anyhow!("infeasible"))?;

    // PJRT handles are thread-affine: construct the engine on the worker.
    let design = plan.design;
    let server = Server::start_with(
        move || {
            let rt = Runtime::cpu()?;
            println!("PJRT platform: {}", rt.platform());
            let model = rt.load_hlo_text(&artifact)?;
            Ok(Box::new(PjrtEngine::new(model, design, dev, (3, 32, 32), max_batch)) as _)
        },
        BatchPolicy { max_batch, max_wait: std::time::Duration::from_millis(2) },
    )?;
    let t0 = std::time::Instant::now();
    let receivers: Vec<_> = (0..requests)
        .map(|i| {
            let input: Vec<f32> =
                (0..3 * 32 * 32).map(|j| ((i * 31 + j) % 255) as f32 / 255.0).collect();
            server.submit(input)
        })
        .collect::<Result<_>>()?;
    for rx in receivers {
        rx.recv()??;
    }
    let elapsed = t0.elapsed();
    let m = server.metrics();
    println!(
        "{requests} requests in {:.1} ms: throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, \
         mean batch {:.1}, simulated accelerator time {:.3} ms",
        elapsed.as_secs_f64() * 1e3,
        m.throughput_rps,
        m.p50_ms,
        m.p99_ms,
        m.mean_batch,
        m.sim_accel_s * 1e3
    );
    server.shutdown();
    Ok(())
}
