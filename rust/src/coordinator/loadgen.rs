//! Open-loop load generation for serving experiments.
//!
//! Closed-loop drivers (fire N requests, wait for all) measure saturation
//! throughput but hide queueing behaviour. An *open-loop* generator emits
//! requests at a fixed offered rate regardless of completions — the regime
//! where latency-vs-load curves (and the knee where the accelerator
//! saturates) become visible. Arrivals are Poisson: exponential
//! inter-arrival gaps from a deterministic PRNG, so every run and every CI
//! failure replays identically.

use std::time::{Duration, Instant};

use crate::util::XorShift64;

/// A deterministic Poisson arrival schedule.
#[derive(Debug, Clone)]
pub struct ArrivalSchedule {
    /// Offsets from t=0 at which each request should be issued, sorted.
    pub offsets: Vec<Duration>,
    /// The offered rate the schedule was built for (requests/second).
    pub rate_rps: f64,
}

impl ArrivalSchedule {
    /// Build `n` Poisson arrivals at `rate_rps`, seeded deterministically.
    pub fn poisson(n: usize, rate_rps: f64, seed: u64) -> ArrivalSchedule {
        assert!(rate_rps > 0.0, "rate must be positive");
        let mut rng = XorShift64::new(seed);
        let mut t = 0.0_f64;
        let mut offsets = Vec::with_capacity(n);
        for _ in 0..n {
            // inverse-CDF exponential gap; clamp the unit sample away from 0
            let u = rng.unit().max(1e-12);
            t += -u.ln() / rate_rps;
            offsets.push(Duration::from_secs_f64(t));
        }
        ArrivalSchedule { offsets, rate_rps }
    }

    /// Uniform (constant-gap) arrivals — the burst-free reference.
    pub fn uniform(n: usize, rate_rps: f64) -> ArrivalSchedule {
        assert!(rate_rps > 0.0, "rate must be positive");
        let gap = 1.0 / rate_rps;
        ArrivalSchedule {
            offsets: (1..=n).map(|i| Duration::from_secs_f64(i as f64 * gap)).collect(),
            rate_rps,
        }
    }

    /// Deterministic non-stationary arrivals: the offered rate ramps
    /// linearly from `start_rps` to `end_rps` across the `n` requests.
    /// Gap `i` is an exponential sample with the locally interpolated rate,
    /// so the schedule sweeps a latency-vs-load curve in ONE run — the
    /// saturation knee shows up as the point in the trace where queueing
    /// delay takes off. `rate_rps` reports the mean of the two endpoints.
    pub fn ramp(n: usize, start_rps: f64, end_rps: f64, seed: u64) -> ArrivalSchedule {
        assert!(start_rps > 0.0 && end_rps > 0.0, "rates must be positive");
        let mut rng = XorShift64::new(seed);
        let mut t = 0.0_f64;
        let mut offsets = Vec::with_capacity(n);
        for i in 0..n {
            let frac = if n > 1 { i as f64 / (n - 1) as f64 } else { 0.0 };
            let rate = start_rps + (end_rps - start_rps) * frac;
            let u = rng.unit().max(1e-12);
            t += -u.ln() / rate;
            offsets.push(Duration::from_secs_f64(t));
        }
        ArrivalSchedule { offsets, rate_rps: 0.5 * (start_rps + end_rps) }
    }

    /// Flash-crowd arrivals: a Poisson stream at `base_rps` with a spike of
    /// `spike_frac · n` requests at `spike_rps` in the middle — the arrival
    /// shape an admission controller has to survive (ROADMAP open item 2).
    /// Seed-deterministic like every other schedule; `rate_rps` reports the
    /// whole-trace average `n / span` implied by the segment rates.
    pub fn burst(
        n: usize,
        base_rps: f64,
        spike_rps: f64,
        spike_frac: f64,
        seed: u64,
    ) -> ArrivalSchedule {
        assert!(base_rps > 0.0 && spike_rps > 0.0, "rates must be positive");
        assert!((0.0..=1.0).contains(&spike_frac), "spike_frac must be in [0, 1]");
        let spike_n = ((n as f64) * spike_frac).round() as usize;
        let pre_n = (n - spike_n) / 2;
        let mut rng = XorShift64::new(seed);
        let mut t = 0.0_f64;
        let mut offsets = Vec::with_capacity(n);
        for i in 0..n {
            let rate = if i < pre_n || i >= pre_n + spike_n { base_rps } else { spike_rps };
            let u = rng.unit().max(1e-12);
            t += -u.ln() / rate;
            offsets.push(Duration::from_secs_f64(t));
        }
        let span = (n - spike_n) as f64 / base_rps + spike_n as f64 / spike_rps;
        ArrivalSchedule { offsets, rate_rps: n as f64 / span.max(1e-12) }
    }

    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Empirical rate of the schedule (n / span) — tests Poisson correctness.
    pub fn empirical_rate(&self) -> f64 {
        match self.offsets.last() {
            None => 0.0,
            Some(last) => self.offsets.len() as f64 / last.as_secs_f64().max(1e-12),
        }
    }

    /// Build `n` arrivals of a **multi-model mix**: one aggregate Poisson
    /// stream at `Σ rate_i`, each arrival assigned to a model with
    /// probability `rate_i / Σ rate_j` (the superposition theorem — the
    /// per-model substreams are themselves Poisson at their spec rates).
    /// Seed-deterministic like every other schedule; this is the offered
    /// load a fleet router sees.
    pub fn mixed(n: usize, specs: &[MixedSpec], seed: u64) -> MixedSchedule {
        assert!(!specs.is_empty(), "mixed: the model mix is empty");
        assert!(specs.iter().all(|s| s.rate_rps > 0.0), "rates must be positive");
        let total: f64 = specs.iter().map(|s| s.rate_rps).sum();
        let mut rng = XorShift64::new(seed);
        let mut t = 0.0_f64;
        let mut offsets = Vec::with_capacity(n);
        let mut picks = Vec::with_capacity(n);
        for _ in 0..n {
            let u = rng.unit().max(1e-12);
            t += -u.ln() / total;
            offsets.push(Duration::from_secs_f64(t));
            // weighted pick from a second draw: walk the cumulative rates
            let mut w = rng.unit() * total;
            let mut pick = specs.len() - 1;
            for (i, s) in specs.iter().enumerate() {
                if w < s.rate_rps {
                    pick = i;
                    break;
                }
                w -= s.rate_rps;
            }
            picks.push(pick);
        }
        MixedSchedule {
            offsets,
            picks,
            models: specs.iter().map(|s| s.model.clone()).collect(),
            rate_rps: total,
        }
    }
}

/// One model's slice of a mixed offered load.
#[derive(Debug, Clone)]
pub struct MixedSpec {
    /// Model name, as registered with the router/registry.
    pub model: String,
    /// This model's offered rate (requests/second).
    pub rate_rps: f64,
}

/// A deterministic multi-model arrival schedule
/// ([`ArrivalSchedule::mixed`]): aggregate Poisson offsets plus a per-arrival
/// model assignment.
#[derive(Debug, Clone)]
pub struct MixedSchedule {
    /// Offsets from t=0 at which each request should be issued, sorted.
    pub offsets: Vec<Duration>,
    /// Index into [`MixedSchedule::models`] per arrival.
    pub picks: Vec<usize>,
    /// Model names, in spec order.
    pub models: Vec<String>,
    /// The aggregate offered rate `Σ rate_i` (requests/second).
    pub rate_rps: f64,
}

impl MixedSchedule {
    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Model name assigned to arrival `i`.
    pub fn model_of(&self, i: usize) -> &str {
        &self.models[self.picks[i]]
    }

    /// Empirical aggregate rate of the schedule (n / span).
    pub fn empirical_rate(&self) -> f64 {
        match self.offsets.last() {
            None => 0.0,
            Some(last) => self.offsets.len() as f64 / last.as_secs_f64().max(1e-12),
        }
    }

    /// Number of arrivals assigned to each model, in spec order.
    pub fn per_model_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.models.len()];
        for &p in &self.picks {
            counts[p] += 1;
        }
        counts
    }
}

/// A completion handle the open-loop drivers can drain: both the server's
/// pooled [`ReplyHandle`](crate::coordinator::ReplyHandle) and the fleet
/// router's [`RouterReply`](crate::coordinator::RouterReply) qualify, so the
/// same driver measures a device directly or a whole fleet through its
/// router.
pub trait Completion {
    /// Block for the response; `None` on a dropped channel or typed error.
    fn completion(self) -> Option<crate::coordinator::Response>;
}

impl Completion for crate::coordinator::ReplyHandle {
    fn completion(self) -> Option<crate::coordinator::Response> {
        match self.recv() {
            Ok(Ok(resp)) => Some(resp),
            _ => None,
        }
    }
}

impl Completion for super::router::RouterReply {
    fn completion(self) -> Option<crate::coordinator::Response> {
        match self.recv() {
            Ok(Ok(resp)) => Some(resp),
            _ => None,
        }
    }
}

/// Outcome of one open-loop run.
#[derive(Debug, Clone)]
pub struct LoadResult {
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Requests rejected by admission control.
    pub rejected: usize,
    pub completed: usize,
}

/// Drive `submit` open-loop along `schedule`, then wait for all responses.
///
/// `submit` is called at (or as close as the clock allows to) each arrival
/// offset and returns a completion handle or an admission error. Latency
/// comes from each [`Response::total`](crate::coordinator::Response) —
/// stamped by the worker at completion, so draining the handles after the
/// submission loop does not inflate early requests (the pooled reply slots
/// buffer completed responses).
pub fn run_open_loop<H, S, E>(schedule: &ArrivalSchedule, mut submit: S) -> LoadResult
where
    H: Completion,
    S: FnMut() -> Result<H, E>,
{
    let start = Instant::now();
    let mut pending: Vec<H> = Vec::new();
    let mut rejected = 0usize;

    for &offset in &schedule.offsets {
        if let Some(sleep) = offset.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        match submit() {
            Ok(rx) => pending.push(rx),
            Err(_) => rejected += 1,
        }
    }

    let mut latencies_ms: Vec<f64> = Vec::with_capacity(pending.len());
    for rx in pending {
        if let Some(resp) = rx.completion() {
            latencies_ms.push(resp.total.as_secs_f64() * 1e3);
        }
    }
    let wall = start.elapsed().as_secs_f64();
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // same linear-interpolation estimator the server metrics use
    let pct = |p: f64| -> f64 { super::metrics::percentile_sorted(&latencies_ms, p) };
    let completed = latencies_ms.len();
    LoadResult {
        offered_rps: schedule.rate_rps,
        achieved_rps: completed as f64 / wall.max(1e-12),
        p50_ms: pct(0.50),
        p95_ms: pct(0.95),
        p99_ms: pct(0.99),
        mean_ms: if completed == 0 {
            0.0
        } else {
            latencies_ms.iter().sum::<f64>() / completed as f64
        },
        rejected,
        completed,
    }
}

/// [`run_open_loop`] for a multi-model mix: `submit` receives the model
/// name assigned to each arrival (a router's `submit(model, input)` curries
/// naturally into this).
pub fn run_open_loop_mixed<H, S, E>(schedule: &MixedSchedule, mut submit: S) -> LoadResult
where
    H: Completion,
    S: FnMut(&str) -> Result<H, E>,
{
    let start = Instant::now();
    let mut pending: Vec<H> = Vec::new();
    let mut rejected = 0usize;

    for (i, &offset) in schedule.offsets.iter().enumerate() {
        if let Some(sleep) = offset.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        match submit(schedule.model_of(i)) {
            Ok(rx) => pending.push(rx),
            Err(_) => rejected += 1,
        }
    }

    let mut latencies_ms: Vec<f64> = Vec::with_capacity(pending.len());
    for rx in pending {
        if let Some(resp) = rx.completion() {
            latencies_ms.push(resp.total.as_secs_f64() * 1e3);
        }
    }
    let wall = start.elapsed().as_secs_f64();
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 { super::metrics::percentile_sorted(&latencies_ms, p) };
    let completed = latencies_ms.len();
    LoadResult {
        offered_rps: schedule.rate_rps,
        achieved_rps: completed as f64 / wall.max(1e-12),
        p50_ms: pct(0.50),
        p95_ms: pct(0.95),
        p99_ms: pct(0.99),
        mean_ms: if completed == 0 {
            0.0
        } else {
            latencies_ms.iter().sum::<f64>() / completed as f64
        },
        rejected,
        completed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_schedule_hits_target_rate() {
        let s = ArrivalSchedule::poisson(2000, 500.0, 42);
        assert_eq!(s.len(), 2000);
        let rate = s.empirical_rate();
        assert!((400.0..600.0).contains(&rate), "empirical rate {rate}");
        // sorted offsets
        for w in s.offsets.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn poisson_is_deterministic_per_seed() {
        let a = ArrivalSchedule::poisson(100, 50.0, 1);
        let b = ArrivalSchedule::poisson(100, 50.0, 1);
        assert_eq!(a.offsets, b.offsets);
        let c = ArrivalSchedule::poisson(100, 50.0, 2);
        assert_ne!(a.offsets, c.offsets);
    }

    #[test]
    fn poisson_gaps_are_bursty_uniform_gaps_are_not() {
        let p = ArrivalSchedule::poisson(1000, 100.0, 3);
        let u = ArrivalSchedule::uniform(1000, 100.0);
        let cv = |s: &ArrivalSchedule| {
            let gaps: Vec<f64> = s
                .offsets
                .windows(2)
                .map(|w| (w[1] - w[0]).as_secs_f64())
                .collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var.sqrt() / mean
        };
        // exponential gaps: coefficient of variation ≈ 1; uniform: 0
        assert!(cv(&p) > 0.8, "poisson cv {}", cv(&p));
        assert!(cv(&u) < 1e-9, "uniform cv {}", cv(&u));
    }

    #[test]
    fn ramp_offsets_are_monotonic_and_deterministic() {
        let a = ArrivalSchedule::ramp(500, 100.0, 1000.0, 7);
        assert_eq!(a.len(), 500);
        for w in a.offsets.windows(2) {
            assert!(w[0] < w[1], "offsets must be strictly increasing");
        }
        let b = ArrivalSchedule::ramp(500, 100.0, 1000.0, 7);
        assert_eq!(a.offsets, b.offsets);
        let c = ArrivalSchedule::ramp(500, 100.0, 1000.0, 8);
        assert_ne!(a.offsets, c.offsets);
        assert!((a.rate_rps - 550.0).abs() < 1e-9, "mean of the endpoints");
    }

    #[test]
    fn ramp_rate_endpoints_match() {
        let n = 4000;
        let s = ArrivalSchedule::ramp(n, 200.0, 2000.0, 13);
        let gaps: Vec<f64> =
            s.offsets.windows(2).map(|w| (w[1] - w[0]).as_secs_f64()).collect();
        let head = n / 10;
        let mean = |g: &[f64]| g.iter().sum::<f64>() / g.len() as f64;
        let head_rate = 1.0 / mean(&gaps[..head]);
        let tail_rate = 1.0 / mean(&gaps[gaps.len() - head..]);
        // first/last decile should sit near the ramp endpoints (exponential
        // noise over ~400 gaps: relative std ≈ 5%, allow ±30%)
        assert!(
            (140.0..280.0).contains(&head_rate),
            "head of the ramp ≈ start rate, got {head_rate}"
        );
        assert!(
            (1400.0..2800.0).contains(&tail_rate),
            "tail of the ramp ≈ end rate, got {tail_rate}"
        );
        assert!(tail_rate > 3.0 * head_rate, "the ramp must actually ramp");
    }

    #[test]
    fn burst_offsets_are_monotonic_and_deterministic() {
        let a = ArrivalSchedule::burst(1000, 100.0, 5000.0, 0.3, 21);
        assert_eq!(a.len(), 1000);
        for w in a.offsets.windows(2) {
            assert!(w[0] < w[1], "offsets must be strictly increasing");
        }
        let b = ArrivalSchedule::burst(1000, 100.0, 5000.0, 0.3, 21);
        assert_eq!(a.offsets, b.offsets);
        let c = ArrivalSchedule::burst(1000, 100.0, 5000.0, 0.3, 22);
        assert_ne!(a.offsets, c.offsets);
    }

    #[test]
    fn burst_spike_sits_in_the_middle_at_spike_rate() {
        let n = 4000;
        let (base, spike, frac) = (200.0, 4000.0, 0.25);
        let s = ArrivalSchedule::burst(n, base, spike, frac, 5);
        let gaps: Vec<f64> =
            s.offsets.windows(2).map(|w| (w[1] - w[0]).as_secs_f64()).collect();
        let spike_n = ((n as f64) * frac).round() as usize;
        let pre_n = (n - spike_n) / 2;
        let mean = |g: &[f64]| g.iter().sum::<f64>() / g.len() as f64;
        // interior slices, clear of the segment boundaries
        let pre_rate = 1.0 / mean(&gaps[..pre_n - 1]);
        let spike_rate = 1.0 / mean(&gaps[pre_n..pre_n + spike_n - 1]);
        let post_rate = 1.0 / mean(&gaps[pre_n + spike_n..]);
        assert!((140.0..280.0).contains(&pre_rate), "pre-spike ≈ base, got {pre_rate}");
        assert!(
            (2800.0..5600.0).contains(&spike_rate),
            "spike ≈ spike_rps, got {spike_rate}"
        );
        assert!((140.0..280.0).contains(&post_rate), "post-spike ≈ base, got {post_rate}");
        assert!(spike_rate > 10.0 * pre_rate, "the flash crowd must actually flash");
    }

    #[test]
    fn burst_reported_rate_averages_the_segments() {
        let s = ArrivalSchedule::burst(2000, 500.0, 10000.0, 0.5, 9);
        // n/span with half the requests at each rate: 2/(1/500 + 1/10000)
        let want = 2.0 / (1.0 / 500.0 + 1.0 / 10000.0);
        assert!(
            (s.rate_rps - want).abs() / want < 1e-9,
            "reported {} vs harmonic mean {want}",
            s.rate_rps
        );
        // degenerate shapes still behave
        let flat = ArrivalSchedule::burst(100, 300.0, 9000.0, 0.0, 1);
        assert_eq!(flat.len(), 100);
        assert!((flat.rate_rps - 300.0).abs() < 1e-9);
        let all = ArrivalSchedule::burst(100, 300.0, 9000.0, 1.0, 1);
        assert!((all.rate_rps - 9000.0).abs() < 1e-9);
    }

    fn mix(pairs: &[(&str, f64)]) -> Vec<MixedSpec> {
        pairs
            .iter()
            .map(|&(m, r)| MixedSpec { model: m.to_string(), rate_rps: r })
            .collect()
    }

    #[test]
    fn mixed_offsets_are_monotonic_and_deterministic() {
        let specs = mix(&[("resnet18", 300.0), ("squeezenet", 100.0)]);
        let a = ArrivalSchedule::mixed(1000, &specs, 17);
        assert_eq!(a.len(), 1000);
        assert_eq!(a.picks.len(), 1000);
        for w in a.offsets.windows(2) {
            assert!(w[0] < w[1], "offsets must be strictly increasing");
        }
        let b = ArrivalSchedule::mixed(1000, &specs, 17);
        assert_eq!(a.offsets, b.offsets);
        assert_eq!(a.picks, b.picks);
        let c = ArrivalSchedule::mixed(1000, &specs, 18);
        assert_ne!(a.picks, c.picks);
    }

    #[test]
    fn mixed_aggregate_rate_and_per_model_shares_match_the_specs() {
        let specs = mix(&[("a", 600.0), ("b", 300.0), ("c", 100.0)]);
        let s = ArrivalSchedule::mixed(4000, &specs, 23);
        assert!((s.rate_rps - 1000.0).abs() < 1e-9, "aggregate rate is Σ rate_i");
        let rate = s.empirical_rate();
        assert!((800.0..1200.0).contains(&rate), "empirical aggregate rate {rate}");
        // per-model shares track rate_i / Σ (binomial: n=4000, rel std ≲ 3%)
        let counts = s.per_model_counts();
        assert_eq!(counts.iter().sum::<usize>(), 4000);
        for (i, want_frac) in [0.6, 0.3, 0.1].iter().enumerate() {
            let frac = counts[i] as f64 / 4000.0;
            assert!(
                (frac - want_frac).abs() < 0.05,
                "model {} share {frac} vs spec {want_frac}",
                s.models[i]
            );
        }
        // every arrival resolves to a registered model name
        assert_eq!(s.model_of(0), s.models[s.picks[0]].as_str());
    }

    #[test]
    fn open_loop_against_live_server() {
        use crate::coordinator::{BatchPolicy, Server, SimOnlyEngine};
        use crate::device::Device;
        use crate::dse::{self, DseConfig};
        use crate::ir::Quant;

        let net = crate::models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let engine = SimOnlyEngine {
            design: r.design,
            device: dev,
            input_len: 3 * 32 * 32,
            output_len: 10,
        };
        let server = Server::start(
            engine,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        let schedule = ArrivalSchedule::poisson(64, 2000.0, 11);
        let res = run_open_loop(&schedule, || server.submit(vec![0.5; 3 * 32 * 32]));
        assert_eq!(res.completed, 64);
        assert_eq!(res.rejected, 0);
        assert!(res.p50_ms <= res.p95_ms && res.p95_ms <= res.p99_ms);
        assert!(res.achieved_rps > 0.0);
        server.shutdown();
    }

    #[test]
    fn mixed_open_loop_against_live_router() {
        use crate::coordinator::{BatchPolicy, Router, Server, ServerOptions, SimOnlyEngine};
        use crate::device::Device;
        use crate::dse::{self, DseConfig};
        use crate::ir::Quant;

        let net = crate::models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let mut router = Router::new();
        for model in ["toy_a", "toy_b"] {
            let engine = SimOnlyEngine {
                design: r.design.clone(),
                device: dev.clone(),
                input_len: 3 * 32 * 32,
                output_len: 10,
            };
            let server = Server::start_with_opts(
                move || Ok(Box::new(engine.clone()) as _),
                BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                ServerOptions::default(),
            )
            .unwrap();
            router.add_server("zcu102", model, 3 * 32 * 32, server);
        }
        let specs = mix(&[("toy_a", 1500.0), ("toy_b", 500.0)]);
        let schedule = ArrivalSchedule::mixed(64, &specs, 11);
        let res =
            run_open_loop_mixed(&schedule, |m| router.submit(m, vec![0.5; 3 * 32 * 32]));
        assert_eq!(res.completed, 64);
        assert_eq!(res.rejected, 0);
        assert!(res.p50_ms <= res.p95_ms && res.p95_ms <= res.p99_ms);
        router.shutdown();
    }
}
