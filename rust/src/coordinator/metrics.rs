//! Serving metrics: latency distribution, throughput, batch sizes, and —
//! since the engine pool — per-worker accounting and dispatch-queue depth.
//!
//! Two layers:
//!
//! - [`Metrics`] — the plain accumulator + [`MetricsSnapshot`] summary
//!   (unchanged public API, directly usable single-threaded).
//! - [`MetricsHub`] — the *lock-free serving front* over it. Workers and
//!   batcher shards never touch a mutex: batch completions travel as
//!   [`BatchRecord`] events over an mpsc sender (lock-free send) and
//!   queue-depth samples land in plain atomics. The only lock is the
//!   snapshot-side fold mutex, taken by **readers** to fold pending events
//!   into a `Metrics` — a metrics read can therefore never stall dispatch,
//!   and dispatch never waits on a metrics read.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Linear-interpolation percentile over an ascending-sorted slice (the
/// "exclusive of the definition, inclusive of the data" estimator used by
/// numpy's default `linear` mode): rank `h = (n-1)·p` falls between two
/// order statistics and the result interpolates between them. On tiny
/// sample sets this matters — nearest-rank snapping makes p99 of a
/// 10-sample set equal its maximum, hiding the tail shape entirely.
pub(crate) fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let h = (sorted.len() as f64 - 1.0) * p.clamp(0.0, 1.0);
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    let frac = h - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Per-worker accounting: how many batches/requests each pool worker
/// served and how long it spent busy in the engine. Uneven `batches`
/// across workers is pool skew; `busy_s` against wall-clock is worker
/// utilization.
#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    /// Batches this worker dispatched to its engine.
    pub batches: u64,
    /// Requests those batches carried.
    pub requests: u64,
    /// Wall-clock the worker spent inside the engine (timing + numerics),
    /// seconds.
    pub busy_s: f64,
}

/// Online metrics accumulator (plain struct; the server wraps it in a lock).
#[derive(Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    batches: u64,
    batch_items: u64,
    sim_accel_s: f64,
    started_at: Option<std::time::Instant>,
    workers: Vec<WorkerStats>,
    queue_samples: u64,
    queue_sum: u64,
    queue_max: usize,
}

/// A point-in-time summary.
///
/// Percentiles (`p50_ms`/`p95_ms`/`p99_ms`) use the **linear-interpolation
/// order-statistic estimator**: the rank `h = (n-1)·p` generally falls
/// between two sorted samples, and the reported value interpolates linearly
/// between them (numpy's default). The earlier estimator snapped to the
/// nearest sample index, which on small batches collapsed every tail
/// percentile onto one sample — p99 of a 10-sample set was just the
/// maximum. Worked 5-sample example:
///
/// ```
/// use std::time::Duration;
/// use autows::coordinator::Metrics;
///
/// let mut m = Metrics::default();
/// let lats: Vec<Duration> =
///     [10u64, 20, 30, 40, 50].iter().map(|&ms| Duration::from_millis(ms)).collect();
/// m.record_batch(&lats, Duration::ZERO);
/// let s = m.snapshot();
/// // h = (5-1)·p: p50 → rank 2.0 (exactly the middle sample) ...
/// assert!((s.p50_ms - 30.0).abs() < 1e-9);
/// // ... p95 → rank 3.8: 40 + 0.8·(50-40) = 48 ms (nearest-rank said 50)
/// assert!((s.p95_ms - 48.0).abs() < 1e-9);
/// // ... p99 → rank 3.96: 40 + 0.96·(50-40) = 49.6 ms
/// assert!((s.p99_ms - 49.6).abs() < 1e-9);
/// ```
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub throughput_rps: f64,
    /// Total *simulated accelerator* time spent, seconds.
    pub sim_accel_s: f64,
    /// Per-worker batch/request counts and engine busy-time. One entry per
    /// pool worker that has served at least one batch (always index-aligned
    /// with worker ids; a worker that served nothing may be absent from the
    /// tail).
    pub per_worker: Vec<WorkerStats>,
    /// Mean of the queue-depth samples the dispatcher took at each batch
    /// dispatch (requests admitted but not yet handed to an engine).
    pub queue_depth_mean: f64,
    /// Maximum observed dispatch-point queue depth.
    pub queue_depth_max: usize,
}

impl Metrics {
    /// Record one dispatched batch against pool worker 0 (the single-worker
    /// server's accounting; pool workers use [`Metrics::record_batch_on`]).
    /// An empty latency slice is a no-op: a batch that served nothing must
    /// not skew `mean_batch` toward zero or start the throughput clock.
    pub fn record_batch(&mut self, latencies: &[Duration], sim_accel: Duration) {
        self.record_batch_on(0, latencies, sim_accel, Duration::ZERO);
    }

    /// Record one dispatched batch served by pool worker `worker`, with the
    /// wall-clock the worker spent inside the engine (`busy`). Empty
    /// latency slices are a no-op, as in [`Metrics::record_batch`].
    pub fn record_batch_on(
        &mut self,
        worker: usize,
        latencies: &[Duration],
        sim_accel: Duration,
        busy: Duration,
    ) {
        self.fold(BatchRecord {
            worker,
            latencies_us: latencies.iter().map(|d| d.as_micros() as u64).collect(),
            sim_accel,
            busy,
            at: Instant::now(),
        });
    }

    /// Fold one completed-batch event. `rec.at` — the worker-side
    /// completion stamp — starts the throughput clock on the first event,
    /// so lazily folded events (the [`MetricsHub`] path) report the same
    /// elapsed window as eagerly recorded ones.
    pub(crate) fn fold(&mut self, rec: BatchRecord) {
        if rec.latencies_us.is_empty() {
            return;
        }
        if self.started_at.is_none() {
            self.started_at = Some(rec.at);
        }
        self.batches += 1;
        self.batch_items += rec.latencies_us.len() as u64;
        self.sim_accel_s += rec.sim_accel.as_secs_f64();
        self.latencies_us.extend_from_slice(&rec.latencies_us);
        if self.workers.len() <= rec.worker {
            self.workers.resize(rec.worker + 1, WorkerStats::default());
        }
        let w = &mut self.workers[rec.worker];
        w.batches += 1;
        w.requests += rec.latencies_us.len() as u64;
        w.busy_s += rec.busy.as_secs_f64();
    }

    /// Sample the dispatch-point queue depth (requests admitted but not yet
    /// handed to an engine). The dispatcher calls this once per dispatched
    /// batch, so the mean weights depth by dispatch activity.
    pub fn record_queue_depth(&mut self, depth: usize) {
        self.queue_samples += 1;
        self.queue_sum += depth as u64;
        self.queue_max = self.queue_max.max(depth);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut sorted_ms: Vec<f64> =
            self.latencies_us.iter().map(|&us| us as f64 / 1e3).collect();
        sorted_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = if sorted_ms.is_empty() {
            0.0
        } else {
            sorted_ms.iter().sum::<f64>() / sorted_ms.len() as f64
        };
        let elapsed = self.started_at.map_or(0.0, |t| t.elapsed().as_secs_f64());
        MetricsSnapshot {
            requests: self.batch_items,
            batches: self.batches,
            mean_batch: if self.batches == 0 {
                0.0
            } else {
                self.batch_items as f64 / self.batches as f64
            },
            p50_ms: percentile_sorted(&sorted_ms, 0.50),
            p95_ms: percentile_sorted(&sorted_ms, 0.95),
            p99_ms: percentile_sorted(&sorted_ms, 0.99),
            mean_ms: mean,
            throughput_rps: if elapsed > 0.0 { self.batch_items as f64 / elapsed } else { 0.0 },
            sim_accel_s: self.sim_accel_s,
            per_worker: self.workers.clone(),
            queue_depth_mean: if self.queue_samples == 0 {
                0.0
            } else {
                self.queue_sum as f64 / self.queue_samples as f64
            },
            queue_depth_max: self.queue_max,
        }
    }
}

/// One completed batch, as an event (what a worker emits instead of taking
/// the metrics lock).
pub(crate) struct BatchRecord {
    pub worker: usize,
    pub latencies_us: Vec<u64>,
    pub sim_accel: Duration,
    pub busy: Duration,
    /// Worker-side completion stamp (starts the throughput clock on fold).
    pub at: Instant,
}

/// A worker's lock-free handle for reporting completed batches: one event
/// send per batch, no shared mutable state.
#[derive(Clone)]
pub(crate) struct BatchSink {
    tx: mpsc::Sender<BatchRecord>,
}

impl BatchSink {
    pub fn record(&self, worker: usize, latencies: &[Duration], sim_accel: Duration, busy: Duration) {
        // A send only fails after the hub is gone (server teardown), when
        // nobody can snapshot anymore — dropping the event is correct.
        let _ = self.tx.send(BatchRecord {
            worker,
            latencies_us: latencies.iter().map(|d| d.as_micros() as u64).collect(),
            sim_accel,
            busy,
            at: Instant::now(),
        });
    }
}

/// The serving-side metrics front: lock-free for writers, folding for
/// readers.
///
/// Writers (workers, batcher shards) use [`BatchSink::record`] — an mpsc
/// send — and [`MetricsHub::record_queue_depth`] — three atomic RMWs.
/// Readers call [`MetricsHub::snapshot`], which takes the fold mutex,
/// drains pending events into the folded [`Metrics`], and summarizes. The
/// fold lock is contended only by concurrent *readers*; the serving path
/// never acquires it, which [`MetricsHub::serving_path_locks`] makes
/// checkable.
pub(crate) struct MetricsHub {
    tx: mpsc::Sender<BatchRecord>,
    fold: Mutex<(mpsc::Receiver<BatchRecord>, Metrics)>,
    queue_samples: AtomicU64,
    queue_sum: AtomicU64,
    queue_max: AtomicU64,
    /// Tripwire: lock acquisitions charged to the dispatch/batch-completion
    /// path. The sharded front is lock-free by construction, so this MUST
    /// stay 0 — any future Mutex introduced on those paths must count
    /// itself here, and the serving tests assert the counter never moves.
    serving_locks: AtomicU64,
    /// Snapshot-side fold-lock acquisitions (diagnostic counterpart).
    fold_locks: AtomicU64,
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        let (tx, rx) = mpsc::channel();
        MetricsHub {
            tx,
            fold: Mutex::new((rx, Metrics::default())),
            queue_samples: AtomicU64::new(0),
            queue_sum: AtomicU64::new(0),
            queue_max: AtomicU64::new(0),
            serving_locks: AtomicU64::new(0),
            fold_locks: AtomicU64::new(0),
        }
    }

    /// A lock-free batch-completion sink for one serving thread.
    pub fn sink(&self) -> BatchSink {
        BatchSink { tx: self.tx.clone() }
    }

    /// Sample the dispatch-point queue depth — atomics only.
    pub fn record_queue_depth(&self, depth: usize) {
        self.queue_samples.fetch_add(1, Ordering::AcqRel);
        self.queue_sum.fetch_add(depth as u64, Ordering::AcqRel);
        self.queue_max.fetch_max(depth as u64, Ordering::AcqRel);
    }

    /// Fold all pending events and summarize. Reader-side work: the fold
    /// mutex is shared with other snapshots, never with the serving path.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.fold_locks.fetch_add(1, Ordering::AcqRel);
        let mut guard = self.fold.lock().unwrap();
        let (rx, folded) = &mut *guard;
        while let Ok(rec) = rx.try_recv() {
            folded.fold(rec);
        }
        let mut snap = folded.snapshot();
        // queue depth lives in the hub's atomics, not the folded struct
        let samples = self.queue_samples.load(Ordering::Acquire);
        let sum = self.queue_sum.load(Ordering::Acquire);
        snap.queue_depth_mean = if samples == 0 { 0.0 } else { sum as f64 / samples as f64 };
        snap.queue_depth_max = self.queue_max.load(Ordering::Acquire) as usize;
        snap
    }

    pub fn serving_path_locks(&self) -> u64 {
        self.serving_locks.load(Ordering::Acquire)
    }

    pub fn fold_locks(&self) -> u64 {
        self.fold_locks.load(Ordering::Acquire)
    }
}

/// Cloneable, thread-safe reader onto a server's metrics hub.
///
/// [`MetricsHub`] itself is crate-private and a `Server` is not `Sync`
/// (its submit side holds mpsc senders); this handle carries just the
/// `Arc`'d hub so stats reporters and exporters can snapshot from any
/// thread without borrowing the server.
#[derive(Clone)]
pub struct MetricsHandle {
    hub: Arc<MetricsHub>,
}

impl MetricsHandle {
    pub(crate) fn new(hub: Arc<MetricsHub>) -> MetricsHandle {
        MetricsHandle { hub }
    }

    /// Fold pending batch events and summarize (same as
    /// `Server::metrics`).
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.hub.snapshot()
    }

    /// The serving-path lock tripwire — must stay 0.
    pub fn serving_path_locks(&self) -> u64 {
        self.hub.serving_path_locks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_zero() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99_ms, 0.0);
        assert!(s.per_worker.is_empty());
        assert_eq!(s.queue_depth_mean, 0.0);
        assert_eq!(s.queue_depth_max, 0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        let lats: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        m.record_batch(&lats, Duration::from_millis(5));
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
        assert!((s.p50_ms - 50.0).abs() < 2.0, "{}", s.p50_ms);
        assert!((s.p99_ms - 100.0).abs() < 2.0, "{}", s.p99_ms);
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::default();
        m.record_batch(&[Duration::from_millis(1); 4], Duration::ZERO);
        m.record_batch(&[Duration::from_millis(1); 2], Duration::ZERO);
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
    }

    #[test]
    fn single_sample_percentiles_collapse_to_the_sample() {
        let mut m = Metrics::default();
        m.record_batch(&[Duration::from_millis(7)], Duration::from_millis(1));
        let s = m.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.batches, 1);
        // every percentile of a 1-sample distribution IS the sample
        assert_eq!(s.p50_ms, 7.0);
        assert_eq!(s.p95_ms, 7.0);
        assert_eq!(s.p99_ms, 7.0);
        assert_eq!(s.mean_ms, 7.0);
        assert!((s.mean_batch - 1.0).abs() < 1e-12);
        assert!((s.sim_accel_s - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut m = Metrics::default();
        m.record_batch(&[], Duration::from_millis(9));
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.batches, 0, "an empty batch must not count as a batch");
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.sim_accel_s, 0.0, "no work was dispatched");
        assert_eq!(s.throughput_rps, 0.0, "the clock must not start on nothing");
        assert!(s.per_worker.is_empty(), "no worker served anything");
        // a real batch after the no-op accounts normally
        m.record_batch(&[Duration::from_millis(2); 3], Duration::ZERO);
        let s = m.snapshot();
        assert_eq!((s.requests, s.batches), (3, 1));
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
    }

    #[test]
    fn snapshots_are_monotone_under_interleaved_batches() {
        let mut m = Metrics::default();
        let mut last_requests = 0;
        let mut last_p99 = 0.0_f64;
        // interleave slow, fast and empty batches: cumulative counters only
        // grow, percentiles stay ordered, and the max-latency tail (p99 on
        // a growing set that keeps its maximum) never shrinks
        let batches: Vec<Vec<Duration>> = vec![
            vec![Duration::from_millis(50); 2],
            vec![],
            vec![Duration::from_millis(1); 8],
            vec![Duration::from_millis(50), Duration::from_millis(2)],
            vec![],
            vec![Duration::from_millis(3); 5],
        ];
        for b in &batches {
            m.record_batch(b, Duration::ZERO);
            let s = m.snapshot();
            assert!(s.requests >= last_requests, "requests are cumulative");
            assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms, "percentiles ordered");
            if s.requests == last_requests {
                assert_eq!(s.p99_ms, last_p99, "an empty batch must not move the tail");
            }
            last_requests = s.requests;
            last_p99 = s.p99_ms;
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 17);
        assert_eq!(s.batches, 4, "two interleaved empties dropped");
        // the 50 ms stragglers keep the tail up after fast batches landed
        assert!(s.p99_ms >= 49.0, "{}", s.p99_ms);
        assert!(s.p50_ms <= 4.0, "{}", s.p50_ms);
    }

    #[test]
    fn small_sample_tails_interpolate_instead_of_snapping() {
        // 10 samples, 1..=10 ms: nearest-rank p99 snapped to the maximum
        // (10 ms); the interpolated estimator lands between the top two
        // order statistics: h = 9·0.99 = 8.91 → 9 + 0.91·(10-9) = 9.91 ms.
        let mut m = Metrics::default();
        let lats: Vec<Duration> = (1..=10).map(Duration::from_millis).collect();
        m.record_batch(&lats, Duration::ZERO);
        let s = m.snapshot();
        assert!((s.p99_ms - 9.91).abs() < 1e-9, "{}", s.p99_ms);
        assert!(s.p99_ms < 10.0, "p99 of 10 samples must not equal the max");
        // p95: h = 9·0.95 = 8.55 → 9 + 0.55·1 = 9.55 ms
        assert!((s.p95_ms - 9.55).abs() < 1e-9, "{}", s.p95_ms);
        // p50: h = 4.5 → 5 + 0.5·1 = 5.5 ms (even-count median, the
        // classic interpolation case)
        assert!((s.p50_ms - 5.5).abs() < 1e-9, "{}", s.p50_ms);
    }

    #[test]
    fn per_worker_accounting_and_queue_depth() {
        let mut m = Metrics::default();
        m.record_batch_on(0, &[Duration::from_millis(1); 4], Duration::ZERO, Duration::from_millis(2));
        m.record_batch_on(2, &[Duration::from_millis(1); 2], Duration::ZERO, Duration::from_millis(3));
        m.record_batch_on(0, &[Duration::from_millis(1); 1], Duration::ZERO, Duration::from_millis(1));
        m.record_queue_depth(4);
        m.record_queue_depth(0);
        m.record_queue_depth(8);
        let s = m.snapshot();
        assert_eq!(s.requests, 7);
        assert_eq!(s.batches, 3);
        assert_eq!(s.per_worker.len(), 3, "ids index the vec; worker 1 served nothing");
        assert_eq!(s.per_worker[0].batches, 2);
        assert_eq!(s.per_worker[0].requests, 5);
        assert!((s.per_worker[0].busy_s - 3e-3).abs() < 1e-12);
        assert_eq!(s.per_worker[1].batches, 0);
        assert_eq!(s.per_worker[2].batches, 1);
        assert_eq!(s.per_worker[2].requests, 2);
        assert!((s.queue_depth_mean - 4.0).abs() < 1e-12);
        assert_eq!(s.queue_depth_max, 8);
        // aggregate view stays consistent with the per-worker split
        let total: u64 = s.per_worker.iter().map(|w| w.requests).sum();
        assert_eq!(total, s.requests);
    }

    #[test]
    fn hub_folds_events_at_snapshot_time() {
        let hub = MetricsHub::new();
        let sink = hub.sink();
        sink.record(0, &[Duration::from_millis(2); 4], Duration::from_millis(1), Duration::ZERO);
        sink.record(2, &[Duration::from_millis(4); 2], Duration::ZERO, Duration::from_millis(3));
        hub.record_queue_depth(3);
        hub.record_queue_depth(9);
        let s = hub.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.per_worker.len(), 3);
        assert_eq!(s.per_worker[0].requests, 4);
        assert_eq!(s.per_worker[2].requests, 2);
        assert!((s.per_worker[2].busy_s - 3e-3).abs() < 1e-12);
        assert!((s.queue_depth_mean - 6.0).abs() < 1e-12);
        assert_eq!(s.queue_depth_max, 9);
        assert!((s.sim_accel_s - 1e-3).abs() < 1e-12);
        // snapshots are cumulative, not consuming
        let again = hub.snapshot();
        assert_eq!(again.requests, 6);
        assert_eq!(hub.fold_locks(), 2, "each snapshot takes the fold lock once");
        assert_eq!(hub.serving_path_locks(), 0, "recording never locked");
    }

    #[test]
    fn hub_writers_are_lock_free_under_concurrent_snapshots() {
        let hub = MetricsHub::new();
        std::thread::scope(|s| {
            for w in 0..4usize {
                let sink = hub.sink();
                let hub = &hub;
                s.spawn(move || {
                    for i in 0..100u64 {
                        sink.record(
                            w,
                            &[Duration::from_micros(50 + i)],
                            Duration::ZERO,
                            Duration::ZERO,
                        );
                        hub.record_queue_depth((i % 7) as usize);
                    }
                });
            }
            // a reader hammering snapshots while writers stream events
            let hub = &hub;
            s.spawn(move || {
                for _ in 0..50 {
                    let snap = hub.snapshot();
                    assert!(snap.requests <= 400);
                    std::thread::yield_now();
                }
            });
        });
        let s = hub.snapshot();
        assert_eq!(s.requests, 400, "no event lost under contention");
        assert_eq!(s.per_worker.len(), 4);
        assert_eq!(hub.serving_path_locks(), 0, "the writer path never took a lock");
        assert!(hub.fold_locks() >= 51);
    }
}
