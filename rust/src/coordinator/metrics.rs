//! Serving metrics: latency distribution, throughput, batch sizes.

use std::time::Duration;

/// Online metrics accumulator (plain struct; the server wraps it in a lock).
#[derive(Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    batches: u64,
    batch_items: u64,
    sim_accel_s: f64,
    started_at: Option<std::time::Instant>,
}

/// A point-in-time summary.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub throughput_rps: f64,
    /// Total *simulated accelerator* time spent, seconds.
    pub sim_accel_s: f64,
}

impl Metrics {
    /// Record one dispatched batch. An empty latency slice is a no-op: a
    /// batch that served nothing must not skew `mean_batch` toward zero or
    /// start the throughput clock.
    pub fn record_batch(&mut self, latencies: &[Duration], sim_accel: Duration) {
        if latencies.is_empty() {
            return;
        }
        if self.started_at.is_none() {
            self.started_at = Some(std::time::Instant::now());
        }
        self.batches += 1;
        self.batch_items += latencies.len() as u64;
        self.sim_accel_s += sim_accel.as_secs_f64();
        self.latencies_us.extend(latencies.iter().map(|d| d.as_micros() as u64));
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut sorted = self.latencies_us.clone();
        sorted.sort_unstable();
        let pct = |p: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
            sorted[idx] as f64 / 1e3
        };
        let mean = if sorted.is_empty() {
            0.0
        } else {
            sorted.iter().sum::<u64>() as f64 / sorted.len() as f64 / 1e3
        };
        let elapsed = self.started_at.map_or(0.0, |t| t.elapsed().as_secs_f64());
        MetricsSnapshot {
            requests: self.batch_items,
            batches: self.batches,
            mean_batch: if self.batches == 0 {
                0.0
            } else {
                self.batch_items as f64 / self.batches as f64
            },
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            mean_ms: mean,
            throughput_rps: if elapsed > 0.0 { self.batch_items as f64 / elapsed } else { 0.0 },
            sim_accel_s: self.sim_accel_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_zero() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99_ms, 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        let lats: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        m.record_batch(&lats, Duration::from_millis(5));
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
        assert!((s.p50_ms - 50.0).abs() < 2.0, "{}", s.p50_ms);
        assert!((s.p99_ms - 100.0).abs() < 2.0, "{}", s.p99_ms);
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::default();
        m.record_batch(&[Duration::from_millis(1); 4], Duration::ZERO);
        m.record_batch(&[Duration::from_millis(1); 2], Duration::ZERO);
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
    }

    #[test]
    fn single_sample_percentiles_collapse_to_the_sample() {
        let mut m = Metrics::default();
        m.record_batch(&[Duration::from_millis(7)], Duration::from_millis(1));
        let s = m.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.batches, 1);
        // every percentile of a 1-sample distribution IS the sample
        assert_eq!(s.p50_ms, 7.0);
        assert_eq!(s.p95_ms, 7.0);
        assert_eq!(s.p99_ms, 7.0);
        assert_eq!(s.mean_ms, 7.0);
        assert!((s.mean_batch - 1.0).abs() < 1e-12);
        assert!((s.sim_accel_s - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut m = Metrics::default();
        m.record_batch(&[], Duration::from_millis(9));
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.batches, 0, "an empty batch must not count as a batch");
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.sim_accel_s, 0.0, "no work was dispatched");
        assert_eq!(s.throughput_rps, 0.0, "the clock must not start on nothing");
        // a real batch after the no-op accounts normally
        m.record_batch(&[Duration::from_millis(2); 3], Duration::ZERO);
        let s = m.snapshot();
        assert_eq!((s.requests, s.batches), (3, 1));
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
    }

    #[test]
    fn snapshots_are_monotone_under_interleaved_batches() {
        let mut m = Metrics::default();
        let mut last_requests = 0;
        let mut last_p99 = 0.0_f64;
        // interleave slow, fast and empty batches: cumulative counters only
        // grow, percentiles stay ordered, and the max-latency tail (p99 on
        // a growing set that keeps its maximum) never shrinks
        let batches: Vec<Vec<Duration>> = vec![
            vec![Duration::from_millis(50); 2],
            vec![],
            vec![Duration::from_millis(1); 8],
            vec![Duration::from_millis(50), Duration::from_millis(2)],
            vec![],
            vec![Duration::from_millis(3); 5],
        ];
        for b in &batches {
            m.record_batch(b, Duration::ZERO);
            let s = m.snapshot();
            assert!(s.requests >= last_requests, "requests are cumulative");
            assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms, "percentiles ordered");
            if s.requests == last_requests {
                assert_eq!(s.p99_ms, last_p99, "an empty batch must not move the tail");
            }
            last_requests = s.requests;
            last_p99 = s.p99_ms;
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 17);
        assert_eq!(s.batches, 4, "two interleaved empties dropped");
        // the 50 ms stragglers keep the tail up after fast batches landed
        assert!(s.p99_ms >= 49.0, "{}", s.p99_ms);
        assert!(s.p50_ms <= 4.0, "{}", s.p50_ms);
    }
}
