//! Serving coordinator — the Layer-3 request path.
//!
//! The accelerator's static schedule (from the DSE) fixes the batch timing;
//! the coordinator's job is the classic serving loop around it: queue
//! incoming requests, form batches, dispatch each batch to the engine
//! (PJRT numerics + simulated accelerator clock), and report metrics.
//!
//! Everything here is synchronous-core: the batching policy and metrics are
//! plain testable structs; [`Server`] wires them to threads and lock-free
//! channels. Execution scales out via an engine pool
//! ([`ServerOptions::workers`]) behind a sharded batching front
//! ([`ServerOptions::dispatch_shards`]): each shard owns its own
//! [`PriorityBatcher`] and hands formed batches to workers through
//! per-worker lock-free mailboxes (`sync::AtomicBox`), replies ride pooled
//! oneshot slots ([`ReplyHandle`]), and metrics fold lazily in the hub so
//! the steady-state serving path never takes a lock. Each worker's engine
//! is constructed on its own thread (the PJRT thread-affinity contract).
//! See `server.rs` for the topology diagram.
//!
//! Fleet deployments front several such stacks — per-device [`Server`]s and
//! [`ModelRegistry`]s — behind one [`Router`]: per-model routing with
//! least-outstanding-requests replica selection and per-model/per-device
//! metrics rollup (see `router.rs` for that topology).

mod batcher;
mod chain;
mod loadgen;
mod metrics;
mod oneshot;
mod priority;
mod registry;
mod router;
mod server;
mod sync;

pub use batcher::{BatchPolicy, Batcher};
pub use chain::ChainedEngine;
pub use loadgen::{
    run_open_loop, run_open_loop_mixed, ArrivalSchedule, Completion, LoadResult, MixedSchedule,
    MixedSpec,
};
pub use metrics::{Metrics, MetricsHandle, MetricsSnapshot, WorkerStats};
pub use oneshot::ReplyHandle;
pub use priority::{Priority, PriorityBatcher};
pub use registry::{ModelEntry, ModelRegistry};
pub use router::{EndpointMetrics, Router, RouterReply};
pub use server::{
    Engine, PacedEngine, PjrtEngine, Request, Response, Server, ServerOptions, SimOnlyEngine,
};
