//! Serving coordinator — the Layer-3 request path.
//!
//! The accelerator's static schedule (from the DSE) fixes the batch timing;
//! the coordinator's job is the classic serving loop around it: queue
//! incoming requests, form batches, dispatch each batch to the engine
//! (PJRT numerics + simulated accelerator clock), and report metrics.
//!
//! Everything here is synchronous-core: the batching policy and metrics are
//! plain testable structs; [`Server`] wires them to threads and channels.
//! Execution scales out via an engine pool ([`ServerOptions::workers`]):
//! one shared [`PriorityBatcher`] front dispatches formed batches to K
//! workers, each owning an engine constructed on its own thread (the PJRT
//! thread-affinity contract). See `server.rs` for the topology diagram.

mod batcher;
mod chain;
mod loadgen;
mod metrics;
mod priority;
mod registry;
mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use chain::ChainedEngine;
pub use loadgen::{run_open_loop, ArrivalSchedule, LoadResult};
pub use metrics::{Metrics, MetricsSnapshot, WorkerStats};
pub use priority::{Priority, PriorityBatcher};
pub use registry::{ModelEntry, ModelRegistry};
pub use server::{
    Engine, PacedEngine, PjrtEngine, Request, Response, Server, ServerOptions, SimOnlyEngine,
};
