//! Chained engine: serve a sharded deployment behind one
//! [`Server`](super::Server).
//!
//! A partitioned design is still one model — requests enter partition 0 and
//! predictions leave the last partition — so the coordinator keeps its
//! single queue, batcher and metrics and only the engine changes: accel
//! timing comes from the partitioned simulator
//! ([`crate::sim::simulate_partitioned`]), which accounts for every
//! partition's DMA schedule and the inter-device links.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::Result;

use super::server::Engine;
use crate::device::Device;
use crate::dse::Design;
use crate::sim::{simulate_partitioned, SimConfig};

/// Timing-only engine for a chain of partitions (the sharded counterpart of
/// [`super::SimOnlyEngine`]): checksum numerics + the partitioned
/// simulator's accelerator clock. `Clone` so one template chain can seed
/// every worker of an engine pool.
#[derive(Clone)]
pub struct ChainedEngine {
    /// `(design, device)` per partition, in chain order.
    pub stages: Vec<(Design, Device)>,
    /// Flattened input length of the whole network (partition 0's input).
    pub input_len: usize,
    /// Output vector length per request.
    pub output_len: usize,
    accel_cache: HashMap<usize, Duration>,
}

impl ChainedEngine {
    pub fn new(stages: Vec<(Design, Device)>, input_len: usize, output_len: usize) -> Self {
        assert!(!stages.is_empty(), "a chain needs at least one partition");
        ChainedEngine { stages, input_len, output_len, accel_cache: HashMap::new() }
    }
}

impl Engine for ChainedEngine {
    fn infer(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(batch
            .iter()
            .map(|b| {
                let s: f32 = b.iter().sum();
                vec![s; self.output_len]
            })
            .collect())
    }

    fn input_len(&self) -> usize {
        self.input_len
    }

    fn accel_batch_time(&mut self, batch: usize) -> Duration {
        if let Some(d) = self.accel_cache.get(&batch) {
            return *d;
        }
        let refs: Vec<(&Design, &Device)> =
            self.stages.iter().map(|(d, dev)| (d, dev)).collect();
        let sim = simulate_partitioned(
            &refs,
            &SimConfig { batch: batch as u64, ..Default::default() },
        );
        let d = Duration::from_secs_f64(sim.makespan_s);
        self.accel_cache.insert(batch, d);
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchPolicy, Server};
    use crate::dse::{partition, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    #[test]
    fn chain_engine_serves_behind_one_server() {
        let net = models::toy_cnn(Quant::W8A8);
        let devs = [Device::zcu102(), Device::zcu102()];
        let p = partition::partition(&net, &devs, &DseConfig::default()).unwrap();
        let stages: Vec<(Design, Device)> = p
            .parts
            .iter()
            .map(|part| (part.result.design.clone(), part.device.clone()))
            .collect();
        let input_len = 3 * 32 * 32;
        let engine = ChainedEngine::new(stages, input_len, 10);
        let server = Server::start(engine, BatchPolicy::default());
        let resp = server.infer(vec![0.5; input_len]).unwrap();
        assert_eq!(resp.output.len(), 10);
        assert!(resp.accel > Duration::ZERO);
        assert_eq!(server.metrics().requests, 1, "batching/metrics unchanged");
        server.shutdown();
    }
}
