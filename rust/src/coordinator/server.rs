//! The serving loop: a sharded batching front dispatching to a pool of
//! engine workers over lock-free mailboxes.
//!
//! (This build is fully offline/self-contained, so the front-end is plain
//! threads + atomics rather than an async executor; the coordinator logic —
//! batching, dispatch, metrics — is identical.)
//!
//! Topology — `ServerOptions::workers` picks between two shapes:
//!
//! ```text
//! workers = 1 (default)              workers = K > 1, S dispatch shards
//!
//! submit → [queue] → worker          submit ─ round-robin ┬→ shard 0 (batcher) ─┐
//!           (batcher + engine                             ├→ shard 1 (batcher) ─┤
//!            on one thread)                               └→ …      (S shards)  │
//!                                        per-worker single-slot mailboxes  ◄────┘
//!                                        (lock-free AtomicBox hand-off,
//!                                         idle workers steal from siblings)
//!                                            ├→ worker 0 (its own engine)
//!                                            ├→ worker 1 (its own engine)
//!                                            └→ worker K-1 …
//! ```
//!
//! No single lock or thread serializes the pool: each shard owns its own
//! [`PriorityBatcher`] and request queue (submits are spread round-robin),
//! formed batches are handed to workers through per-worker
//! [`AtomicBox`](super::sync::AtomicBox) mailboxes (one CAS, no shared
//! `Mutex<Receiver>`), metrics flow as events into the lock-free
//! [`MetricsHub`](super::metrics::MetricsHub), and replies ride pooled
//! oneshot slots ([`super::oneshot`]) instead of per-request channels. A
//! shard prefers its own workers but overflows into any free mailbox, and
//! an idle worker steals from sibling mailboxes — skew cannot strand a
//! formed batch behind a busy worker.
//!
//! Each worker constructs its engine **on its own thread** via the shared
//! factory — the PJRT thread-affinity contract (`Rc` internals) is
//! per-worker, exactly as it was per-server. The single-worker shape is the
//! pre-pool server verbatim: batcher and engine on one thread, no hand-off
//! queue, so `workers: 1` (and `dispatch_shards: 1`) behaves bit-identically
//! to the old code path.
//!
//! Failure classes are typed ([`crate::Error`]): admission control rejects
//! with [`Error::Overloaded`], a request stranded undispatched by an
//! abortive shutdown gets [`Error::ShuttingDown`], and engine failures
//! surface as [`Error::Serve`].

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread::Thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::metrics::{BatchSink, MetricsHandle, MetricsHub};
use super::oneshot::{ReplyHandle, ReplySender, SlotPool};
use super::sync::AtomicBox;
use super::{BatchPolicy, MetricsSnapshot, Priority, PriorityBatcher};
use crate::device::Device;
use crate::dse::Design;
use crate::error::Error;
use crate::runtime::{LoadedModel, Tensor};
use crate::sim::{simulate, SimConfig};
use crate::telemetry::{
    counters_snapshot, SpanKind, SpanScribe, TelemetryHub, TelemetrySnapshot,
    DEFAULT_SPAN_CAPACITY,
};

/// An inference request entering the coordinator.
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub priority: Priority,
    pub submitted: Instant,
    reply: ReplySender,
}

/// Server-level options beyond the batching policy.
#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// Admission control: maximum in-flight (queued + executing) requests.
    /// `0` disables the cap. Overloaded submits fail fast with the typed
    /// [`Error::Overloaded`] instead of growing the queue without bound.
    pub queue_cap: usize,
    /// Engine-pool size: how many workers (each with its own engine,
    /// constructed on its own thread) consume batches from the batching
    /// front. `1` (the default) is the pre-pool single-worker server,
    /// bit-identical in behavior; `0` is normalized to `1`.
    pub workers: usize,
    /// Batcher shards on the dispatch front. `0` (the default) auto-sizes
    /// from the pool — `⌈workers/2⌉`, capped at 8 — so one batcher thread
    /// never has to feed more than ~2 engines; any other value pins the
    /// shard count (clamped to `workers`). With `workers = 1` the front is
    /// always the single pre-pool loop, whatever this says.
    pub dispatch_shards: usize,
    /// Record serving-path spans (wait/engine/reply per worker, batch per
    /// shard, steal markers) into per-lane lock-free rings readable via
    /// [`Server::telemetry`]. Recording is a handful of relaxed/release
    /// atomic stores per batch — it keeps [`Server::serving_path_locks`]
    /// at 0 — but can be switched off for overhead A/B runs.
    pub telemetry: bool,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions { queue_cap: 0, workers: 1, dispatch_shards: 0, telemetry: true }
    }
}

impl ServerOptions {
    /// The shard count [`Server::start_with_opts`] will actually run:
    /// `dispatch_shards` clamped to the pool, or the `⌈workers/2⌉` (≤ 8)
    /// auto-size when unset.
    pub fn effective_dispatch_shards(&self) -> usize {
        let workers = self.workers.max(1);
        if workers == 1 {
            return 1;
        }
        match self.dispatch_shards {
            0 => ((workers + 1) / 2).min(8),
            pinned => pinned.min(workers),
        }
    }
}

/// The reply to a request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    /// Wall-clock from submit to reply.
    pub total: Duration,
    /// Simulated accelerator time for the batch this request rode in.
    pub accel: Duration,
    /// Batch size this request was served with.
    pub batch: usize,
}

/// What the coordinator dispatches batches to.
///
/// NOT `Send`: PJRT handles are thread-affine (`Rc` internals), so each
/// engine lives entirely on its worker thread — construct it there via
/// [`Server::start_with`] / [`Server::start_with_opts`].
pub trait Engine: 'static {
    /// Run the numerics for a batch of flattened inputs; one output per input.
    fn infer(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
    /// Expected flattened input length.
    fn input_len(&self) -> usize;
    /// Simulated accelerator wall-clock for a batch of this size.
    fn accel_batch_time(&mut self, batch: usize) -> Duration;
}

/// Engine backed by a PJRT executable for numerics and the event simulator
/// for accelerator timing.
pub struct PjrtEngine {
    model: LoadedModel,
    design: Design,
    device: Device,
    /// (channels, height, width) of one sample.
    pub input_shape: (usize, usize, usize),
    /// Batch size the artifact was lowered with: smaller batches are padded.
    pub artifact_batch: usize,
    accel_cache: std::collections::HashMap<usize, Duration>,
}

impl PjrtEngine {
    pub fn new(
        model: LoadedModel,
        design: Design,
        device: Device,
        input_shape: (usize, usize, usize),
        artifact_batch: usize,
    ) -> PjrtEngine {
        PjrtEngine {
            model,
            design,
            device,
            input_shape,
            artifact_batch,
            accel_cache: std::collections::HashMap::new(),
        }
    }
}

impl Engine for PjrtEngine {
    fn infer(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if batch.len() > self.artifact_batch {
            bail!("batch {} exceeds artifact batch {}", batch.len(), self.artifact_batch);
        }
        let (c, h, w) = self.input_shape;
        let sample = c * h * w;
        // pad to the artifact's static batch shape
        let mut data = Vec::with_capacity(self.artifact_batch * sample);
        for b in batch {
            if b.len() != sample {
                bail!("input len {} != {}", b.len(), sample);
            }
            data.extend_from_slice(b);
        }
        data.resize(self.artifact_batch * sample, 0.0);
        let t = Tensor::new(data, vec![self.artifact_batch as i64, c as i64, h as i64, w as i64])?;
        let outs = self.model.run(&[t])?;
        let logits = &outs[0];
        let per = logits.data.len() / self.artifact_batch;
        Ok(batch
            .iter()
            .enumerate()
            .map(|(i, _)| logits.data[i * per..(i + 1) * per].to_vec())
            .collect())
    }

    fn input_len(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }

    fn accel_batch_time(&mut self, batch: usize) -> Duration {
        if let Some(d) = self.accel_cache.get(&batch) {
            return *d;
        }
        let sim = simulate(
            &self.design,
            &self.device,
            &SimConfig { batch: batch as u64, ..Default::default() },
        );
        let d = Duration::from_secs_f64(sim.makespan_s);
        self.accel_cache.insert(batch, d);
        d
    }
}

/// Timing-only engine (no PJRT): echoes a checksum vector. Used by tests and
/// benches where the numerics are irrelevant. `Clone` so one template engine
/// can seed every worker of a pool.
#[derive(Clone)]
pub struct SimOnlyEngine {
    pub design: Design,
    pub device: Device,
    pub input_len: usize,
    pub output_len: usize,
}

impl Engine for SimOnlyEngine {
    fn infer(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(batch
            .iter()
            .map(|b| {
                let s: f32 = b.iter().sum();
                vec![s; self.output_len]
            })
            .collect())
    }

    fn input_len(&self) -> usize {
        self.input_len
    }

    fn accel_batch_time(&mut self, batch: usize) -> Duration {
        let sim = simulate(
            &self.design,
            &self.device,
            &SimConfig { batch: batch as u64, ..Default::default() },
        );
        Duration::from_secs_f64(sim.makespan_s)
    }
}

/// Engine adapter that *occupies* its worker for the simulated accelerator
/// time: `infer` sleeps `accel_batch_time(batch) · pace` before running the
/// inner numerics. With no hardware in the loop, the inner engines complete
/// a batch in microseconds regardless of what the accelerator would take —
/// pacing restores the occupancy that makes pool scaling (and saturation
/// knees under [`super::run_open_loop`]) measurable. `pace = 1.0` is
/// real-time emulation of the simulated clock; `pace <= 0` disables the
/// sleep.
#[derive(Clone)]
pub struct PacedEngine<E: Engine> {
    pub inner: E,
    pub pace: f64,
}

impl<E: Engine> PacedEngine<E> {
    pub fn new(inner: E, pace: f64) -> PacedEngine<E> {
        PacedEngine { inner, pace }
    }
}

impl<E: Engine> Engine for PacedEngine<E> {
    fn infer(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if self.pace > 0.0 {
            let accel = self.inner.accel_batch_time(batch.len());
            std::thread::sleep(accel.mul_f64(self.pace));
        }
        self.inner.infer(batch)
    }

    fn input_len(&self) -> usize {
        self.inner.input_len()
    }

    fn accel_batch_time(&mut self, batch: usize) -> Duration {
        self.inner.accel_batch_time(batch)
    }
}

/// Handle to a running coordinator.
pub struct Server {
    /// One request queue per dispatch shard; submits route round-robin.
    txs: Option<Vec<mpsc::Sender<Request>>>,
    next_shard: AtomicUsize,
    hub: Arc<MetricsHub>,
    replies: Arc<SlotPool>,
    next_id: AtomicU64,
    /// Shards (pools only) + workers, joined on shutdown/drop.
    threads: Vec<std::thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
    queue_cap: usize,
    shards: usize,
    /// Abortive-shutdown flag: when set, the drain path fails
    /// queued-but-undispatched requests with [`Error::ShuttingDown`]
    /// instead of flushing them through the engines.
    abort: Arc<AtomicBool>,
    /// Span rings (one per lane), present when `ServerOptions::telemetry`
    /// was on at boot.
    telemetry: Option<Arc<TelemetryHub>>,
}

/// Adapt a single-shot factory to the pool-compatible `Fn` bound. The
/// wrapper errors on a second call, so it only composes with `workers: 1`
/// — which is exactly what [`Server::start`]/[`Server::start_with`]
/// guarantee by using default options. (The `Mutex` here guards engine
/// *boot*, never the serving path.)
fn once_factory<F>(factory: F) -> impl Fn() -> Result<Box<dyn Engine>> + Send + Sync + 'static
where
    F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
{
    let cell = Mutex::new(Some(factory));
    move || match cell.lock().unwrap().take() {
        Some(f) => f(),
        None => bail!("single-shot engine factory supports workers = 1 only"),
    }
}

impl Server {
    /// Spawn the single-worker serving loop with a `Send` engine.
    pub fn start<E: Engine + Send>(engine: E, policy: BatchPolicy) -> Server {
        Self::start_with(move || Ok(Box::new(engine) as Box<dyn Engine>), policy)
            .expect("infallible factory")
    }

    /// Single-worker [`Server::start_with_opts`] with default options,
    /// accepting a single-shot factory (the engine is constructed once, on
    /// the one worker thread).
    pub fn start_with<F>(factory: F, policy: BatchPolicy) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
    {
        Self::start_with_opts(once_factory(factory), policy, ServerOptions::default())
    }

    /// Spawn the serving stack: `opts.workers` engine workers behind a
    /// sharded batching front. The factory runs once **on each worker
    /// thread** (required for PJRT engines, whose handles are thread-
    /// affine). Blocks until every engine is ready; factory errors are
    /// returned here (first error wins, all threads are reaped).
    pub fn start_with_opts<F>(
        factory: F,
        policy: BatchPolicy,
        opts: ServerOptions,
    ) -> Result<Server>
    where
        F: Fn() -> Result<Box<dyn Engine>> + Send + Sync + 'static,
    {
        let workers = opts.workers.max(1);
        let shards = opts.effective_dispatch_shards();
        let (txs, mut rxs): (Vec<_>, Vec<_>) =
            (0..shards).map(|_| mpsc::channel::<Request>()).unzip();
        let hub = Arc::new(MetricsHub::new());
        let in_flight = Arc::new(AtomicUsize::new(0));
        let abort = Arc::new(AtomicBool::new(false));
        // Rings exist before any traffic: the hot path only ever clones an
        // Arc it was handed at spawn. The single-worker shape batches on
        // the worker thread, so it has no shard lanes.
        let telemetry = opts.telemetry.then(|| {
            Arc::new(TelemetryHub::new(
                workers,
                if workers == 1 { 0 } else { shards },
                DEFAULT_SPAN_CAPACITY,
            ))
        });

        let (threads, ready_rx) = if workers == 1 {
            let rx = rxs.pop().expect("one shard");
            let scribe = telemetry.as_ref().map(|t| t.worker_scribe(0));
            spawn_single(factory, policy, &hub, &in_flight, &abort, rx, scribe)
        } else {
            spawn_pool(
                Arc::new(factory),
                PoolConfig { workers, shards, policy },
                &hub,
                &in_flight,
                &abort,
                rxs,
                telemetry.as_deref(),
            )
        };

        // Wait for every engine to boot. On any failure: close the request
        // queues (shards exit, closing the worker hand-off), reap all
        // threads, and report the first error.
        let mut boot_err: Option<anyhow::Error> = None;
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => boot_err = boot_err.or(Some(e)),
                Err(_) => {
                    boot_err = boot_err.or(Some(anyhow!("engine factory panicked")));
                    break;
                }
            }
        }
        if let Some(e) = boot_err {
            drop(txs);
            for t in threads {
                let _ = t.join();
            }
            return Err(e);
        }

        Ok(Server {
            txs: Some(txs),
            next_shard: AtomicUsize::new(0),
            hub,
            replies: SlotPool::new(),
            next_id: AtomicU64::new(0),
            threads,
            in_flight,
            queue_cap: opts.queue_cap,
            shards,
            abort,
            telemetry,
        })
    }

    /// Submit one input and block until its response arrives.
    pub fn infer(&self, input: Vec<f32>) -> Result<Response, Error> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| Error::Serve("coordinator dropped request".to_string()))?
    }

    /// Submit one input at normal priority; returns the handle the response
    /// will arrive on (lets callers issue many requests concurrently).
    pub fn submit(&self, input: Vec<f32>) -> Result<ReplyHandle, Error> {
        self.submit_with(input, Priority::Normal)
    }

    /// Submit with an explicit service class. Fails fast with
    /// [`Error::Overloaded`] when admission control is enabled and the
    /// in-flight count is at the cap, and with [`Error::ShuttingDown`] once
    /// the server has stopped accepting work. The whole submit path is
    /// lock-free: admission is an atomic reservation, the reply slot comes
    /// from a recycling pool, and shard routing is one atomic counter.
    pub fn submit_with(&self, input: Vec<f32>, priority: Priority) -> Result<ReplyHandle, Error> {
        if self.queue_cap > 0 {
            // optimistic reservation; backed out on send failure
            let prev = self.in_flight.fetch_add(1, Ordering::AcqRel);
            if prev >= self.queue_cap {
                self.in_flight.fetch_sub(1, Ordering::AcqRel);
                return Err(Error::Overloaded { in_flight: prev, cap: self.queue_cap });
            }
        } else {
            self.in_flight.fetch_add(1, Ordering::AcqRel);
        }
        let (reply, rx) = self.replies.oneshot();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let sent = self.txs.as_ref().ok_or(Error::ShuttingDown).and_then(|txs| {
            let shard = self.next_shard.fetch_add(1, Ordering::Relaxed) % txs.len();
            txs[shard]
                .send(Request { id, input, priority, submitted: Instant::now(), reply })
                .map_err(|_| Error::ShuttingDown)
        });
        if let Err(e) = sent {
            self.in_flight.fetch_sub(1, Ordering::AcqRel);
            // rx (and the request's sender, inside the SendError) drop here,
            // recycling the slot
            return Err(e);
        }
        Ok(rx)
    }

    /// Requests currently queued or executing.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Fold pending metrics events and summarize. Reader-side work only —
    /// a snapshot under sustained load can never stall dispatch, because
    /// the serving path records through lock-free sinks and never touches
    /// the fold lock this takes.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.hub.snapshot()
    }

    /// Cloneable, thread-safe reader onto this server's metrics hub — for
    /// stats reporters and exporters that must snapshot from other threads
    /// without borrowing the server.
    pub fn metrics_handle(&self) -> MetricsHandle {
        MetricsHandle::new(self.hub.clone())
    }

    /// One coherent telemetry observation: folded request metrics, the
    /// process-wide counter registry, and every ring-resident serving span
    /// (empty when `ServerOptions::telemetry` was off). Reader-side work
    /// only — the span rings are read through their seqlocks, never
    /// blocking a writer.
    pub fn telemetry(&self) -> TelemetrySnapshot {
        TelemetrySnapshot {
            metrics: self.hub.snapshot(),
            counters: counters_snapshot(),
            spans: self.telemetry.as_ref().map(|t| t.spans()).unwrap_or_default(),
        }
    }

    /// Total spans recorded since boot (0 with telemetry off).
    pub fn spans_recorded(&self) -> u64 {
        self.telemetry.as_ref().map(|t| t.recorded()).unwrap_or(0)
    }

    /// Just the ring-resident spans (no metrics fold, no counter reads) —
    /// the building block `Router`/`ModelRegistry` rollups use to combine
    /// several servers into one snapshot without reading the process-wide
    /// counters once per server.
    pub fn telemetry_spans(&self) -> Vec<crate::telemetry::Span> {
        self.telemetry.as_ref().map(|t| t.spans()).unwrap_or_default()
    }

    /// Dispatch shards actually running (1 for the single-worker shape).
    pub fn dispatch_shards(&self) -> usize {
        self.shards
    }

    /// Lock acquisitions charged to the steady-state dispatch/batch-
    /// completion path since boot. The sharded front is lock-free by
    /// construction — mailbox hand-off, reply delivery and metrics
    /// recording are atomics and channel sends — so this MUST read 0; any
    /// future Mutex on those paths is contractually obliged to count
    /// itself here (and the serving tests pin the counter at zero).
    pub fn serving_path_locks(&self) -> u64 {
        self.hub.serving_path_locks()
    }

    /// Reply slots served from the recycling pool so far (observability
    /// for the zero-allocation steady state).
    pub fn reply_slots_recycled(&self) -> usize {
        self.replies.recycled()
    }

    /// Graceful shutdown: close the queues, flush every pending request
    /// through the engines (split into policy-sized batches), then join the
    /// workers.
    pub fn shutdown(mut self) {
        drop(self.txs.take());
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Abortive shutdown: close the queues and fail every queued-but-
    /// undispatched request with the typed [`Error::ShuttingDown`] instead
    /// of flushing it — callers waiting on a reply get a matchable error,
    /// never a dropped channel. Batches already handed to a worker still
    /// complete normally.
    pub fn shutdown_now(mut self) {
        self.abort.store(true, Ordering::Release);
        drop(self.txs.take());
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.txs.take());
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// The pre-pool single-worker shape: batcher and engine on ONE thread, no
/// hand-off queue — `workers: 1` stays behaviorally identical to the server
/// before the pool existed. (Queue depth is sampled exactly once per loop
/// pass that dispatches, through the hub's atomics.)
fn spawn_single<F>(
    factory: F,
    policy: BatchPolicy,
    hub: &Arc<MetricsHub>,
    in_flight: &Arc<AtomicUsize>,
    abort: &Arc<AtomicBool>,
    rx: mpsc::Receiver<Request>,
    scribe: Option<SpanScribe>,
) -> (Vec<std::thread::JoinHandle<()>>, mpsc::Receiver<Result<()>>)
where
    F: Fn() -> Result<Box<dyn Engine>> + Send + Sync + 'static,
{
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let hub = hub.clone();
    let in_flight = in_flight.clone();
    let abort = abort.clone();
    let handle = std::thread::spawn(move || {
        let mut engine = match factory() {
            Ok(e) => {
                let _ = ready_tx.send(Ok(()));
                drop(ready_tx);
                e
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return;
            }
        };
        let sink = hub.sink();
        let epoch = Instant::now();
        let now = |e: &Instant| e.elapsed().as_secs_f64();
        let mut batcher: PriorityBatcher<Request> = PriorityBatcher::new(policy);
        loop {
            let wait =
                batcher.time_to_deadline(now(&epoch)).unwrap_or(Duration::from_secs(3600));
            // one batch may form per pass (push-full or deadline flush) …
            let formed = match rx.recv_timeout(wait) {
                Ok(r) => {
                    let prio = r.priority;
                    batcher.push(r, prio, now(&epoch))
                }
                Err(mpsc::RecvTimeoutError::Timeout) => batcher.poll(now(&epoch)),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    while let Some(batch) = batcher.drain() {
                        if abort.load(Ordering::Acquire) {
                            fail_undispatched(batch, &in_flight);
                        } else {
                            // the drain can exceed max_batch; split so the
                            // flush never feeds an engine an oversized batch
                            for chunk in split_batches(batch, policy.max_batch) {
                                process(&mut engine, chunk, &sink, &in_flight, 0, scribe.as_ref());
                            }
                        }
                    }
                    break;
                }
            };
            // … and queue depth is sampled exactly once for it.
            if let Some(batch) = formed {
                hub.record_queue_depth(batcher.pending());
                process(&mut engine, batch, &sink, &in_flight, 0, scribe.as_ref());
            }
        }
    });
    (vec![handle], ready_rx)
}

/// Pool sizing handed to [`spawn_pool`].
struct PoolConfig {
    workers: usize,
    shards: usize,
    policy: BatchPolicy,
}

/// State shared between the batcher shards and the worker pool — all of it
/// atomics and lock-free cells; nothing here can block a thread.
struct PoolShared {
    /// One single-slot batch mailbox per worker.
    mailboxes: Vec<AtomicBox<Vec<Request>>>,
    /// Worker thread handles (for unpark); set once, after the workers
    /// spawn and before any shard runs.
    workers: OnceLock<Vec<Thread>>,
    /// Live shard threads. 0 ⇒ no further mailbox puts can ever happen.
    shards_live: AtomicUsize,
    /// Live worker threads. 0 ⇒ dispatch must fail batches typed.
    workers_live: AtomicUsize,
    /// Requests sitting in mailboxes, not yet picked up by a worker.
    queued: AtomicUsize,
    /// Requests received by a shard, still pending in its batcher.
    front_pending: AtomicUsize,
}

/// Panic-safe worker liveness: decrements on thread exit however it exits.
struct WorkerLiveGuard(Arc<PoolShared>);

impl Drop for WorkerLiveGuard {
    fn drop(&mut self) {
        self.0.workers_live.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Panic-safe shard liveness; the last shard out wakes every worker so
/// they observe the closed front and drain the mailboxes.
struct ShardLiveGuard(Arc<PoolShared>);

impl Drop for ShardLiveGuard {
    fn drop(&mut self) {
        if self.0.shards_live.fetch_sub(1, Ordering::AcqRel) == 1 {
            if let Some(threads) = self.0.workers.get() {
                for t in threads {
                    t.unpark();
                }
            }
        }
    }
}

/// The pool shape: `cfg.shards` batcher shards each own a slice of the
/// request stream and hand formed batches to `cfg.workers` workers through
/// lock-free per-worker mailboxes; each worker constructs its own engine on
/// its own thread and steals from sibling mailboxes when idle.
#[allow(clippy::too_many_arguments)]
fn spawn_pool<F>(
    factory: Arc<F>,
    cfg: PoolConfig,
    hub: &Arc<MetricsHub>,
    in_flight: &Arc<AtomicUsize>,
    abort: &Arc<AtomicBool>,
    rxs: Vec<mpsc::Receiver<Request>>,
    telemetry: Option<&TelemetryHub>,
) -> (Vec<std::thread::JoinHandle<()>>, mpsc::Receiver<Result<()>>)
where
    F: Fn() -> Result<Box<dyn Engine>> + Send + Sync + 'static,
{
    let PoolConfig { workers, shards, policy } = cfg;
    debug_assert_eq!(rxs.len(), shards);
    let shared = Arc::new(PoolShared {
        mailboxes: (0..workers).map(|_| AtomicBox::empty()).collect(),
        workers: OnceLock::new(),
        shards_live: AtomicUsize::new(shards),
        workers_live: AtomicUsize::new(workers),
        queued: AtomicUsize::new(0),
        front_pending: AtomicUsize::new(0),
    });
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let mut handles = Vec::with_capacity(workers + shards);

    for idx in 0..workers {
        let factory = factory.clone();
        let ready_tx = ready_tx.clone();
        let shared = shared.clone();
        let sink = hub.sink();
        let in_flight = in_flight.clone();
        let scribe = telemetry.map(|t| t.worker_scribe(idx));
        handles.push(std::thread::spawn(move || {
            // liveness first: a failed boot must still decrement
            let _live = WorkerLiveGuard(shared.clone());
            // PJRT thread-affinity contract: the engine is constructed on
            // the thread that will run it, one engine per worker.
            let mut engine = match factory() {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    drop(ready_tx);
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            worker_loop(idx, &mut engine, &shared, &sink, &in_flight, scribe.as_ref());
        }));
    }
    drop(ready_tx);
    let worker_threads: Vec<Thread> = handles.iter().map(|h| h.thread().clone()).collect();
    let _ = shared.workers.set(worker_threads);

    for (shard, rx) in rxs.into_iter().enumerate() {
        let shared = shared.clone();
        let hub = hub.clone();
        let in_flight = in_flight.clone();
        let abort = abort.clone();
        let scribe = telemetry.map(|t| t.shard_scribe(shard));
        handles.push(std::thread::spawn(move || {
            let _live = ShardLiveGuard(shared.clone());
            shard_loop(shard, shards, policy, rx, &shared, &hub, &in_flight, &abort, scribe);
        }));
    }
    (handles, ready_rx)
}

/// One batcher shard: the same recv/push/poll/drain loop as the single-
/// worker server, over this shard's slice of the request stream.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    shard: usize,
    shards: usize,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Request>,
    shared: &Arc<PoolShared>,
    hub: &MetricsHub,
    in_flight: &AtomicUsize,
    abort: &AtomicBool,
    scribe: Option<SpanScribe>,
) {
    let epoch = Instant::now();
    let now = |e: &Instant| e.elapsed().as_secs_f64();
    let mut batcher: PriorityBatcher<Request> = PriorityBatcher::new(policy);
    let mut router = ShardRouter::new(shard, shards, shared, hub, in_flight, scribe);
    loop {
        let wait = batcher.time_to_deadline(now(&epoch)).unwrap_or(Duration::from_secs(3600));
        let formed = match rx.recv_timeout(wait) {
            Ok(r) => {
                shared.front_pending.fetch_add(1, Ordering::AcqRel);
                let prio = r.priority;
                batcher.push(r, prio, now(&epoch))
            }
            Err(mpsc::RecvTimeoutError::Timeout) => batcher.poll(now(&epoch)),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                while let Some(batch) = batcher.drain() {
                    shared.front_pending.fetch_sub(batch.len(), Ordering::AcqRel);
                    if abort.load(Ordering::Acquire) {
                        fail_undispatched(batch, in_flight);
                    } else {
                        for chunk in split_batches(batch, policy.max_batch) {
                            router.dispatch(chunk);
                        }
                    }
                }
                break;
            }
        };
        if let Some(batch) = formed {
            shared.front_pending.fetch_sub(batch.len(), Ordering::AcqRel);
            router.dispatch(batch);
        }
    }
    // ShardLiveGuard drops on return: the last shard wakes every worker.
}

/// A shard's view of the mailboxes: its own workers (stride-assigned) in
/// rotation first, every other mailbox as overflow.
struct ShardRouter<'a> {
    own: Vec<usize>,
    foreign: Vec<usize>,
    rotate: usize,
    shared: &'a PoolShared,
    hub: &'a MetricsHub,
    in_flight: &'a AtomicUsize,
    /// This shard lane's span ring (telemetry on): records one `Batch`
    /// span per dispatch, covering the mailbox hand-off.
    scribe: Option<SpanScribe>,
}

impl<'a> ShardRouter<'a> {
    fn new(
        shard: usize,
        shards: usize,
        shared: &'a PoolShared,
        hub: &'a MetricsHub,
        in_flight: &'a AtomicUsize,
        scribe: Option<SpanScribe>,
    ) -> ShardRouter<'a> {
        let workers = shared.mailboxes.len();
        ShardRouter {
            own: (0..workers).filter(|w| w % shards == shard).collect(),
            foreign: (0..workers).filter(|w| w % shards != shard).collect(),
            rotate: 0,
            shared,
            hub,
            in_flight,
            scribe,
        }
    }

    /// Hand one formed batch to a worker mailbox — own workers in rotation
    /// first, then any foreign mailbox, retrying with a short backoff while
    /// the whole pool is saturated (the bounded mailboxes ARE the
    /// backpressure: further requests pile up in the batchers and, with
    /// `queue_cap`, into typed rejections at submit).
    fn dispatch(&mut self, batch: Vec<Request>) {
        let n = batch.len();
        // span start: the Batch span covers the hand-off, including any
        // backpressure wait for a free mailbox
        let t0 = Instant::now();
        // one queue-depth sample per dispatched batch: everything admitted
        // but not yet on an engine = pending in batchers + parked in
        // mailboxes (the just-formed batch intentionally excluded, exactly
        // like the pre-shard dispatcher)
        self.hub.record_queue_depth(
            self.shared.front_pending.load(Ordering::Acquire)
                + self.shared.queued.load(Ordering::Acquire),
        );
        self.shared.queued.fetch_add(n, Ordering::AcqRel);
        let threads = self.shared.workers.get().expect("set before shards spawn");
        let mut boxed = Box::new(batch);
        loop {
            for k in 0..self.own.len() {
                let w = self.own[(self.rotate + k) % self.own.len()];
                match self.shared.mailboxes[w].put(boxed) {
                    Ok(()) => {
                        self.rotate = (self.rotate + k + 1) % self.own.len();
                        threads[w].unpark();
                        if let Some(s) = &self.scribe {
                            s.record_between(SpanKind::Batch, n, t0, Instant::now());
                        }
                        return;
                    }
                    Err(back) => boxed = back,
                }
            }
            for &w in &self.foreign {
                match self.shared.mailboxes[w].put(boxed) {
                    Ok(()) => {
                        threads[w].unpark();
                        if let Some(s) = &self.scribe {
                            s.record_between(SpanKind::Batch, n, t0, Instant::now());
                        }
                        return;
                    }
                    Err(back) => boxed = back,
                }
            }
            if self.shared.workers_live.load(Ordering::Acquire) == 0 {
                // every worker died (boot-failure teardown): the requests
                // were never dispatched — fail them typed
                self.shared.queued.fetch_sub(n, Ordering::AcqRel);
                fail_undispatched(*boxed, self.in_flight);
                return;
            }
            std::thread::sleep(Duration::from_micros(50));
        }
    }
}

/// One pool worker: drain the own mailbox, steal from siblings when idle,
/// park briefly when there is nothing anywhere. Exits once the front has
/// shut down AND a final full sweep finds the mailboxes dry — a batch
/// published right before the last shard exited can never be stranded
/// (the shard's puts happen-before its `shards_live` decrement, which this
/// loop's `Acquire` load observes before the conclusive sweep).
fn worker_loop(
    idx: usize,
    engine: &mut Box<dyn Engine>,
    shared: &Arc<PoolShared>,
    sink: &BatchSink,
    in_flight: &AtomicUsize,
    scribe: Option<&SpanScribe>,
) {
    let n = shared.mailboxes.len();
    let mut front_done = false;
    loop {
        let mut served = false;
        // own mailbox first, then steal from siblings
        for off in 0..n {
            let w = (idx + off) % n;
            if let Some(batch) = shared.mailboxes[w].take() {
                shared.queued.fetch_sub(batch.len(), Ordering::AcqRel);
                if off != 0 {
                    // a steal: instantaneous marker on the stealing lane
                    if let Some(s) = scribe {
                        s.mark(SpanKind::Steal, batch.len());
                    }
                }
                process(engine, *batch, sink, in_flight, idx, scribe);
                served = true;
                break;
            }
        }
        if served {
            continue;
        }
        if front_done {
            break; // full sweep after the front closed found nothing
        }
        if shared.shards_live.load(Ordering::Acquire) == 0 {
            front_done = true; // one more conclusive sweep, then exit
            continue;
        }
        std::thread::park_timeout(Duration::from_millis(1));
    }
}

/// Split an oversized (shutdown-drain) batch into policy-sized chunks.
fn split_batches(batch: Vec<Request>, max_batch: usize) -> Vec<Vec<Request>> {
    let cap = max_batch.max(1);
    if batch.len() <= cap {
        return vec![batch];
    }
    let mut out = Vec::with_capacity(batch.len() / cap + usize::from(batch.len() % cap != 0));
    let mut it = batch.into_iter();
    loop {
        let chunk: Vec<Request> = it.by_ref().take(cap).collect();
        if chunk.is_empty() {
            break;
        }
        out.push(chunk);
    }
    out
}

/// Fail every request of an undispatched batch with the typed shutdown
/// error (the abortive-shutdown and dead-pool paths).
fn fail_undispatched(batch: Vec<Request>, in_flight: &AtomicUsize) {
    in_flight.fetch_sub(batch.len(), Ordering::AcqRel);
    for req in batch {
        req.reply.send(Err(Error::ShuttingDown));
    }
}

/// Run one batch through the engine and deliver the replies. Zero-copy
/// hand-off: each request's input vector is *moved* into the engine batch
/// (`mem::take`), and metrics go through the lock-free sink — nothing on
/// this path clones a payload or takes a lock.
fn process(
    engine: &mut Box<dyn Engine>,
    mut batch: Vec<Request>,
    sink: &BatchSink,
    in_flight: &AtomicUsize,
    worker: usize,
    scribe: Option<&SpanScribe>,
) {
    let inputs: Vec<Vec<f32>> =
        batch.iter_mut().map(|r| std::mem::take(&mut r.input)).collect();
    let t0 = Instant::now();
    let accel = engine.accel_batch_time(batch.len());
    let result = engine.infer(&inputs);
    let busy = t0.elapsed();
    let done = Instant::now();
    let latencies: Vec<Duration> = batch.iter().map(|r| done - r.submitted).collect();
    sink.record(worker, &latencies, accel, busy);
    in_flight.fetch_sub(batch.len(), Ordering::AcqRel);
    let n = batch.len();
    if let Some(s) = scribe {
        // Wait covers the oldest request's queue time (admission → engine
        // pickup); Engine covers the batch execution on this lane
        if let Some(earliest) = batch.iter().map(|r| r.submitted).min() {
            s.record_between(SpanKind::Wait, n, earliest, t0);
        }
        s.record_between(SpanKind::Engine, n, t0, done);
    }
    match result {
        Ok(outputs) => {
            for (req, (out, lat)) in
                batch.into_iter().zip(outputs.into_iter().zip(latencies.into_iter()))
            {
                req.reply.send(Ok(Response { id: req.id, output: out, total: lat, accel, batch: n }));
            }
        }
        Err(e) => {
            let msg = format!("{e:?}");
            for req in batch {
                req.reply.send(Err(Error::Serve(format!("batch failed: {msg}"))));
            }
        }
    }
    if let Some(s) = scribe {
        // Reply covers the fan-out back to the submitters
        s.record_between(SpanKind::Reply, n, done, Instant::now());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    fn sim_engine() -> SimOnlyEngine {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        SimOnlyEngine { design: r.design, device: dev, input_len: 3 * 32 * 32, output_len: 10 }
    }

    #[test]
    fn single_request_roundtrip() {
        let server = Server::start(sim_engine(), BatchPolicy::default());
        let resp = server.infer(vec![0.5; 3 * 32 * 32]).unwrap();
        assert_eq!(resp.output.len(), 10);
        assert!(resp.accel > Duration::ZERO);
        let m = server.metrics();
        assert_eq!(m.requests, 1);
        server.shutdown();
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let server = Server::start(
            sim_engine(),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(20) },
        );
        let receivers: Vec<_> =
            (0..8).map(|i| server.submit(vec![i as f32; 3 * 32 * 32]).unwrap()).collect();
        let mut max_batch = 0;
        for rx in receivers {
            let r = rx.recv().unwrap().unwrap();
            max_batch = max_batch.max(r.batch);
        }
        assert!(max_batch >= 2, "some batching must occur, saw max {max_batch}");
        let m = server.metrics();
        assert_eq!(m.requests, 8);
        assert!(m.batches <= 7);
        server.shutdown();
    }

    #[test]
    fn sim_engine_checksum_numerics() {
        let server = Server::start(sim_engine(), BatchPolicy::default());
        let input = vec![1.0f32; 3 * 32 * 32];
        let resp = server.infer(input).unwrap();
        assert!((resp.output[0] - 3072.0).abs() < 1e-3);
        server.shutdown();
    }

    #[test]
    fn admission_control_rejects_overload() {
        let e = sim_engine();
        let server = Server::start_with_opts(
            move || Ok(Box::new(e.clone()) as _),
            // huge wait so requests pile up in the queue
            BatchPolicy { max_batch: 64, max_wait: Duration::from_secs(5) },
            ServerOptions { queue_cap: 4, workers: 1, dispatch_shards: 0, telemetry: true },
        )
        .unwrap();
        let mut pending = Vec::new();
        let mut rejected = 0;
        for _ in 0..8 {
            match server.submit(vec![0.0; 3 * 32 * 32]) {
                Ok(rx) => pending.push(rx),
                Err(e) => {
                    assert!(
                        matches!(e, Error::Overloaded { cap: 4, .. }),
                        "typed admission error, got {e}"
                    );
                    assert!(e.to_string().contains("queue full"), "{e}");
                    rejected += 1;
                }
            }
        }
        assert_eq!(pending.len(), 4);
        assert_eq!(rejected, 4);
        assert_eq!(server.in_flight(), 4);
        server.shutdown(); // flush: all accepted requests complete
        for rx in pending {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn high_priority_rides_first_in_batch() {
        let server = Server::start(
            sim_engine(),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
        );
        // 3 normal + 1 high fill one batch of 4; all complete
        let n: Vec<_> =
            (0..3).map(|_| server.submit(vec![0.0; 3 * 32 * 32]).unwrap()).collect();
        let h = server.submit_with(vec![1.0; 3 * 32 * 32], Priority::High).unwrap();
        let hr = h.recv().unwrap().unwrap();
        assert_eq!(hr.batch, 4, "high request rode the shared batch");
        for rx in n {
            assert!(rx.recv().unwrap().is_ok());
        }
        server.shutdown();
    }

    #[test]
    fn in_flight_returns_to_zero() {
        let server = Server::start(sim_engine(), BatchPolicy::default());
        for _ in 0..5 {
            server.infer(vec![0.0; 3 * 32 * 32]).unwrap();
        }
        assert_eq!(server.in_flight(), 0);
        server.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let server = Server::start(
            sim_engine(),
            BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(10) },
        );
        let rx = server.submit(vec![0.0; 3 * 32 * 32]).unwrap();
        server.shutdown(); // must flush rather than drop the pending request
        assert!(rx.recv().unwrap().is_ok());
    }

    #[test]
    fn shutdown_now_fails_undispatched_typed() {
        let server = Server::start(
            sim_engine(),
            // huge wait: the requests sit in the batcher, undispatched
            BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(10) },
        );
        let rxs: Vec<_> =
            (0..4).map(|_| server.submit(vec![0.0; 3 * 32 * 32]).unwrap()).collect();
        // give the worker a beat to pull the submissions into the batcher
        std::thread::sleep(Duration::from_millis(20));
        server.shutdown_now();
        for rx in rxs {
            let res = rx.recv().expect("typed error, NOT a dropped channel");
            assert!(
                matches!(res, Err(Error::ShuttingDown)),
                "expected ShuttingDown, got {res:?}"
            );
        }
    }

    #[test]
    fn submit_after_shutdown_is_typed() {
        let server = Server::start(sim_engine(), BatchPolicy::default());
        // steal the sender the way shutdown does, then check the submit path
        let m = server.metrics();
        assert_eq!(m.requests, 0);
        server.shutdown();
        // (shutdown consumes the server; a fresh one proves the error path
        // via its dropped clone instead)
        let server = Server::start(sim_engine(), BatchPolicy::default());
        let ok = server.submit(vec![0.0; 3 * 32 * 32]);
        assert!(ok.is_ok());
        drop(ok);
        server.shutdown();
    }

    #[test]
    fn pool_serves_all_requests_across_workers() {
        let e = sim_engine();
        let server = Server::start_with_opts(
            move || Ok(Box::new(e.clone()) as _),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            ServerOptions { queue_cap: 0, workers: 4, dispatch_shards: 0, telemetry: true },
        )
        .unwrap();
        assert_eq!(server.dispatch_shards(), 2, "workers=4 auto-sizes to 2 shards");
        let receivers: Vec<_> =
            (0..64).map(|i| server.submit(vec![i as f32; 3 * 32 * 32]).unwrap()).collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            // checksum engine: output echoes the per-request input sum
            let want = (i as f32) * 3072.0;
            assert!((r.output[0] - want).abs() < 1e-1, "request {i}: {}", r.output[0]);
        }
        let m = server.metrics();
        assert_eq!(m.requests, 64, "no responses lost");
        let served: u64 = m.per_worker.iter().map(|w| w.requests).sum();
        assert_eq!(served, 64, "per-worker accounting covers every request");
        assert!(
            m.per_worker.iter().filter(|w| w.batches > 0).count() >= 1,
            "at least one worker served"
        );
        server.shutdown();
    }

    #[test]
    fn pool_boot_failure_is_reported_and_reaped() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = calls.clone();
        let err = Server::start_with_opts(
            move || {
                let n = c.fetch_add(1, Ordering::AcqRel);
                if n == 1 {
                    bail!("worker {n} artifact missing");
                }
                let net = models::toy_cnn(Quant::W8A8);
                let dev = Device::zcu102();
                let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
                Ok(Box::new(SimOnlyEngine {
                    design: r.design,
                    device: dev,
                    input_len: 3 * 32 * 32,
                    output_len: 10,
                }) as _)
            },
            BatchPolicy::default(),
            ServerOptions { queue_cap: 0, workers: 3, dispatch_shards: 0, telemetry: true },
        );
        assert!(err.is_err(), "one failed engine fails the whole boot");
        assert_eq!(calls.load(Ordering::Acquire), 3, "every worker tried its factory");
    }

    #[test]
    fn paced_engine_occupies_but_preserves_numerics() {
        let inner = sim_engine();
        let mut paced = PacedEngine::new(inner.clone(), 0.0);
        let mut raw = inner;
        let batch = vec![vec![1.0f32; 3 * 32 * 32]];
        assert_eq!(
            paced.infer(&batch).unwrap(),
            raw.infer(&batch).unwrap(),
            "pacing must not touch outputs"
        );
        assert_eq!(paced.input_len(), raw.input_len());
        assert_eq!(paced.accel_batch_time(4), raw.accel_batch_time(4));
    }

    #[test]
    fn shard_auto_sizing_follows_the_pool() {
        let eff = |workers, dispatch_shards| {
            ServerOptions { queue_cap: 0, workers, dispatch_shards, telemetry: true }
                .effective_dispatch_shards()
        };
        assert_eq!(eff(1, 0), 1);
        assert_eq!(eff(2, 0), 1);
        assert_eq!(eff(4, 0), 2);
        assert_eq!(eff(8, 0), 4);
        assert_eq!(eff(32, 0), 8, "auto-sizing caps at 8 shards");
        assert_eq!(eff(8, 3), 3, "explicit pin wins");
        assert_eq!(eff(4, 64), 4, "pins clamp to the pool size");
        assert_eq!(eff(1, 5), 1, "workers=1 is always the single-thread loop");
        assert_eq!(eff(0, 0), 1, "workers=0 normalizes to 1");
    }

    #[test]
    fn pinned_shards_serve_all_requests() {
        let e = sim_engine();
        let server = Server::start_with_opts(
            move || Ok(Box::new(e.clone()) as _),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            ServerOptions { queue_cap: 0, workers: 4, dispatch_shards: 4, telemetry: true },
        )
        .unwrap();
        assert_eq!(server.dispatch_shards(), 4);
        let receivers: Vec<_> =
            (0..48).map(|i| server.submit(vec![i as f32; 3 * 32 * 32]).unwrap()).collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            let want = (i as f32) * 3072.0;
            assert!((r.output[0] - want).abs() < 1e-1, "request {i}: {}", r.output[0]);
        }
        assert_eq!(server.metrics().requests, 48);
        server.shutdown();
    }

    #[test]
    fn steady_state_serving_takes_no_lock_and_recycles_reply_slots() {
        let e = sim_engine();
        let server = Server::start_with_opts(
            move || Ok(Box::new(e.clone()) as _),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
            ServerOptions { queue_cap: 0, workers: 4, dispatch_shards: 2, telemetry: true },
        )
        .unwrap();
        for round in 0..8 {
            let rxs: Vec<_> =
                (0..16).map(|_| server.submit(vec![0.5; 3 * 32 * 32]).unwrap()).collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
            // interleave metrics reads: snapshots must not charge the
            // serving path either
            let m = server.metrics();
            assert_eq!(m.requests, (round + 1) * 16);
        }
        assert_eq!(
            server.serving_path_locks(),
            0,
            "dispatch/batch-completion must never take a lock — telemetry is ON here"
        );
        assert!(
            server.reply_slots_recycled() > 64,
            "steady-state submits must reuse pooled reply slots, recycled {}",
            server.reply_slots_recycled()
        );
        assert!(server.spans_recorded() > 0, "telemetry defaults on and records spans");
        server.shutdown();
    }

    #[test]
    fn telemetry_snapshot_covers_the_request_lifecycle() {
        let e = sim_engine();
        let server = Server::start_with_opts(
            move || Ok(Box::new(e.clone()) as _),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            ServerOptions { queue_cap: 0, workers: 4, dispatch_shards: 2, telemetry: true },
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..32).map(|_| server.submit(vec![0.5; 3 * 32 * 32]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let t = server.telemetry();
        assert_eq!(t.metrics.requests, 32);
        use crate::telemetry::SpanKind;
        let count = |k: SpanKind| t.spans.iter().filter(|s| s.kind == k).count();
        assert!(count(SpanKind::Engine) > 0, "engine spans recorded");
        assert!(count(SpanKind::Wait) > 0, "wait spans recorded");
        assert!(count(SpanKind::Reply) > 0, "reply spans recorded");
        assert!(count(SpanKind::Batch) > 0, "shard lanes record batch spans");
        // engine spans carry the served requests
        let engine_items: u64 = t
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Engine)
            .map(|s| u64::from(s.items))
            .sum();
        assert_eq!(engine_items, 32, "engine spans account for every request");
        assert!(t.spans.iter().any(|s| s.is_shard_lane()), "shard lanes present");
        assert!(t.counters.iter().any(|(n, _)| n == "sim_runs"));
        assert_eq!(server.serving_path_locks(), 0);
        server.shutdown();
    }

    #[test]
    fn telemetry_off_records_nothing() {
        let e = sim_engine();
        let server = Server::start_with_opts(
            move || Ok(Box::new(e.clone()) as _),
            BatchPolicy::default(),
            ServerOptions { queue_cap: 0, workers: 2, dispatch_shards: 0, telemetry: false },
        )
        .unwrap();
        server.infer(vec![0.5; 3 * 32 * 32]).unwrap();
        assert_eq!(server.spans_recorded(), 0);
        let t = server.telemetry();
        assert!(t.spans.is_empty(), "no rings exist with telemetry off");
        assert_eq!(t.metrics.requests, 1, "metrics still flow");
        server.shutdown();
    }
}
