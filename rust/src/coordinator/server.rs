//! The serving loop: a dedicated worker thread around the batcher + engine.
//!
//! (This build is fully offline/self-contained, so the front-end is a plain
//! thread + channel rather than an async executor; the coordinator logic —
//! batching, dispatch, metrics — is identical.)

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::{BatchPolicy, Metrics, MetricsSnapshot, Priority, PriorityBatcher};
use crate::device::Device;
use crate::dse::Design;
use crate::runtime::{LoadedModel, Tensor};
use crate::sim::{simulate, SimConfig};

/// An inference request entering the coordinator.
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub priority: Priority,
    pub submitted: Instant,
    reply: mpsc::Sender<Result<Response>>,
}

/// Server-level options beyond the batching policy.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerOptions {
    /// Admission control: maximum in-flight (queued + executing) requests.
    /// `0` disables the cap. Overloaded submits fail fast with a "queue
    /// full" error instead of growing the queue without bound.
    pub queue_cap: usize,
}

/// The reply to a request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    /// Wall-clock from submit to reply.
    pub total: Duration,
    /// Simulated accelerator time for the batch this request rode in.
    pub accel: Duration,
    /// Batch size this request was served with.
    pub batch: usize,
}

/// What the coordinator dispatches batches to.
///
/// NOT `Send`: PJRT handles are thread-affine (`Rc` internals), so the
/// engine lives entirely on the worker thread — construct it there via
/// [`Server::start_with`].
pub trait Engine: 'static {
    /// Run the numerics for a batch of flattened inputs; one output per input.
    fn infer(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
    /// Expected flattened input length.
    fn input_len(&self) -> usize;
    /// Simulated accelerator wall-clock for a batch of this size.
    fn accel_batch_time(&mut self, batch: usize) -> Duration;
}

/// Engine backed by a PJRT executable for numerics and the event simulator
/// for accelerator timing.
pub struct PjrtEngine {
    model: LoadedModel,
    design: Design,
    device: Device,
    /// (channels, height, width) of one sample.
    pub input_shape: (usize, usize, usize),
    /// Batch size the artifact was lowered with: smaller batches are padded.
    pub artifact_batch: usize,
    accel_cache: std::collections::HashMap<usize, Duration>,
}

impl PjrtEngine {
    pub fn new(
        model: LoadedModel,
        design: Design,
        device: Device,
        input_shape: (usize, usize, usize),
        artifact_batch: usize,
    ) -> PjrtEngine {
        PjrtEngine {
            model,
            design,
            device,
            input_shape,
            artifact_batch,
            accel_cache: std::collections::HashMap::new(),
        }
    }
}

impl Engine for PjrtEngine {
    fn infer(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if batch.len() > self.artifact_batch {
            bail!("batch {} exceeds artifact batch {}", batch.len(), self.artifact_batch);
        }
        let (c, h, w) = self.input_shape;
        let sample = c * h * w;
        // pad to the artifact's static batch shape
        let mut data = Vec::with_capacity(self.artifact_batch * sample);
        for b in batch {
            if b.len() != sample {
                bail!("input len {} != {}", b.len(), sample);
            }
            data.extend_from_slice(b);
        }
        data.resize(self.artifact_batch * sample, 0.0);
        let t = Tensor::new(data, vec![self.artifact_batch as i64, c as i64, h as i64, w as i64])?;
        let outs = self.model.run(&[t])?;
        let logits = &outs[0];
        let per = logits.data.len() / self.artifact_batch;
        Ok(batch
            .iter()
            .enumerate()
            .map(|(i, _)| logits.data[i * per..(i + 1) * per].to_vec())
            .collect())
    }

    fn input_len(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }

    fn accel_batch_time(&mut self, batch: usize) -> Duration {
        if let Some(d) = self.accel_cache.get(&batch) {
            return *d;
        }
        let sim = simulate(
            &self.design,
            &self.device,
            &SimConfig { batch: batch as u64, ..Default::default() },
        );
        let d = Duration::from_secs_f64(sim.makespan_s);
        self.accel_cache.insert(batch, d);
        d
    }
}

/// Timing-only engine (no PJRT): echoes a checksum vector. Used by tests and
/// benches where the numerics are irrelevant.
pub struct SimOnlyEngine {
    pub design: Design,
    pub device: Device,
    pub input_len: usize,
    pub output_len: usize,
}

impl Engine for SimOnlyEngine {
    fn infer(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(batch
            .iter()
            .map(|b| {
                let s: f32 = b.iter().sum();
                vec![s; self.output_len]
            })
            .collect())
    }

    fn input_len(&self) -> usize {
        self.input_len
    }

    fn accel_batch_time(&mut self, batch: usize) -> Duration {
        let sim = simulate(
            &self.design,
            &self.device,
            &SimConfig { batch: batch as u64, ..Default::default() },
        );
        Duration::from_secs_f64(sim.makespan_s)
    }
}

/// Handle to a running coordinator.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    metrics: Arc<Mutex<Metrics>>,
    next_id: AtomicU64,
    worker: Option<std::thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
    queue_cap: usize,
}

impl Server {
    /// Spawn the serving loop with a `Send` engine.
    pub fn start<E: Engine + Send>(engine: E, policy: BatchPolicy) -> Server {
        Self::start_with(move || Ok(Box::new(engine) as Box<dyn Engine>), policy)
            .expect("infallible factory")
    }

    /// [`Server::start_with`] with default options.
    pub fn start_with<F>(factory: F, policy: BatchPolicy) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
    {
        Self::start_with_opts(factory, policy, ServerOptions::default())
    }

    /// Spawn the serving loop, constructing the engine *on* the worker
    /// thread (required for PJRT engines, whose handles are thread-affine).
    /// Blocks until the engine is ready; factory errors are returned here.
    pub fn start_with_opts<F>(
        factory: F,
        policy: BatchPolicy,
        opts: ServerOptions,
    ) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let metrics_worker = metrics.clone();
        let in_flight = Arc::new(AtomicUsize::new(0));
        let in_flight_worker = in_flight.clone();

        let worker = std::thread::spawn(move || {
            let mut engine = match factory() {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let epoch = Instant::now();
            let now = |e: &Instant| e.elapsed().as_secs_f64();
            let mut batcher: PriorityBatcher<Request> = PriorityBatcher::new(policy);
            loop {
                let wait =
                    batcher.time_to_deadline(now(&epoch)).unwrap_or(Duration::from_secs(3600));
                match rx.recv_timeout(wait) {
                    Ok(r) => {
                        let prio = r.priority;
                        if let Some(batch) = batcher.push(r, prio, now(&epoch)) {
                            process(&mut engine, batch, &metrics_worker, &in_flight_worker);
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if let Some(batch) = batcher.poll(now(&epoch)) {
                            process(&mut engine, batch, &metrics_worker, &in_flight_worker);
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        while let Some(batch) = batcher.drain() {
                            process(&mut engine, batch, &metrics_worker, &in_flight_worker);
                        }
                        break;
                    }
                }
            }
        });

        ready_rx.recv().map_err(|_| anyhow!("engine factory panicked"))??;
        Ok(Server {
            tx: Some(tx),
            metrics,
            next_id: AtomicU64::new(0),
            worker: Some(worker),
            in_flight,
            queue_cap: opts.queue_cap,
        })
    }

    /// Submit one input and block until its response arrives.
    pub fn infer(&self, input: Vec<f32>) -> Result<Response> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped request"))?
    }

    /// Submit one input at normal priority; returns the channel the response
    /// will arrive on (lets callers issue many requests concurrently).
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Response>>> {
        self.submit_with(input, Priority::Normal)
    }

    /// Submit with an explicit service class. Fails fast with a "queue full"
    /// error when admission control is enabled and the in-flight count is at
    /// the cap.
    pub fn submit_with(
        &self,
        input: Vec<f32>,
        priority: Priority,
    ) -> Result<mpsc::Receiver<Result<Response>>> {
        if self.queue_cap > 0 {
            // optimistic reservation; backed out on send failure
            let prev = self.in_flight.fetch_add(1, Ordering::AcqRel);
            if prev >= self.queue_cap {
                self.in_flight.fetch_sub(1, Ordering::AcqRel);
                bail!("queue full: {} in flight (cap {})", prev, self.queue_cap);
            }
        } else {
            self.in_flight.fetch_add(1, Ordering::AcqRel);
        }
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("coordinator stopped"))
            .and_then(|tx| {
                tx.send(Request { id, input, priority, submitted: Instant::now(), reply })
                    .map_err(|_| anyhow!("coordinator stopped"))
            })
            .inspect_err(|_| {
                self.in_flight.fetch_sub(1, Ordering::AcqRel);
            })?;
        Ok(rx)
    }

    /// Requests currently queued or executing.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.lock().unwrap().snapshot()
    }

    /// Graceful shutdown: close the queue (flushing pending requests), then
    /// join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn process(
    engine: &mut Box<dyn Engine>,
    batch: Vec<Request>,
    metrics: &Arc<Mutex<Metrics>>,
    in_flight: &Arc<AtomicUsize>,
) {
    let inputs: Vec<Vec<f32>> = batch.iter().map(|r| r.input.clone()).collect();
    let accel = engine.accel_batch_time(batch.len());
    let result = engine.infer(&inputs);
    let done = Instant::now();
    let latencies: Vec<Duration> = batch.iter().map(|r| done - r.submitted).collect();
    metrics.lock().unwrap().record_batch(&latencies, accel);
    in_flight.fetch_sub(batch.len(), Ordering::AcqRel);
    let n = batch.len();
    match result {
        Ok(outputs) => {
            for (req, (out, lat)) in
                batch.into_iter().zip(outputs.into_iter().zip(latencies.into_iter()))
            {
                let _ = req.reply.send(Ok(Response {
                    id: req.id,
                    output: out,
                    total: lat,
                    accel,
                    batch: n,
                }));
            }
        }
        Err(e) => {
            let msg = format!("{e:?}");
            for req in batch {
                let _ = req.reply.send(Err(anyhow!("batch failed: {msg}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;

    fn sim_engine() -> SimOnlyEngine {
        let net = models::toy_cnn(Quant::W8A8);
        let dev = Device::zcu102();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        SimOnlyEngine { design: r.design, device: dev, input_len: 3 * 32 * 32, output_len: 10 }
    }

    #[test]
    fn single_request_roundtrip() {
        let server = Server::start(sim_engine(), BatchPolicy::default());
        let resp = server.infer(vec![0.5; 3 * 32 * 32]).unwrap();
        assert_eq!(resp.output.len(), 10);
        assert!(resp.accel > Duration::ZERO);
        let m = server.metrics();
        assert_eq!(m.requests, 1);
        server.shutdown();
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let server = Server::start(
            sim_engine(),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(20) },
        );
        let receivers: Vec<_> =
            (0..8).map(|i| server.submit(vec![i as f32; 3 * 32 * 32]).unwrap()).collect();
        let mut max_batch = 0;
        for rx in receivers {
            let r = rx.recv().unwrap().unwrap();
            max_batch = max_batch.max(r.batch);
        }
        assert!(max_batch >= 2, "some batching must occur, saw max {max_batch}");
        let m = server.metrics();
        assert_eq!(m.requests, 8);
        assert!(m.batches <= 7);
        server.shutdown();
    }

    #[test]
    fn sim_engine_checksum_numerics() {
        let server = Server::start(sim_engine(), BatchPolicy::default());
        let input = vec![1.0f32; 3 * 32 * 32];
        let resp = server.infer(input).unwrap();
        assert!((resp.output[0] - 3072.0).abs() < 1e-3);
        server.shutdown();
    }

    #[test]
    fn admission_control_rejects_overload() {
        let server = Server::start_with_opts(
            {
                let e = sim_engine();
                move || Ok(Box::new(e) as _)
            },
            // huge wait so requests pile up in the queue
            BatchPolicy { max_batch: 64, max_wait: Duration::from_secs(5) },
            ServerOptions { queue_cap: 4 },
        )
        .unwrap();
        let mut pending = Vec::new();
        let mut rejected = 0;
        for _ in 0..8 {
            match server.submit(vec![0.0; 3 * 32 * 32]) {
                Ok(rx) => pending.push(rx),
                Err(e) => {
                    assert!(e.to_string().contains("queue full"), "{e}");
                    rejected += 1;
                }
            }
        }
        assert_eq!(pending.len(), 4);
        assert_eq!(rejected, 4);
        assert_eq!(server.in_flight(), 4);
        server.shutdown(); // flush: all accepted requests complete
        for rx in pending {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn high_priority_rides_first_in_batch() {
        let server = Server::start(
            sim_engine(),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
        );
        // 3 normal + 1 high fill one batch of 4; all complete
        let n: Vec<_> =
            (0..3).map(|_| server.submit(vec![0.0; 3 * 32 * 32]).unwrap()).collect();
        let h = server.submit_with(vec![1.0; 3 * 32 * 32], Priority::High).unwrap();
        let hr = h.recv().unwrap().unwrap();
        assert_eq!(hr.batch, 4, "high request rode the shared batch");
        for rx in n {
            assert!(rx.recv().unwrap().is_ok());
        }
        server.shutdown();
    }

    #[test]
    fn in_flight_returns_to_zero() {
        let server = Server::start(sim_engine(), BatchPolicy::default());
        for _ in 0..5 {
            server.infer(vec![0.0; 3 * 32 * 32]).unwrap();
        }
        assert_eq!(server.in_flight(), 0);
        server.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let server = Server::start(
            sim_engine(),
            BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(10) },
        );
        let rx = server.submit(vec![0.0; 3 * 32 * 32]).unwrap();
        server.shutdown(); // must flush rather than drop the pending request
        assert!(rx.recv().unwrap().is_ok());
    }
}
