//! Priority-aware dynamic batching.
//!
//! Two service classes: `High` (latency-sensitive, e.g. interactive
//! requests) and `Normal` (throughput traffic). Batches are formed
//! high-first, and the flush deadline follows the oldest *high* item when
//! one is pending — so a stream of bulk traffic can never starve the
//! interactive class, while a lone bulk request still flushes within its own
//! deadline.
//!
//! Since the sharded serving front landed, a pooled server runs one
//! `PriorityBatcher` **per dispatch shard** (see `server.rs`): the state
//! machine itself stays single-threaded — submits are spread round-robin
//! across shards, each shard batching its slice independently — so the
//! deadline math needs no synchronization, and the starvation bound holds
//! per shard (a high request always lands in *some* shard's batcher and
//! boosts that shard's flush).

use std::time::Duration;

use super::BatchPolicy;

/// Service class of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Normal,
    High,
}

/// Priority batching state machine (time injected, like [`super::Batcher`]).
#[derive(Debug)]
pub struct PriorityBatcher<T> {
    policy: BatchPolicy,
    /// Deadline multiplier for the high class (fraction of `max_wait`).
    high_wait_frac: f64,
    high: Vec<T>,
    normal: Vec<T>,
    deadline: Option<f64>,
}

impl<T> PriorityBatcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        PriorityBatcher { policy, high_wait_frac: 0.25, high: Vec::new(), normal: Vec::new(), deadline: None }
    }

    fn total_pending(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    fn form_batch(&mut self) -> Vec<T> {
        self.deadline = None;
        let mut batch = Vec::with_capacity(self.policy.max_batch);
        // high first, then backfill with normal traffic
        while batch.len() < self.policy.max_batch && !self.high.is_empty() {
            batch.push(self.high.remove(0));
        }
        while batch.len() < self.policy.max_batch && !self.normal.is_empty() {
            batch.push(self.normal.remove(0));
        }
        // items left over keep accumulating under a fresh deadline set by
        // the next push/poll cycle
        batch
    }

    /// Add a request at monotonic `now` (seconds). Returns a full batch —
    /// or the pending batch immediately under a zero-wait policy (the
    /// [`BatchPolicy`] edge-case contract: `max_wait == 0` never holds a
    /// request, `max_batch == 1` never arms a deadline).
    pub fn push(&mut self, item: T, prio: Priority, now: f64) -> Option<Vec<T>> {
        let had_pending = self.total_pending() > 0;
        match prio {
            Priority::High => self.high.push(item),
            Priority::Normal => self.normal.push(item),
        }
        if self.total_pending() >= self.policy.max_batch || self.policy.max_wait.is_zero() {
            return Some(self.form_batch());
        }
        let wait = match prio {
            Priority::High => self.policy.max_wait.as_secs_f64() * self.high_wait_frac,
            Priority::Normal => self.policy.max_wait.as_secs_f64(),
        };
        let item_deadline = now + wait;
        // the batch deadline is the *earliest* pending deadline
        self.deadline = Some(match self.deadline {
            Some(d) if had_pending => d.min(item_deadline),
            _ => item_deadline,
        });
        None
    }

    /// Flush if the earliest deadline has passed.
    pub fn poll(&mut self, now: f64) -> Option<Vec<T>> {
        match self.deadline {
            Some(d) if now >= d && self.total_pending() > 0 => Some(self.form_batch()),
            _ => None,
        }
    }

    /// Unconditional flush (shutdown path). May return more than one batch's
    /// worth; the caller splits if needed.
    pub fn drain(&mut self) -> Option<Vec<T>> {
        self.deadline = None;
        if self.total_pending() == 0 {
            return None;
        }
        let mut out = std::mem::take(&mut self.high);
        out.append(&mut self.normal);
        Some(out)
    }

    pub fn pending(&self) -> usize {
        self.total_pending()
    }

    pub fn time_to_deadline(&self, now: f64) -> Option<Duration> {
        self.deadline.map(|d| Duration::from_secs_f64((d - now).max(0.0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn high_items_lead_the_batch() {
        let mut b = PriorityBatcher::new(policy(3, 100));
        assert!(b.push("n1", Priority::Normal, 0.0).is_none());
        assert!(b.push("n2", Priority::Normal, 0.001).is_none());
        let batch = b.push("h1", Priority::High, 0.002).unwrap();
        assert_eq!(batch, vec!["h1", "n1", "n2"]);
    }

    #[test]
    fn high_deadline_is_tighter() {
        let mut b = PriorityBatcher::new(policy(8, 100)); // normal: 100ms, high: 25ms
        b.push(1, Priority::Normal, 0.0);
        // normal-only pending: no flush at 30ms
        assert!(b.poll(0.030).is_none());
        b.push(2, Priority::High, 0.030); // high deadline = 55ms
        assert!(b.poll(0.050).is_none());
        let batch = b.poll(0.056).expect("high deadline flushes early");
        assert_eq!(batch, vec![2, 1]);
    }

    #[test]
    fn normal_traffic_cannot_starve_high() {
        let mut b = PriorityBatcher::new(policy(2, 10));
        b.push("h", Priority::High, 0.0);
        // a flood of normal traffic fills batches; high goes out in the first
        let batch = b.push("n1", Priority::Normal, 0.001).unwrap();
        assert_eq!(batch[0], "h");
    }

    #[test]
    fn overflow_stays_pending() {
        let mut b = PriorityBatcher::new(policy(2, 10));
        b.push(1, Priority::Normal, 0.0);
        let full = b.push(2, Priority::Normal, 0.0).unwrap();
        assert_eq!(full.len(), 2);
        b.push(3, Priority::Normal, 0.001);
        assert_eq!(b.pending(), 1);
        assert_eq!(b.drain().unwrap(), vec![3]);
    }

    #[test]
    fn zero_wait_and_unit_batch_edge_cases() {
        let mut b = PriorityBatcher::new(policy(8, 0));
        assert_eq!(b.push(1, Priority::Normal, 0.0).unwrap(), vec![1]);
        assert!(b.time_to_deadline(0.0).is_none());

        let mut b = PriorityBatcher::new(policy(1, 100));
        assert_eq!(b.push("h", Priority::High, 0.0).unwrap(), vec!["h"]);
        assert!(b.time_to_deadline(0.0).is_none(), "unit batch never arms a deadline");
    }

    #[test]
    fn deadline_tracks_earliest() {
        let mut b = PriorityBatcher::new(policy(8, 100));
        b.push(1, Priority::Normal, 0.0); // deadline 0.1
        b.push(2, Priority::Normal, 0.05); // own deadline 0.15, batch keeps 0.1
        let d = b.time_to_deadline(0.06).unwrap();
        assert!((d.as_secs_f64() - 0.04).abs() < 1e-9, "{d:?}");
    }
}
