//! Fleet router — ONE submit surface over many per-device serving stacks.
//!
//! A fleet deployment boots one serving stack per placement: a [`Server`]
//! for a solo or sharded model, a [`ModelRegistry`] for a co-located group.
//! The router fronts all of them behind a single `submit(model, input)`
//! call:
//!
//! ```text
//!                         Router
//!              ┌────────────┼──────────────┐
//!         endpoint 0    endpoint 1     endpoint 2
//!         Server        Server         ModelRegistry
//!         (resnet50     (resnet50      (resnet18 + squeezenet
//!          shard A)      shard B)       co-located)
//! ```
//!
//! Routing is by model name. When the same model is registered on several
//! endpoints those are **replicas**, and each submit picks the replica with
//! the fewest outstanding requests (least-outstanding-requests — the
//! classic low-overhead approximation of join-shortest-queue; ties go to
//! the lowest endpoint index, so routing is deterministic under equal
//! load). Outstanding counts are per-endpoint atomics, incremented at
//! submit and retired exactly once when the reply is received *or* dropped
//! ([`RouterReply`]), so an abandoned reply can never wedge a replica into
//! appearing busy.
//!
//! Metrics roll up two ways: per endpoint ([`Router::endpoint_metrics`],
//! the per-device view) and per model ([`Router::model_metrics`], the
//! cross-replica view — counts and throughput sum, latency percentiles take
//! the conservative max, means weight by request count).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::RecvError;
use std::sync::Arc;

use crate::error::Error;
use crate::telemetry::{counters_snapshot, TelemetrySnapshot};

use super::{MetricsHandle, MetricsSnapshot, ModelRegistry, Priority, ReplyHandle, Response, Server};

/// One per-device serving stack behind the router.
enum Backend {
    /// A single-model server (solo or sharded placement). `input_len` is
    /// kept here so the router types payload-shape errors exactly like the
    /// registry does.
    Server { model: String, input_len: usize, server: Server },
    /// A multi-tenant registry (co-located placement); it validates routes
    /// and payloads itself.
    Registry(ModelRegistry),
}

struct Endpoint {
    label: String,
    backend: Backend,
    /// Requests submitted through this endpoint whose replies have not been
    /// retired yet — the least-outstanding-requests routing signal.
    outstanding: Arc<AtomicUsize>,
}

impl Endpoint {
    fn models(&self) -> Vec<String> {
        match &self.backend {
            Backend::Server { model, .. } => vec![model.clone()],
            Backend::Registry(r) => r.models().iter().map(|m| m.to_string()).collect(),
        }
    }
}

/// Per-endpoint metrics view: the device-side rollup of
/// [`Router::endpoint_metrics`].
#[derive(Debug, Clone)]
pub struct EndpointMetrics {
    /// The label the endpoint was registered under (a fleet uses the device
    /// names of the placement).
    pub label: String,
    /// Requests in flight through this endpoint right now.
    pub outstanding: usize,
    /// One serving snapshot per model this endpoint answers.
    pub per_model: Vec<(String, MetricsSnapshot)>,
    /// Request-weighted mean dispatch-queue depth across this endpoint's
    /// servers (the underlying `Server`s collect it; the rollup used to
    /// drop it).
    pub queue_depth_mean: f64,
    /// Deepest dispatch queue observed on any of this endpoint's servers.
    pub queue_depth_max: usize,
    /// Fewest batches any single pool worker served — with
    /// `worker_batches_max`, the endpoint's pool-skew signal.
    pub worker_batches_min: u64,
    /// Most batches any single pool worker served.
    pub worker_batches_max: u64,
}

/// Reply handle returned by [`Router::submit`]: wraps the backend's
/// [`ReplyHandle`] and retires the endpoint's outstanding count **exactly
/// once** — on the first successful `recv`, or at drop if the caller
/// abandons the reply.
pub struct RouterReply {
    inner: ReplyHandle,
    outstanding: Arc<AtomicUsize>,
    retired: AtomicBool,
}

impl RouterReply {
    /// Block for the reply (same contract as [`ReplyHandle::recv`]: a second
    /// call after consumption reports [`RecvError`]).
    pub fn recv(&self) -> Result<Result<Response, Error>, RecvError> {
        let out = self.inner.recv();
        if out.is_ok() {
            self.retire();
        }
        out
    }

    /// Decrement the endpoint's outstanding count exactly once (the atomic
    /// swap makes recv-then-drop safe).
    fn retire(&self) {
        if !self.retired.swap(true, Ordering::Relaxed) {
            self.outstanding.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

impl Drop for RouterReply {
    fn drop(&mut self) {
        self.retire();
    }
}

/// The fleet-level submit surface. See the module docs for the topology.
#[derive(Default)]
pub struct Router {
    endpoints: Vec<Endpoint>,
    /// model name → endpoint indices serving it (≥ 2 entries = replicas).
    routes: HashMap<String, Vec<usize>>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register a single-model [`Server`] endpoint (a solo or sharded
    /// placement). Registering the same model name again adds a replica —
    /// that is the point, not an error.
    pub fn add_server(
        &mut self,
        label: impl Into<String>,
        model: impl Into<String>,
        input_len: usize,
        server: Server,
    ) {
        let model = model.into();
        let idx = self.endpoints.len();
        self.endpoints.push(Endpoint {
            label: label.into(),
            backend: Backend::Server { model: model.clone(), input_len, server },
            outstanding: Arc::new(AtomicUsize::new(0)),
        });
        self.routes.entry(model).or_default().push(idx);
    }

    /// Register a [`ModelRegistry`] endpoint (a co-located placement):
    /// every model the registry serves becomes routable here.
    pub fn add_registry(&mut self, label: impl Into<String>, registry: ModelRegistry) {
        let idx = self.endpoints.len();
        let models: Vec<String> = registry.models().iter().map(|m| m.to_string()).collect();
        self.endpoints.push(Endpoint {
            label: label.into(),
            backend: Backend::Registry(registry),
            outstanding: Arc::new(AtomicUsize::new(0)),
        });
        for model in models {
            self.routes.entry(model).or_default().push(idx);
        }
    }

    /// Every routable model name, sorted.
    pub fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.routes.keys().cloned().collect();
        names.sort_unstable();
        names
    }

    /// How many endpoints serve `model` (0 = unrouted).
    pub fn replicas(&self, model: &str) -> usize {
        self.routes.get(model).map(|v| v.len()).unwrap_or(0)
    }

    /// Endpoint labels in registration order.
    pub fn endpoint_labels(&self) -> Vec<&str> {
        self.endpoints.iter().map(|e| e.label.as_str()).collect()
    }

    /// Submit one input for `model`, routed to the least-outstanding
    /// replica. Typed failures pass through: [`Error::UnknownModel`] for an
    /// unrouted name, [`Error::InputLength`] for a wrong payload shape, and
    /// the backend's own admission errors ([`Error::Overloaded`],
    /// [`Error::ShuttingDown`]).
    pub fn submit(&self, model: &str, input: Vec<f32>) -> Result<RouterReply, Error> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| Error::UnknownModel(model.to_string()))?;
        // Least outstanding requests; the index tie-break keeps routing
        // deterministic when replicas are equally loaded.
        let &idx = route
            .iter()
            .min_by_key(|&&i| (self.endpoints[i].outstanding.load(Ordering::Relaxed), i))
            .expect("a route is never registered empty");
        let endpoint = &self.endpoints[idx];
        let inner = match &endpoint.backend {
            Backend::Server { input_len, server, .. } => {
                if input.len() != *input_len {
                    return Err(Error::InputLength {
                        model: model.to_string(),
                        expected: *input_len,
                        got: input.len(),
                    });
                }
                server.submit(input)?
            }
            Backend::Registry(registry) => registry.submit(model, input, Priority::Normal)?,
        };
        endpoint.outstanding.fetch_add(1, Ordering::Relaxed);
        Ok(RouterReply {
            inner,
            outstanding: Arc::clone(&endpoint.outstanding),
            retired: AtomicBool::new(false),
        })
    }

    /// Submit one input and block until its response arrives.
    pub fn infer(&self, model: &str, input: Vec<f32>) -> Result<Response, Error> {
        let reply = self.submit(model, input)?;
        reply
            .recv()
            .map_err(|_| Error::Serve("router: reply channel dropped".to_string()))?
    }

    /// Per-endpoint metrics: one entry per registered serving stack, with a
    /// snapshot per model it answers.
    pub fn endpoint_metrics(&self) -> Vec<EndpointMetrics> {
        self.endpoints
            .iter()
            .map(|e| {
                let per_model = match &e.backend {
                    Backend::Server { model, server, .. } => {
                        vec![(model.clone(), server.metrics())]
                    }
                    Backend::Registry(r) => r
                        .models()
                        .iter()
                        .filter_map(|m| r.metrics(m).map(|s| (m.to_string(), s)))
                        .collect(),
                };
                // fold the endpoint's own servers so queue depth and
                // worker skew survive the rollup boundary
                let snaps: Vec<MetricsSnapshot> =
                    per_model.iter().map(|(_, s)| s.clone()).collect();
                let folded = fold_snapshots(&snaps);
                let (min_b, max_b) = worker_skew(&folded);
                EndpointMetrics {
                    label: e.label.clone(),
                    outstanding: e.outstanding.load(Ordering::Relaxed),
                    per_model,
                    queue_depth_mean: folded.queue_depth_mean,
                    queue_depth_max: folded.queue_depth_max,
                    worker_batches_min: min_b,
                    worker_batches_max: max_b,
                }
            })
            .collect()
    }

    /// Cloneable metrics reader handles for every single-model server
    /// endpoint, labeled `label/model`. (Registry endpoints expose their
    /// own via [`ModelRegistry::metrics_handles`].)
    pub fn metrics_handles(&self) -> Vec<(String, MetricsHandle)> {
        let mut out = Vec::new();
        for e in &self.endpoints {
            match &e.backend {
                Backend::Server { model, server, .. } => {
                    out.push((format!("{}/{}", e.label, model), server.metrics_handle()));
                }
                Backend::Registry(r) => {
                    for (model, h) in r.metrics_handles() {
                        out.push((format!("{}/{}", e.label, model), h));
                    }
                }
            }
        }
        out
    }

    /// One combined telemetry observation across the whole fleet: metrics
    /// folded conservatively over every endpoint's servers, spans
    /// concatenated in endpoint order, process-wide counters read once.
    /// Span timestamps stay relative to each server's own boot epoch.
    pub fn telemetry(&self) -> TelemetrySnapshot {
        let mut snaps = Vec::new();
        let mut spans = Vec::new();
        for e in &self.endpoints {
            match &e.backend {
                Backend::Server { server, .. } => {
                    snaps.push(server.metrics());
                    spans.extend(server.telemetry_spans());
                }
                Backend::Registry(r) => {
                    let t = r.telemetry();
                    snaps.push(t.metrics);
                    spans.extend(t.spans);
                }
            }
        }
        TelemetrySnapshot {
            metrics: fold_snapshots(&snaps),
            counters: counters_snapshot(),
            spans,
        }
    }

    /// Cross-replica rollup for one model: request/batch counts and
    /// throughput sum over replicas, latency percentiles take the
    /// conservative max, means weight by request count. `None` for an
    /// unrouted name.
    pub fn model_metrics(&self, model: &str) -> Option<MetricsSnapshot> {
        let route = self.routes.get(model)?;
        let snaps: Vec<MetricsSnapshot> = route
            .iter()
            .filter_map(|&i| match &self.endpoints[i].backend {
                Backend::Server { server, .. } => Some(server.metrics()),
                Backend::Registry(r) => r.metrics(model),
            })
            .collect();
        Some(fold_snapshots(&snaps))
    }

    /// Shut down every endpoint's serving loops, flushing pending requests.
    pub fn shutdown(self) {
        for e in self.endpoints {
            match e.backend {
                Backend::Server { server, .. } => server.shutdown(),
                Backend::Registry(registry) => registry.shutdown(),
            }
        }
    }
}

/// Pool-skew extremes across a snapshot's per-worker accounting.
fn worker_skew(snap: &MetricsSnapshot) -> (u64, u64) {
    let batches = snap.per_worker.iter().map(|w| w.batches);
    match (batches.clone().min(), batches.max()) {
        (Some(min), Some(max)) => (min, max),
        _ => (0, 0),
    }
}

/// Fold replica snapshots into one conservative model-level view (counts
/// and throughput sum, percentiles max, means weight by requests,
/// per-worker entries concatenate). Shared by the model/endpoint rollups
/// here and the registry's combined telemetry.
pub(crate) fn fold_snapshots(snaps: &[MetricsSnapshot]) -> MetricsSnapshot {
    let mut out = MetricsSnapshot {
        requests: 0,
        batches: 0,
        mean_batch: 0.0,
        p50_ms: 0.0,
        p95_ms: 0.0,
        p99_ms: 0.0,
        mean_ms: 0.0,
        throughput_rps: 0.0,
        sim_accel_s: 0.0,
        per_worker: Vec::new(),
        queue_depth_mean: 0.0,
        queue_depth_max: 0,
    };
    let mut weighted_mean = 0.0;
    let mut weighted_depth = 0.0;
    for s in snaps {
        out.requests += s.requests;
        out.batches += s.batches;
        out.p50_ms = out.p50_ms.max(s.p50_ms);
        out.p95_ms = out.p95_ms.max(s.p95_ms);
        out.p99_ms = out.p99_ms.max(s.p99_ms);
        out.throughput_rps += s.throughput_rps;
        out.sim_accel_s += s.sim_accel_s;
        out.per_worker.extend(s.per_worker.iter().cloned());
        out.queue_depth_max = out.queue_depth_max.max(s.queue_depth_max);
        weighted_mean += s.mean_ms * s.requests as f64;
        weighted_depth += s.queue_depth_mean * s.requests as f64;
    }
    if out.requests > 0 {
        out.mean_ms = weighted_mean / out.requests as f64;
        out.queue_depth_mean = weighted_depth / out.requests as f64;
    }
    if out.batches > 0 {
        out.mean_batch = out.requests as f64 / out.batches as f64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchPolicy, Engine, ModelEntry, ServerOptions};
    use anyhow::Result;
    use std::time::Duration;

    /// Checksum engine with a configurable hold time so requests stay
    /// outstanding long enough to observe the routing decision.
    #[derive(Clone)]
    struct EchoEngine {
        input_len: usize,
        hold: Duration,
    }

    impl Engine for EchoEngine {
        fn infer(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            if !self.hold.is_zero() {
                std::thread::sleep(self.hold);
            }
            Ok(batch.iter().map(|b| vec![b.iter().sum()]).collect())
        }

        fn input_len(&self) -> usize {
            self.input_len
        }

        fn accel_batch_time(&mut self, _batch: usize) -> Duration {
            Duration::ZERO
        }
    }

    fn server(input_len: usize, hold: Duration) -> Server {
        let engine = EchoEngine { input_len, hold };
        Server::start_with_opts(
            move || Ok(Box::new(engine.clone()) as _),
            BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
            ServerOptions::default(),
        )
        .expect("echo server boots")
    }

    #[test]
    fn routes_by_model_and_rejects_unknown() {
        let mut router = Router::new();
        router.add_server("dev0", "toy", 4, server(4, Duration::ZERO));
        assert_eq!(router.models(), vec!["toy".to_string()]);
        assert_eq!(router.replicas("toy"), 1);
        assert_eq!(router.replicas("resnet9000"), 0);

        let r = router.infer("toy", vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(r.output, vec![10.0]);

        let e = router.submit("resnet9000", vec![0.0; 4]).unwrap_err();
        assert!(matches!(e, Error::UnknownModel(ref m) if m == "resnet9000"), "{e}");
        let e = router.submit("toy", vec![0.0; 3]).unwrap_err();
        assert!(
            matches!(e, Error::InputLength { expected: 4, got: 3, .. }),
            "{e}"
        );
        router.shutdown();
    }

    #[test]
    fn least_outstanding_spreads_replicas_and_retires_on_recv() {
        let mut router = Router::new();
        // two replicas of the same model; a hold keeps requests in flight
        router.add_server("dev0", "toy", 2, server(2, Duration::from_millis(50)));
        router.add_server("dev1", "toy", 2, server(2, Duration::from_millis(50)));
        assert_eq!(router.replicas("toy"), 2);

        // first pick ties at (0, 0) -> endpoint 0; second sees it loaded
        let a = router.submit("toy", vec![1.0, 2.0]).unwrap();
        let b = router.submit("toy", vec![3.0, 4.0]).unwrap();
        let outstanding: Vec<usize> =
            router.endpoint_metrics().iter().map(|e| e.outstanding).collect();
        assert_eq!(outstanding, vec![1, 1], "LOR must spread equal load");

        assert_eq!(a.recv().unwrap().unwrap().output, vec![3.0]);
        assert_eq!(b.recv().unwrap().unwrap().output, vec![7.0]);
        let outstanding: Vec<usize> =
            router.endpoint_metrics().iter().map(|e| e.outstanding).collect();
        assert_eq!(outstanding, vec![0, 0], "recv retires the count");
        router.shutdown();
    }

    #[test]
    fn dropped_reply_still_retires_exactly_once() {
        let mut router = Router::new();
        router.add_server("dev0", "toy", 2, server(2, Duration::ZERO));
        {
            let reply = router.submit("toy", vec![1.0, 1.0]).unwrap();
            // received AND dropped: the count must come down exactly once
            let _ = reply.recv();
        }
        {
            let _abandoned = router.submit("toy", vec![1.0, 1.0]).unwrap();
            // dropped without recv
        }
        // allow the abandoned request to drain through the server
        std::thread::sleep(Duration::from_millis(20));
        let outstanding: Vec<usize> =
            router.endpoint_metrics().iter().map(|e| e.outstanding).collect();
        assert_eq!(outstanding, vec![0]);
        router.shutdown();
    }

    #[test]
    fn registry_endpoint_routes_all_its_models() {
        let mut registry = ModelRegistry::new();
        for name in ["alpha", "beta"] {
            let engine = EchoEngine { input_len: 3, hold: Duration::ZERO };
            registry
                .register(
                    ModelEntry {
                        name: name.to_string(),
                        input_len: 3,
                        policy: BatchPolicy { max_batch: 1, max_wait: Duration::ZERO },
                        options: ServerOptions::default(),
                    },
                    move || Ok(Box::new(engine.clone()) as _),
                )
                .unwrap();
        }
        let mut router = Router::new();
        router.add_registry("dev0", registry);
        assert_eq!(router.models(), vec!["alpha".to_string(), "beta".to_string()]);
        let r = router.infer("beta", vec![1.0, 2.0, 4.0]).unwrap();
        assert_eq!(r.output, vec![7.0]);
        // the registry types its own payload-shape failures
        let e = router.submit("alpha", vec![0.0; 2]).unwrap_err();
        assert!(matches!(e, Error::InputLength { expected: 3, got: 2, .. }), "{e}");
        router.shutdown();
    }

    #[test]
    fn model_metrics_roll_up_across_replicas() {
        let mut router = Router::new();
        router.add_server("dev0", "toy", 2, server(2, Duration::ZERO));
        router.add_server("dev1", "toy", 2, server(2, Duration::ZERO));
        for i in 0..6 {
            let _ = router.infer("toy", vec![i as f32, 1.0]).unwrap();
        }
        let rolled = router.model_metrics("toy").expect("routed model");
        assert_eq!(rolled.requests, 6, "replica counts must sum");
        assert!(rolled.throughput_rps > 0.0);
        // per-endpoint views account for every request exactly once
        let per_endpoint: u64 = router
            .endpoint_metrics()
            .iter()
            .flat_map(|e| e.per_model.iter().map(|(_, s)| s.requests))
            .sum();
        assert_eq!(per_endpoint, 6);
        assert!(router.model_metrics("resnet9000").is_none());
        router.shutdown();
    }

    #[test]
    fn endpoint_rollup_keeps_queue_depth_and_worker_skew() {
        let mut router = Router::new();
        router.add_server("dev0", "toy", 2, server(2, Duration::ZERO));
        for i in 0..8 {
            let _ = router.infer("toy", vec![i as f32, 1.0]).unwrap();
        }
        let eps = router.endpoint_metrics();
        assert_eq!(eps.len(), 1);
        let e = &eps[0];
        // the underlying server sampled queue depth and per-worker batches;
        // the endpoint view must carry them instead of dropping them
        assert!(e.queue_depth_mean >= 0.0);
        assert!(
            e.worker_batches_max >= e.worker_batches_min,
            "skew bounds ordered: {}..{}",
            e.worker_batches_min,
            e.worker_batches_max
        );
        assert!(e.worker_batches_max > 0, "the one worker served batches");
        // model-level rollup keeps the same signals
        let rolled = router.model_metrics("toy").unwrap();
        assert!(!rolled.per_worker.is_empty(), "per-worker stats survive the fold");
        assert_eq!(rolled.queue_depth_max, e.queue_depth_max);
        router.shutdown();
    }

    #[test]
    fn router_telemetry_combines_endpoints() {
        let mut router = Router::new();
        router.add_server("dev0", "toy", 2, server(2, Duration::ZERO));
        router.add_server("dev1", "toy", 2, server(2, Duration::ZERO));
        for i in 0..6 {
            let _ = router.infer("toy", vec![i as f32, 1.0]).unwrap();
        }
        let t = router.telemetry();
        assert_eq!(t.metrics.requests, 6, "fleet-wide fold covers every endpoint");
        assert!(!t.spans.is_empty(), "default-on telemetry records spans");
        assert!(t.counters.iter().any(|(n, _)| n == "sim_runs"));
        assert_eq!(router.metrics_handles().len(), 2);
        router.shutdown();
    }
}
