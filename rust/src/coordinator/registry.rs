//! Multi-model registry: route requests to per-model serving loops.
//!
//! A deployment typically hosts several accelerator designs at once (e.g.
//! one per model variant or quantization). The registry owns one [`Server`]
//! per entry — each with its own worker thread, engine, batcher and metrics
//! — and routes by model name, mirroring the model-registry pattern of
//! serving frameworks (vLLM router, Triton).
//!
//! Failures surface as the typed [`crate::Error`] enum — an unknown model
//! is [`Error::UnknownModel`], a name collision [`Error::DuplicateModel`],
//! a malformed request [`Error::InputLength`] — so callers match on the
//! class instead of string-probing, same as the pipeline surface.

use std::collections::HashMap;

use anyhow::Result;

use super::{
    BatchPolicy, Engine, MetricsHandle, MetricsSnapshot, Priority, Response, Server, ServerOptions,
};
use crate::error::Error;
use crate::telemetry::{counters_snapshot, TelemetrySnapshot};

/// Static description of one served model.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    /// Flattened input length the engine expects.
    pub input_len: usize,
    pub policy: BatchPolicy,
    pub options: ServerOptions,
}

/// A set of named serving loops.
pub struct ModelRegistry {
    servers: HashMap<String, (ModelEntry, Server)>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry { servers: HashMap::new() }
    }

    /// Register a model with an engine factory. The factory runs once on
    /// **each** of the entry's pool workers' threads (required for PJRT
    /// engines, and why the bound is `Fn` rather than `FnOnce` — with
    /// [`ServerOptions::workers`] > 1 it is called that many times). A taken
    /// name is [`Error::DuplicateModel`]; a factory failure is
    /// [`Error::Serve`].
    pub fn register<F>(&mut self, entry: ModelEntry, factory: F) -> Result<(), Error>
    where
        F: Fn() -> Result<Box<dyn Engine>> + Send + Sync + 'static,
    {
        if self.servers.contains_key(&entry.name) {
            return Err(Error::DuplicateModel(entry.name));
        }
        let server = Server::start_with_opts(factory, entry.policy, entry.options)
            .map_err(|e| Error::Serve(e.to_string()))?;
        self.servers.insert(entry.name.clone(), (entry, server));
        Ok(())
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.servers.keys().map(String::as_str).collect();
        names.sort_unstable();
        names
    }

    pub fn entry(&self, model: &str) -> Option<&ModelEntry> {
        self.servers.get(model).map(|(e, _)| e)
    }

    /// Validate the route and payload shape for `model`.
    fn lookup(&self, model: &str, input_len: usize) -> Result<&(ModelEntry, Server), Error> {
        let found = self
            .servers
            .get(model)
            .ok_or_else(|| Error::UnknownModel(model.to_string()))?;
        if input_len != found.0.input_len {
            return Err(Error::InputLength {
                model: model.to_string(),
                expected: found.0.input_len,
                got: input_len,
            });
        }
        Ok(found)
    }

    /// Blocking inference against a named model.
    pub fn infer(&self, model: &str, input: Vec<f32>) -> Result<Response, Error> {
        self.infer_with(model, input, Priority::Normal)
    }

    /// Blocking inference with an explicit service class. Admission and
    /// shutdown failures pass through typed ([`Error::Overloaded`],
    /// [`Error::ShuttingDown`]) so callers can back off or drain.
    pub fn infer_with(
        &self,
        model: &str,
        input: Vec<f32>,
        prio: Priority,
    ) -> Result<Response, Error> {
        let (_, server) = self.lookup(model, input.len())?;
        let rx = server.submit_with(input, prio)?;
        rx.recv().map_err(|_| Error::Serve("coordinator dropped request".to_string()))?
    }

    /// Async submit against a named model. The handle yields the worker's
    /// typed response result.
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
        prio: Priority,
    ) -> Result<super::ReplyHandle, Error> {
        let (_, server) = self.lookup(model, input.len())?;
        server.submit_with(input, prio)
    }

    /// Per-model metrics.
    pub fn metrics(&self, model: &str) -> Option<MetricsSnapshot> {
        self.servers.get(model).map(|(_, s)| s.metrics())
    }

    /// Cloneable metrics reader handles, one per registered model (sorted
    /// by name) — for stats reporters snapshotting from other threads.
    pub fn metrics_handles(&self) -> Vec<(String, MetricsHandle)> {
        let mut out: Vec<(String, MetricsHandle)> = self
            .servers
            .iter()
            .map(|(name, (_, s))| (name.clone(), s.metrics_handle()))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// One combined telemetry observation across every registered model:
    /// metrics folded conservatively (counts sum, percentiles max), spans
    /// concatenated in model-name order, process-wide counters read once.
    /// Span timestamps stay relative to each server's own boot epoch.
    pub fn telemetry(&self) -> TelemetrySnapshot {
        let mut names: Vec<&String> = self.servers.keys().collect();
        names.sort_unstable();
        let mut snaps = Vec::with_capacity(names.len());
        let mut spans = Vec::new();
        for name in names {
            let (_, server) = &self.servers[name];
            snaps.push(server.metrics());
            spans.extend(server.telemetry_spans());
        }
        TelemetrySnapshot {
            metrics: super::router::fold_snapshots(&snaps),
            counters: counters_snapshot(),
            spans,
        }
    }

    /// Shut down every serving loop, flushing pending requests.
    pub fn shutdown(self) {
        for (_, (_, server)) in self.servers {
            server.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SimOnlyEngine;
    use crate::device::Device;
    use crate::dse::{self, DseConfig};
    use crate::ir::Quant;
    use crate::models;
    use std::time::Duration;

    fn engine_for(model: &str, q: Quant, out_len: usize) -> SimOnlyEngine {
        let net = models::by_name(model, q).unwrap();
        let dev = Device::u250();
        let r = dse::run(&net, &dev, &DseConfig::default()).unwrap();
        let input_len = {
            let (c, h, w) = net.input_shape;
            (c * h * w) as usize
        };
        SimOnlyEngine { design: r.design, device: dev, input_len, output_len: out_len }
    }

    fn entry(name: &str, input_len: usize) -> ModelEntry {
        ModelEntry {
            name: name.into(),
            input_len,
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            options: ServerOptions::default(),
        }
    }

    #[test]
    fn routes_to_the_right_model() {
        let mut reg = ModelRegistry::new();
        let toy = engine_for("toy", Quant::W8A8, 10);
        let toy_len = toy.input_len;
        reg.register(entry("toy", toy_len), move || Ok(Box::new(toy.clone()) as _)).unwrap();
        let resp = reg.infer("toy", vec![1.0; toy_len]).unwrap();
        assert_eq!(resp.output.len(), 10);
        let err = reg.infer("nonexistent", vec![0.0; 4]).unwrap_err();
        assert!(matches!(err, Error::UnknownModel(ref m) if m == "nonexistent"), "{err}");
        assert_eq!(reg.models(), vec!["toy"]);
        reg.shutdown();
    }

    #[test]
    fn rejects_wrong_input_length() {
        let mut reg = ModelRegistry::new();
        let toy = engine_for("toy", Quant::W8A8, 10);
        let toy_len = toy.input_len;
        reg.register(entry("toy", toy_len), move || Ok(Box::new(toy.clone()) as _)).unwrap();
        let err = reg.infer("toy", vec![0.0; 7]).unwrap_err();
        assert!(
            matches!(err, Error::InputLength { expected, got, .. }
                if expected == toy_len && got == 7),
            "{err}"
        );
        assert!(err.to_string().contains("expects input length"), "{err}");
        reg.shutdown();
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut reg = ModelRegistry::new();
        let a = engine_for("toy", Quant::W8A8, 10);
        let len = a.input_len;
        reg.register(entry("toy", len), move || Ok(Box::new(a.clone()) as _)).unwrap();
        let b = engine_for("toy", Quant::W8A8, 10);
        let err =
            reg.register(entry("toy", len), move || Ok(Box::new(b.clone()) as _)).unwrap_err();
        assert!(matches!(err, Error::DuplicateModel(ref m) if m == "toy"), "{err}");
        assert!(err.to_string().contains("already registered"));
        reg.shutdown();
    }

    #[test]
    fn independent_metrics_per_model() {
        let mut reg = ModelRegistry::new();
        let a = engine_for("toy", Quant::W8A8, 10);
        let la = a.input_len;
        reg.register(entry("toy-a", la), move || Ok(Box::new(a.clone()) as _)).unwrap();
        let b = engine_for("toy", Quant::W8A8, 10);
        reg.register(entry("toy-b", la), move || Ok(Box::new(b.clone()) as _)).unwrap();
        for _ in 0..3 {
            reg.infer("toy-a", vec![0.0; la]).unwrap();
        }
        reg.infer("toy-b", vec![0.0; la]).unwrap();
        assert_eq!(reg.metrics("toy-a").unwrap().requests, 3);
        assert_eq!(reg.metrics("toy-b").unwrap().requests, 1);
        assert!(reg.metrics("missing").is_none());
        reg.shutdown();
    }

    #[test]
    fn engine_factory_failure_is_a_serve_error() {
        let mut reg = ModelRegistry::new();
        let err = reg
            .register(entry("broken", 4), || anyhow::bail!("no such artifact"))
            .unwrap_err();
        assert!(matches!(err, Error::Serve(_)), "{err}");
        assert!(reg.models().is_empty());
        reg.shutdown();
    }
}
