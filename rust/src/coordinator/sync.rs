//! Lock-free single-slot box exchanger — the one `unsafe` building block
//! under the sharded serving front.
//!
//! [`AtomicBox`] is a cell holding at most one `Box<T>`, exchanged with
//! compare-and-swap on the raw pointer. Three serving-path structures are
//! built from it, so the whole hot path concentrates its unsafety here:
//!
//! - the per-worker **batch mailbox** (shard puts a formed batch, worker —
//!   or a stealing sibling — takes it),
//! - the **value** and **waiter** cells of a pooled oneshot reply slot,
//! - the recycling shelf of the reply-slot pool.
//!
//! Safety model: ownership of the `Box` transfers atomically with the
//! pointer. `put` installs a pointer only into an observed-null cell
//! (`compare_exchange`), `take` detaches with an unconditional `swap`, so
//! no two parties can ever hold the same allocation; `AcqRel`/`Acquire`
//! ordering makes the boxed contents visible to whichever thread wins the
//! exchange. Multi-producer/multi-consumer safe — every operation is one
//! atomic RMW on the pointer.

use std::sync::atomic::{AtomicPtr, Ordering};

/// A lock-free cell holding zero or one `Box<T>`.
pub(crate) struct AtomicBox<T> {
    ptr: AtomicPtr<T>,
}

impl<T> AtomicBox<T> {
    pub fn empty() -> AtomicBox<T> {
        AtomicBox { ptr: AtomicPtr::new(std::ptr::null_mut()) }
    }

    /// Try to install `value` into an empty cell. On a full cell the box
    /// comes back in `Err` (same allocation — retry loops never realloc).
    pub fn put(&self, value: Box<T>) -> Result<(), Box<T>> {
        let raw = Box::into_raw(value);
        match self.ptr.compare_exchange(
            std::ptr::null_mut(),
            raw,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => Ok(()),
            // SAFETY: the exchange failed, so `raw` was never published —
            // this thread still exclusively owns the allocation.
            Err(_) => Err(unsafe { Box::from_raw(raw) }),
        }
    }

    /// Detach the current contents, leaving the cell empty.
    pub fn take(&self) -> Option<Box<T>> {
        let raw = self.ptr.swap(std::ptr::null_mut(), Ordering::AcqRel);
        if raw.is_null() {
            None
        } else {
            // SAFETY: the swap atomically transferred the published pointer
            // to this thread; no other `take` can observe it again.
            Some(unsafe { Box::from_raw(raw) })
        }
    }

    /// Install `value`, dropping whatever the cell held before. Single-
    /// writer cells only (the oneshot value/waiter, where one side writes).
    pub fn replace(&self, value: Box<T>) {
        let raw = Box::into_raw(value);
        let old = self.ptr.swap(raw, Ordering::AcqRel);
        if !old.is_null() {
            // SAFETY: the swap detached the old pointer exclusively.
            drop(unsafe { Box::from_raw(old) });
        }
    }
}

impl<T> Drop for AtomicBox<T> {
    fn drop(&mut self) {
        // &mut self: no concurrent access; reclaim any remaining contents.
        let raw = *self.ptr.get_mut();
        if !raw.is_null() {
            // SAFETY: exclusive access via &mut, pointer came from Box::into_raw.
            drop(unsafe { Box::from_raw(raw) });
        }
    }
}

// SAFETY: the cell hands the Box across threads whole (ownership moves with
// the pointer), so Send on the payload is exactly what both bounds need.
unsafe impl<T: Send> Send for AtomicBox<T> {}
unsafe impl<T: Send> Sync for AtomicBox<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn put_take_roundtrip() {
        let cell: AtomicBox<Vec<u32>> = AtomicBox::empty();
        assert!(cell.take().is_none());
        cell.put(Box::new(vec![1, 2, 3])).unwrap();
        assert_eq!(*cell.take().unwrap(), vec![1, 2, 3]);
        assert!(cell.take().is_none(), "take empties the cell");
    }

    #[test]
    fn put_into_full_cell_returns_the_box() {
        let cell: AtomicBox<u64> = AtomicBox::empty();
        cell.put(Box::new(7)).unwrap();
        let back = cell.put(Box::new(9)).unwrap_err();
        assert_eq!(*back, 9, "rejected put returns the caller's own box");
        assert_eq!(*cell.take().unwrap(), 7, "cell contents untouched");
    }

    #[test]
    fn replace_swaps_and_drops_old() {
        let cell: AtomicBox<&'static str> = AtomicBox::empty();
        cell.replace(Box::new("a"));
        cell.replace(Box::new("b"));
        assert_eq!(*cell.take().unwrap(), "b");
    }

    #[test]
    fn drop_reclaims_contents() {
        struct Counted<'a>(&'a AtomicUsize);
        impl Drop for Counted<'_> {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::AcqRel);
            }
        }
        let drops = AtomicUsize::new(0);
        {
            let cell = AtomicBox::empty();
            cell.put(Box::new(Counted(&drops))).unwrap_or_else(|_| panic!("empty cell"));
        }
        assert_eq!(drops.load(Ordering::Acquire), 1, "cell drop frees its payload");
    }

    #[test]
    fn concurrent_exchange_loses_nothing() {
        // 4 producers push 256 values each through one cell, 4 consumers
        // drain; every value arrives exactly once.
        let cell: AtomicBox<usize> = AtomicBox::empty();
        let sum = AtomicUsize::new(0);
        let taken = AtomicUsize::new(0);
        const PER: usize = 256;
        const PRODUCERS: usize = 4;
        std::thread::scope(|s| {
            for p in 0..PRODUCERS {
                let cell = &cell;
                s.spawn(move || {
                    for i in 0..PER {
                        let mut b = Box::new(p * PER + i + 1);
                        loop {
                            match cell.put(b) {
                                Ok(()) => break,
                                Err(back) => {
                                    b = back;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                });
            }
            for _ in 0..PRODUCERS {
                let (cell, sum, taken) = (&cell, &sum, &taken);
                s.spawn(move || {
                    while taken.load(Ordering::Acquire) < PRODUCERS * PER {
                        if let Some(v) = cell.take() {
                            sum.fetch_add(*v, Ordering::AcqRel);
                            taken.fetch_add(1, Ordering::AcqRel);
                        } else {
                            std::thread::yield_now();
                        }
                    }
                });
            }
        });
        let n = PRODUCERS * PER;
        assert_eq!(taken.load(Ordering::Acquire), n);
        assert_eq!(sum.load(Ordering::Acquire), n * (n + 1) / 2, "each value exactly once");
    }
}
