//! Pooled oneshot reply slots — the per-request reply path without a
//! per-request `mpsc::channel` allocation.
//!
//! Every submit used to allocate a fresh mpsc channel (two Arcs, a buffer,
//! a condvar chain) just to carry ONE `Result<Response, Error>` back.
//! A reply is a oneshot: the coordinator writes exactly once, the caller
//! reads exactly once. [`SlotPool::oneshot`] hands out a recycled slot —
//! two [`super::sync::AtomicBox`] cells (value + parked waiter) and two
//! small atomics — so the steady-state serving path allocates nothing per
//! request and never takes a lock:
//!
//! - [`ReplySender::send`] publishes the value with an atomic pointer swap
//!   and unparks the waiter if one is registered.
//! - [`ReplyHandle::recv`] spins a bounded park loop: take the value, or
//!   register `thread::current()` and re-check before parking (the sender
//!   reads the waiter cell only *after* publishing the value, so the
//!   two-phase check cannot lose a wakeup).
//! - Dropping an unsent [`ReplySender`] delivers a typed
//!   `Error::Serve("coordinator dropped request")` — receivers are never
//!   left hanging, mirroring the old channel's disconnect semantics.
//! - The *last* endpoint to drop (a 2-owner atomic count) returns the slot
//!   to the pool's lock-free shelf for reuse.
//!
//! `recv()` keeps the `Result<_, mpsc::RecvError>` shape of
//! `mpsc::Receiver::recv`, so every call site written against the old
//! channel (`rx.recv().unwrap().unwrap()`) compiles and behaves
//! identically; a second `recv` after consumption reports `RecvError` just
//! as a drained, disconnected channel would.

use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::RecvError;
use std::sync::Arc;
use std::thread::{self, Thread};
use std::time::Duration;

use super::server::Response;
use super::sync::AtomicBox;
use crate::error::Error;

type Payload = Result<Response, Error>;

/// One reply slot: written once by the coordinator, read once by the caller.
struct Slot {
    value: AtomicBox<Payload>,
    /// The receiver parked waiting for the value, if any.
    waiter: AtomicBox<Thread>,
    /// Set once the handle has taken the payload: a later `recv` is a
    /// drained-and-disconnected channel, i.e. `RecvError`.
    consumed: AtomicBool,
    /// Live endpoints (sender + handle). The last to drop recycles.
    owners: AtomicU8,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            value: AtomicBox::empty(),
            waiter: AtomicBox::empty(),
            consumed: AtomicBool::new(false),
            owners: AtomicU8::new(2),
        }
    }

    /// Re-arm a recycled slot (exclusive access: the pool holds the only
    /// reference between release and the next acquire).
    fn reset(&mut self) {
        drop(self.value.take());
        drop(self.waiter.take());
        *self.consumed.get_mut() = false;
        *self.owners.get_mut() = 2;
    }
}

/// Recycling shelf size. Slots beyond a full shelf are simply freed, so
/// this bounds pool memory, not concurrency.
const SHELF: usize = 256;
/// How many shelf cells an acquire/release probes before giving up.
const PROBES: usize = 8;

/// Lock-free recycling pool of reply slots.
pub(crate) struct SlotPool {
    shelf: Vec<AtomicBox<Slot>>,
    /// Rotating probe start, so concurrent callers spread across the shelf.
    cursor: AtomicUsize,
    /// Acquires served from the shelf (vs fresh allocations) — lets tests
    /// prove recycling actually engages under steady-state load.
    recycled: AtomicUsize,
}

impl SlotPool {
    pub fn new() -> Arc<SlotPool> {
        Arc::new(SlotPool {
            shelf: (0..SHELF).map(|_| AtomicBox::empty()).collect(),
            cursor: AtomicUsize::new(0),
            recycled: AtomicUsize::new(0),
        })
    }

    /// A fresh sender/handle pair over one (possibly recycled) slot.
    pub fn oneshot(self: &Arc<Self>) -> (ReplySender, ReplyHandle) {
        let slot = Box::into_raw(self.acquire());
        (
            ReplySender { slot, pool: self.clone(), sent: false },
            ReplyHandle { slot, pool: self.clone() },
        )
    }

    /// Slots reused from the shelf so far.
    pub fn recycled(&self) -> usize {
        self.recycled.load(Ordering::Acquire)
    }

    fn acquire(&self) -> Box<Slot> {
        let start = self.cursor.fetch_add(1, Ordering::Relaxed);
        for k in 0..PROBES {
            if let Some(mut slot) = self.shelf[(start + k) % SHELF].take() {
                slot.reset();
                self.recycled.fetch_add(1, Ordering::AcqRel);
                return slot;
            }
        }
        Box::new(Slot::new())
    }

    fn release(&self, mut slot: Box<Slot>) {
        let start = self.cursor.fetch_add(1, Ordering::Relaxed);
        for k in 0..PROBES {
            match self.shelf[(start + k) % SHELF].put(slot) {
                Ok(()) => return,
                Err(back) => slot = back,
            }
        }
        // shelf full: let this one free normally
    }
}

/// Decrement the 2-party owner count; the last owner recycles the slot.
fn release_owner(slot: *mut Slot, pool: &SlotPool) {
    // SAFETY: `slot` stays valid until both owners have released — this is
    // at most the second (final) access through the raw pointer.
    let last = unsafe { (*slot).owners.fetch_sub(1, Ordering::AcqRel) } == 1;
    if last {
        // SAFETY: owner count reached zero, so no other endpoint can touch
        // the slot again; reconstituting the Box reclaims it exactly once.
        pool.release(unsafe { Box::from_raw(slot) });
    }
}

/// Write half of a pooled oneshot (held inside the coordinator's
/// [`super::server::Request`]).
pub(crate) struct ReplySender {
    slot: *mut Slot,
    pool: Arc<SlotPool>,
    sent: bool,
}

// SAFETY: the raw pointer is an owner handle over a heap slot whose shared
// mutation goes through atomics only; Send payloads make the whole slot
// safe to hand across threads.
unsafe impl Send for ReplySender {}

impl ReplySender {
    /// Publish the reply and wake the receiver. Consumes the sender (a
    /// oneshot writes once by construction).
    pub fn send(mut self, payload: Payload) {
        self.deliver(payload);
        // Drop runs next and releases this endpoint's ownership.
    }

    fn deliver(&mut self, payload: Payload) {
        if self.sent {
            return;
        }
        self.sent = true;
        // SAFETY: sender endpoint is live (owner count not yet released).
        let slot = unsafe { &*self.slot };
        // Publish first, then look for a waiter: recv's register-then-
        // re-check sees either the value or our take of its waiter.
        slot.value.replace(Box::new(payload));
        if let Some(w) = slot.waiter.take() {
            w.unpark();
        }
    }
}

impl Drop for ReplySender {
    fn drop(&mut self) {
        if !self.sent {
            // Dropped without sending (dead pool, panicking worker): a
            // typed error, never a hung receiver.
            self.deliver(Err(Error::Serve("coordinator dropped request".to_string())));
        }
        release_owner(self.slot, &self.pool);
    }
}

/// Read half of a pooled oneshot — what [`super::server::Server::submit`]
/// returns. API-compatible with the old `mpsc::Receiver`: `recv()` blocks
/// for the single reply, and returns `Err(RecvError)` once consumed (the
/// drained-disconnected-channel contract).
pub struct ReplyHandle {
    slot: *mut Slot,
    pool: Arc<SlotPool>,
}

// SAFETY: same argument as ReplySender — shared state is atomics-only.
unsafe impl Send for ReplyHandle {}

impl ReplyHandle {
    /// Block until the reply arrives. A second call after the value was
    /// taken reports [`RecvError`], exactly like a drained disconnected
    /// mpsc receiver.
    pub fn recv(&self) -> Result<Payload, RecvError> {
        // SAFETY: handle endpoint is live (owner count not yet released).
        let slot = unsafe { &*self.slot };
        if slot.consumed.load(Ordering::Acquire) {
            return Err(RecvError);
        }
        loop {
            if let Some(v) = slot.value.take() {
                slot.consumed.store(true, Ordering::Release);
                return Ok(*v);
            }
            slot.waiter.replace(Box::new(thread::current()));
            // Re-check after registering: the sender publishes the value
            // BEFORE reading the waiter cell, so if it raced past the take
            // above, the value is visible now (no lost wakeup).
            if let Some(v) = slot.value.take() {
                slot.consumed.store(true, Ordering::Release);
                drop(slot.waiter.take());
                return Ok(*v);
            }
            // The timeout is belt-and-braces against spurious coincidences;
            // the common path is one park ended by the sender's unpark.
            thread::park_timeout(Duration::from_millis(5));
        }
    }
}

impl Drop for ReplyHandle {
    fn drop(&mut self) {
        release_owner(self.slot, &self.pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn response(id: u64) -> Response {
        Response {
            id,
            output: vec![id as f32],
            total: Duration::from_millis(1),
            accel: Duration::from_micros(10),
            batch: 1,
        }
    }

    #[test]
    fn send_then_recv_roundtrip() {
        let pool = SlotPool::new();
        let (tx, rx) = pool.oneshot();
        tx.send(Ok(response(7)));
        let got = rx.recv().expect("value present").expect("ok payload");
        assert_eq!(got.id, 7);
        assert_eq!(got.output, vec![7.0]);
    }

    #[test]
    fn recv_blocks_until_cross_thread_send() {
        let pool = SlotPool::new();
        let (tx, rx) = pool.oneshot();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            tx.send(Ok(response(3)));
        });
        let t0 = std::time::Instant::now();
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.id, 3);
        assert!(t0.elapsed() >= Duration::from_millis(10), "recv actually waited");
        sender.join().unwrap();
    }

    #[test]
    fn second_recv_reports_disconnected() {
        let pool = SlotPool::new();
        let (tx, rx) = pool.oneshot();
        tx.send(Ok(response(1)));
        assert!(rx.recv().is_ok());
        assert!(rx.recv().is_err(), "consumed oneshot behaves like a drained channel");
    }

    #[test]
    fn dropped_sender_delivers_typed_error() {
        let pool = SlotPool::new();
        let (tx, rx) = pool.oneshot();
        drop(tx);
        let got = rx.recv().expect("an error value, not a hang");
        assert!(
            matches!(got, Err(Error::Serve(ref m)) if m.contains("dropped request")),
            "{got:?}"
        );
    }

    #[test]
    fn slots_recycle_through_the_pool() {
        let pool = SlotPool::new();
        for i in 0..64 {
            let (tx, rx) = pool.oneshot();
            tx.send(Ok(response(i)));
            assert_eq!(rx.recv().unwrap().unwrap().id, i);
        }
        assert!(
            pool.recycled() >= 32,
            "steady-state oneshot traffic must reuse slots, recycled {}",
            pool.recycled()
        );
    }

    #[test]
    fn many_concurrent_oneshots_stay_isolated() {
        let pool = SlotPool::new();
        const N: u64 = 512;
        let pairs: Vec<_> = (0..N).map(|_| pool.oneshot()).collect();
        let (txs, rxs): (Vec<_>, Vec<_>) = pairs.into_iter().unzip();
        std::thread::scope(|s| {
            s.spawn(move || {
                for (i, tx) in txs.into_iter().enumerate() {
                    tx.send(Ok(response(i as u64)));
                }
            });
            for (i, rx) in rxs.iter().enumerate() {
                let got = rx.recv().unwrap().unwrap();
                assert_eq!(got.id, i as u64, "replies must land on their own handles");
            }
        });
    }
}
