//! Dynamic batching policy.
//!
//! The layer-wise pipelined accelerator amortizes its pipeline fill across a
//! batch (paper Eq. 3: weights are reused over the `b` dimension), so the
//! coordinator collects up to `max_batch` requests, but never waits longer
//! than `max_wait` once at least one request is pending.
//!
//! The policy is interpreted per batching thread: the single-worker server
//! runs one batcher, a pooled server runs one per dispatch shard
//! ([`super::ServerOptions::dispatch_shards`]), each applying `max_batch` /
//! `max_wait` to its own slice of the request stream.

use std::time::Duration;

/// Batching policy parameters.
///
/// Edge cases (guaranteed by [`Batcher`] and [`super::PriorityBatcher`]):
/// - `max_wait == 0` means "never hold a request": every push flushes the
///   pending batch immediately — no deadline, no extra `poll` needed.
/// - `max_batch == 1` degenerates to unbatched serving: every push returns
///   its item as a complete batch and no deadline is ever armed.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pure batching state machine (time injected for testability).
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    pending: Vec<T>,
    /// Monotonic deadline (seconds) by which the current batch must flush.
    deadline: Option<f64>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, pending: Vec::with_capacity(policy.max_batch), deadline: None }
    }

    /// Add a request at monotonic time `now` (seconds). Returns a full batch
    /// if this push filled it — or the pending batch immediately when the
    /// policy's `max_wait` is zero (zero wait must never require a `poll`).
    pub fn push(&mut self, item: T, now: f64) -> Option<Vec<T>> {
        self.pending.push(item);
        if self.pending.len() >= self.policy.max_batch || self.policy.max_wait.is_zero() {
            self.deadline = None;
            return Some(std::mem::take(&mut self.pending));
        }
        // arm the deadline only for a batch that actually waits — a
        // max_batch == 1 policy flushes above and never reaches this
        if self.pending.len() == 1 {
            self.deadline = Some(now + self.policy.max_wait.as_secs_f64());
        }
        None
    }

    /// Flush if the deadline has passed. Returns the partial batch.
    pub fn poll(&mut self, now: f64) -> Option<Vec<T>> {
        match self.deadline {
            Some(d) if now >= d && !self.pending.is_empty() => {
                self.deadline = None;
                Some(std::mem::take(&mut self.pending))
            }
            _ => None,
        }
    }

    /// Unconditional flush (shutdown path).
    pub fn drain(&mut self) -> Option<Vec<T>> {
        self.deadline = None;
        if self.pending.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.pending))
        }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Time until the current deadline, if any (for the server's sleep).
    pub fn time_to_deadline(&self, now: f64) -> Option<Duration> {
        self.deadline.map(|d| Duration::from_secs_f64((d - now).max(0.0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(policy(3, 100));
        assert!(b.push(1, 0.0).is_none());
        assert!(b.push(2, 0.001).is_none());
        let batch = b.push(3, 0.002).expect("third push fills the batch");
        assert_eq!(batch, vec![1, 2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let mut b = Batcher::new(policy(8, 2));
        b.push("a", 0.0);
        assert!(b.poll(0.001).is_none(), "before deadline");
        let batch = b.poll(0.003).expect("after deadline");
        assert_eq!(batch, vec!["a"]);
    }

    #[test]
    fn deadline_resets_per_batch() {
        let mut b = Batcher::new(policy(8, 2));
        b.push(1, 0.0);
        b.poll(0.01).unwrap();
        assert!(b.poll(0.02).is_none(), "no pending, no flush");
        b.push(2, 0.05);
        assert!(b.poll(0.051).is_none());
        assert_eq!(b.poll(0.06).unwrap(), vec![2]);
    }

    #[test]
    fn zero_wait_flushes_on_every_push() {
        let mut b = Batcher::new(policy(8, 0));
        let batch = b.push(1, 0.0).expect("max_wait == 0 must flush immediately");
        assert_eq!(batch, vec![1]);
        assert_eq!(b.pending(), 0);
        assert!(b.time_to_deadline(0.0).is_none(), "no deadline may be armed");
        // and again: the state machine fully resets
        assert_eq!(b.push(2, 1.0).unwrap(), vec![2]);
    }

    #[test]
    fn unit_batch_never_arms_a_deadline() {
        let mut b = Batcher::new(policy(1, 100));
        let batch = b.push("only", 0.0).expect("max_batch == 1 flushes every push");
        assert_eq!(batch, vec!["only"]);
        assert!(b.time_to_deadline(0.0).is_none(), "max_batch == 1 must never set a deadline");
        assert!(b.poll(1000.0).is_none());
    }

    #[test]
    fn drain_empties() {
        let mut b = Batcher::new(policy(8, 2));
        b.push(1, 0.0);
        b.push(2, 0.0);
        assert_eq!(b.drain().unwrap(), vec![1, 2]);
        assert!(b.drain().is_none());
    }
}
