//! Run configuration: a typed spec loaded from a TOML-subset file, so that
//! DSE runs, simulations, sweeps and serving sessions are reproducible
//! artifacts instead of ad-hoc flag soup (`autows run --config <file>`).
//!
//! ```toml
//! title = "resnet18 on zcu102"
//!
//! [model]
//! name  = "resnet18"      # zoo name, or  file = "nets/custom.net"
//! quant = "w4a5"
//!
//! [device]
//! name      = "zcu102"
//! mem_scale = 1.0          # optional Fig. 6-style budget scaling
//!
//! [dse]
//! phi       = 1
//! mu        = 512
//! vanilla   = false
//! bw_margin = 0.9
//!
//! [sim]
//! batch = 8
//!
//! [serve]
//! artifact  = "artifacts/toy_cnn_b8.hlo.txt"
//! requests  = 64
//! max_batch = 8
//! ```

mod toml;

pub use toml::{Document, ParseError, Value};

use crate::device::Device;
use crate::dse::DseConfig;
use crate::ir::{Network, Quant};
use crate::models;

/// Which model to run: a zoo builder by name, or a `.net` description file
/// (see [`crate::ir::textfmt`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSource {
    Zoo(String),
    File(String),
}

/// Fully-resolved run specification.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub title: String,
    pub model: ModelSource,
    pub quant: Quant,
    pub device: Device,
    pub dse: DseConfig,
    /// Batch size for the simulation step.
    pub sim_batch: u64,
    /// Optional serving section.
    pub serve: Option<ServeSpec>,
    /// Optional memory sweep (Fig. 6 style): list of `A_mem` scale factors.
    pub mem_sweep: Vec<f64>,
}

/// Serving parameters (`[serve]` section).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    pub artifact: String,
    pub requests: usize,
    pub max_batch: usize,
    pub max_wait_ms: u64,
}

/// A configuration error: parse failure or semantic problem.
#[derive(Debug, Clone)]
pub enum ConfigError {
    Parse(ParseError),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(e) => write!(f, "config parse error: {e}"),
            ConfigError::Invalid(m) => write!(f, "invalid config: {m}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<ParseError> for ConfigError {
    fn from(e: ParseError) -> Self {
        ConfigError::Parse(e)
    }
}

fn invalid(msg: impl Into<String>) -> ConfigError {
    ConfigError::Invalid(msg.into())
}

impl RunSpec {
    /// Parse and validate a run spec from config text.
    pub fn from_str(text: &str) -> Result<RunSpec, ConfigError> {
        let doc = Document::parse(text)?;

        // Reject unknown sections early: a typo'd `[dze]` silently falling
        // back to defaults is the worst failure mode a config system can have.
        const KNOWN: [&str; 6] = ["", "model", "device", "dse", "sim", "serve"];
        for s in doc.sections() {
            if !KNOWN.contains(&s) {
                return Err(invalid(format!("unknown section `[{s}]`")));
            }
        }

        let title = doc.str_or("", "title", "untitled run").to_string();

        // [model]
        let model = match (doc.get("model", "name"), doc.get("model", "file")) {
            (Some(v), None) => {
                let name = v.as_str().ok_or_else(|| invalid("model.name must be a string"))?;
                ModelSource::Zoo(name.to_string())
            }
            (None, Some(v)) => {
                let path = v.as_str().ok_or_else(|| invalid("model.file must be a string"))?;
                ModelSource::File(path.to_string())
            }
            (Some(_), Some(_)) => {
                return Err(invalid("model: give either `name` or `file`, not both"))
            }
            (None, None) => return Err(invalid("missing [model] name or file")),
        };
        let quant_label = doc.str_or("model", "quant", "w8a8");
        let quant = Quant::parse(quant_label)
            .ok_or_else(|| invalid(format!("bad model.quant `{quant_label}`")))?;

        // [device]
        let dev_name = doc.str_or("device", "name", "zcu102");
        let mut device = Device::by_name(dev_name)
            .ok_or_else(|| invalid(format!("unknown device `{dev_name}`")))?;
        let mem_scale = doc.float_or("device", "mem_scale", 1.0);
        if !(0.01..=10.0).contains(&mem_scale) {
            return Err(invalid(format!("device.mem_scale {mem_scale} out of range (0.01..10)")));
        }
        if (mem_scale - 1.0).abs() > 1e-12 {
            device = device.with_mem_scale(mem_scale);
        }

        // [dse]
        let phi = doc.int_or("dse", "phi", 1);
        let mu = doc.int_or("dse", "mu", 512);
        let bw_margin = doc.float_or("dse", "bw_margin", 0.90);
        if phi < 1 || phi > 1024 {
            return Err(invalid(format!("dse.phi {phi} out of range (1..1024)")));
        }
        if mu < 1 {
            return Err(invalid(format!("dse.mu {mu} must be >= 1")));
        }
        if !(0.1..=1.0).contains(&bw_margin) {
            return Err(invalid(format!("dse.bw_margin {bw_margin} out of range (0.1..1.0)")));
        }
        let dse = DseConfig {
            phi: phi as u32,
            mu: mu as u64,
            batch: doc.int_or("dse", "batch", 1).max(1) as u64,
            allow_streaming: !doc.bool_or("dse", "vanilla", false),
            bw_margin,
            warm_start: doc.bool_or("dse", "warm_start", false),
        };

        // [sim]
        let sim_batch = doc.int_or("sim", "batch", 1).max(1) as u64;

        // [serve]
        let serve = if doc.has_section("serve") {
            let artifact = doc.str_or("serve", "artifact", "artifacts/toy_cnn_b8.hlo.txt");
            let requests = doc.int_or("serve", "requests", 64);
            let max_batch = doc.int_or("serve", "max_batch", 8);
            let max_wait_ms = doc.int_or("serve", "max_wait_ms", 2);
            if requests < 1 || max_batch < 1 || max_wait_ms < 0 {
                return Err(invalid("serve: requests/max_batch must be >= 1, max_wait_ms >= 0"));
            }
            Some(ServeSpec {
                artifact: artifact.to_string(),
                requests: requests as usize,
                max_batch: max_batch as usize,
                max_wait_ms: max_wait_ms as u64,
            })
        } else {
            None
        };

        // device.mem_sweep = [0.5, 1.0, ...]
        let mem_sweep = match doc.get("device", "mem_sweep") {
            None => Vec::new(),
            Some(v) => {
                let arr = v.as_array().ok_or_else(|| invalid("device.mem_sweep must be an array"))?;
                let mut out = Vec::with_capacity(arr.len());
                for item in arr {
                    let f = item
                        .as_float()
                        .ok_or_else(|| invalid("device.mem_sweep entries must be numbers"))?;
                    if !(0.01..=10.0).contains(&f) {
                        return Err(invalid(format!("mem_sweep scale {f} out of range")));
                    }
                    out.push(f);
                }
                out
            }
        };

        Ok(RunSpec { title, model, quant, device, dse, sim_batch, serve, mem_sweep })
    }

    /// Load a spec from a file path.
    pub fn from_file(path: &str) -> Result<RunSpec, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| invalid(format!("cannot read `{path}`: {e}")))?;
        RunSpec::from_str(&text)
    }

    /// Resolve the model source into a network (zoo lookup or `.net` file).
    pub fn build_network(&self) -> Result<Network, ConfigError> {
        match &self.model {
            ModelSource::Zoo(name) => models::by_name(name, self.quant)
                .ok_or_else(|| invalid(format!("unknown zoo model `{name}`"))),
            ModelSource::File(path) => {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| invalid(format!("cannot read `{path}`: {e}")))?;
                crate::ir::parse_network(&text, self.quant)
                    .map_err(|e| invalid(format!("{path}: {e}")))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
title = "resnet18 on zcu102"
[model]
name  = "resnet18"
quant = "w4a5"
[device]
name      = "zcu102"
mem_scale = 0.8
mem_sweep = [0.5, 1.0, 1.5]
[dse]
phi     = 2
mu      = 256
vanilla = false
[sim]
batch = 8
[serve]
artifact  = "artifacts/toy_cnn_b8.hlo.txt"
requests  = 32
max_batch = 4
"#;

    #[test]
    fn full_spec_roundtrip() {
        let s = RunSpec::from_str(FULL).unwrap();
        assert_eq!(s.title, "resnet18 on zcu102");
        assert_eq!(s.model, ModelSource::Zoo("resnet18".into()));
        assert_eq!(s.quant, Quant::W4A5);
        assert_eq!(s.device.name, "zcu102");
        // mem_scale applied
        assert!(s.device.mem_bits() < Device::zcu102().mem_bits());
        assert_eq!(s.dse.phi, 2);
        assert_eq!(s.dse.mu, 256);
        assert!(s.dse.allow_streaming);
        assert_eq!(s.sim_batch, 8);
        let serve = s.serve.unwrap();
        assert_eq!(serve.requests, 32);
        assert_eq!(serve.max_batch, 4);
        assert_eq!(s.mem_sweep, vec![0.5, 1.0, 1.5]);
    }

    #[test]
    fn minimal_spec_uses_defaults() {
        let s = RunSpec::from_str("[model]\nname = \"toy\"").unwrap();
        assert_eq!(s.quant, Quant::W8A8);
        assert_eq!(s.device.name, "zcu102");
        assert_eq!(s.dse.phi, 1);
        assert!(s.serve.is_none());
        assert!(s.mem_sweep.is_empty());
        let net = s.build_network().unwrap();
        assert_eq!(net.name, "toy_cnn");
    }

    #[test]
    fn unknown_section_rejected() {
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[dze]\nphi = 2").unwrap_err();
        assert!(e.to_string().contains("unknown section"), "{e}");
    }

    #[test]
    fn missing_model_rejected() {
        let e = RunSpec::from_str("title = \"x\"").unwrap_err();
        assert!(e.to_string().contains("missing [model]"), "{e}");
    }

    #[test]
    fn bad_quant_rejected() {
        let e = RunSpec::from_str("[model]\nname = \"toy\"\nquant = \"w3b7\"").unwrap_err();
        assert!(e.to_string().contains("quant"), "{e}");
    }

    #[test]
    fn name_and_file_conflict() {
        let e =
            RunSpec::from_str("[model]\nname = \"toy\"\nfile = \"x.net\"").unwrap_err();
        assert!(e.to_string().contains("not both"), "{e}");
    }

    #[test]
    fn custom_quant_pairs_accepted() {
        let s = RunSpec::from_str("[model]\nname = \"toy\"\nquant = \"w2a8\"").unwrap();
        assert_eq!(s.quant, Quant { w_bits: 2, a_bits: 8 });
    }

    #[test]
    fn out_of_range_hyperparameters() {
        for bad in [
            "[model]\nname = \"toy\"\n[dse]\nphi = 0",
            "[model]\nname = \"toy\"\n[dse]\nbw_margin = 1.5",
            "[model]\nname = \"toy\"\n[device]\nname = \"zcu102\"\nmem_scale = 100.0",
        ] {
            assert!(RunSpec::from_str(bad).is_err(), "{bad}");
        }
    }
}
