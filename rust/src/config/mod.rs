//! Run configuration: a typed spec loaded from a TOML-subset file, so that
//! DSE runs, simulations, sweeps and serving sessions are reproducible
//! artifacts instead of ad-hoc flag soup (`autows run --config <file>`).
//!
//! ```toml
//! title = "resnet18 on zcu102"
//!
//! [model]
//! name  = "resnet18"      # zoo name, or  file = "nets/custom.net"
//! quant = "w4a5"
//!
//! [device]
//! name      = "zcu102"     # or  devices = ["zcu102", "zcu102"]  (sharded)
//! mem_scale = 1.0          # optional Fig. 6-style budget scaling
//!
//! [dse]
//! phi       = 1
//! mu        = 512
//! vanilla   = false
//! bw_margin = 0.9
//!
//! [sim]
//! batch = 8
//!
//! [serve]
//! artifact  = "artifacts/toy_cnn_b8.hlo.txt"
//! requests  = 64
//! max_batch = 8
//!
//! [telemetry]
//! metrics_out      = "out/serve_metrics.prom"  # or a .json path for a JSON snapshot
//! trace_out        = "out/serve_spans.json"    # Chrome trace-event (Perfetto)
//! stats_interval_s = 5                         # periodic stderr stats
//! ```
//!
//! A co-located (multi-tenant) run replaces `[model]` with a `[[tenant]]`
//! array — every tenant is planned onto the ONE `[device]` by the joint
//! budget search (`configs/multitenant_zcu102.toml`):
//!
//! ```toml
//! [device]
//! name = "zcu102"
//!
//! [[tenant]]
//! name  = "resnet18"
//! quant = "w4a5"
//!
//! [[tenant]]
//! name  = "squeezenet"      # quant defaults to w8a8
//! ```
//!
//! A fleet run adds a `[fleet]` section: the model set (the `[[tenant]]`
//! list, or the single `[model]`) is placed over the whole `devices` pool —
//! per model solo, sharded, or co-located — under the stated objective
//! (`configs/fleet_mixed.toml`):
//!
//! ```toml
//! [device]
//! devices = ["zcu102", "zc706"]
//!
//! [[tenant]]
//! name  = "resnet18"
//! quant = "w4a5"
//!
//! [[tenant]]
//! name = "squeezenet"
//!
//! [fleet]
//! objective = "max_aggregate_throughput"  # or "min_devices_at_slo" + slo_p99_ms
//! ```

mod toml;

pub use toml::{Document, ParseError, Value};

use crate::device::Device;
use crate::dse::{DseConfig, FleetObjective};
use crate::ir::{Network, Quant};
use crate::models;

/// Which model to run: a zoo builder by name, or a `.net` description file
/// (see [`crate::ir::textfmt`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSource {
    Zoo(String),
    File(String),
}

/// One co-located tenant (`[[tenant]]` array element).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub model: ModelSource,
    pub quant: Quant,
}

/// Fleet placement parameters (`[fleet]` section). Its presence makes the
/// run a fleet placement: the model set (the `[[tenant]]` list, or the
/// single `[model]`) is placed onto the whole device pool, per model solo,
/// sharded or co-located.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub objective: FleetObjective,
}

/// Fully-resolved run specification.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub title: String,
    /// The primary model — for a co-located spec this mirrors
    /// `tenants[0]` (the whole set is [`RunSpec::tenants`]), the same way
    /// [`RunSpec::device`] mirrors `devices[0]` for sharded specs.
    pub model: ModelSource,
    pub quant: Quant,
    /// Device chain. One entry for a single-device run; more for a sharded
    /// deployment (`[device] devices = [...]`), in chain order. The primary
    /// (single-device) target is [`RunSpec::device`].
    pub devices: Vec<Device>,
    /// Co-located tenants (`[[tenant]]` array). Empty for single-model
    /// runs; a non-empty list makes this a multi-tenant deployment of
    /// every tenant onto the ONE [`RunSpec::device`].
    pub tenants: Vec<TenantSpec>,
    /// Fleet placement (`[fleet]` section). `Some` turns the spec into a
    /// fleet run: the model set over the whole device pool.
    pub fleet: Option<FleetSpec>,
    pub dse: DseConfig,
    /// Batch size for the simulation step.
    pub sim_batch: u64,
    /// Optional serving section.
    pub serve: Option<ServeSpec>,
    /// Optional memory sweep (Fig. 6 style): list of `A_mem` scale factors.
    pub mem_sweep: Vec<f64>,
    /// Telemetry outputs (`[telemetry]` section; all-default when absent).
    pub telemetry: TelemetrySpec,
}

/// Serving parameters (`[serve]` section).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    pub artifact: String,
    pub requests: usize,
    pub max_batch: usize,
    pub max_wait_ms: u64,
    /// Engine-pool size ([`crate::coordinator::ServerOptions::workers`]):
    /// each worker constructs its own engine on its own thread. `1` is the
    /// single-worker server.
    pub workers: usize,
    /// Batcher shards on the dispatch front
    /// ([`crate::coordinator::ServerOptions::dispatch_shards`]): `0` (the
    /// default) auto-sizes from the pool, any other value pins the count.
    pub dispatch_shards: usize,
}

/// Telemetry outputs (`[telemetry]` section). Span recording defaults on
/// (its hot-path cost is gated below 2% by `benches/e2e_serve.rs`); the
/// writers and the periodic reporter are opt-in.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySpec {
    /// Record serving spans
    /// ([`crate::coordinator::ServerOptions::telemetry`]). Request metrics
    /// and counters are always collected; this gates only the span rings.
    pub enabled: bool,
    /// Write the final metrics snapshot here after the serving session:
    /// Prometheus text, or a JSON snapshot when the path ends in `.json`.
    pub metrics_out: Option<String>,
    /// Write the serving spans here as Chrome trace-event (Perfetto) JSON.
    pub trace_out: Option<String>,
    /// Periodic one-line stats to stderr every this many seconds while the
    /// serving session runs.
    pub stats_interval_s: Option<f64>,
}

impl Default for TelemetrySpec {
    fn default() -> Self {
        TelemetrySpec {
            enabled: true,
            metrics_out: None,
            trace_out: None,
            stats_interval_s: None,
        }
    }
}

/// A configuration error: parse failure or semantic problem.
#[derive(Debug, Clone)]
pub enum ConfigError {
    Parse(ParseError),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(e) => write!(f, "config parse error: {e}"),
            ConfigError::Invalid(m) => write!(f, "invalid config: {m}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<ParseError> for ConfigError {
    fn from(e: ParseError) -> Self {
        ConfigError::Parse(e)
    }
}

fn invalid(msg: impl Into<String>) -> ConfigError {
    ConfigError::Invalid(msg.into())
}

/// Known keys per section: a typo'd key silently falling back to its
/// default is the worst failure mode a config system can have, so anything
/// not listed here is rejected with the expected alternatives.
const KNOWN_KEYS: [(&str, &[&str]); 8] = [
    ("", &["title"]),
    ("model", &["name", "file", "quant"]),
    ("device", &["name", "devices", "mem_scale", "mem_sweep"]),
    ("dse", &["phi", "mu", "batch", "vanilla", "bw_margin", "warm_start"]),
    ("sim", &["batch"]),
    ("serve", &["artifact", "requests", "max_batch", "max_wait_ms", "workers", "dispatch_shards"]),
    ("fleet", &["objective", "slo_p99_ms"]),
    ("telemetry", &["enabled", "metrics_out", "trace_out", "stats_interval_s"]),
];

impl RunSpec {
    /// Parse and validate a run spec from config text.
    pub fn from_str(text: &str) -> Result<RunSpec, ConfigError> {
        let doc = Document::parse(text)?;

        // Reject unknown sections and keys early (a typo'd `[dze]` or
        // `phy = 2` must not silently run with defaults).
        for s in doc.sections() {
            let Some((_, keys)) = KNOWN_KEYS.iter().find(|(name, _)| *name == s) else {
                return Err(invalid(format!("unknown section `[{s}]`")));
            };
            for k in doc.keys(s) {
                if !keys.contains(&k) {
                    let path = if s.is_empty() { k.to_string() } else { format!("{s}.{k}") };
                    return Err(invalid(format!(
                        "unknown key `{path}` (expected one of: {})",
                        keys.join(", ")
                    )));
                }
            }
        }

        let title = doc.try_str_or("", "title", "untitled run").map_err(invalid)?.to_string();

        // [[tenant]] — co-located multi-tenant deployments. Only `tenant`
        // arrays exist; each element takes the same name/file/quant keys as
        // [model].
        for name in doc.array_names() {
            if name != "tenant" {
                return Err(invalid(format!("unknown array of tables `[[{name}]]`")));
            }
        }
        const TENANT_KEYS: &[&str] = &["name", "file", "quant"];
        let mut tenants = Vec::with_capacity(doc.array_len("tenant"));
        for i in 0..doc.array_len("tenant") {
            for k in doc.array_keys("tenant", i) {
                if !TENANT_KEYS.contains(&k) {
                    return Err(invalid(format!(
                        "unknown key `tenant[{i}].{k}` (expected one of: {})",
                        TENANT_KEYS.join(", ")
                    )));
                }
            }
            let model = match (
                doc.array_get("tenant", i, "name"),
                doc.array_get("tenant", i, "file"),
            ) {
                (Some(_), None) => {
                    let name =
                        doc.try_array_str_or("tenant", i, "name", "").map_err(invalid)?;
                    ModelSource::Zoo(name.to_string())
                }
                (None, Some(_)) => {
                    let path =
                        doc.try_array_str_or("tenant", i, "file", "").map_err(invalid)?;
                    ModelSource::File(path.to_string())
                }
                (Some(_), Some(_)) => {
                    return Err(invalid(format!(
                        "tenant[{i}]: give either `name` or `file`, not both"
                    )))
                }
                (None, None) => {
                    return Err(invalid(format!("tenant[{i}]: missing `name` or `file`")))
                }
            };
            let ql = doc.try_array_str_or("tenant", i, "quant", "w8a8").map_err(invalid)?;
            let quant = Quant::parse(ql)
                .ok_or_else(|| invalid(format!("bad tenant[{i}].quant `{ql}`")))?;
            tenants.push(TenantSpec { model, quant });
        }

        // [model] — mutually exclusive with [[tenant]]; a co-located spec's
        // primary model mirrors its first tenant.
        let (model, quant) = if tenants.is_empty() {
            let model = match (doc.get("model", "name"), doc.get("model", "file")) {
                (Some(_), None) => {
                    let name = doc.try_str_or("model", "name", "").map_err(invalid)?;
                    ModelSource::Zoo(name.to_string())
                }
                (None, Some(_)) => {
                    let path = doc.try_str_or("model", "file", "").map_err(invalid)?;
                    ModelSource::File(path.to_string())
                }
                (Some(_), Some(_)) => {
                    return Err(invalid("model: give either `name` or `file`, not both"))
                }
                (None, None) => {
                    return Err(invalid("missing [model] name/file (or [[tenant]] tenants)"))
                }
            };
            let quant_label = doc.try_str_or("model", "quant", "w8a8").map_err(invalid)?;
            let quant = Quant::parse(quant_label)
                .ok_or_else(|| invalid(format!("bad model.quant `{quant_label}`")))?;
            (model, quant)
        } else {
            if doc.has_section("model") {
                return Err(invalid("give either [model] or [[tenant]] tenants, not both"));
            }
            (tenants[0].model.clone(), tenants[0].quant)
        };

        // [fleet] — present = fleet placement of the model set over the
        // whole device pool (the only spec shape where [[tenant]] combines
        // with a `devices` chain).
        let fleet = if doc.has_section("fleet") {
            let label = doc
                .try_str_or("fleet", "objective", "max_aggregate_throughput")
                .map_err(invalid)?;
            let objective = match label {
                "max_aggregate_throughput" => {
                    if doc.get("fleet", "slo_p99_ms").is_some() {
                        return Err(invalid(
                            "fleet.slo_p99_ms applies to objective = \"min_devices_at_slo\" only",
                        ));
                    }
                    FleetObjective::MaxAggregateThroughput
                }
                "min_devices_at_slo" => {
                    if doc.get("fleet", "slo_p99_ms").is_none() {
                        return Err(invalid(
                            "fleet.objective = \"min_devices_at_slo\" requires fleet.slo_p99_ms",
                        ));
                    }
                    let p99_ms =
                        doc.try_float_or("fleet", "slo_p99_ms", 0.0).map_err(invalid)?;
                    if p99_ms <= 0.0 {
                        return Err(invalid(format!(
                            "fleet.slo_p99_ms {p99_ms} must be positive"
                        )));
                    }
                    FleetObjective::MinDevicesAtSlo { p99_ms }
                }
                other => {
                    return Err(invalid(format!(
                        "fleet.objective `{other}` is not `max_aggregate_throughput` or \
                         `min_devices_at_slo`"
                    )))
                }
            };
            Some(FleetSpec { objective })
        } else {
            None
        };

        // [device] — either a single `name` or a `devices` chain
        let mut devices = match doc.get("device", "devices") {
            None => {
                let dev_name = doc.try_str_or("device", "name", "zcu102").map_err(invalid)?;
                vec![Device::by_name(dev_name)
                    .ok_or_else(|| invalid(format!("unknown device `{dev_name}`")))?]
            }
            Some(v) => {
                if doc.get("device", "name").is_some() {
                    return Err(invalid("device: give either `name` or `devices`, not both"));
                }
                let arr = v
                    .as_array()
                    .ok_or_else(|| invalid("device.devices must be an array of names"))?;
                if arr.is_empty() {
                    return Err(invalid("device.devices must not be empty"));
                }
                let mut out = Vec::with_capacity(arr.len());
                for item in arr {
                    let name = item
                        .as_str()
                        .ok_or_else(|| invalid("device.devices entries must be strings"))?;
                    out.push(
                        Device::by_name(name)
                            .ok_or_else(|| invalid(format!("unknown device `{name}`")))?,
                    );
                }
                out
            }
        };
        if fleet.is_none() && !tenants.is_empty() && devices.len() > 1 {
            return Err(invalid(
                "co-location is single-device: give [device] name, not a devices chain \
                 (shard OR co-locate, not both — or add a [fleet] section to place the \
                 tenant set over the pool)",
            ));
        }
        let mem_scale = doc.try_float_or("device", "mem_scale", 1.0).map_err(invalid)?;
        if !(0.01..=10.0).contains(&mem_scale) {
            return Err(invalid(format!("device.mem_scale {mem_scale} out of range (0.01..10)")));
        }
        if (mem_scale - 1.0).abs() > 1e-12 {
            for d in &mut devices {
                *d = d.with_mem_scale(mem_scale);
            }
        }

        // [dse]
        let phi = doc.try_int_or("dse", "phi", 1).map_err(invalid)?;
        let mu = doc.try_int_or("dse", "mu", 512).map_err(invalid)?;
        let bw_margin = doc.try_float_or("dse", "bw_margin", 0.90).map_err(invalid)?;
        if phi < 1 || phi > 1024 {
            return Err(invalid(format!("dse.phi {phi} out of range (1..1024)")));
        }
        if mu < 1 {
            return Err(invalid(format!("dse.mu {mu} must be >= 1")));
        }
        if !(0.1..=1.0).contains(&bw_margin) {
            return Err(invalid(format!("dse.bw_margin {bw_margin} out of range (0.1..1.0)")));
        }
        let dse = DseConfig::default()
            .with_phi(phi as u32)
            .with_mu(mu as u64)
            .with_batch(doc.try_int_or("dse", "batch", 1).map_err(invalid)?.max(1) as u64)
            .with_streaming(!doc.try_bool_or("dse", "vanilla", false).map_err(invalid)?)
            .with_bw_margin(bw_margin)
            .with_warm_start(doc.try_bool_or("dse", "warm_start", false).map_err(invalid)?);

        // [sim]
        let sim_batch = doc.try_int_or("sim", "batch", 1).map_err(invalid)?.max(1) as u64;

        // [serve]
        // The PJRT artifact path is single-device and single-model; sharded
        // runs serve the sim-only chain and co-located runs serve one
        // sim-only engine per tenant, so an explicit artifact there is a
        // spec error (mirrors the CLI's --artifact/--devices rejection).
        if fleet.is_some() && doc.get("serve", "artifact").is_some() {
            return Err(invalid(
                "serve.artifact is single-model; fleet runs serve sim-only engines behind \
                 the router (drop the key)",
            ));
        }
        if devices.len() > 1 && doc.get("serve", "artifact").is_some() {
            return Err(invalid(
                "serve.artifact is single-device; sharded runs serve the sim-only chain (drop the key)",
            ));
        }
        if !tenants.is_empty() && doc.get("serve", "artifact").is_some() {
            return Err(invalid(
                "serve.artifact is single-model; co-located runs serve one sim-only engine \
                 per tenant (drop the key)",
            ));
        }
        let serve = if doc.has_section("serve") {
            let artifact = doc
                .try_str_or("serve", "artifact", "artifacts/toy_cnn_b8.hlo.txt")
                .map_err(invalid)?;
            let requests = doc.try_int_or("serve", "requests", 64).map_err(invalid)?;
            let max_batch = doc.try_int_or("serve", "max_batch", 8).map_err(invalid)?;
            let max_wait_ms = doc.try_int_or("serve", "max_wait_ms", 2).map_err(invalid)?;
            let workers = doc.try_int_or("serve", "workers", 1).map_err(invalid)?;
            let dispatch_shards =
                doc.try_int_or("serve", "dispatch_shards", 0).map_err(invalid)?;
            if requests < 1 || max_batch < 1 || max_wait_ms < 0 {
                return Err(invalid("serve: requests/max_batch must be >= 1, max_wait_ms >= 0"));
            }
            if !(1..=64).contains(&workers) {
                return Err(invalid(format!("serve.workers {workers} out of range (1..64)")));
            }
            if !(0..=64).contains(&dispatch_shards) {
                return Err(invalid(format!(
                    "serve.dispatch_shards {dispatch_shards} out of range (0..64, 0 = auto)"
                )));
            }
            Some(ServeSpec {
                artifact: artifact.to_string(),
                requests: requests as usize,
                max_batch: max_batch as usize,
                max_wait_ms: max_wait_ms as u64,
                workers: workers as usize,
                dispatch_shards: dispatch_shards as usize,
            })
        } else {
            None
        };

        // [telemetry]
        let telemetry = {
            let enabled = doc.try_bool_or("telemetry", "enabled", true).map_err(invalid)?;
            let opt_str = |key: &str| -> Result<Option<String>, ConfigError> {
                match doc.get("telemetry", key) {
                    None => Ok(None),
                    Some(_) => Ok(Some(
                        doc.try_str_or("telemetry", key, "").map_err(invalid)?.to_string(),
                    )),
                }
            };
            let metrics_out = opt_str("metrics_out")?;
            let trace_out = opt_str("trace_out")?;
            let stats_interval_s = match doc.get("telemetry", "stats_interval_s") {
                None => None,
                Some(_) => {
                    let secs = doc
                        .try_float_or("telemetry", "stats_interval_s", 0.0)
                        .map_err(invalid)?;
                    if !secs.is_finite() || secs <= 0.0 {
                        return Err(invalid(format!(
                            "telemetry.stats_interval_s {secs} must be positive"
                        )));
                    }
                    Some(secs)
                }
            };
            if !enabled && trace_out.is_some() {
                return Err(invalid(
                    "telemetry.trace_out needs span recording: drop the key or set \
                     telemetry.enabled = true (the trace would be empty)",
                ));
            }
            if (metrics_out.is_some() || trace_out.is_some() || stats_interval_s.is_some())
                && !doc.has_section("serve")
            {
                return Err(invalid(
                    "telemetry outputs describe the serving session: add a [serve] section \
                     or drop the output keys",
                ));
            }
            TelemetrySpec { enabled, metrics_out, trace_out, stats_interval_s }
        };

        // device.mem_sweep = [0.5, 1.0, ...]
        let mem_sweep = match doc.get("device", "mem_sweep") {
            None => Vec::new(),
            Some(v) => {
                let arr = v.as_array().ok_or_else(|| invalid("device.mem_sweep must be an array"))?;
                let mut out = Vec::with_capacity(arr.len());
                for item in arr {
                    let f = item
                        .as_float()
                        .ok_or_else(|| invalid("device.mem_sweep entries must be numbers"))?;
                    if !(0.01..=10.0).contains(&f) {
                        return Err(invalid(format!("mem_sweep scale {f} out of range")));
                    }
                    out.push(f);
                }
                out
            }
        };

        Ok(RunSpec {
            title,
            model,
            quant,
            devices,
            tenants,
            fleet,
            dse,
            sim_batch,
            serve,
            mem_sweep,
            telemetry,
        })
    }

    /// The primary device — the single-device pipeline target
    /// (`devices[0]`; sharded specs use the whole [`RunSpec::devices`]).
    pub fn device(&self) -> &Device {
        &self.devices[0]
    }

    /// Is this spec a sharded (multi-device) deployment?
    pub fn is_sharded(&self) -> bool {
        self.devices.len() > 1
    }

    /// Is this spec a co-located (multi-tenant) deployment?
    pub fn is_colocated(&self) -> bool {
        !self.tenants.is_empty()
    }

    /// Is this spec a fleet placement (`[fleet]` section present)?
    pub fn is_fleet(&self) -> bool {
        self.fleet.is_some()
    }

    /// Load a spec from a file path.
    pub fn from_file(path: &str) -> Result<RunSpec, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| invalid(format!("cannot read `{path}`: {e}")))?;
        RunSpec::from_str(&text)
    }

    /// Resolve the model source into a network (zoo lookup or `.net` file).
    pub fn build_network(&self) -> Result<Network, ConfigError> {
        match &self.model {
            ModelSource::Zoo(name) => models::by_name(name, self.quant)
                .ok_or_else(|| invalid(format!("unknown zoo model `{name}`"))),
            ModelSource::File(path) => {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| invalid(format!("cannot read `{path}`: {e}")))?;
                crate::ir::parse_network(&text, self.quant)
                    .map_err(|e| invalid(format!("{path}: {e}")))
            }
        }
    }

    /// The one place a [`ModelSource`] + quantization becomes a pipeline
    /// stage-0 builder (single-model and per-tenant paths both route here).
    fn deployment_for(model: &ModelSource, quant: Quant) -> crate::pipeline::Deployment {
        match model {
            ModelSource::Zoo(name) => crate::pipeline::Deployment::for_model(name),
            ModelSource::File(path) => crate::pipeline::Deployment::for_net_file(path),
        }
        .quant(quant)
    }

    fn deployment(&self) -> crate::pipeline::Deployment {
        Self::deployment_for(&self.model, self.quant)
    }

    /// Resolve the spec's model and (budget-scaled) device into a pipeline
    /// [`Planned`](crate::pipeline::Planned) stage.
    pub fn plan(&self) -> Result<crate::pipeline::Planned, crate::Error> {
        self.deployment().on_device(self.device().clone())
    }

    /// Resolve the spec's model and device chain into a pipeline
    /// [`PartitionedPlanned`](crate::pipeline::PartitionedPlanned) stage.
    pub fn plan_sharded(&self) -> Result<crate::pipeline::PartitionedPlanned, crate::Error> {
        self.deployment().on_devices(&self.devices)
    }

    /// Resolve the spec's tenant list and shared device into a pipeline
    /// [`ColocatedPlanned`](crate::pipeline::ColocatedPlanned) stage.
    pub fn plan_colocated(&self) -> Result<crate::pipeline::ColocatedPlanned, crate::Error> {
        let tenants: Vec<crate::pipeline::Deployment> =
            self.tenants.iter().map(|t| Self::deployment_for(&t.model, t.quant)).collect();
        crate::pipeline::Deployment::colocate(tenants).on_device(self.device().clone())
    }

    /// Resolve the spec's model set (tenants, or the single `[model]`) and
    /// device pool into a pipeline
    /// [`FleetPlanned`](crate::pipeline::FleetPlanned) stage with the
    /// `[fleet]` objective applied.
    pub fn plan_fleet(&self) -> Result<crate::pipeline::FleetPlanned, crate::Error> {
        let models: Vec<crate::pipeline::Deployment> = if self.tenants.is_empty() {
            vec![self.deployment()]
        } else {
            self.tenants.iter().map(|t| Self::deployment_for(&t.model, t.quant)).collect()
        };
        let planned = crate::pipeline::Deployment::fleet(models, &self.devices)?;
        Ok(match &self.fleet {
            Some(f) => planned.with_objective(f.objective),
            None => planned,
        })
    }

    /// Spawn the `[telemetry]` periodic stderr reporter, when configured.
    fn start_stats(
        &self,
        handles: Vec<crate::coordinator::MetricsHandle>,
    ) -> Option<crate::telemetry::StatsReporter> {
        self.telemetry.stats_interval_s.map(|secs| {
            crate::telemetry::StatsReporter::start(
                handles,
                std::time::Duration::from_secs_f64(secs),
            )
        })
    }

    /// Write the `[telemetry]` output files from the final serving snapshot
    /// (metrics format by extension, spans as Chrome trace-event JSON).
    fn emit_telemetry(
        &self,
        t: &crate::telemetry::TelemetrySnapshot,
    ) -> Result<(), crate::Error> {
        if let Some(path) = &self.telemetry.metrics_out {
            let text = if path.ends_with(".json") {
                crate::telemetry::json_snapshot(t)
            } else {
                crate::telemetry::prometheus_text(t)
            };
            std::fs::write(path, text)
                .map_err(|source| crate::Error::Io { path: path.clone(), source })?;
            println!("  metrics written to {path}");
        }
        if let Some(path) = &self.telemetry.trace_out {
            let text = crate::telemetry::chrome_trace_spans(&t.spans);
            std::fs::write(path, text)
                .map_err(|source| crate::Error::Io { path: path.clone(), source })?;
            println!("  span trace written to {path}");
        }
        Ok(())
    }

    /// Execute the full run this spec describes — DSE, simulation, the
    /// optional memory sweep, the optional serving session — printing the
    /// launcher's progress report to stdout. This is `autows run`.
    pub fn execute(&self) -> Result<(), crate::Error> {
        use crate::coordinator::{BatchPolicy, ServerOptions};
        use crate::pipeline::{self, EngineSpec};
        use crate::sim::SimConfig;

        if self.is_fleet() {
            return self.execute_fleet();
        }
        if self.is_colocated() {
            return self.execute_colocated();
        }
        if self.is_sharded() {
            return self.execute_sharded();
        }

        let plan = self.plan()?;
        println!("== {} ==", self.title);
        let s = plan.network().stats();
        println!(
            "model {} ({}): {} layers, {:.2}M params, {:.2}G MACs on {}",
            plan.network().name,
            self.quant,
            s.total_layers,
            s.params as f64 / 1e6,
            s.macs as f64 / 1e9,
            self.device().name
        );

        // DSE (through the design cache; sweep/serve below reuse the entry)
        let explored = match plan.clone().explore(&self.dse) {
            Err(e) if e.is_infeasible() => {
                println!("DSE: INFEASIBLE (vanilla={})", !self.dse.allow_streaming);
                return Ok(());
            }
            other => other?,
        };
        let r = explored.result();
        println!(
            "DSE: θ={:.1} fps, latency={:.2} ms, mem {:.0}%, bw {:.2}/{:.2} Gbps, {} streaming layers",
            r.throughput,
            r.latency_ms,
            r.area.mem_utilization(self.device()) * 100.0,
            r.bandwidth_bps / 1e9,
            self.device().bandwidth_gbps(),
            r.design.streaming_count()
        );

        // Simulation
        let scheduled = explored.schedule_for_batch(self.sim_batch);
        let sim = scheduled.simulate(&SimConfig { batch: self.sim_batch, ..Default::default() });
        println!(
            "sim (batch={}): makespan={:.3} ms, stalls={:.1} us, DMA busy {:.0}%",
            self.sim_batch,
            sim.makespan_s * 1e3,
            sim.total_stall_s * 1e6,
            sim.dma_busy_frac * 100.0
        );

        // Optional memory sweep (cache-aware, fanned across cores)
        if !self.mem_sweep.is_empty() {
            println!("mem sweep (A_mem scale -> fps):");
            for (scale, fps) in pipeline::sweep::mem_sweep_points(&plan, &self.mem_sweep, &self.dse)
            {
                match fps {
                    None => println!("  {scale:>5.2}x  infeasible"),
                    Some(fps) => println!("  {scale:>5.2}x  {fps:.1} fps"),
                }
            }
        }

        // Optional serving session
        if let Some(serve) = &self.serve {
            println!("serving {} requests (max batch {}):", serve.requests, serve.max_batch);
            // the bundled artifacts are lowered for the toy CNN's 3x32x32
            // input; the engine pads/validates against this shape
            let (c, h, w) = (3usize, 32, 32);
            let server = scheduled
                .clone()
                .with_engine(EngineSpec::Pjrt {
                    artifact: serve.artifact.clone(),
                    input_shape: (c, h, w),
                    artifact_batch: serve.max_batch,
                })
                .serve(
                    BatchPolicy {
                        max_batch: serve.max_batch,
                        max_wait: std::time::Duration::from_millis(serve.max_wait_ms),
                    },
                    ServerOptions {
                        workers: serve.workers,
                        dispatch_shards: serve.dispatch_shards,
                        telemetry: self.telemetry.enabled,
                        ..Default::default()
                    },
                )?;
            let stats = self.start_stats(vec![server.metrics_handle()]);
            crate::pipeline::drive_synthetic(&server, serve.requests, c * h * w)?;
            let m = server.metrics();
            println!(
                "  throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
                m.throughput_rps, m.p50_ms, m.p99_ms, m.mean_batch
            );
            if let Some(s) = stats {
                s.stop();
            }
            self.emit_telemetry(&server.telemetry())?;
            server.shutdown();
        }
        Ok(())
    }

    /// The fleet launcher path: the placement search over the device pool,
    /// the placement table, per-placement simulation and (optionally) a
    /// serving session routing every model through one router. `mem_sweep`
    /// is single-model-only and skipped here.
    fn execute_fleet(&self) -> Result<(), crate::Error> {
        use crate::coordinator::{BatchPolicy, ServerOptions};
        use crate::sim::SimConfig;

        let plan = self.plan_fleet()?;
        println!("== {} ==", self.title);
        let names: Vec<&str> = plan.networks().iter().map(|n| n.name.as_str()).collect();
        let pool: Vec<&str> = plan.devices().iter().map(|d| d.name).collect();
        println!(
            "{} models [{}] fleet-placed over [{}]",
            names.len(),
            names.join(", "),
            pool.join(", ")
        );

        let explored = match plan.explore(&self.dse) {
            Err(e) if e.is_infeasible() => {
                println!(
                    "DSE: INFEASIBLE for the fleet (vanilla={})",
                    !self.dse.allow_streaming
                );
                return Ok(());
            }
            other => other?,
        };
        let scheduled = explored.schedule_for_batch(self.sim_batch);
        print!("{}", scheduled.report());

        let sim = scheduled.simulate(&SimConfig { batch: self.sim_batch, ..Default::default() });
        println!(
            "sim (batch={}): fleet makespan={:.3} ms, stalls={:.1} us",
            self.sim_batch,
            sim.makespan_s * 1e3,
            sim.total_stall_s * 1e6
        );

        if !self.mem_sweep.is_empty() {
            println!("mem sweep: skipped (single-model only)");
        }

        if let Some(serve) = &self.serve {
            println!(
                "serving {} requests per model through the fleet router (max batch {}):",
                serve.requests, serve.max_batch
            );
            let router = scheduled.serve(
                BatchPolicy {
                    max_batch: serve.max_batch,
                    max_wait: std::time::Duration::from_millis(serve.max_wait_ms),
                },
                ServerOptions {
                    workers: serve.workers,
                    dispatch_shards: serve.dispatch_shards,
                    telemetry: self.telemetry.enabled,
                    ..Default::default()
                },
            )?;
            let stats = self
                .start_stats(router.metrics_handles().into_iter().map(|(_, h)| h).collect());
            for name in scheduled.model_names() {
                let input_len =
                    scheduled.input_len(name).expect("names come from the plan itself");
                let mut pending = Vec::with_capacity(serve.requests);
                for _ in 0..serve.requests {
                    pending.push(router.submit(name, vec![0.5; input_len])?);
                }
                for rx in pending {
                    rx.recv().map_err(|_| {
                        crate::Error::Serve("router: reply channel dropped".to_string())
                    })??;
                }
                let m = router.model_metrics(name).expect("routed above");
                println!(
                    "  {name}: throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, \
                     mean batch {:.1}",
                    m.throughput_rps, m.p50_ms, m.p99_ms, m.mean_batch
                );
            }
            if let Some(s) = stats {
                s.stop();
            }
            self.emit_telemetry(&router.telemetry())?;
            router.shutdown();
        }
        Ok(())
    }

    /// The co-located launcher path: joint budget search + per-tenant DSE,
    /// the multi-tenant report, the shared-port simulation and (optionally)
    /// a serving session answering every tenant from one registry.
    /// `mem_sweep` is single-model-only and skipped here.
    fn execute_colocated(&self) -> Result<(), crate::Error> {
        use crate::coordinator::{BatchPolicy, ServerOptions};
        use crate::sim::SimConfig;

        let plan = self.plan_colocated()?;
        println!("== {} ==", self.title);
        let names: Vec<&str> = plan.networks().iter().map(|n| n.name.as_str()).collect();
        println!(
            "{} tenants [{}] co-located on {}",
            names.len(),
            names.join(", "),
            self.device().name
        );

        let explored = match plan.explore(&self.dse) {
            Err(e) if e.is_infeasible() => {
                println!(
                    "DSE: INFEASIBLE for the joint tenant set (vanilla={})",
                    !self.dse.allow_streaming
                );
                return Ok(());
            }
            other => other?,
        };
        let scheduled = explored.schedule_for_batch(self.sim_batch);
        print!("{}", scheduled.report());

        let sim = scheduled.simulate(&SimConfig { batch: self.sim_batch, ..Default::default() });
        println!(
            "sim (batch={}): makespan={:.3} ms, stalls={:.1} us, port busy {:.0}%, {} events",
            self.sim_batch,
            sim.makespan_s * 1e3,
            sim.total_stall_s * 1e6,
            sim.port_busy_frac * 100.0,
            sim.events
        );

        if !self.mem_sweep.is_empty() {
            println!("mem sweep: skipped (single-model only)");
        }

        if let Some(serve) = &self.serve {
            println!(
                "serving {} requests per tenant ({} tenants, max batch {}):",
                serve.requests,
                scheduled.tenants().len(),
                serve.max_batch
            );
            let registry = scheduled.serve(
                BatchPolicy {
                    max_batch: serve.max_batch,
                    max_wait: std::time::Duration::from_millis(serve.max_wait_ms),
                },
                ServerOptions {
                    workers: serve.workers,
                    dispatch_shards: serve.dispatch_shards,
                    telemetry: self.telemetry.enabled,
                    ..Default::default()
                },
            )?;
            let stats = self
                .start_stats(registry.metrics_handles().into_iter().map(|(_, h)| h).collect());
            for name in scheduled.tenant_names() {
                let input_len =
                    scheduled.input_len(name).expect("names come from the plan itself");
                crate::pipeline::drive_synthetic_tenant(
                    &registry,
                    name,
                    serve.requests,
                    input_len,
                )?;
                let m = registry.metrics(name).expect("registered above");
                println!(
                    "  {name}: throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
                    m.throughput_rps, m.p50_ms, m.p99_ms, m.mean_batch
                );
            }
            if let Some(s) = stats {
                s.stop();
            }
            self.emit_telemetry(&registry.telemetry())?;
            registry.shutdown();
        }
        Ok(())
    }

    /// The sharded launcher path: cut search + per-partition DSE, the
    /// partitioned report, the chain simulation and (optionally) the chained
    /// serving session. `mem_sweep` is single-device-only and skipped here.
    fn execute_sharded(&self) -> Result<(), crate::Error> {
        use crate::coordinator::{BatchPolicy, ServerOptions};
        use crate::sim::SimConfig;

        let plan = self.plan_sharded()?;
        println!("== {} ==", self.title);
        let s = plan.network().stats();
        let chain: Vec<&str> = self.devices.iter().map(|d| d.name).collect();
        println!(
            "model {} ({}): {} layers, {:.2}M params, {:.2}G MACs sharded across [{}]",
            plan.network().name,
            self.quant,
            s.total_layers,
            s.params as f64 / 1e6,
            s.macs as f64 / 1e9,
            chain.join(", ")
        );

        let explored = match plan.explore(&self.dse) {
            Err(e) if e.is_infeasible() => {
                println!("DSE: INFEASIBLE on every cut (vanilla={})", !self.dse.allow_streaming);
                return Ok(());
            }
            other => other?,
        };
        let scheduled = explored.schedule_for_batch(self.sim_batch);
        print!("{}", scheduled.report());

        let sim = scheduled.simulate(&SimConfig { batch: self.sim_batch, ..Default::default() });
        println!(
            "sim (batch={}): makespan={:.3} ms, stalls={:.1} us, steady period={:.2} us, \
             bottleneck={:?}",
            self.sim_batch,
            sim.makespan_s * 1e3,
            sim.total_stall_s * 1e6,
            sim.steady_period_s * 1e6,
            sim.bottleneck
        );

        if !self.mem_sweep.is_empty() {
            println!("mem sweep: skipped (single-device only)");
        }

        if let Some(serve) = &self.serve {
            println!(
                "serving {} requests through the {}-partition chain (max batch {}):",
                serve.requests,
                self.devices.len(),
                serve.max_batch
            );
            let server = scheduled.serve(
                BatchPolicy {
                    max_batch: serve.max_batch,
                    max_wait: std::time::Duration::from_millis(serve.max_wait_ms),
                },
                ServerOptions {
                    workers: serve.workers,
                    dispatch_shards: serve.dispatch_shards,
                    telemetry: self.telemetry.enabled,
                    ..Default::default()
                },
            )?;
            let stats = self.start_stats(vec![server.metrics_handle()]);
            crate::pipeline::drive_synthetic(&server, serve.requests, scheduled.input_len())?;
            let m = server.metrics();
            println!(
                "  throughput {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
                m.throughput_rps, m.p50_ms, m.p99_ms, m.mean_batch
            );
            if let Some(s) = stats {
                s.stop();
            }
            self.emit_telemetry(&server.telemetry())?;
            server.shutdown();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
title = "resnet18 on zcu102"
[model]
name  = "resnet18"
quant = "w4a5"
[device]
name      = "zcu102"
mem_scale = 0.8
mem_sweep = [0.5, 1.0, 1.5]
[dse]
phi     = 2
mu      = 256
vanilla = false
[sim]
batch = 8
[serve]
artifact  = "artifacts/toy_cnn_b8.hlo.txt"
requests  = 32
max_batch = 4
workers   = 2
dispatch_shards = 2
"#;

    #[test]
    fn full_spec_roundtrip() {
        let s = RunSpec::from_str(FULL).unwrap();
        assert_eq!(s.title, "resnet18 on zcu102");
        assert_eq!(s.model, ModelSource::Zoo("resnet18".into()));
        assert_eq!(s.quant, Quant::W4A5);
        assert_eq!(s.device().name, "zcu102");
        // mem_scale applied
        assert!(s.device().mem_bits() < Device::zcu102().mem_bits());
        assert_eq!(s.dse.phi, 2);
        assert_eq!(s.dse.mu, 256);
        assert!(s.dse.allow_streaming);
        assert_eq!(s.sim_batch, 8);
        let serve = s.serve.unwrap();
        assert_eq!(serve.requests, 32);
        assert_eq!(serve.max_batch, 4);
        assert_eq!(serve.workers, 2);
        assert_eq!(serve.dispatch_shards, 2);
        assert_eq!(s.mem_sweep, vec![0.5, 1.0, 1.5]);
    }

    #[test]
    fn serve_workers_defaults_and_bounds() {
        // absent keys -> single-worker server, auto-sized shards
        let s = RunSpec::from_str("[model]\nname = \"toy\"\n[serve]\nrequests = 8").unwrap();
        let serve = s.serve.unwrap();
        assert_eq!(serve.workers, 1);
        assert_eq!(serve.dispatch_shards, 0, "0 = auto-size from the pool");
        // zero and absurd pool sizes are spec errors, not silent clamps
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[serve]\nworkers = 0")
            .unwrap_err();
        assert!(e.to_string().contains("workers"), "{e}");
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[serve]\nworkers = 1000")
            .unwrap_err();
        assert!(e.to_string().contains("out of range"), "{e}");
        // dispatch_shards = 0 is the explicit auto value, not an error …
        let s =
            RunSpec::from_str("[model]\nname = \"toy\"\n[serve]\ndispatch_shards = 0").unwrap();
        assert_eq!(s.serve.unwrap().dispatch_shards, 0);
        // … but out-of-range pins are rejected like workers
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[serve]\ndispatch_shards = 1000")
            .unwrap_err();
        assert!(e.to_string().contains("dispatch_shards"), "{e}");
        // a typo'd key is rejected with alternatives, as everywhere else
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[serve]\nworker = 2").unwrap_err();
        assert!(e.to_string().contains("unknown key"), "{e}");
    }

    #[test]
    fn minimal_spec_uses_defaults() {
        let s = RunSpec::from_str("[model]\nname = \"toy\"").unwrap();
        assert_eq!(s.quant, Quant::W8A8);
        assert_eq!(s.device().name, "zcu102");
        assert_eq!(s.dse.phi, 1);
        assert!(s.serve.is_none());
        assert!(s.mem_sweep.is_empty());
        let net = s.build_network().unwrap();
        assert_eq!(net.name, "toy_cnn");
    }

    #[test]
    fn device_chain_parses_and_scales() {
        let s = RunSpec::from_str(
            "[model]\nname = \"resnet50\"\n[device]\ndevices = [\"zcu102\", \"zcu102\"]\nmem_scale = 0.5",
        )
        .unwrap();
        assert!(s.is_sharded());
        assert_eq!(s.devices.len(), 2);
        assert_eq!(s.device().name, "zcu102");
        // mem_scale applies to every device in the chain
        for d in &s.devices {
            assert!(d.mem_bits() < Device::zcu102().mem_bits());
        }
        let plan = s.plan_sharded().unwrap();
        assert_eq!(plan.devices().len(), 2);
    }

    #[test]
    fn device_chain_conflicts_and_errors() {
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[device]\nname = \"zcu102\"\ndevices = [\"zcu102\"]",
        )
        .unwrap_err();
        assert!(e.to_string().contains("not both"), "{e}");
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[device]\ndevices = []").unwrap_err();
        assert!(e.to_string().contains("not be empty"), "{e}");
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[device]\ndevices = [\"nope\"]")
            .unwrap_err();
        assert!(e.to_string().contains("unknown device"), "{e}");
        // PJRT artifact serving is single-device; sharded specs must not
        // silently fall back to checksum numerics
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[device]\ndevices = [\"zcu102\", \"zcu102\"]\n\
             [serve]\nartifact = \"x.hlo.txt\"",
        )
        .unwrap_err();
        assert!(e.to_string().contains("single-device"), "{e}");
        // a sharded [serve] without an artifact is fine (sim-only chain)
        let s = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[device]\ndevices = [\"zcu102\", \"zcu102\"]\n\
             [serve]\nrequests = 8",
        )
        .unwrap();
        assert!(s.serve.is_some());
    }

    #[test]
    fn tenant_array_parses_into_a_colocated_spec() {
        let s = RunSpec::from_str(
            "[device]\nname = \"zcu102\"\n\
             [[tenant]]\nname = \"resnet18\"\nquant = \"w4a5\"\n\
             [[tenant]]\nname = \"squeezenet\"\n",
        )
        .unwrap();
        assert!(s.is_colocated());
        assert!(!s.is_sharded());
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.tenants[0].model, ModelSource::Zoo("resnet18".into()));
        assert_eq!(s.tenants[0].quant, Quant::W4A5);
        assert_eq!(s.tenants[1].quant, Quant::W8A8, "tenant quant defaults to w8a8");
        // the primary model mirrors tenant 0 (devices[0] symmetry)
        assert_eq!(s.model, s.tenants[0].model);
        assert_eq!(s.quant, Quant::W4A5);
        let plan = s.plan_colocated().unwrap();
        assert_eq!(plan.networks().len(), 2);
        assert_eq!(plan.device().name, "zcu102");
    }

    #[test]
    fn tenant_array_conflicts_and_errors() {
        // [model] and [[tenant]] are mutually exclusive
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[[tenant]]\nname = \"toy\"",
        )
        .unwrap_err();
        assert!(e.to_string().contains("not both"), "{e}");
        // co-location is single-device
        let e = RunSpec::from_str(
            "[device]\ndevices = [\"zcu102\", \"zcu102\"]\n[[tenant]]\nname = \"toy\"",
        )
        .unwrap_err();
        assert!(e.to_string().contains("single-device"), "{e}");
        // a per-tenant artifact cannot exist
        let e = RunSpec::from_str(
            "[[tenant]]\nname = \"toy\"\n[serve]\nartifact = \"x.hlo.txt\"",
        )
        .unwrap_err();
        assert!(e.to_string().contains("single-model"), "{e}");
        // unknown arrays and keys are rejected with the path
        let e = RunSpec::from_str("[[tenent]]\nname = \"toy\"").unwrap_err();
        assert!(e.to_string().contains("[[tenent]]"), "{e}");
        let e = RunSpec::from_str("[[tenant]]\nnome = \"toy\"").unwrap_err();
        assert!(e.to_string().contains("tenant[0].nome"), "{e}");
        // each tenant needs a model source, exactly one way
        let e = RunSpec::from_str("[[tenant]]\nquant = \"w8a8\"").unwrap_err();
        assert!(e.to_string().contains("missing"), "{e}");
        let e = RunSpec::from_str("[[tenant]]\nname = \"toy\"\nfile = \"x.net\"").unwrap_err();
        assert!(e.to_string().contains("not both"), "{e}");
        let e = RunSpec::from_str("[[tenant]]\nname = \"toy\"\nquant = \"w9z9\"").unwrap_err();
        assert!(e.to_string().contains("tenant[0].quant"), "{e}");
        // a colocated spec still accepts [serve] without an artifact
        let s = RunSpec::from_str(
            "[[tenant]]\nname = \"toy\"\n[serve]\nrequests = 4",
        )
        .unwrap();
        assert!(s.serve.is_some());
        assert!(s.is_colocated());
    }

    #[test]
    fn fleet_section_parses_over_a_device_pool() {
        let s = RunSpec::from_str(
            "[device]\ndevices = [\"zcu102\", \"zc706\"]\n\
             [[tenant]]\nname = \"resnet18\"\nquant = \"w4a5\"\n\
             [[tenant]]\nname = \"squeezenet\"\n\
             [fleet]\nobjective = \"min_devices_at_slo\"\nslo_p99_ms = 50.0",
        )
        .unwrap();
        assert!(s.is_fleet());
        assert_eq!(
            s.fleet.as_ref().unwrap().objective,
            FleetObjective::MinDevicesAtSlo { p99_ms: 50.0 }
        );
        // tenants WITH a devices chain is legal here — fleet places the set
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.devices.len(), 2);
        let plan = s.plan_fleet().unwrap();
        assert_eq!(plan.networks().len(), 2);
        assert_eq!(plan.devices().len(), 2);
        assert_eq!(plan.objective(), FleetObjective::MinDevicesAtSlo { p99_ms: 50.0 });

        // the objective defaults to max aggregate throughput
        let s = RunSpec::from_str(
            "[device]\ndevices = [\"zcu102\", \"zc706\"]\n\
             [[tenant]]\nname = \"resnet18\"\n[[tenant]]\nname = \"squeezenet\"\n\
             [fleet]\nobjective = \"max_aggregate_throughput\"",
        )
        .unwrap();
        assert_eq!(
            s.fleet.as_ref().unwrap().objective,
            FleetObjective::MaxAggregateThroughput
        );
        // a single [model] over a pool is also a legal fleet
        let s = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[device]\ndevices = [\"zcu102\", \"zcu102\"]\n\
             [fleet]\nobjective = \"max_aggregate_throughput\"",
        )
        .unwrap();
        assert!(s.is_fleet());
        assert_eq!(s.plan_fleet().unwrap().networks().len(), 1);
    }

    #[test]
    fn fleet_section_conflicts_and_errors() {
        // min_devices_at_slo requires the SLO value
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[fleet]\nobjective = \"min_devices_at_slo\"",
        )
        .unwrap_err();
        assert!(e.to_string().contains("slo_p99_ms"), "{e}");
        // ... and the SLO key is meaningless under max aggregate
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[fleet]\nobjective = \"max_aggregate_throughput\"\n\
             slo_p99_ms = 50.0",
        )
        .unwrap_err();
        assert!(e.to_string().contains("min_devices_at_slo"), "{e}");
        // unknown objectives and non-positive SLOs are rejected
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[fleet]\nobjective = \"fastest\"")
            .unwrap_err();
        assert!(e.to_string().contains("fleet.objective"), "{e}");
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[fleet]\nobjective = \"min_devices_at_slo\"\n\
             slo_p99_ms = -1.0",
        )
        .unwrap_err();
        assert!(e.to_string().contains("positive"), "{e}");
        // a typo'd fleet key is rejected with alternatives
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[fleet]\nobjectve = \"agg\"")
            .unwrap_err();
        assert!(e.to_string().contains("unknown key"), "{e}");
        // fleet serving is router-fronted sim-only: no artifact
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[fleet]\nobjective = \"max_aggregate_throughput\"\n\
             [serve]\nartifact = \"x.hlo.txt\"",
        )
        .unwrap_err();
        assert!(e.to_string().contains("router"), "{e}");
        // without [fleet], tenants × devices chain stays rejected
        let e = RunSpec::from_str(
            "[device]\ndevices = [\"zcu102\", \"zcu102\"]\n[[tenant]]\nname = \"toy\"",
        )
        .unwrap_err();
        assert!(e.to_string().contains("single-device"), "{e}");
    }

    #[test]
    fn telemetry_section_parses_and_validates() {
        // defaults when absent: spans on, no outputs
        let s = RunSpec::from_str("[model]\nname = \"toy\"").unwrap();
        assert_eq!(s.telemetry, TelemetrySpec::default());
        assert!(s.telemetry.enabled);
        // the full section
        let s = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[serve]\nrequests = 8\n\
             [telemetry]\nmetrics_out = \"m.prom\"\ntrace_out = \"t.json\"\n\
             stats_interval_s = 2",
        )
        .unwrap();
        assert_eq!(s.telemetry.metrics_out.as_deref(), Some("m.prom"));
        assert_eq!(s.telemetry.trace_out.as_deref(), Some("t.json"));
        assert_eq!(s.telemetry.stats_interval_s, Some(2.0));
        assert!(s.telemetry.enabled);
        // spans off makes trace_out contradictory (the file would be empty)
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[serve]\nrequests = 8\n\
             [telemetry]\nenabled = false\ntrace_out = \"t.json\"",
        )
        .unwrap_err();
        assert!(e.to_string().contains("enabled"), "{e}");
        // ... but metrics_out stays legal: request metrics are always on
        let s = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[serve]\nrequests = 8\n\
             [telemetry]\nenabled = false\nmetrics_out = \"m.prom\"",
        )
        .unwrap();
        assert!(!s.telemetry.enabled);
        // outputs without a serving session are a spec error, not a no-op
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[telemetry]\nmetrics_out = \"m.prom\"",
        )
        .unwrap_err();
        assert!(e.to_string().contains("[serve]"), "{e}");
        // non-positive intervals and typo'd keys rejected
        let e = RunSpec::from_str(
            "[model]\nname = \"toy\"\n[serve]\nrequests = 8\n\
             [telemetry]\nstats_interval_s = 0",
        )
        .unwrap_err();
        assert!(e.to_string().contains("positive"), "{e}");
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[telemetry]\nenbled = true")
            .unwrap_err();
        assert!(e.to_string().contains("unknown key"), "{e}");
    }

    #[test]
    fn single_device_spec_is_not_sharded() {
        let s = RunSpec::from_str("[model]\nname = \"toy\"").unwrap();
        assert!(!s.is_sharded());
        assert_eq!(s.devices.len(), 1);
        assert_eq!(&s.devices[0], s.device());
    }

    #[test]
    fn unknown_section_rejected() {
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[dze]\nphi = 2").unwrap_err();
        assert!(e.to_string().contains("unknown section"), "{e}");
    }

    #[test]
    fn missing_model_rejected() {
        let e = RunSpec::from_str("title = \"x\"").unwrap_err();
        assert!(e.to_string().contains("missing [model]"), "{e}");
    }

    #[test]
    fn bad_quant_rejected() {
        let e = RunSpec::from_str("[model]\nname = \"toy\"\nquant = \"w3b7\"").unwrap_err();
        assert!(e.to_string().contains("quant"), "{e}");
    }

    #[test]
    fn name_and_file_conflict() {
        let e =
            RunSpec::from_str("[model]\nname = \"toy\"\nfile = \"x.net\"").unwrap_err();
        assert!(e.to_string().contains("not both"), "{e}");
    }

    #[test]
    fn custom_quant_pairs_accepted() {
        let s = RunSpec::from_str("[model]\nname = \"toy\"\nquant = \"w2a8\"").unwrap();
        assert_eq!(s.quant, Quant { w_bits: 2, a_bits: 8 });
    }

    #[test]
    fn wrong_type_names_key_and_expected_type() {
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[dse]\nphi = \"two\"").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("`dse.phi`"), "{msg}");
        assert!(msg.contains("expected integer"), "{msg}");
        assert!(msg.contains("string"), "{msg}");

        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[dse]\nvanilla = 1").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("`dse.vanilla`") && msg.contains("expected boolean"), "{msg}");

        let e = RunSpec::from_str("[model]\nname = 3").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("`model.name`") && msg.contains("expected string"), "{msg}");
    }

    #[test]
    fn unknown_key_rejected_with_alternatives() {
        let e = RunSpec::from_str("[model]\nname = \"toy\"\n[dse]\nphy = 2").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("unknown key `dse.phy`"), "{msg}");
        assert!(msg.contains("phi"), "alternatives must be listed: {msg}");
    }

    #[test]
    fn plan_resolves_model_and_device() {
        let s = RunSpec::from_str("[model]\nname = \"toy\"\n[device]\nname = \"zedboard\"")
            .unwrap();
        let plan = s.plan().unwrap();
        assert_eq!(plan.network().name, "toy_cnn");
        assert_eq!(plan.device().name, "zedboard");
    }

    #[test]
    fn out_of_range_hyperparameters() {
        for bad in [
            "[model]\nname = \"toy\"\n[dse]\nphi = 0",
            "[model]\nname = \"toy\"\n[dse]\nbw_margin = 1.5",
            "[model]\nname = \"toy\"\n[device]\nname = \"zcu102\"\nmem_scale = 100.0",
        ] {
            assert!(RunSpec::from_str(bad).is_err(), "{bad}");
        }
    }
}
