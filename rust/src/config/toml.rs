//! A self-contained parser for the TOML subset used by AutoWS run
//! configurations (this build is fully offline, so no external TOML crate).
//!
//! Supported grammar:
//!
//! ```text
//! # comment
//! [section]            # and [section.subsection]
//! key = "string"
//! key = 3              # integer
//! key = 3.25           # float
//! key = true | false
//! key = [1, 2, 3]      # homogeneous scalar arrays
//! [[name]]             # array of tables: each header appends one table
//! key = "per-element"
//! ```
//!
//! Everything the AutoWS launcher needs; deliberately *not* a full TOML
//! implementation (no dates, no inline tables, no multi-line strings).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    /// Human-readable type name for error messages.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
            Value::Array(_) => "array",
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`mu = 512` is a valid float).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// A parse failure with its 1-based line number.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, message: impl Into<String>) -> ParseError {
    ParseError { line, message: message.into() }
}

/// A parsed document: `section -> key -> value`, plus `name -> [table]` for
/// `[[name]]` arrays of tables. Keys outside any `[section]` header live in
/// the root section `""`. Dotted headers (`[a.b]`) are kept as the literal
/// section name `"a.b"`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
    arrays: BTreeMap<String, Vec<BTreeMap<String, Value>>>,
}

/// Where subsequent `key = value` lines land: a plain `[section]` table or
/// the latest element of a `[[name]]` array of tables.
enum Cursor {
    Section(String),
    ArrayElem(String),
}

impl Document {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Document, ParseError> {
        let mut doc = Document::default();
        let mut cursor = Cursor::Section(String::new());
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("[[") {
                let name = rest
                    .strip_suffix("]]")
                    .ok_or_else(|| err(lineno, "unterminated array-of-tables header"))?
                    .trim();
                check_section_name(name, lineno)?;
                if doc.sections.contains_key(name) {
                    return Err(err(
                        lineno,
                        format!("`[[{name}]]` conflicts with section `[{name}]`"),
                    ));
                }
                doc.arrays.entry(name.to_string()).or_default().push(BTreeMap::new());
                cursor = Cursor::ArrayElem(name.to_string());
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?
                    .trim();
                check_section_name(name, lineno)?;
                if doc.arrays.contains_key(name) {
                    return Err(err(
                        lineno,
                        format!("`[{name}]` conflicts with array of tables `[[{name}]]`"),
                    ));
                }
                doc.sections.entry(name.to_string()).or_default();
                cursor = Cursor::Section(name.to_string());
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| err(lineno, format!("expected `key = value`, got `{line}`")))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            if !key.chars().all(|c| c.is_ascii_alphanumeric() || "_-".contains(c)) {
                return Err(err(lineno, format!("invalid key `{key}`")));
            }
            let value = parse_value(value.trim(), lineno)?;
            let (table, place) = match &cursor {
                Cursor::Section(section) => (
                    doc.sections.entry(section.clone()).or_default(),
                    format!("section `[{section}]`"),
                ),
                Cursor::ArrayElem(name) => (
                    doc.arrays
                        .get_mut(name)
                        .and_then(|v| v.last_mut())
                        .expect("cursor points at the table its header just pushed"),
                    format!("this `[[{name}]]` element"),
                ),
            };
            if table.insert(key.to_string(), value).is_some() {
                return Err(err(lineno, format!("duplicate key `{key}` in {place}")));
            }
        }
        Ok(doc)
    }

    /// Names of all sections present (the root section only if it has keys).
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }

    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }

    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Keys of one section in sorted order.
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|t| t.keys().map(String::as_str).collect())
            .unwrap_or_default()
    }

    // --- arrays of tables (`[[name]]`) --------------------------------------

    /// Names of all arrays of tables present.
    pub fn array_names(&self) -> impl Iterator<Item = &str> {
        self.arrays.keys().map(String::as_str)
    }

    pub fn has_array(&self, name: &str) -> bool {
        self.arrays.contains_key(name)
    }

    /// Number of `[[name]]` elements (0 when the array is absent).
    pub fn array_len(&self, name: &str) -> usize {
        self.arrays.get(name).map_or(0, Vec::len)
    }

    /// Keys of one array element in sorted order.
    pub fn array_keys(&self, name: &str, idx: usize) -> Vec<&str> {
        self.arrays
            .get(name)
            .and_then(|v| v.get(idx))
            .map(|t| t.keys().map(String::as_str).collect())
            .unwrap_or_default()
    }

    /// Raw value lookup inside one array element.
    pub fn array_get(&self, name: &str, idx: usize, key: &str) -> Option<&Value> {
        self.arrays.get(name)?.get(idx)?.get(key)
    }

    /// Checked string accessor inside one array element: a present key of
    /// the wrong type is an error naming `name[idx].key`.
    pub fn try_array_str_or<'a>(
        &'a self,
        name: &str,
        idx: usize,
        key: &str,
        default: &'a str,
    ) -> Result<&'a str, String> {
        match self.array_get(name, idx, key) {
            None => Ok(default),
            Some(v) => v.as_str().ok_or_else(|| {
                format!("`{name}[{idx}].{key}`: expected string, found {} {v}", v.type_name())
            }),
        }
    }

    // --- typed accessors with defaults -------------------------------------

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    // --- checked typed accessors ------------------------------------------
    //
    // Like the `*_or` family, but a key that exists with the WRONG type is
    // an error naming the key, the expected type and what was found —
    // silent fallback to the default on a typo'd type is how config bugs
    // hide.

    fn expect<'a, T>(
        &'a self,
        section: &str,
        key: &str,
        want: &str,
        convert: impl Fn(&'a Value) -> Option<T>,
        default: T,
    ) -> Result<T, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => convert(v).ok_or_else(|| {
                let path = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                format!("`{path}`: expected {want}, found {} {v}", v.type_name())
            }),
        }
    }

    pub fn try_str_or<'a>(
        &'a self,
        section: &str,
        key: &str,
        default: &'a str,
    ) -> Result<&'a str, String> {
        self.expect(section, key, "string", Value::as_str, default)
    }

    pub fn try_int_or(&self, section: &str, key: &str, default: i64) -> Result<i64, String> {
        self.expect(section, key, "integer", Value::as_int, default)
    }

    /// Integer literals are accepted as floats (`mu = 512` is a valid float).
    pub fn try_float_or(&self, section: &str, key: &str, default: f64) -> Result<f64, String> {
        self.expect(section, key, "number", Value::as_float, default)
    }

    pub fn try_bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool, String> {
        self.expect(section, key, "boolean", Value::as_bool, default)
    }
}

/// Validate a `[section]` / `[[array]]` header name.
fn check_section_name(name: &str, line: usize) -> Result<(), ParseError> {
    if name.is_empty() {
        return Err(err(line, "empty section name"));
    }
    if !name.chars().all(|c| c.is_ascii_alphanumeric() || "._-".contains(c)) {
        return Err(err(line, format!("invalid section name `{name}`")));
    }
    Ok(())
}

/// Strip a `#` comment, respecting `#` inside quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    if s.is_empty() {
        return Err(err(line, "missing value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(line, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(line, "embedded quotes are not supported"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = split_array_items(inner, line)?;
        let values: Result<Vec<Value>, ParseError> =
            items.iter().map(|i| parse_value(i.trim(), line)).collect();
        let values = values?;
        if values.iter().any(|v| matches!(v, Value::Array(_))) {
            return Err(err(line, "nested arrays are not supported"));
        }
        return Ok(Value::Array(values));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        // TOML permits `1_000_000` separators
        if s.chars().all(|c| c.is_ascii_digit() || "+-_".contains(c)) {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(line, format!("cannot parse value `{s}` (strings need quotes)")))
}

/// Split a flat array body on commas (no nesting, strings may hold commas).
fn split_array_items(s: &str, line: usize) -> Result<Vec<String>, ParseError> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                items.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        return Err(err(line, "unterminated string in array"));
    }
    items.push(cur);
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Document::parse(
            r#"
# run configuration
title = "demo"
[dse]
phi = 2
mu = 512
bw_margin = 0.9
vanilla = false
[model]
name = "resnet18"
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("", "title", "?"), "demo");
        assert_eq!(doc.int_or("dse", "phi", 0), 2);
        assert_eq!(doc.float_or("dse", "bw_margin", 0.0), 0.9);
        assert!(!doc.bool_or("dse", "vanilla", true));
        assert_eq!(doc.str_or("model", "name", "?"), "resnet18");
    }

    #[test]
    fn int_readable_as_float() {
        let doc = Document::parse("x = 512").unwrap();
        assert_eq!(doc.float_or("", "x", 0.0), 512.0);
    }

    #[test]
    fn arrays() {
        let doc = Document::parse(r#"scales = [0.5, 1.0, 1.5]
names = ["a", "b"]
empty = []"#)
            .unwrap();
        let v = doc.get("", "scales").unwrap().as_array().unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[1].as_float(), Some(1.0));
        let n = doc.get("", "names").unwrap().as_array().unwrap();
        assert_eq!(n[0].as_str(), Some("a"));
        assert_eq!(doc.get("", "empty").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn comments_respect_strings() {
        let doc = Document::parse(r##"path = "a#b" # trailing comment"##).unwrap();
        assert_eq!(doc.str_or("", "path", "?"), "a#b");
    }

    #[test]
    fn underscore_separators_in_numbers() {
        let doc = Document::parse("big = 1_000_000").unwrap();
        assert_eq!(doc.int_or("", "big", 0), 1_000_000);
    }

    #[test]
    fn dotted_section_names() {
        let doc = Document::parse("[sweep.mem]\nlo = 0.5").unwrap();
        assert!(doc.has_section("sweep.mem"));
        assert_eq!(doc.float_or("sweep.mem", "lo", 0.0), 0.5);
    }

    #[test]
    fn array_of_tables() {
        let doc = Document::parse(
            r#"
[device]
name = "zcu102"
[[tenant]]
name = "resnet18"
quant = "w4a5"
[[tenant]]
name = "squeezenet"
"#,
        )
        .unwrap();
        assert!(doc.has_array("tenant"));
        assert_eq!(doc.array_len("tenant"), 2);
        assert_eq!(doc.array_names().collect::<Vec<_>>(), vec!["tenant"]);
        assert_eq!(doc.array_get("tenant", 0, "name").unwrap().as_str(), Some("resnet18"));
        assert_eq!(doc.array_get("tenant", 0, "quant").unwrap().as_str(), Some("w4a5"));
        assert_eq!(doc.array_get("tenant", 1, "name").unwrap().as_str(), Some("squeezenet"));
        assert!(doc.array_get("tenant", 1, "quant").is_none());
        assert_eq!(doc.array_keys("tenant", 0), vec!["name", "quant"]);
        // typed accessor: default on absent, typed error on mismatch
        assert_eq!(doc.try_array_str_or("tenant", 1, "quant", "w8a8").unwrap(), "w8a8");
        let doc2 = Document::parse("[[tenant]]\nname = 3").unwrap();
        let e = doc2.try_array_str_or("tenant", 0, "name", "?").unwrap_err();
        assert!(e.contains("`tenant[0].name`") && e.contains("expected string"), "{e}");
        // the plain section is untouched
        assert_eq!(doc.str_or("device", "name", "?"), "zcu102");
        assert_eq!(doc.array_len("absent"), 0);
    }

    #[test]
    fn array_table_conflicts_and_duplicates() {
        // same name as section and array is rejected, both orders
        let e = Document::parse("[tenant]\na = 1\n[[tenant]]\nb = 2").unwrap_err();
        assert!(e.message.contains("conflicts"), "{e}");
        let e = Document::parse("[[tenant]]\nb = 2\n[tenant]\na = 1").unwrap_err();
        assert!(e.message.contains("conflicts"), "{e}");
        // duplicate keys are per element, not across elements
        let ok = Document::parse("[[t]]\na = 1\n[[t]]\na = 2").unwrap();
        assert_eq!(ok.array_len("t"), 2);
        let e = Document::parse("[[t]]\na = 1\na = 2").unwrap_err();
        assert!(e.message.contains("duplicate"), "{e}");
        // malformed headers
        assert!(Document::parse("[[t]\na = 1").is_err());
        assert!(Document::parse("[[]]").is_err());
    }

    #[test]
    fn error_line_numbers() {
        let e = Document::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Document::parse("x = \"unterminated").unwrap_err();
        assert_eq!(e.line, 1);
        assert!(e.to_string().contains("line 1"));
    }

    #[test]
    fn duplicate_keys_rejected() {
        let e = Document::parse("[s]\na = 1\na = 2").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.message.contains("duplicate"));
    }

    #[test]
    fn unquoted_string_rejected() {
        let e = Document::parse("name = resnet18").unwrap_err();
        assert!(e.message.contains("strings need quotes"));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let doc = Document::parse("a = -3\nb = 1.5e9").unwrap();
        assert_eq!(doc.int_or("", "a", 0), -3);
        assert_eq!(doc.float_or("", "b", 0.0), 1.5e9);
    }
}
