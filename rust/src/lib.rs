//! # AutoWS — Automated Weights Streaming for Layer-wise Pipelined DNN Accelerators
//!
//! Reproduction of Yu & Bouganis, *"AutoWS: Automate Weights Streaming in
//! Layer-wise Pipelined DNN Accelerators"* (2023).
//!
//! ## Quickstart: the deployment pipeline
//!
//! The front door is [`pipeline`] — one typed, staged builder from model
//! name to served requests. Each stage returns a distinct type, so an
//! out-of-order pipeline is a compile error; exploration is memoized in a
//! process-wide content-keyed design cache, so sweeps and repeated serve
//! runs skip redundant DSE work:
//!
//! ```no_run
//! use autows::coordinator::{BatchPolicy, ServerOptions};
//! use autows::dse::DseConfig;
//! use autows::ir::Quant;
//! use autows::pipeline::Deployment;
//!
//! fn main() -> Result<(), autows::Error> {
//!     let scheduled = Deployment::for_model("resnet18")   // model ingest
//!         .quant(Quant::W4A5)                             // quantization
//!         .on_device("zcu102")?                           // -> Planned
//!         .explore(&DseConfig::default())?                // -> Explored (Algorithm 1, cached)
//!         .schedule();                                    // -> Scheduled (Eq. 8-10)
//!     print!("{}", scheduled.report());                   // terminal: report
//!     let server = scheduled.serve(BatchPolicy::default(), ServerOptions::default())?;
//!     server.infer(vec![0.5; scheduled.input_len()]).expect("served"); // terminal: serve
//!     server.shutdown();
//!     Ok(())
//! }
//! ```
//!
//! Failures surface as the crate-level [`Error`] enum (unknown model/device,
//! infeasible design point, config or serving problems) — match on the
//! class instead of string-probing.
//!
//! **Sharded deployments** swap `on_device` for
//! `on_devices(&["zcu102", "zcu102"])`: the network is partitioned across a
//! chain of devices joined by streaming links (cut-point search in
//! [`dse::partition`]), each partition gets its own DMA burst schedule, the
//! partitioned simulator models the links, and the chain serves behind one
//! coordinator — see [`pipeline`] for the staged walk-through.
//!
//! **Co-located deployments** are the dual: `Deployment::colocate([...])`
//! plans several networks onto ONE device. A joint budget search
//! ([`dse::colocate`]) splits area and DMA bandwidth into per-tenant
//! shares, each tenant's burst schedule is derived against its bandwidth
//! slice and composed under the port cap
//! ([`schedule::SharedDmaSchedule`]), the co-located simulator interleaves
//! the tenants' burst trains on the shared DDR port
//! ([`sim::simulate_colocated`]), and `.serve` registers every tenant
//! behind one [`coordinator::ModelRegistry`].
//!
//! ## Layers (bottom-up)
//!
//! - [`ir`] — DNN graph intermediate representation (layers, shapes, bitwidths).
//! - [`models`] — model zoo builders (MobileNetV2, ResNet18/50, YOLOv5n, VGG16).
//! - [`device`] — FPGA device library (Zedboard, ZC706, ZCU102, U50, U250).
//! - [`ce`] — the Compute Engine template: fragmented weights memory (paper
//!   Eq. 1–3), analytic throughput/area/bandwidth models (Eq. 4–5).
//! - [`dse`] — the greedy Design Space Exploration (paper Algorithm 1).
//! - [`schedule`] — the deterministic DMA burst scheduler (Eq. 8–10, Fig. 5).
//! - [`sim`] — cycle-accurate event-driven simulator of the pipelined
//!   accelerator (CEs + FIFOs + time-multiplexed DMA + two clock domains).
//! - [`baseline`] — comparison architectures: vanilla layer-pipelined
//!   (all weights on-chip) and layer-sequential (single tiled CE).
//! - [`runtime`] — PJRT runtime: loads AOT-compiled HLO artifacts and
//!   executes the actual DNN numerics (Python never on the request path).
//! - [`coordinator`] — serving loop: request batching, schedule-aware
//!   dispatch, metrics.
//! - [`pipeline`] — the staged deployment builder tying all of the above
//!   together, with the content-keyed design cache and cache-aware sweeps.
//! - [`telemetry`] — lock-free serving spans, process-wide counters, and
//!   exposition (Prometheus text, JSON, Chrome trace-event).
//! - [`config`] — `autows run` launcher specs ([`config::RunSpec`]) parsed
//!   from a TOML subset, executed through the pipeline.
//! - [`report`] — regenerates every table and figure of the paper's
//!   evaluation section (also pipeline-backed, so figures sharing design
//!   points share the cache).

pub mod baseline;
pub mod ce;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod dse;
mod error;
pub mod ir;
pub mod models;
pub mod pipeline;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod telemetry;
pub mod util;

pub use ce::{CeConfig, CeModel};
pub use device::Device;
pub use dse::{DseConfig, DseResult};
pub use error::Error;
pub use ir::{Layer, Network, OpKind};
pub use pipeline::Deployment;
