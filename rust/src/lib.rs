//! # AutoWS — Automated Weights Streaming for Layer-wise Pipelined DNN Accelerators
//!
//! Reproduction of Yu & Bouganis, *"AutoWS: Automate Weights Streaming in
//! Layer-wise Pipelined DNN Accelerators"* (2023).
//!
//! The crate is organized bottom-up:
//!
//! - [`ir`] — DNN graph intermediate representation (layers, shapes, bitwidths).
//! - [`models`] — model zoo builders (MobileNetV2, ResNet18/50, YOLOv5n, VGG16).
//! - [`device`] — FPGA device library (Zedboard, ZC706, ZCU102, U50, U250).
//! - [`ce`] — the Compute Engine template: fragmented weights memory (paper
//!   Eq. 1–3), analytic throughput/area/bandwidth models (Eq. 4–5).
//! - [`dse`] — the greedy Design Space Exploration (paper Algorithm 1).
//! - [`schedule`] — the deterministic DMA burst scheduler (Eq. 8–10, Fig. 5).
//! - [`sim`] — cycle-accurate event-driven simulator of the pipelined
//!   accelerator (CEs + FIFOs + time-multiplexed DMA + two clock domains).
//! - [`baseline`] — comparison architectures: vanilla layer-pipelined
//!   (all weights on-chip) and layer-sequential (single tiled CE).
//! - [`runtime`] — PJRT runtime: loads AOT-compiled HLO artifacts and
//!   executes the actual DNN numerics (Python never on the request path).
//! - [`coordinator`] — serving loop: request batching, schedule-aware
//!   dispatch, metrics.
//! - [`report`] — regenerates every table and figure of the paper's
//!   evaluation section.

pub mod baseline;
pub mod ce;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod dse;
pub mod ir;
pub mod models;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod util;

pub use ce::{CeConfig, CeModel};
pub use device::Device;
pub use dse::{DseConfig, DseResult};
pub use ir::{Layer, Network, OpKind};
