//! Regenerates every table and figure of the paper's evaluation section as
//! text output (the bench harnesses wrap these same functions), plus the
//! extension studies (memory technology assignment, pruning/encoding
//! co-design, DSE strategy comparison).

mod plot;

pub use plot::{line_chart, stacked_bars};

use crate::baseline::{self, sequential_latency_ms};
use crate::device::Device;
use crate::dse::{self, delta_bandwidth, DseConfig};
use crate::ir::{Network, Quant};
use crate::models;
use crate::pipeline::{sweep::mem_sweep, Deployment, Explored, Planned};
use crate::sim::{fig5_scenario, render_gantt, simulate, SimConfig};

/// Explore a zoo model on a device through the pipeline's design cache:
/// figures that revisit the same design point (Fig. 6 / Fig. 7 / Table III
/// all use resnet18-ZCU102) share one DSE run. `None` == infeasible.
fn explore(model: &str, quant: Quant, dev: &Device) -> Option<Explored> {
    Deployment::for_model(model)
        .quant(quant)
        .on_device(dev.clone())
        .ok()?
        .explore_default()
        .ok()
}

/// [`explore`] for an already-built network (compressed variants).
fn explore_net(net: Network, dev: &Device, cfg: &DseConfig) -> Option<Explored> {
    Planned::from_parts(net, dev.clone()).explore(cfg).ok()
}

/// Table I: characteristics of the evaluated models.
pub fn table1() -> String {
    let mut out = String::from(
        "Table I: Characteristics of evaluated models\n\
         network       params       MACs    weight-layers\n",
    );
    for name in ["mobilenetv2", "resnet18", "resnet50"] {
        let n = models::by_name(name, Quant::W8A8).unwrap();
        let s = n.stats();
        out.push_str(&format!(
            "{:<12} {:>7.1}M {:>9.1}G {:>12}\n",
            name,
            s.params as f64 / 1e6,
            s.macs as f64 / 1e9,
            s.weight_layers
        ));
    }
    out
}

/// One Table II cell: latency in ms of the three architectures for
/// `(network, quant)` on `device`. `None` == "X" (does not fit).
#[derive(Debug, Clone)]
pub struct Table2Cell {
    pub network: String,
    pub device: String,
    pub quant: String,
    pub sequential_ms: f64,
    pub vanilla_ms: Option<f64>,
    pub autows_ms: Option<f64>,
}

/// Evaluate one Table II cell (simulated latencies for the pipelined
/// architectures, analytic for layer-sequential).
pub fn table2_cell(network: &str, device: &str, quant: Quant) -> Table2Cell {
    let net = models::by_name(network, quant).unwrap();
    let dev = Device::by_name(device).unwrap();
    let plan = Planned::from_parts(net, dev.clone());
    let seq = sequential_latency_ms(plan.network(), &dev);
    let vanilla = baseline::vanilla(plan.network(), &dev)
        .map(|r| simulate(&r.design, &dev, &SimConfig::default()).latency_ms);
    let autows = plan
        .explore_default()
        .ok()
        .map(|e| e.schedule().simulate(&SimConfig::default()).latency_ms);
    Table2Cell {
        network: network.into(),
        device: device.into(),
        quant: quant.label(),
        sequential_ms: seq,
        vanilla_ms: vanilla,
        autows_ms: autows,
    }
}

/// The (network, device, quant) grid of paper Table II.
pub fn table2_grid() -> Vec<(&'static str, &'static str, Quant)> {
    vec![
        ("mobilenetv2", "zedboard", Quant::W4A4),
        ("mobilenetv2", "zc706", Quant::W4A4),
        ("mobilenetv2", "zcu102", Quant::W4A5),
        ("resnet18", "zc706", Quant::W4A4),
        ("resnet18", "zcu102", Quant::W4A5),
        ("resnet18", "u50", Quant::W8A8),
        ("resnet50", "zcu102", Quant::W4A5),
        ("resnet50", "u50", Quant::W8A8),
        ("resnet50", "u250", Quant::W8A8),
    ]
}

/// Full Table II.
pub fn table2() -> String {
    let mut out = String::from(
        "Table II: Latency (ms) across networks and devices\n\
         network       device    quant   layer-seq   vanilla    AutoWS\n",
    );
    for (net, dev, q) in table2_grid() {
        let c = table2_cell(net, dev, q);
        let fmt = |v: Option<f64>| v.map_or("X".to_string(), |x| format!("{x:.1}"));
        out.push_str(&format!(
            "{:<12} {:<9} {:<7} {:>9.1} {:>9} {:>9}\n",
            c.network,
            c.device,
            c.quant,
            c.sequential_ms,
            fmt(c.vanilla_ms),
            fmt(c.autows_ms),
        ));
    }
    out
}

/// Table III row: memory/bandwidth breakdown for a design point.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub label: String,
    pub bw_act_gbps: f64,
    pub bw_wt_gbps: f64,
    pub bw_total_util: f64,
    pub bram_act_fifo_mb: f64,
    pub bram_wt_buff_mb: f64,
    pub bram_wt_mem_mb: f64,
    pub bram_total_mb: f64,
    pub bram_util: f64,
    pub dsp: u32,
    pub fps: f64,
}

fn table3_row(label: &str, r: &dse::DseResult, dev: &Device) -> Table3Row {
    let a = r.area;
    let bits_per_block = crate::device::BRAM36_BITS as f64 / 8.0 / 1e6;
    Table3Row {
        label: label.into(),
        bw_act_gbps: r.design.io_bandwidth() / 1e9,
        bw_wt_gbps: r.design.total_weight_bandwidth() / 1e9,
        bw_total_util: r.bandwidth_bps / dev.bandwidth_bps,
        bram_act_fifo_mb: a.bram.act_fifo as f64 * bits_per_block,
        bram_wt_buff_mb: a.bram.wt_buff as f64 * bits_per_block,
        bram_wt_mem_mb: a.bram.wt_mem as f64 * bits_per_block,
        bram_total_mb: a.bram.mbytes(),
        bram_util: a.mem_utilization(dev),
        dsp: a.dsp,
        fps: r.throughput,
    }
}

/// Table III: resnet18-ZCU102 resource breakdown, design points d0 (vanilla,
/// evaluated on an enlarged device so it exists) and d1 (AutoWS on the real
/// device).
pub fn table3() -> String {
    let net = models::resnet18(Quant::W4A5);
    let dev = Device::zcu102();
    // d0: vanilla — on zcu102 it needs ~172% of the memory, so evaluate it
    // on a 2x-memory virtual device and report utilization vs the REAL one
    // (exactly what the paper's "172%" denotes).
    let big = dev.with_mem_scale(2.0);
    let d0 = baseline::vanilla(&net, &big).expect("vanilla fits on 2x device");
    let d1 = explore("resnet18", Quant::W4A5, &dev).expect("autows fits");
    let rows =
        vec![table3_row("Vanilla (d0)", &d0, &dev), table3_row("AutoWS  (d1)", d1.result(), &dev)];
    let mut out = String::from(
        "Table III: resnet18-ZCU102 memory resource breakdown\n\
         design        BW act  BW wt  BW util | act_fifo wt_buff  wt_mem   total (util) |   DSP     FPS\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:<13} {:>6.2} {:>6.2} {:>7.0}% | {:>7.1} {:>7.1} {:>7.1} {:>7.1} ({:>3.0}%) | {:>5} {:>7.1}\n",
            r.label,
            r.bw_act_gbps,
            r.bw_wt_gbps,
            r.bw_total_util * 100.0,
            r.bram_act_fifo_mb,
            r.bram_wt_buff_mb,
            r.bram_wt_mem_mb,
            r.bram_total_mb,
            r.bram_util * 100.0,
            r.dsp,
            r.fps
        ));
    }
    out
}

/// Fig. 5: two-layer DMA schedule, imbalanced vs balanced — ASCII timeline
/// plus stall totals.
pub fn fig5() -> String {
    let mut out = String::from("Fig. 5: two-layer write/read scheduling\n");
    for (balanced, label) in [(false, "(a) imbalanced burst numbers"), (true, "(b) balanced burst numbers")] {
        let (d, dev) = fig5_scenario(balanced);
        let sim = simulate(
            &d,
            &dev,
            &SimConfig { batch: 2, trace: true, max_trace_events: 64, ..Default::default() },
        );
        out.push_str(&format!(
            "\n{label}: r_l1={} r_l2={} stalls={:.2}us makespan={:.2}us\n",
            d.repeats(0, 2),
            d.repeats(1, 2),
            sim.total_stall_s * 1e6,
            sim.makespan_s * 1e6
        ));
        for t in sim.traces.iter().take(24) {
            out.push_str(&format!(
                "  l{} {:<10} {:>8.2} -> {:>8.2} us\n",
                t.layer + 1,
                format!("{:?}", t.kind),
                t.start * 1e6,
                t.end * 1e6
            ));
        }
    }
    out
}

/// Fig. 6: resnet18-ZCU102 memory/performance trade-off sweep.
pub fn fig6() -> String {
    let plan = Planned::from_parts(models::resnet18(Quant::W4A5), Device::zcu102());
    let scales: Vec<f64> = (2..=20).map(|i| i as f64 * 0.1).collect();
    let pts = mem_sweep(&plan, &scales);
    let mut out = String::from(
        "Fig. 6: resnet18-ZCU102 memory vs performance (A_mem normalized)\n\
         A_mem   AutoWS fps   vanilla fps   off-chip frac\n",
    );
    for p in pts {
        let fmt = |v: Option<f64>| v.map_or("     X".to_string(), |x| format!("{x:>6.1}"));
        out.push_str(&format!(
            "{:>5.2}   {:>10}   {:>11}   {:>6.1}%\n",
            p.mem_scale,
            fmt(p.autows_fps),
            fmt(p.vanilla_fps),
            p.autows_offchip_frac * 100.0
        ));
    }
    out
}

/// Fig. 7: per-layer on/off-chip allocation of the AutoWS resnet18-ZCU102
/// design point, with the ΔB criterion per layer.
pub fn fig7() -> String {
    let dev = Device::zcu102();
    let e = explore("resnet18", Quant::W4A5, &dev).unwrap();
    let cfg = DseConfig::default();
    let mut out = String::from(
        "Fig. 7: resnet18-ZCU102 per-layer weight allocation (design d1)\n\
         idx  layer                     on-chip KB  off-chip KB   ΔB (Mbps)\n",
    );
    let mut wi = 0;
    for (i, l) in e.design().network.layers.iter().enumerate() {
        if !l.has_weights() {
            continue;
        }
        wi += 1;
        let frag = e.design().cfgs[i].frag;
        let total_bits = l.weight_bits() as f64;
        let off_bits = total_bits * frag.off_chip_ratio();
        let db = delta_bandwidth(e.design(), i, &cfg);
        out.push_str(&format!(
            "{:>3}  {:<24} {:>10.1} {:>12.1} {:>11.1}\n",
            wi,
            l.name,
            (total_bits - off_bits) / 8.0 / 1e3,
            off_bits / 8.0 / 1e3,
            db / 1e6
        ));
    }
    out
}

/// Fig. 5 as an ASCII Gantt chart (the rendered counterpart of [`fig5`]).
pub fn fig5_gantt() -> String {
    let mut out = String::from("Fig. 5 (rendered): two-layer DMA schedule\n");
    for (balanced, label) in
        [(false, "(a) imbalanced burst numbers"), (true, "(b) balanced burst numbers")]
    {
        let (d, dev) = fig5_scenario(balanced);
        let sim = simulate(
            &d,
            &dev,
            &SimConfig { batch: 2, trace: true, max_trace_events: 256, ..Default::default() },
        );
        out.push_str(&format!("\n{label} — stalls {:.2} us:\n", sim.total_stall_s * 1e6));
        out.push_str(&render_gantt(&sim.traces, 96));
    }
    out
}

/// Fig. 6 as an ASCII line chart (AutoWS vs vanilla fps over `A_mem`).
pub fn fig6_chart() -> String {
    let plan = Planned::from_parts(models::resnet18(Quant::W4A5), Device::zcu102());
    let scales: Vec<f64> = (2..=20).map(|i| i as f64 * 0.1).collect();
    let pts = mem_sweep(&plan, &scales);
    let autows: Vec<(f64, Option<f64>)> =
        pts.iter().map(|p| (p.mem_scale, p.autows_fps)).collect();
    let vanilla: Vec<(f64, Option<f64>)> =
        pts.iter().map(|p| (p.mem_scale, p.vanilla_fps)).collect();
    line_chart(
        "Fig. 6 (rendered): resnet18-ZCU102 throughput vs A_mem budget",
        &[("AutoWS", autows), ("vanilla", vanilla)],
        72,
        16,
    )
}

/// Fig. 7 as stacked bars (per-layer on/off-chip weight kilobytes).
pub fn fig7_chart() -> String {
    let dev = Device::zcu102();
    let e = explore("resnet18", Quant::W4A5, &dev).unwrap();
    let rows: Vec<(String, f64, f64)> = e
        .design()
        .network
        .layers
        .iter()
        .enumerate()
        .filter(|(_, l)| l.has_weights())
        .map(|(i, l)| {
            let frag = e.design().cfgs[i].frag;
            let total_kb = l.weight_bits() as f64 / 8.0 / 1e3;
            let off = total_kb * frag.off_chip_ratio();
            (l.name.clone(), total_kb - off, off)
        })
        .collect();
    stacked_bars(
        "Fig. 7 (rendered): resnet18-ZCU102 per-layer weight allocation",
        &rows,
        48,
        "KB",
    )
}

/// Extension study: memory technology assignment (URAM/LUTRAM/overclock) on
/// the paper's device grid.
pub fn tech() -> String {
    use crate::ce::{assign_memory_tech, TechOptions};
    let mut out = String::from(
        "Extension: memory technology assignment (fpgaConvNet/hls4ml/FINN idioms)\n\
         network      device   baseline-BRAM  after-BRAM  URAM  +LUTs   saved(BRAM36-eq)\n",
    );
    for (model, q, dev) in [
        ("resnet18", Quant::W4A5, Device::zcu102()),
        ("resnet50", Quant::W8A8, Device::u50()),
        ("mobilenetv2", Quant::W4A4, Device::zc706()),
    ] {
        let Some(e) = explore(model, q, &dev) else {
            continue;
        };
        let plan = assign_memory_tech(e.design(), &dev, &TechOptions::for_device(&dev));
        out.push_str(&format!(
            "{:<12} {:<8} {:>13} {:>11} {:>5} {:>6} {:>12}\n",
            model,
            dev.name,
            plan.baseline_bram,
            plan.bram,
            plan.uram,
            plan.extra_luts,
            plan.bram_saved()
        ));
    }
    out
}

/// Extension study: pruning + encoding co-design sweep (paper §VI future
/// work) — latency/feasibility vs sparsity on a memory-tight pair.
pub fn compress() -> String {
    use crate::compress::{compress_network, CompressionSpec};
    let net = models::resnet18(Quant::W8A8);
    let dev = Device::zc706();
    let mut out = String::from(
        "Extension: pruning + encoding co-design (resnet18-W8A8 on ZC706)\n\
         sparsity  ratio  enc-luts  acc-proxy   AutoWS fps   vanilla fps\n",
    );
    for s in [0.0, 0.2, 0.4, 0.6, 0.8] {
        let (cnet, rep) = compress_network(&net, &CompressionSpec::pruned(s));
        let fps = explore_net(cnet.clone(), &dev, &DseConfig::default())
            .map(|e| e.result().throughput);
        let vfps =
            explore_net(cnet, &dev, &DseConfig::vanilla()).map(|e| e.result().throughput);
        let fmt = |v: Option<f64>| v.map_or("      X".into(), |x| format!("{x:>7.1}"));
        out.push_str(&format!(
            "{:>8.1} {:>6.2} {:>9} {:>8.1}pp {:>12} {:>13}\n",
            s,
            rep.ratio(),
            rep.decoder_luts,
            rep.accuracy_drop_proxy,
            fmt(fps),
            fmt(vfps)
        ));
    }
    out
}

/// Extension study: greedy (paper Algorithm 1) vs random search vs
/// simulated annealing on solution quality.
pub fn strategies() -> String {
    use crate::dse::{run_with_strategy, Strategy};
    let net = models::toy_cnn(Quant::W8A8);
    let dev = Device::zcu102();
    let cfg = DseConfig::default();
    let mut out = String::from(
        "Extension: DSE strategy comparison (toy CNN on ZCU102)\n\
         strategy                 fps      latency(ms)\n",
    );
    for (label, s) in [
        ("greedy (Algorithm 1)", Strategy::Greedy),
        ("random x200", Strategy::Random { samples: 200, seed: 7 }),
        ("anneal x2000", Strategy::Anneal { iters: 2000, t0: 0.5, seed: 7 }),
    ] {
        match run_with_strategy(&net, &dev, &cfg, s) {
            None => out.push_str(&format!("{label:<24} INFEASIBLE\n")),
            Some(r) => out.push_str(&format!(
                "{label:<24} {:>8.1} {:>12.3}\n",
                r.throughput, r.latency_ms
            )),
        }
    }
    out
}

/// §V-D: YOLOv5n object detection on ZCU102.
pub fn yolo() -> String {
    let net = models::yolov5n(Quant::W8A8);
    let dev = Device::zcu102();
    let seq = sequential_latency_ms(&net, &dev);
    let fmt = |v: Option<f64>| v.map_or("X".to_string(), |x| format!("{x:.1} ms"));
    let vanilla = baseline::vanilla(&net, &dev)
        .map(|r| simulate(&r.design, &dev, &SimConfig::default()).latency_ms);
    let autows = explore("yolov5n", Quant::W8A8, &dev)
        .map(|e| e.schedule().simulate(&SimConfig::default()).latency_ms);
    format!(
        "§V-D: YOLOv5n-COCO on ZCU102\n\
         layer-sequential (Vitis-AI-like): {seq:.1} ms\n\
         vanilla layer-pipelined:          {}\n\
         AutoWS (this work):               {}\n",
        fmt(vanilla),
        fmt(autows)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_all_models() {
        let t = table1();
        assert!(t.contains("mobilenetv2") && t.contains("resnet50"));
        assert!(t.contains("11.7M") || t.contains("11.6M"), "{t}");
    }

    #[test]
    fn table2_cell_small_device_shape() {
        // resnet18-W4A5 on zedboard: vanilla X, AutoWS feasible
        let c = table2_cell("resnet18", "zedboard", Quant::W4A5);
        assert!(c.vanilla_ms.is_none());
        assert!(c.autows_ms.is_some());
        assert!(c.sequential_ms > 0.0);
    }

    #[test]
    fn fig5_report_shows_stall_reduction() {
        let f = fig5();
        assert!(f.contains("imbalanced"));
        assert!(f.contains("balanced"));
    }
}
